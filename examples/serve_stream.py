"""End-to-end REAL serving: BMPR-driven fidelity on actual AR-DiT chunk
generation with playout-slack bookkeeping (the paper's mechanism on a
live model instead of the simulator).

    PYTHONPATH=src python examples/serve_stream.py [n_streams] [chunks]
    PYTHONPATH=src python examples/serve_stream.py --batched [n] [chunks]

``--batched`` serves all streams through the credit-ordered micro-batch
executor (one jitted denoise step per sub-batch) instead of one stream
at a time.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.executor import serve_session


def main():
    args = [a for a in sys.argv[1:] if a != "--batched"]
    batched = "--batched" in sys.argv[1:]
    n_streams = int(args[0]) if args else 2
    chunks = int(args[1]) if len(args) > 1 else 4
    streams = serve_session(n_streams=n_streams,
                            chunks_per_stream=chunks,
                            batched=batched)
    print("\nper-stream fidelity decisions:")
    for s in streams:
        print(f"  stream {s.sid}: {s.fidelity_log}")


if __name__ == "__main__":
    main()
