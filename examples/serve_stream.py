"""End-to-end REAL serving: BMPR-driven fidelity on actual AR-DiT chunk
generation with playout-slack bookkeeping (the paper's mechanism on a
live model instead of the simulator).

    PYTHONPATH=src python examples/serve_stream.py [n_streams] [chunks]
    PYTHONPATH=src python examples/serve_stream.py --batched [n] [chunks]
    PYTHONPATH=src python examples/serve_stream.py --batched --pool=P ...
    PYTHONPATH=src python examples/serve_stream.py --batched \
        --context-backend=gather ...

``--batched`` serves all streams through the credit-ordered micro-batch
executor (one jitted denoise step per sub-batch) instead of one stream
at a time.  ``--pool=P`` caps the page pool at P co-resident streams —
with P < n_streams the session oversubscribes: overflow streams spill
to host and rotate back in via credit-aware eviction.
``--context-backend`` picks how sub-batches see cached KV: ``paged``
(default) serves attention straight from the page pool through block
tables; ``gather`` materializes the contiguous context per chunk
boundary (the executable reference path).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.executor import serve_session


def main():
    pool = None
    backend = "paged"
    args = []
    argv = sys.argv[1:]
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--batched":
            pass
        elif a.startswith("--pool="):
            pool = int(a.split("=", 1)[1])
        elif a == "--pool":
            i += 1
            if i >= len(argv):
                sys.exit("--pool requires a value (e.g. --pool 2)")
            pool = int(argv[i])
        elif a.startswith("--context-backend="):
            backend = a.split("=", 1)[1]
        elif a == "--context-backend":
            i += 1
            if i >= len(argv):
                sys.exit("--context-backend requires a value "
                         "(gather|paged)")
            backend = argv[i]
        else:
            args.append(a)
        i += 1
    batched = "--batched" in argv
    if pool is not None and not batched:
        sys.exit("--pool only applies to the batched executor; "
                 "add --batched")
    if backend not in ("gather", "paged"):
        sys.exit(f"unknown context backend {backend!r} (gather|paged)")
    if any(a.startswith("--context-backend") for a in argv) \
            and not batched:
        sys.exit("--context-backend only applies to the batched "
                 "executor; add --batched")
    n_streams = int(args[0]) if args else 2
    chunks = int(args[1]) if len(args) > 1 else 4
    streams = serve_session(n_streams=n_streams,
                            chunks_per_stream=chunks,
                            batched=batched,
                            pool_streams=pool,
                            context_backend=backend)
    print("\nper-stream fidelity decisions:")
    for s in streams:
        print(f"  stream {s.sid}: {s.fidelity_log}")


if __name__ == "__main__":
    main()
