"""End-to-end REAL serving: BMPR-driven fidelity on actual AR-DiT chunk
generation with playout-slack bookkeeping (the paper's mechanism on a
live model instead of the simulator).

    PYTHONPATH=src python examples/serve_stream.py [n_streams] [chunks]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.executor import serve_session


def main():
    n_streams = int(sys.argv[1]) if len(sys.argv) > 1 else 2
    chunks = int(sys.argv[2]) if len(sys.argv) > 2 else 4
    streams = serve_session(n_streams=n_streams,
                            chunks_per_stream=chunks)
    print("\nper-stream fidelity decisions:")
    for s in streams:
        print(f"  stream {s.sid}: {s.fidelity_log}")


if __name__ == "__main__":
    main()
