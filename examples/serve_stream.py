"""End-to-end REAL serving: BMPR-driven fidelity on actual AR-DiT chunk
generation with playout-slack bookkeeping (the paper's mechanism on a
live model instead of the simulator), driven by the unified
``repro.serve.session.StreamingSession`` control loop.

    PYTHONPATH=src python examples/serve_stream.py [n_streams] [chunks]
    PYTHONPATH=src python examples/serve_stream.py --batched [n] [chunks]
    PYTHONPATH=src python examples/serve_stream.py --batched --pool=P ...
    PYTHONPATH=src python examples/serve_stream.py --batched \
        --context-backend=gather ...
    PYTHONPATH=src python examples/serve_stream.py --batched \
        --workload=burst --arrival-scale=0.25 4 2
    PYTHONPATH=src python examples/serve_stream.py --lanes=2 \
        --workload=burst 9 4

``--batched`` serves all streams through the credit-ordered micro-batch
executor (one jitted denoise step per sub-batch) instead of one stream
at a time.  ``--pool=P`` caps the page pool at P co-resident streams —
with P < n_streams the session oversubscribes: overflow streams spill
to host and rotate back in via credit-aware eviction.
``--context-backend`` picks how sub-batches see cached KV: ``paged``
(default) serves attention straight from the page pool through block
tables; ``gather`` materializes the contiguous context per chunk
boundary (the executable reference path).
``--workload=steady|burst|trace`` replaces the default
everyone-at-t=0 arrivals with ONLINE arrivals from the named
``sched_sim.workloads`` generator (the same StreamSpec objects the
cluster simulator consumes); ``--arrival-scale`` compresses the
generator's event times so demos don't wait out real Poisson gaps.
``--lanes=N`` serves through N device lanes (one batched executor +
paged KV pool each) under the full control plane: re-homing decisions
become real cross-lane KV moves and elastic SP becomes a real Ulysses
SP2 head split on the donor lane (applied counts printed at the end).
The run ends with the same CPR/TTFC ``Summary`` line the simulator
prints — one metrics surface for sim and real.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sched_sim.metrics import summarize
from repro.sched_sim.workloads import WORKLOADS
from repro.serve.session import (SessionConfig, StreamingSession,
                                 cap_specs, uniform_specs)


def main():
    pool = None
    backend = "paged"
    workload = None
    arrival_scale = 1.0
    lanes = 1
    args = []
    argv = sys.argv[1:]
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--batched":
            pass
        elif a.startswith("--lanes="):
            lanes = int(a.split("=", 1)[1])
        elif a == "--lanes":
            i += 1
            if i >= len(argv):
                sys.exit("--lanes requires a value (e.g. --lanes 2)")
            lanes = int(argv[i])
        elif a.startswith("--pool="):
            pool = int(a.split("=", 1)[1])
        elif a == "--pool":
            i += 1
            if i >= len(argv):
                sys.exit("--pool requires a value (e.g. --pool 2)")
            pool = int(argv[i])
        elif a.startswith("--context-backend="):
            backend = a.split("=", 1)[1]
        elif a == "--context-backend":
            i += 1
            if i >= len(argv):
                sys.exit("--context-backend requires a value "
                         "(gather|paged)")
            backend = argv[i]
        elif a.startswith("--workload="):
            workload = a.split("=", 1)[1]
        elif a == "--workload":
            i += 1
            if i >= len(argv):
                sys.exit("--workload requires a value "
                         "(steady|burst|trace)")
            workload = argv[i]
        elif a.startswith("--arrival-scale="):
            arrival_scale = float(a.split("=", 1)[1])
        else:
            args.append(a)
        i += 1
    batched = "--batched" in argv or lanes > 1   # lanes imply batched
    if pool is not None and not batched:
        sys.exit("--pool only applies to the batched executor; "
                 "add --batched")
    if backend not in ("gather", "paged"):
        sys.exit(f"unknown context backend {backend!r} (gather|paged)")
    if any(a.startswith("--context-backend") for a in argv) \
            and not batched:
        sys.exit("--context-backend only applies to the batched "
                 "executor; add --batched")
    if workload is not None and workload not in WORKLOADS:
        sys.exit(f"unknown workload {workload!r} "
                 f"({'|'.join(WORKLOADS)})")
    n_streams = int(args[0]) if args else 2
    chunks = int(args[1]) if len(args) > 1 else 4

    if workload is None:
        specs = uniform_specs(n_streams, chunks)      # legacy: all at t=0
    else:
        specs = cap_specs(WORKLOADS[workload](n=n_streams, seed=0),
                          chunks)
    session = StreamingSession(SessionConfig(
        executor="batched" if batched else "sequential",
        lanes=lanes, pool_streams=pool or (n_streams + 1),
        context_backend=backend, arrival_scale=arrival_scale))
    handles = [session.submit(spec) for spec in specs]
    res = session.run()

    print("\nper-stream fidelity decisions:")
    for h in handles:
        print(f"  stream {h.sid}: {h.fidelity_log}")
    wl = workload or "all-at-t0"
    label = (f"{lanes}-lane" if lanes > 1 else
             "batched" if batched else "sequential")
    print(f"{label} on {wl}: {summarize(res).row()}")
    if lanes > 1:
        print(f"applied: migrations={res.n_migrations_applied} "
              f"sp_expands={res.n_sp_expands_applied} "
              f"sp_releases={res.n_sp_releases_applied}")


if __name__ == "__main__":
    main()
