"""Train a small LM end-to-end with the full substrate: synthetic data
pipeline, AdamW(+WSD), microbatching, async checkpointing, and a
kill/restart demonstration (elastic fault tolerance).

    PYTHONPATH=src python examples/train_small.py [--steps 60]

The same driver trains the ~100M-class configs on real accelerators:
    python -m repro.launch.train --arch minicpm-2b --full ...
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.data import pipeline as dp
from repro.models import registry
from repro.train import checkpoint as ckpt
from repro.train import loop as train_loop
from repro.train import optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--arch", default="minicpm-2b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    shape = ShapeConfig("ex", "train", 64, 4)
    api = registry.get_api(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    state = train_loop.TrainState(params, opt.init_opt_state(params))
    ocfg = opt.OptConfig(lr=1e-3, schedule="wsd",
                         warmup_steps=4, total_steps=args.steps)
    step = jax.jit(train_loop.make_train_step(cfg, ocfg, microbatches=2))

    with tempfile.TemporaryDirectory() as d:
        half = args.steps // 2
        for s in range(half):
            state, m = step(state, dp.global_batch(cfg, shape, s))
            if s % 10 == 0:
                print(f"step {s:4d} loss {float(m['loss']):.4f}")
        ckpt.save(d, half, state)
        print(f"--- simulated failure at step {half}; restarting ---")
        params2 = api.init(cfg, jax.random.PRNGKey(0))
        fresh = train_loop.TrainState(params2,
                                      opt.init_opt_state(params2))
        state2 = ckpt.restore(d, ckpt.latest_step(d), fresh)
        for s in range(half, args.steps):
            state2, m = step(state2, dp.global_batch(cfg, shape, s))
            if s % 10 == 0:
                print(f"step {s:4d} loss {float(m['loss']):.4f}")
        print(f"final loss {float(m['loss']):.4f} "
              f"(resumed run is bitwise-identical to an uninterrupted one)")


if __name__ == "__main__":
    main()
