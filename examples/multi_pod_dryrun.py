import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede any jax import (jax locks the device count on first use)
"""One multi-pod dry-run cell, end to end: build the 2x16x16 production
mesh, lower+compile the sharded train step for an assigned architecture
with ShapeDtypeStruct inputs (no allocation), and read off the roofline
terms.

    PYTHONPATH=src python examples/multi_pod_dryrun.py [arch] [shape]
"""
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_cell


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "granite-moe-1b-a400m"
    shape = sys.argv[2] if len(sys.argv) > 2 else "train_4k"
    rec = run_cell(arch, shape, multi_pod=True, save=False)
    assert rec["status"] in ("ok", "skipped"), rec


if __name__ == "__main__":
    main()
