"""Quickstart: the three layers of the framework in ~a minute.

    PYTHONPATH=src python examples/quickstart.py

1. BMPR — the paper's fidelity router on its profiled Pareto frontier.
2. Cluster serving — the real control plane on a simulated 16-worker
   cluster (QoE / TTFC / quality, SS7 metrics).
3. Real model — one AR-DiT chunk generated at two fidelity configs.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

# --- 1. BMPR ----------------------------------------------------------------
from repro.core.bmpr import BMPR
from repro.profiler.profiles import get_profile

bmpr = BMPR(get_profile("causal-forcing"))
print("Pareto frontier:", len(bmpr.frontier.points), "points,",
      f"quality floor {bmpr.frontier.q_floor:.2f}")
for budget in (1.0, 0.5, 0.1):
    d = bmpr.select(budget)
    print(f"  slack budget {budget:4.1f}s -> {d.fidelity.key:22s} "
          f"({d.mode}, L={d.latency:.2f}s, Q={d.quality:.2f})")

# --- 2. cluster serving ------------------------------------------------------
from repro.sched_sim.metrics import summarize
from repro.sched_sim.policies import make_policy
from repro.sched_sim.simulator import SimConfig, Simulator
from repro.sched_sim.workloads import steady

specs = steady(n=100, rate=1.0, seed=0)
res = Simulator(SimConfig(), specs, make_policy("slackserve")).run()
print("\n16-worker cluster, 100 streams:", summarize(res).row())

# --- 3. real model -----------------------------------------------------------
from repro.configs.base import get_config
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.models import ardit as A

cfg = get_config("ardit-self-forcing").reduced()
params = A.init_params(cfg, jax.random.PRNGKey(0))
cond = 0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                (1, A.COND_TOKENS, cfg.d_model))
cache = A.init_cache(cfg, params, cond)
noise = jax.random.normal(jax.random.PRNGKey(2),
                          (1, A.chunk_tokens(cfg), A.LATENT_CH))
import time
for fid in (HIGHEST_QUALITY, FidelityConfig(2, 0.9, 1, "fp8")):
    t0 = time.perf_counter()
    chunk, cache = A.serve_chunk(cfg, params, cache, noise, fid)
    chunk.block_until_ready()
    print(f"\ngenerated chunk at {fid.key}: shape {chunk.shape}, "
          f"{time.perf_counter()-t0:.2f}s wall")
print("done.")
