"""Model plane: registry-backed heterogeneous co-serving.

Fast tier: bundle resolution + geometry, (model, kv_dtype) sub-batch
grouping, weighted placement (``Worker.load`` / ``choose_home``),
per-model Summary rows, the keyed front-door service EMAs (single-key
bit-identity AND the low-fidelity over-reject regression), and the
mixed-model workload generator.

Slow tier: a live 2-model co-serve session whose per-model chunks
match each model's SOLO session within the repo's batched-parity
tolerance (allclose 1e-5), with zero unserved streams and per-model
Summary rows; plus single-bundle degeneration (bit-identical chunks to
the legacy single-model session path)."""
import dataclasses
import types

import numpy as np
import pytest

from repro.core.control_plane import ControlPlane
from repro.core.fidelity import FidelityConfig
from repro.core.types import ClusterView, Stream, Worker
from repro.sched_sim.frontdoor import FrontDoor, FrontDoorConfig
from repro.sched_sim.metrics import summarize
from repro.sched_sim.workloads import mixed_models, steady
from repro.serve.batcher import compose_batch

FID = FidelityConfig(2, 0.0, 2, "bf16")
MODELS = ["ardit-self-forcing", "ardit-causal-forcing"]


# ---------------------------------------------------------------------------
# bundle resolution
# ---------------------------------------------------------------------------

class TestResolveBundles:
    def test_geometry_and_primary_normalization(self):
        from repro.serve.modelplane import resolve_bundles
        bundles = resolve_bundles(MODELS)
        assert [b.name for b in bundles] == MODELS
        primary = bundles[0]
        assert primary.step_cost == 1.0 and primary.page_cost == 1.0
        assert primary.placement_weight == 1.0
        for b in bundles:
            # sink page + ring pages, page fits cond AND one chunk
            assert b.pages_per_stream == 1 + b.cfg.ardit_window_chunks
            assert b.page_tokens > 0 and b.page_bytes > 0
            assert b.stream_bytes == b.pages_per_stream * b.page_bytes
            assert b.params is not None and b.profile is not None
        # both reduced ardit configs share geometry -> equal page cost
        assert bundles[1].page_cost == pytest.approx(1.0)

    def test_rejects_empty_duplicates_and_non_ardit(self):
        from repro.serve.modelplane import resolve_bundle, resolve_bundles
        with pytest.raises(ValueError):
            resolve_bundles([])
        with pytest.raises(ValueError, match="duplicate"):
            resolve_bundles(["ardit-self-forcing", "ardit-self-forcing"])
        with pytest.raises(ValueError, match="ardit-family"):
            resolve_bundle("mamba2-780m")

    def test_profile_name_mapping(self):
        from repro.serve.modelplane import profile_name_of
        assert profile_name_of("ardit-self-forcing") == "self-forcing"
        assert profile_name_of("ardit-causal-forcing") == "causal-forcing"
        assert profile_name_of("mamba2-780m") == "mamba2-780m"


# ---------------------------------------------------------------------------
# (model, kv_dtype) sub-batch grouping
# ---------------------------------------------------------------------------

class TestComposeBatchModelGrouping:
    FIDS = {0: FidelityConfig(4, 0.0, 7, "bf16"),
            1: FidelityConfig(4, 0.0, 7, "bf16"),
            2: FidelityConfig(2, 0.5, 5, "bf16"),
            3: FidelityConfig(2, 0.5, 5, "fp8")}

    def test_no_model_of_is_legacy(self):
        legacy = compose_batch([0, 1, 2, 3], self.FIDS.get, 4)
        explicit = compose_batch([0, 1, 2, 3], self.FIDS.get, 4,
                                 model_of=None)
        assert legacy == explicit

    def test_models_split_groups(self):
        model_of = {0: "a", 1: "b", 2: "a", 3: "a"}.get
        groups = compose_batch([0, 1, 2, 3], self.FIDS.get, 4,
                               model_of=model_of)
        # same fidelity but different model never shares a group
        assert [0] in groups and [1] in groups
        for grp in groups:
            assert len({model_of(s) for s in grp}) == 1

    def test_fused_groups_by_model_and_dtype(self):
        model_of = {0: "a", 1: "a", 2: "a", 3: "a"}.get
        groups = compose_batch([0, 1, 2, 3], self.FIDS.get, 4,
                               fuse=True, model_of=model_of)
        # one model, two dtypes -> exactly two fused groups
        assert sorted(map(sorted, groups)) == [[0, 1, 2], [3]]


# ---------------------------------------------------------------------------
# weighted placement
# ---------------------------------------------------------------------------

class TestWeightedPlacement:
    def _worker(self, wid, queue=(), running=None, donated=None):
        w = Worker(wid, node=0)
        w.queue = list(queue)
        w.running = running
        w.donated_to = donated
        return w

    def test_load_none_is_legacy_integer(self):
        w = self._worker(0, queue=[1, 2], running=3, donated=4)
        assert w.load() == 4
        assert isinstance(w.load(), int)

    def test_load_weighted_sums_stream_weights(self):
        w = self._worker(0, queue=[1, 2], running=3)
        weight = {1: 1.0, 2: 2.5, 3: 0.5}.get
        assert w.load(lambda sid: weight(sid)) == pytest.approx(4.0)

    def test_choose_home_unweighted_parity(self):
        workers = [self._worker(0, queue=[1, 2]), self._worker(1, queue=[3])]
        view = ClusterView({}, workers, 2)
        assert view.stream_weight is None
        assert ControlPlane().choose_home(view) == 1

    def test_choose_home_weighs_heavy_models(self):
        # worker 0 holds ONE heavy stream, worker 1 TWO light ones: the
        # integer argmin would pick worker 0, the weighted one must not
        workers = [self._worker(0, queue=[10]),
                   self._worker(1, queue=[11, 12])]
        view = ClusterView({}, workers, 2)
        assert ControlPlane().choose_home(view) == 0
        view.stream_weight = lambda sid: 5.0 if sid == 10 else 1.0
        assert ControlPlane().choose_home(view) == 1


# ---------------------------------------------------------------------------
# per-model Summary rows
# ---------------------------------------------------------------------------

def _stream(sid, model, arrival=0.0, ready=(1.0,), deadlines=(2.0,)):
    s = Stream(sid=sid, arrival=arrival, target_chunks=len(ready),
               chunk_seconds=1.0, home=0, ttfc_slack=1.0)
    s.model = model
    s.ready_times = list(ready)
    s.deadlines = list(deadlines)
    s.first_chunk_time = ready[0] if ready else None
    s.qualities = [80.0] * len(ready)
    return s


class TestSummaryByModel:
    def test_rows_keyed_by_model(self):
        res = types.SimpleNamespace(streams={
            0: _stream(0, "a", ready=(1.0, 2.0), deadlines=(2.0, 3.0)),
            1: _stream(1, "b", ready=(3.0,), deadlines=(2.0,)),  # late
            2: _stream(2, "a", ready=(1.5,), deadlines=(2.0,)),
        })
        summ = summarize(res)
        assert set(summ.by_model) == {"a", "b"}
        assert summ.by_model["a"]["cpr"] == 1.0
        assert summ.by_model["b"]["cpr"] == 0.0
        assert summ.by_model["a"]["n_streams"] == 2
        assert summ.by_model["a"]["streams_per_s"] > 0
        assert len(summ.model_rows()) == 2

    def test_untagged_streams_yield_no_rows(self):
        res = types.SimpleNamespace(streams={
            0: _stream(0, None), 1: _stream(1, None)})
        summ = summarize(res)
        assert summ.by_model == {}
        assert summ.model_rows() == []


# ---------------------------------------------------------------------------
# keyed front-door service EMAs (satellite: over-reject regression)
# ---------------------------------------------------------------------------

def _view(load=0, n_workers=2):
    workers = []
    for w in range(n_workers):
        worker = Worker(w, node=0)
        worker.queue = list(range(load))
        workers.append(worker)
    return ClusterView({}, workers, n_workers)


class TestKeyedServiceEMA:
    def test_single_key_traffic_bit_identical_to_global(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        kd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        for v in (0.5, 0.7, 0.3, 0.9, 0.4):
            fd.observe_chunk(v)                          # legacy keyless
            kd.observe_chunk(v, fidelity="S4", model="m")
        assert kd.chunk_service_ema == fd.chunk_service_ema
        # the keyed recurrence reproduces the global one EXACTLY
        assert kd.expected_service() == kd.chunk_service_ema
        assert kd.predict_ttfc(_view(load=3)) == \
            fd.predict_ttfc(_view(load=3))

    def test_no_observations_falls_back_to_global(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        assert fd.expected_service() == fd.chunk_service_ema
        assert fd.predict_ttfc(_view(load=5)) == \
            5 * fd.chunk_service_ema + 1.0

    def test_low_fidelity_heavy_fleet_no_longer_over_rejects(self):
        """Regression (the satellite's motivating scenario): a fleet
        serving mostly cheap low-fidelity chunks, with a couple of
        RECENT slow high-fidelity completions.  The old single global
        EMA is dragged to the recent expensive observations and
        over-predicts TTFC -> over-rejects; the observation-weighted
        keyed mix stays near the traffic's real cost -> admits."""
        fd = FrontDoor(FrontDoorConfig(autoscale=False, queue_limit=0),
                       first_chunk_estimate=1.0)
        for _ in range(20):
            fd.observe_chunk(0.1, fidelity="S1_lo")
        for _ in range(2):
            fd.observe_chunk(1.0, fidelity="S4_hi")
        view = _view(load=8)
        slo = fd.slo_ttfc()
        old_prediction = 8 * fd.chunk_service_ema + fd.first_est
        new_prediction = fd.predict_ttfc(view)
        # the single global EMA would have over-predicted past the SLO
        assert old_prediction > slo
        # the keyed mix tracks the 20:2 cheap-heavy traffic ratio
        assert new_prediction < old_prediction
        assert new_prediction <= slo
        dec = fd.on_arrival(view, 23.0, 1.0, sid=0)
        assert dec.action == "admit"

    def test_per_model_keys_are_distinct(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        fd.observe_chunk(0.1, fidelity="S4", model="light")
        fd.observe_chunk(1.0, fidelity="S4", model="heavy")
        assert fd._service_emas[("light", "S4")] != \
            fd._service_emas[("heavy", "S4")]


# ---------------------------------------------------------------------------
# mixed-model workload generator
# ---------------------------------------------------------------------------

class TestMixedModelsWorkload:
    def test_arrivals_match_steady_and_models_are_tagged(self):
        base = steady(n=20, rate=1.0, seed=3)
        mixed = mixed_models(n=20, rate=1.0, seed=3)
        assert [s.arrival for s in mixed] == [s.arrival for s in base]
        assert [s.frames for s in mixed] == [s.frames for s in base]
        assert all(s.model in ("causal-forcing", "self-forcing")
                   for s in mixed)
        assert len({s.model for s in mixed}) == 2
        # deterministic per seed
        again = mixed_models(n=20, rate=1.0, seed=3)
        assert [s.model for s in again] == [s.model for s in mixed]

    def test_weights_bias_the_draw(self):
        mixed = mixed_models(n=200, rate=1.0, seed=0,
                             models=("a", "b"), weights=(9.0, 1.0))
        n_a = sum(1 for s in mixed if s.model == "a")
        assert n_a > 150
        with pytest.raises(ValueError):
            mixed_models(n=4, models=())

    def test_simulator_attributes_model_and_cost(self):
        """Tagged streams carry their model into the Stream record and
        a heavier model's chunks take proportionally longer."""
        from repro.profiler.profiles import MODEL_COST
        from repro.sched_sim.policies import make_policy
        from repro.sched_sim.simulator import SimConfig, Simulator
        specs = [dataclasses.replace(s, model=m) for s, m in zip(
            steady(n=4, rate=5.0, seed=0),
            ["causal-forcing", "minitron-8b"] * 2)]
        cfg = SimConfig(n_workers=2, max_time=2e4)
        res = Simulator(cfg, specs, make_policy("slackserve")).run()
        summ = summarize(res)
        assert set(summ.by_model) == {"causal-forcing", "minitron-8b"}
        for s in res.streams.values():
            assert s.model in ("causal-forcing", "minitron-8b")
        assert MODEL_COST["minitron-8b"] > 1.0


# ---------------------------------------------------------------------------
# live co-serving sessions (slow tier: JAX-compiling)
# ---------------------------------------------------------------------------

def _tagged_specs(n, chunks, models):
    from repro.serve.session import uniform_specs
    return [dataclasses.replace(sp, model=models[i % len(models)])
            for i, sp in enumerate(uniform_specs(n, chunks))]


def _run_session(models, specs, pool=8):
    from repro.core.bmpr import StaticFidelity
    from repro.serve.session import SessionConfig, StreamingSession
    session = StreamingSession(
        SessionConfig(executor="batched", models=list(models),
                      pool_streams=pool, verbose=False),
        fidelity_policy=StaticFidelity(FID))
    handles = [session.submit(sp) for sp in specs]
    res = session.run()
    return session, handles, res


@pytest.mark.slow
def test_co_serve_session_matches_solo_runs():
    """A 2-model co-serve session completes with zero unserved streams,
    keeps every sub-batch same-model, reports per-model Summary rows,
    and generates chunks matching each model's SOLO session within the
    repo's batched-parity tolerance."""
    specs = _tagged_specs(4, 2, MODELS)
    _, co_handles, co_res = _run_session(MODELS, specs)
    co_summ = summarize(co_res)
    assert co_summ.n_unserved == 0
    assert set(co_summ.by_model) == set(MODELS)
    for m in MODELS:
        assert co_summ.by_model[m]["n_streams"] == 2
        assert co_summ.by_model[m]["n_chunks"] == 4

    co_chunks = {h.sid: [np.asarray(c) for c in h.chunks]
                 for h in co_handles}
    for m in MODELS:
        solo_specs = [sp for sp in specs if sp.model == m]
        _, solo_handles, solo_res = _run_session([m], solo_specs)
        assert summarize(solo_res).n_unserved == 0
        for h in solo_handles:
            assert len(co_chunks[h.sid]) == len(h.chunks) == 2
            for got, ref in zip(co_chunks[h.sid], h.chunks):
                np.testing.assert_allclose(got, np.asarray(ref),
                                           rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_single_bundle_session_degenerates_to_legacy():
    """models=[one ardit config] produces chunks BIT-identical to the
    legacy model_cfg single-model path (same seeds, fixed fidelity)."""
    from repro.configs.base import get_config
    from repro.core.bmpr import StaticFidelity
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     uniform_specs)
    specs = uniform_specs(2, 2)
    _, handles_a, _ = _run_session(["ardit-causal-forcing"], specs)
    legacy = StreamingSession(
        SessionConfig(executor="batched",
                      model_cfg=get_config("ardit-causal-forcing")
                      .reduced(),
                      pool_streams=8, verbose=False),
        fidelity_policy=StaticFidelity(FID))
    handles_b = [legacy.submit(sp) for sp in specs]
    legacy.run()
    for ha, hb in zip(handles_a, handles_b):
        assert len(ha.chunks) == len(hb.chunks) == 2
        for ca, cb in zip(ha.chunks, hb.chunks):
            assert np.array_equal(np.asarray(ca), np.asarray(cb))


@pytest.mark.slow
def test_same_model_only_migration_routing():
    """LanePool resolves migration src/dst through the stream's OWN
    bundle: after a cross-lane migration of a non-primary stream its
    pages live in the non-primary pool of the destination lane."""
    from repro.serve.lanes import LanePool
    from repro.serve.modelplane import resolve_bundles
    bundles = resolve_bundles(MODELS)
    lanes = LanePool(2, seed=0, max_streams=4, bundles=bundles)
    other = MODELS[1]
    lanes.admit(0, 0, seed=0, model=other)
    ex_src = lanes.ex_for(0, other)
    ex_dst = lanes.ex_for(1, other)
    assert ex_src is lanes.bundle_executors[other][0]
    assert ex_src is not lanes.ex(0)
    ex_src.begin_chunk(0, FID, 0.0)
    while 0 in ex_src.inflight:
        ex_src.run_step([0])
    assert lanes.migrate(0, 0, 1)
    assert ex_dst.pool.resident(0)
    assert not ex_src.pool.resident(0)
    # the primary bundle's pools never saw the stream
    assert not lanes.ex(0).pool.resident(0)
    assert not lanes.ex(1).pool.resident(0)
    assert lanes.model_of[0] == other
    assert lanes.lane_of[0] == 1
