"""State Plane: paged pool accounting + transfer protocol semantics."""
import pytest

from repro.core.state_plane import AsyncTransferEngine, PagedKVPool


class TestPagedPool:
    def test_alloc_free_accounting(self):
        pool = PagedKVPool(10)
        assert pool.alloc(1, 4) and pool.alloc(2, 5)
        assert pool.free == 1
        assert not pool.alloc(3, 2)                # full
        assert pool.release(1) == 4
        assert pool.free == 5
        assert pool.alloc(3, 2)
        assert pool.pages_of(3) == 2
        assert sorted(pool.resident_sids()) == [2, 3]

    def test_incremental_growth(self):
        pool = PagedKVPool(10)
        pool.alloc(1, 2)
        pool.alloc(1, 3)
        assert pool.pages_of(1) == 5
        assert pool.release(1) == 5
        assert pool.free == 10


class TestTransferEngine:
    def test_protocol_readiness_ordering(self):
        """sync/async-nostream wait for the full state; async-stream
        re-queues after the FIRST layer (Fig. 13)."""
        n_bytes = 30 * 10_000_000
        sync = AsyncTransferEngine(protocol="sync", n_layers=30)
        nostream = AsyncTransferEngine(protocol="async-nostream",
                                       n_layers=30)
        stream = AsyncTransferEngine(protocol="async-stream", n_layers=30)
        t_sync = sync.transfer(0.0, n_bytes, cross_node=False)
        t_ns = nostream.transfer(0.0, n_bytes, cross_node=False)
        t_st = stream.transfer(0.0, n_bytes, cross_node=False)
        assert t_sync.complete == t_ns.complete == t_st.complete
        assert t_sync.first_layer_ready == t_sync.complete
        assert t_ns.first_layer_ready == t_ns.complete
        assert t_st.first_layer_ready < t_st.complete
        # layer-wise streaming: residual wait ~ 1/30 of the move + overhead
        assert t_st.residual_wait < 0.1 * t_st.total + stream.overhead
        assert sync.blocks_dispatcher()
        assert not stream.blocks_dispatcher()

    def test_cross_node_slower(self):
        eng = AsyncTransferEngine()
        intra = eng.transfer(0.0, 10**9, cross_node=False)
        inter = eng.transfer(0.0, 10**9, cross_node=True)
        assert inter.total > intra.total

    def test_log_accumulates(self):
        eng = AsyncTransferEngine()
        for i in range(5):
            eng.transfer(float(i), 10**6, cross_node=bool(i % 2))
        assert len(eng.log) == 5
        assert sum(t.cross_node for t in eng.log) == 2
