"""Forced-device-count parity harness (run in a SUBPROCESS).

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` must be set
before JAX initializes, so these checks cannot run in the main pytest
process (its JAX backend is already up with one device).  The driver
(``tests/test_device_lanes.py``) launches this file with the flag in
the environment; the assertions here cover the device-backed lane
matrix:

  1. cross-lane migration is a REAL ``jax.device_put`` move (measured,
     recorded on the engine) and the stream's chunks stay bit-identical
     to a never-migrated run;
  2. elastic SP across devices takes batch-axis mode: the guest is
     co-served in the donor's own fused jitted call (one ``run_step``)
     and stays bit-identical to the SP1 step through expand, appends
     under SP, and release;
  3. (2 devices) a full StreamingSession applies a forced re-homing +
     SP expand across real devices, bit-identical to the single-lane
     session, with measured moves on the engine.

Prints ``DEVICE-LANES-OK`` + a stats JSON on success; any assertion
failure exits nonzero.
"""
import dataclasses
import json
import os
import sys

N_DEV = int(sys.argv[1]) if len(sys.argv) > 1 else 2
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + f" --xla_force_host_platform_device_count="
                             f"{N_DEV}").strip()

import jax                                                   # noqa: E402
import numpy as np                                           # noqa: E402

from repro.configs.base import get_config                    # noqa: E402
from repro.core.fidelity import FidelityConfig               # noqa: E402
from repro.serve.lanes import LanePool                       # noqa: E402

FID = FidelityConfig(2, 0.0, 2, "bf16")


def tiny_cfg(window_chunks=2):
    return dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=window_chunks)


def gen_chunks(ex, sid, n=1, fid=FID):
    out = []
    for _ in range(n):
        ex.begin_chunk(sid, fid, 0.0)
        while sid in ex.inflight:
            ex.run_step([sid])
        out.append(np.asarray(ex.chunks[sid][-1]))
    return out


def device_of(arr):
    return next(iter(arr.devices()))


def check_migration(cfg, ref_params, ref_chunks_5):
    """Real cross-device migration: measured move, committed landing,
    bit-exact continuation."""
    lanes = LanePool(2, cfg=cfg, params=ref_params, max_streams=3)
    assert lanes.lane_devices[0] != lanes.lane_devices[1]
    assert device_of(lanes.ex(0).pool.k) == lanes.lane_devices[0]
    assert device_of(lanes.ex(1).pool.k) == lanes.lane_devices[1]
    lanes.admit(5, 0, seed=0)
    got = gen_chunks(lanes.ex(0), 5, 2)
    n_meas, n_log = len(lanes.engine.measured), len(lanes.engine.log)
    assert lanes.migrate(5, 0, 1)
    # the direct path: one MEASURED device_put + one modeled transfer
    assert len(lanes.engine.measured) == n_meas + 1
    assert len(lanes.engine.log) == n_log + 1
    m = lanes.engine.measured[-1]
    assert m.kind == "migration" and m.n_bytes > 0 and m.seconds > 0
    assert m.bytes_per_s > 0
    # per-lane attribution: src sent, dst received, same bytes
    assert lanes.ex(0).pool.transfer_bytes_out == m.n_bytes
    assert lanes.ex(1).pool.transfer_bytes_in == m.n_bytes
    # immediately page-resident on the destination DEVICE
    assert lanes.ex(1).pool.resident(5)
    assert device_of(lanes.ex(1).pool.k) == lanes.lane_devices[1]
    got += gen_chunks(lanes.ex(1), 5, 2)
    for c, (a, b) in enumerate(zip(ref_chunks_5, got)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"chunk {c} diverged across the device move")
    lanes.ex(0).pool.ledger.check()
    lanes.ex(1).pool.ledger.check()
    return {"migration_bw": m.bytes_per_s, "migration_bytes": m.n_bytes}


def check_batch_axis_sp(cfg, ref_params, ref_chunks_0):
    """Cross-device SP goes batch-axis: guest co-served in the donor's
    fused call, SP2 == SP1 bit-exactly through expand/append/release."""
    lanes = LanePool(2, cfg=cfg, params=ref_params, max_streams=3)
    lanes.admit(0, 0, seed=0)
    lanes.admit(9, 1, seed=9)             # the donor's own stream
    got = gen_chunks(lanes.ex(0), 0, 1)
    assert lanes.sp_expand(0, 1)
    link = lanes.sp_link(0)
    assert link is not None and link.mode == "batch", \
        "cross-device lanes must take batch-axis SP"
    sp_moves = [m for m in lanes.engine.measured if m.kind == "sp-expand"]
    assert len(sp_moves) == 1 and sp_moves[0].n_bytes > 0
    # co-serve: guest 0 + donor stream 9 advance in ONE fused jitted
    # call on the donor lane — no solo dispatch slot consumed
    donor_ex = lanes.ex(1)
    donor_ex.begin_chunk(0, FID, 0.0)
    donor_ex.begin_chunk(9, FID, 0.0)
    while 0 in donor_ex.inflight:
        completed, _ = donor_ex.run_step([0, 9])
    assert 9 not in donor_ex.inflight, \
        "same-fidelity co-batch must complete together"
    got.append(np.asarray(donor_ex.chunks[0][-1]))
    got += gen_chunks(donor_ex, 0, 1)     # another guest chunk, solo row
    lanes.sp_release(0)
    assert lanes.sp_link(0) is None
    donor_ex.pool.ledger.check()
    got += gen_chunks(lanes.ex(0), 0, 1)  # home serves again post-release
    for c, (a, b) in enumerate(zip(ref_chunks_0, got)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"chunk {c}: batch-axis SP diverged from SP1")
    return {"sp_expand_bytes": sp_moves[0].n_bytes}


def check_session_2dev(cfg):
    """A 2-device session applying a forced re-homing + SP expand stays
    bit-identical to the single-lane session, with the moves measured."""
    from repro.core.bmpr import StaticFidelity
    from repro.core.elastic_sp import SPDecision
    from repro.core.rehoming import Migration
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     uniform_specs)
    n, chunks = 2, 3
    ref = StreamingSession(
        SessionConfig(lanes=1, model_cfg=cfg, pool_streams=n + 1,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        ref.submit(spec)
    ref.run()
    ref_chunks = {i: [np.asarray(c) for c in ref.handles[i].chunks]
                  for i in range(n)}

    sess = StreamingSession(
        SessionConfig(lanes=2, model_cfg=cfg, pool_streams=n + 1,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        sess.submit(spec)
    state = {"mig": False, "sp": False}
    orig_tick = sess.control.tick

    def tick(view, now):
        d = orig_tick(view, now)
        s0, s1 = view.streams.get(0), view.streams.get(1)
        if (not state["mig"] and s0 is not None and s0.chunks_done >= 1
                and not s0.done and not sess.lanes.is_inflight(0)):
            src = sess.lanes.lane_of[0]
            d.migrations.append(Migration(0, src, 1 - src,
                                          cross_node=False))
            state["mig"] = True
        if (not state["sp"] and s1 is not None and s1.chunks_done >= 1
                and not s1.done
                and sess.lanes.ex(sess.lanes.lane_of[1]).pool.resident(1)):
            d.sp_decisions.append(
                SPDecision(1, 1 - sess.lanes.lane_of[1], "expand"))
            state["sp"] = True
        return d

    sess.control.tick = tick
    res = sess.run()
    assert res.n_migrations_applied >= 1
    assert res.n_sp_expands_applied >= 1
    kinds = {m.kind for m in res.engine.measured}
    assert "migration" in kinds, \
        "the applied re-homing must be a real measured device move"
    assert "sp-expand" in kinds
    for i in range(n):
        got = [np.asarray(c) for c in sess.handles[i].chunks]
        assert len(got) == chunks
        for c in range(chunks):
            np.testing.assert_array_equal(
                ref_chunks[i][c], got[c],
                err_msg=f"stream {i} chunk {c} diverged on device lanes")
    st = res.engine.measured_stats()
    assert st["count"] >= 2 and st["bytes_per_s"] > 0
    return {"session_measured": st}


def main():
    assert jax.local_device_count() == N_DEV, \
        f"forced device count not honored: {jax.local_device_count()}"
    cfg = tiny_cfg()
    # references: one single-lane executor per sid (sid seeds the noise)
    ref_pool = LanePool(1, cfg=cfg, max_streams=3)
    ref_ex = ref_pool.ex(0)
    ref_ex.admit(5, seed=0)
    ref5 = gen_chunks(ref_ex, 5, 4)
    ref_ex2 = LanePool(1, cfg=cfg, params=ref_ex.params,
                       max_streams=3).ex(0)
    ref_ex2.admit(0, seed=0)
    ref0 = gen_chunks(ref_ex2, 0, 4)

    stats = {"devices": N_DEV}
    stats.update(check_migration(cfg, ref_ex.params, ref5))
    stats.update(check_batch_axis_sp(cfg, ref_ex.params, ref0))
    if N_DEV == 2:
        stats.update(check_session_2dev(cfg))
    if N_DEV >= 4:
        # far-lane move on the wider mesh: lane 0 -> lane 3
        lanes = LanePool(4, cfg=cfg, params=ref_ex.params, max_streams=3)
        assert len({str(d) for d in lanes.lane_devices}) == 4
        lanes.admit(5, 0, seed=0)
        got = gen_chunks(lanes.ex(0), 5, 2)
        assert lanes.migrate(5, 0, 3)
        assert lanes.engine.measured[-1].kind == "migration"
        got += gen_chunks(lanes.ex(3), 5, 2)
        for a, b in zip(ref5, got):
            np.testing.assert_array_equal(a, b)
    print("DEVICE-LANES-OK", json.dumps(stats))


if __name__ == "__main__":
    main()
