"""Page-table-native attention backend conformance suite.

Four angles on the ``paged`` context backend (the serving default):
  * mask layout — ``kvcache.mask_to_pages`` maps the contiguous
    sink+ring visibility mask into table coordinates exactly, with page
    tails always invalid;
  * attention math — the chunk-query paged partials (jnp oracle and the
    Pallas kernel under ``REPRO_FORCE_PALLAS_INTERPRET=1``) merged with
    the in-chunk segment reproduce dense masked attention over the
    gathered context;
  * backend parity — ``BatchedChunkExecutor(context_backend="paged")``
    matches the ``gather`` backend numerically across fidelity windows,
    fp8/bf16 KV, sparsity, ring wrap-around, and join/leave sequences
    (the PR 2 parity matrix);
  * oversubscription conformance — an oversubscribed paged-backend
    executor completes every stream numerically on the trajectory of an
    unconstrained gather-backend run (spill/restore + page-table
    indirection lose nothing).

The single-chunk parity test runs in the fast tier; matrix sweeps are
slow-tier.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fidelity import FidelityConfig
from repro.models import ardit as A
from repro.models import kvcache
from repro.models.attention import mha, paged_mha
from repro.serve.batcher import BatchedChunkExecutor

from test_batcher import nondegenerate_params, tiny_cfg

KEY = jax.random.PRNGKey(0)

RTOL, ATOL = 1e-4, 2e-4          # fp32 online-softmax merge-order slack


# ---------------------------------------------------------------------------
# mask layout: contiguous sink+ring -> page/table coordinates
# ---------------------------------------------------------------------------

def test_mask_to_pages_layout():
    sink, tc, page = 5, 3, 7
    mask = np.zeros((2, sink + 2 * tc), bool)
    mask[0, :sink] = True                      # sink only
    mask[1, :] = True                          # everything
    mask[1, sink + 1] = False                  # ... minus one ring token
    out = kvcache.mask_to_pages(mask, n_ring=2, sink=sink,
                                chunk_tokens=tc, page_tokens=page)
    assert out.shape == (2, 3 * page)
    # sink page: first `sink` tokens mirror the mask, tail invalid
    np.testing.assert_array_equal(out[:, :sink], mask[:, :sink])
    assert not out[:, sink:page].any()
    for r in range(2):
        lo = (1 + r) * page
        np.testing.assert_array_equal(
            out[:, lo:lo + tc], mask[:, sink + r * tc:sink + (r + 1) * tc])
        assert not out[:, lo + tc:lo + page].any()   # ring page tails


def test_mask_to_pages_zero_ring():
    out = kvcache.mask_to_pages(np.ones((1, 4), bool), n_ring=0, sink=4,
                                chunk_tokens=3, page_tokens=6)
    assert out.shape == (1, 6)
    np.testing.assert_array_equal(out[0], [1, 1, 1, 1, 0, 0])


# ---------------------------------------------------------------------------
# attention math: paged partials + in-chunk merge == dense masked mha
# ---------------------------------------------------------------------------

def _paged_case(seed=0, B=2, Sq=6, Hq=4, Hkv=2, D=8, n=3, page=7,
                p_total=9):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(p_total, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(p_total, page, Hkv, D)), jnp.float32)
    bt = jnp.asarray(rng.choice(p_total, size=(B, n), replace=False)
                     if B * n <= p_total else
                     rng.integers(0, p_total, size=(B, n)), jnp.int32)
    mask = rng.random((B, n * page)) < 0.7
    mask[0, page:2 * page] = False             # a fully-masked page
    mask[1, :] = False
    mask[1, :4] = True                         # nearly-empty stream
    ck = jnp.asarray(rng.normal(size=(B, Sq, Hkv, D)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(B, Sq, Hkv, D)), jnp.float32)
    return q, kp, vp, bt, jnp.asarray(mask), ck, cv


def _dense_reference(q, kp, vp, bt, mask, ck, cv, Hkv):
    b, n = bt.shape
    _, page, _, d = kp.shape
    kg = kp[bt.reshape(-1)].reshape(b, n * page, Hkv, d)
    vg = vp[bt.reshape(-1)].reshape(b, n * page, Hkv, d)
    k_all = jnp.concatenate([kg, ck], axis=1)
    v_all = jnp.concatenate([vg, cv], axis=1)
    kv_mask = jnp.concatenate(
        [mask, jnp.ones((b, q.shape[1]), bool)], axis=1)
    return mha(q, k_all, v_all, n_kv_heads=Hkv, causal=False,
               kv_mask=kv_mask)


def test_paged_mha_matches_dense_masked_mha():
    q, kp, vp, bt, mask, ck, cv = _paged_case()
    out = paged_mha(q, kp, vp, bt, mask, ck, cv, n_kv_heads=2)
    ref = _dense_reference(q, kp, vp, bt, mask, ck, cv, Hkv=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_ref_compact_layout_equals_full_pages():
    """The sink/chunk_tokens layout hint (oracle skips always-masked
    page tails) must not change the partials — given a mask whose page
    tails are indeed dead."""
    from repro.kernels.paged_attention.ref import paged_chunk_attention_ref
    q, kp, vp, bt, mask, _, _ = _paged_case(seed=5)
    page = kp.shape[1]
    sink, tc = page - 2, page - 3
    m = np.asarray(mask).copy().reshape(q.shape[0], -1, page)
    m[:, 0, sink:] = False                     # dead sink-page tail
    m[:, 1:, tc:] = False                      # dead ring-page tails
    m = jnp.asarray(m.reshape(q.shape[0], -1))
    full = paged_chunk_attention_ref(q, kp, vp, bt, m)
    compact = paged_chunk_attention_ref(q, kp, vp, bt, m, sink=sink,
                                        chunk_tokens=tc)
    for f, c, name in zip(full, compact, ("m", "l", "acc")):
        np.testing.assert_allclose(np.asarray(f), np.asarray(c),
                                   rtol=1e-6, atol=1e-6, err_msg=name)


def test_all_visible_fast_path_equals_explicit_mask():
    """page_mask=None (every valid-prefix token visible) must equal the
    explicit prefix mask — jnp oracle and interpret-mode kernel both."""
    from repro.kernels.paged_attention.kernel import \
        paged_chunk_attention_pallas
    from repro.kernels.paged_attention.ref import paged_chunk_attention_ref
    q, kp, vp, bt, _, _, _ = _paged_case(seed=9)
    b, page, n = q.shape[0], kp.shape[1], bt.shape[1]
    sink, tc = page - 1, page - 3
    m = np.zeros((b, n, page), bool)
    m[:, 0, :sink] = True
    m[:, 1:, :tc] = True
    m = jnp.asarray(m.reshape(b, -1))
    want = paged_chunk_attention_ref(q, kp, vp, bt, m)
    got_ref = paged_chunk_attention_ref(q, kp, vp, bt, None, sink=sink,
                                        chunk_tokens=tc)
    got_krn = paged_chunk_attention_pallas(q, kp, vp, bt, None,
                                           sink=sink, chunk_tokens=tc,
                                           interpret=True)
    for g in (got_ref, got_krn):
        for a, w, name in zip(g, want, ("m", "l", "acc")):
            np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                       rtol=2e-5, atol=2e-5,
                                       err_msg=name)


@pytest.mark.slow
def test_paged_chunk_kernel_matches_ref_interpret(monkeypatch):
    """The chunk-query Pallas kernel (interpret mode, forced through the
    ops dispatcher env switch) agrees with the jnp oracle — partials
    and the merged paged_mha output."""
    from repro.kernels.paged_attention import ops
    from repro.kernels.paged_attention.ref import paged_chunk_attention_ref
    q, kp, vp, bt, mask, ck, cv = _paged_case(seed=3)
    want = paged_chunk_attention_ref(q, kp, vp, bt, mask)
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    got = ops.paged_chunk_attention(q, kp, vp, bt, mask)
    for g, w, name in zip(got, want, ("m", "l", "acc")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5, err_msg=name)
    out = paged_mha(q, kp, vp, bt, mask, ck, cv, n_kv_heads=2)
    ref = _dense_reference(q, kp, vp, bt, mask, ck, cv, Hkv=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("B,Sq,Hq,Hkv,D,n,page", [
    (1, 4, 2, 2, 16, 2, 5),       # MHA, tiny pages
    (3, 8, 8, 2, 8, 4, 6),        # GQA group of 4
    (2, 5, 6, 3, 4, 1, 9),        # single-page table
])
def test_paged_chunk_kernel_shape_sweep(B, Sq, Hq, Hkv, D, n, page):
    from repro.kernels.paged_attention.kernel import \
        paged_chunk_attention_pallas
    from repro.kernels.paged_attention.ref import paged_chunk_attention_ref
    rng = np.random.default_rng(B * 100 + n)
    p_total = max(B * n, n + 2)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(p_total, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(p_total, page, Hkv, D)), jnp.float32)
    bt = jnp.asarray(rng.integers(0, p_total, size=(B, n)), jnp.int32)
    mask = jnp.asarray(rng.random((B, n * page)) < 0.6)
    got = paged_chunk_attention_pallas(q, kp, vp, bt, mask,
                                       interpret=True)
    want = paged_chunk_attention_ref(q, kp, vp, bt, mask)
    for g, w, name in zip(got, want, ("m", "l", "acc")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5, err_msg=name)


# ---------------------------------------------------------------------------
# backend parity: paged executor == gather executor
# ---------------------------------------------------------------------------

def _run_backend(cfg, p, backend, schedule, max_streams=4):
    """Drive an executor through ``schedule`` = list of (sids, fid)
    chunk rounds (each round runs every listed stream to completion,
    stepped together) and return the generated chunks."""
    ex = BatchedChunkExecutor(cfg=cfg, params=p, max_streams=max_streams,
                              context_backend=backend)
    admitted = set()
    for sids, fid in schedule:
        for sid in sids:
            if sid not in admitted:
                assert ex.admit(sid, seed=sid)
                admitted.add(sid)
            ex.begin_chunk(sid, fid, 0.0)
        while any(sid in ex.inflight for sid in sids):
            grp = [sid for sid in sids if sid in ex.inflight]
            ex.run_step(grp)
    return {sid: [np.asarray(c) for c in ex.chunks[sid]]
            for sid in admitted}


def _assert_same(got, want):
    assert set(got) == set(want)
    for sid in want:
        assert len(got[sid]) == len(want[sid])
        for a, b in zip(got[sid], want[sid]):
            np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_paged_backend_matches_gather_single_chunk():
    """Fast-tier core parity claim: one two-stream chunk, paged ==
    gather (the matrix sweep is slow-tier)."""
    cfg = tiny_cfg(window_chunks=2)
    p = nondegenerate_params(cfg, KEY)
    fid = FidelityConfig(2, 0.0, 2, "bf16")
    schedule = [([0, 1], fid)]
    _assert_same(_run_backend(cfg, p, "paged", schedule),
                 _run_backend(cfg, p, "gather", schedule))


@pytest.mark.slow
@pytest.mark.parametrize("window_chunks", [2, 3])
def test_paged_backend_parity_matrix(window_chunks):
    """The tentpole parity claim on the PR 2 matrix: windows x fp8/bf16
    x sparsity x ring wrap-around, served end-to-end by both context
    backends."""
    cfg = tiny_cfg(window_chunks=window_chunks)
    p = nondegenerate_params(cfg, KEY)
    fids = [FidelityConfig(2, 0.0, 2, "bf16"),
            FidelityConfig(2, 0.9, 1, "fp8"),
            FidelityConfig(2, 0.6, window_chunks, "bf16"),
            FidelityConfig(2, 0.0, 2, "bf16")]   # wraps the ring
    schedule = [([0, 1], fid) for fid in fids]
    _assert_same(_run_backend(cfg, p, "paged", schedule),
                 _run_backend(cfg, p, "gather", schedule))


@pytest.mark.slow
def test_paged_backend_join_leave_matches_gather():
    """Join/leave: stream 0 runs two chunks alone (heterogeneous fills),
    then stream 1 joins mid-session — the paged backend must stay on the
    gather backend's trajectory throughout."""
    cfg = tiny_cfg(window_chunks=3)
    p = nondegenerate_params(cfg, KEY)
    fid = FidelityConfig(2, 0.0, 2, "bf16")
    schedule = [([0], fid), ([0], fid), ([0, 1], fid)]
    _assert_same(_run_backend(cfg, p, "paged", schedule),
                 _run_backend(cfg, p, "gather", schedule))


# ---------------------------------------------------------------------------
# oversubscription conformance across backends
# ---------------------------------------------------------------------------

def _drive_round_robin(ex, sids, n_chunks, fid, streams=None):
    for _ in range(n_chunks):
        for sid in sids:
            if streams is not None:
                for s in sids:
                    streams[s].credit = float(len(ex.chunks[s]))
            assert ex.ensure_resident(sid, streams, protect=[sid])
            ex.begin_chunk(sid, fid, 0.0)
            while sid in ex.inflight:
                ex.run_step([sid])
    return {sid: [np.asarray(c) for c in ex.chunks[sid]] for sid in sids}


@pytest.mark.slow
def test_oversubscribed_paged_matches_unconstrained_gather():
    """2x pool capacity through the PAGED backend (page tables change on
    every spill/restore) completes with chunks numerically identical to
    an everyone-resident GATHER run — the acceptance bar combining both
    PR mechanisms."""
    from repro.core.types import Stream
    cfg = tiny_cfg(window_chunks=2)
    p = nondegenerate_params(cfg, KEY)
    fid = FidelityConfig(2, 0.0, 2, "bf16")
    sids = [0, 1, 2, 3]
    n_chunks = 2

    full = BatchedChunkExecutor(cfg=cfg, params=p, max_streams=4,
                                context_backend="gather")
    for sid in sids:
        assert full.admit(sid, seed=sid)
    want = _drive_round_robin(full, sids, n_chunks, fid)

    over = BatchedChunkExecutor(cfg=cfg, params=p, max_streams=2,
                                context_backend="paged")
    streams = {sid: Stream(sid=sid, arrival=0.0, target_chunks=n_chunks,
                           chunk_seconds=1.0, home=0, ttfc_slack=1e9)
               for sid in sids}
    admitted = [over.admit(sid, seed=sid) for sid in sids]
    assert admitted == [True, True, False, False]   # overflow defers
    got = _drive_round_robin(over, sids, n_chunks, fid, streams=streams)

    assert over.evictions > 0 and over.restores > 0
    # satellite: spill/restore went through the async transfer engine
    assert len(over.pool.engine.log) == over.evictions + over.restores
    assert over.pool.transfer_bytes > 0
    assert over.transfer_wait_s > 0.0
    _assert_same(got, want)
    over.pool.ledger.check()


# ---------------------------------------------------------------------------
# device-side page-table caching (per-step upload fix)
# ---------------------------------------------------------------------------

def test_device_tables_cached_and_invalidated():
    """``tables_for`` reuses one device array per residency epoch and
    rebuilds only after admit/evict/restore/retire change the table."""
    cfg = tiny_cfg(window_chunks=2)
    ex = BatchedChunkExecutor(cfg=cfg, max_streams=2)
    ex.admit(0, seed=0)
    t1 = ex.pool.device_table(0)
    assert ex.pool.device_table(0) is t1        # cached, no re-upload
    np.testing.assert_array_equal(np.asarray(t1),
                                  ex.pool.ledger.tables[0])
    ex.admit(1, seed=1)
    assert ex.pool.device_table(0) is t1        # untouched by others
    ex.pool.evict(0)
    assert 0 not in ex.pool._dev_tables         # invalidated
    ex.pool.restore(0)
    t2 = ex.pool.device_table(0)
    np.testing.assert_array_equal(np.asarray(t2),
                                  ex.pool.ledger.tables[0])
    ex.retire(0)
    assert 0 not in ex.pool._dev_tables
