"""SlackServe core: service credit, tiers, queues, re-homing, elastic
SP, BMPR — the paper's control mechanisms, unit-tested."""
import pytest

from repro.core import elastic_sp, queues, rehoming, slack
from repro.core.bmpr import (BMPR, FixedLevelSwitcher, StaticFidelity,
                             pareto_frontier)
from repro.core.fidelity import HIGHEST_QUALITY, candidate_space
from repro.core.types import ClusterView, Stream, Tier, Worker
from repro.profiler.profiles import get_profile


def mk_stream(sid, home=0, deadline=10.0, t_next=1.0, running=None,
              remaining=0.0, **kw):
    s = Stream(sid=sid, arrival=0.0, target_chunks=10, chunk_seconds=0.75,
               home=home, ttfc_slack=3.0, next_deadline=deadline, **kw)
    s.t_next = t_next
    s.running_on = running
    s.remaining = remaining
    return s


def mk_view(n_workers=4, per_node=2):
    return ClusterView({}, [Worker(w, node=w // per_node)
                            for w in range(n_workers)], per_node)


# ---------------------------------------------------------------------------
# Eq. 1 + tiers
# ---------------------------------------------------------------------------

def test_service_credit_formula():
    s = mk_stream(0, deadline=10.0, t_next=2.0)
    assert slack.service_credit(s, now=4.0) == pytest.approx(10 - 4 - 2)
    s.running_on = (0,)
    s.remaining = 1.5
    assert slack.service_credit(s, now=4.0) == pytest.approx(
        10 - 4 - (1.5 + 2.0))


def test_tier_thresholds_alpha():
    t = 1.0
    assert slack.classify(1.9, t, alpha=2.0) is Tier.URGENT
    assert slack.classify(2.0, t, alpha=2.0) is Tier.NORMAL
    assert slack.classify(4.0, t, alpha=2.0) is Tier.NORMAL
    assert slack.classify(4.1, t, alpha=2.0) is Tier.RELAXED
    # alpha sweep (Table 3): thresholds scale
    assert slack.classify(2.5, t, alpha=3.0) is Tier.URGENT
    assert slack.classify(6.5, t, alpha=3.0) is Tier.RELAXED


def test_paused_stream_not_dispatched_until_pause_end():
    """Regression: a prompt-switch-paused stream must be skipped while
    ``paused_until > now`` even though it still has chunks to generate
    (the old condition AND-ed the pause with being finished, which
    dispatched paused streams)."""
    view = mk_view()
    s = mk_stream(0, deadline=5.0)
    s.paused_until = 10.0                       # mid-pause, NOT finished
    assert s.chunks_done < s.target_chunks
    view.streams[0] = s
    view.workers[0].queue.append(0)
    assert queues.next_dispatch(view.workers[0], view.streams, now=3.0) \
        is None
    # pause elapsed: dispatchable again
    assert queues.next_dispatch(view.workers[0], view.streams,
                                now=10.0) == 0


def test_next_dispatch_set_credit_order_and_cap():
    """The batched executor's runnable set: credit order preserved,
    paused/finished skipped, max_batch respected."""
    view = mk_view()
    for i, ddl in enumerate([5.0, 2.0, 9.0, 7.0]):
        s = mk_stream(i, deadline=ddl)
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
        view.workers[0].queue.append(i)
    view.streams[3].paused_until = 100.0
    view.streams[2].chunks_done = view.streams[2].target_chunks  # finished
    queues.order_all(view)
    w = view.workers[0]
    assert queues.next_dispatch_set(w, view.streams, now=0.0) == [1, 0]
    assert queues.next_dispatch_set(w, view.streams, now=0.0,
                                    max_batch=1) == [1]
    assert queues.next_dispatch(w, view.streams, now=0.0) == 1


def test_queue_order_and_eviction():
    view = mk_view()
    for i, ddl in enumerate([5.0, 2.0, 9.0]):
        s = mk_stream(i, deadline=ddl)
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
        view.workers[0].queue.append(i)
    queues.order_all(view)
    assert view.workers[0].queue == [1, 0, 2]      # lowest credit first
    # credit-aware eviction evicts the HIGHEST credit (least likely stall)
    victim = queues.pick_eviction([0, 1, 2], view.streams)
    assert victim == 2
    assert queues.pick_eviction([0, 1, 2], view.streams, protect=2) == 0


def test_next_dispatch_set_empty_and_all_paused():
    """Edge cases of the runnable set: an empty queue and a queue of
    only-paused streams both yield an empty dispatch set (the batched
    executor must idle, not crash)."""
    view = mk_view()
    w = view.workers[0]
    assert queues.next_dispatch_set(w, view.streams, now=0.0) == []
    assert queues.next_dispatch(w, view.streams, now=0.0) is None
    for i in range(3):
        s = mk_stream(i)
        s.paused_until = 99.0
        view.streams[i] = s
        w.queue.append(i)
    assert queues.next_dispatch_set(w, view.streams, now=0.0) == []
    assert queues.next_dispatch(w, view.streams, now=0.0) is None
    # pause elapsed: all runnable again
    assert len(queues.next_dispatch_set(w, view.streams, now=100.0)) == 3


def test_pick_eviction_protect_and_empty():
    """``protect`` being the only resident -> no victim; empty resident
    set -> no victim; protect accepts an iterable (the batched
    executor shields the whole in-flight set)."""
    view = mk_view()
    for i, ddl in enumerate([5.0, 2.0, 9.0]):
        s = mk_stream(i, deadline=ddl)
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
    assert queues.pick_eviction([], view.streams) is None
    assert queues.pick_eviction([2], view.streams, protect=2) is None
    assert queues.pick_eviction([0, 1, 2], view.streams,
                                protect={2, 0}) == 1
    assert queues.pick_eviction([0, 1, 2], view.streams,
                                protect=[0, 1, 2]) is None


def test_pick_eviction_credit_tie_break_is_deterministic():
    """Equal credits: the LOWEST sid is evicted — pinned so replayed
    schedules evict identically."""
    view = mk_view()
    for i in range(4):
        s = mk_stream(i, deadline=7.0)         # identical credit inputs
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
    credits = {view.streams[i].credit for i in range(4)}
    assert len(credits) == 1                   # genuine tie
    assert queues.pick_eviction([0, 1, 2, 3], view.streams) == 0
    assert queues.pick_eviction([3, 1, 2], view.streams) == 1
    assert queues.pick_eviction([0, 1, 2, 3], view.streams,
                                protect=0) == 1


# ---------------------------------------------------------------------------
# Algorithm 1: re-homing
# ---------------------------------------------------------------------------

def _loaded_view():
    view = mk_view(4, per_node=2)
    # w0: two queued URGENT; w1: RELAXED only; w2 (other node): empty
    for i, (home, ddl) in enumerate([(0, 1.0), (0, 1.2), (1, 30.0)]):
        s = mk_stream(i, home=home, deadline=ddl)
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
        view.workers[home].queue.append(i)
    return view


def test_rehoming_moves_urgent_to_relaxed_intranode_first():
    view = _loaded_view()
    plan = rehoming.plan_rehoming(view, now=0.0)
    assert plan, "should migrate"
    assert all(m.src == 0 for m in plan)
    # intra-node receiver (w1, same node as w0) preferred over w2/w3
    assert plan[0].dst == 1
    assert not plan[0].cross_node
    # lowest-credit urgent stream moves first
    assert plan[0].sid == 0


def test_rehoming_caps_and_cooldown():
    view = mk_view(4, per_node=2)
    for i in range(5):                  # five urgent on w0
        s = mk_stream(i, home=0, deadline=1.0 + 0.01 * i)
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
        view.workers[0].queue.append(i)
    plan = rehoming.plan_rehoming(view, now=0.0)
    assert len(plan) <= rehoming.CAP_SEND        # send cap = 2
    per_dst = {}
    for m in plan:
        per_dst[m.dst] = per_dst.get(m.dst, 0) + 1
    assert all(v <= rehoming.CAP_RECV for v in per_dst.values())
    # migrated streams are in cooldown: immediate replan moves OTHERS
    plan2 = rehoming.plan_rehoming(view, now=1.0)
    assert not ({m.sid for m in plan} & {m.sid for m in plan2})
    # after the cooldown they are eligible again
    for s in view.streams.values():
        s.next_deadline = 1.0 + 61.0             # still urgent later
        slack.update_stream_credit(s, now=61.0)
    plan3 = rehoming.plan_rehoming(view, now=100.0)
    assert plan3


def test_rehoming_no_receivers_under_global_pressure():
    view = mk_view(2, per_node=2)
    for i in range(4):
        s = mk_stream(i, home=i % 2, deadline=0.5)
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
        view.workers[i % 2].queue.append(i)
    assert rehoming.plan_rehoming(view, now=0.0) == []


def test_rehoming_receiver_excludes_sp_donor():
    """Regression: a worker serving someone else's SP2 half looks
    'relaxed' to its own tier counts (the borrowed stream is homed
    elsewhere), but it is NOT slack headroom — migrations must not
    land on it."""
    view = mk_view(2, per_node=2)
    for i in range(2):                         # two queued URGENT on w0
        s = mk_stream(i, home=0, deadline=1.0 + 0.01 * i)
        slack.update_stream_credit(s, now=0.0)
        view.streams[i] = s
        view.workers[0].queue.append(i)
    view.workers[1].donated_to = 99            # empty queue, but donating
    assert rehoming.plan_rehoming(view, now=0.0) == []
    # donation released: w1 is genuine headroom again
    view.workers[1].donated_to = None
    plan = rehoming.plan_rehoming(view, now=0.0)
    assert plan and plan[0].dst == 1


def test_choose_home_skips_sp_donor():
    """Regression: admission must not home a new stream on a donating
    worker — its donated compute is invisible to its own queue."""
    from repro.core.control_plane import ControlPlane
    cp = ControlPlane()
    view = mk_view(2, per_node=2)
    for i in range(2):                         # w0 carries two streams
        s = mk_stream(i, home=0)
        view.streams[i] = s
        view.workers[0].queue.append(i)
    view.workers[1].donated_to = 99            # "empty" but donating
    assert cp.choose_home(view) == 0
    view.workers[1].donated_to = None
    assert cp.choose_home(view) == 1
    # a donating worker also counts its donation as load
    view.workers[1].donated_to = 99
    assert view.workers[1].load() == 1


# ---------------------------------------------------------------------------
# SS4.3: elastic SP
# ---------------------------------------------------------------------------

def test_elastic_sp_trigger_and_donor_selection():
    view = mk_view(4, per_node=2)
    s0 = mk_stream(0, home=0, deadline=-1.0)       # projected miss: C<0
    r1 = mk_stream(1, home=1, deadline=50.0)       # relaxed on w1
    r3 = mk_stream(2, home=3, deadline=90.0)       # relaxed, OTHER node
    for s in (s0, r1, r3):
        slack.update_stream_credit(s, now=0.0)
        view.streams[s.sid] = s
        view.workers[s.home].queue.append(s.sid)
    decs = elastic_sp.plan_elastic_sp(view, now=0.0)
    expands = [d for d in decs if d.kind == "expand"]
    assert len(expands) == 1 and expands[0].sid == 0
    assert expands[0].donor == 1                   # same-node donor only


def test_elastic_sp_release_at_normal():
    view = mk_view(2, per_node=2)
    s = mk_stream(0, home=0, deadline=50.0)        # recovered
    s.sp_donor = 1
    view.workers[1].donated_to = 0
    slack.update_stream_credit(s, now=0.0)
    view.streams[0] = s
    decs = elastic_sp.plan_elastic_sp(view, now=0.0)
    assert any(d.kind == "release" and d.sid == 0 for d in decs)


def test_elastic_sp_exclude_just_migrated():
    view = mk_view(4, per_node=2)
    s0 = mk_stream(0, home=0, deadline=-1.0)
    r1 = mk_stream(1, home=1, deadline=50.0)
    for s in (s0, r1):
        slack.update_stream_credit(s, now=0.0)
        view.streams[s.sid] = s
        view.workers[s.home].queue.append(s.sid)
    decs = elastic_sp.plan_elastic_sp(view, now=0.0, exclude={0})
    assert not [d for d in decs if d.kind == "expand"]


def test_elastic_sp_no_release_without_latency_estimate():
    """Regression: the release check compared credit against
    RELEASE_FACTOR * t_next with t_next still its 0.0 default (e.g.
    use_fidelity=False, or before the first selection), so a donor was
    released on the very tick it was borrowed."""
    view = mk_view(2, per_node=2)
    s = mk_stream(0, home=0, deadline=5.0, t_next=0.0)   # no estimate yet
    s.sp_donor = 1
    view.workers[1].donated_to = 0
    slack.update_stream_credit(s, now=0.0)
    assert s.credit >= 0.0                     # would trip credit >= 0
    view.streams[0] = s
    decs = elastic_sp.plan_elastic_sp(view, now=0.0)
    assert not [d for d in decs if d.kind == "release"]
    # with a real estimate and recovered credit the release DOES fire
    s.t_next = 1.0
    slack.update_stream_credit(s, now=0.0)
    decs = elastic_sp.plan_elastic_sp(view, now=0.0)
    assert [d for d in decs if d.kind == "release"]


def test_elastic_sp_released_donor_rejoins_same_tick():
    """Regression: a donor released this tick was stranded until the
    next one — it must be eligible to serve a C<0 stream in the SAME
    plan (releases are planned first, applied first)."""
    view = mk_view(2, per_node=2)
    rec = mk_stream(0, home=0, deadline=50.0)  # recovered: releases w1
    rec.sp_donor = 1
    view.workers[1].donated_to = 0
    beh = mk_stream(1, home=0, deadline=-1.0)  # projected miss: C<0
    for s in (rec, beh):
        slack.update_stream_credit(s, now=0.0)
        view.streams[s.sid] = s
        view.workers[0].queue.append(s.sid)
    decs = elastic_sp.plan_elastic_sp(view, now=0.0)
    kinds = [(d.kind, d.sid, d.donor) for d in decs]
    assert ("release", 0, 1) in kinds
    assert ("expand", 1, 1) in kinds           # the freed donor, reused
    # release precedes expand, so applying in order is consistent
    assert kinds.index(("release", 0, 1)) < kinds.index(("expand", 1, 1))


def test_control_tick_migration_excluded_from_same_tick_sp():
    """A stream helped by re-homing this tick must not ALSO borrow an
    SP donor (SS4: elastic SP is the next line of defense, not a
    parallel one) — pinned through ControlPlane.tick's exclude= path."""
    from repro.core.control_plane import ControlConfig, ControlPlane
    cp = ControlPlane(ControlConfig(use_fidelity=False))
    view = mk_view(4, per_node=4)
    urgent = mk_stream(0, home=0, deadline=-1.0, t_next=1.0)
    waiting = mk_stream(1, home=0, deadline=-0.5, t_next=1.0)
    relaxed = mk_stream(2, home=1, deadline=90.0, t_next=1.0)
    for s in (urgent, waiting, relaxed):
        slack.update_stream_credit(s, now=0.0)
        view.streams[s.sid] = s
        view.workers[s.home].queue.append(s.sid)
    decs = cp.tick(view, now=0.0)
    migrated = {m.sid for m in decs.migrations}
    assert migrated                            # the C<0 stream moved
    for d in decs.sp_decisions:
        assert not (d.kind == "expand" and d.sid in migrated), \
            "stream got re-homing AND elastic SP in one tick"


# ---------------------------------------------------------------------------
# SS5: BMPR
# ---------------------------------------------------------------------------

def test_pareto_frontier_nondominated_sorted():
    prof = get_profile()
    f = pareto_frontier(prof)
    pts = f.points
    assert len(pts) >= 5
    for i in range(len(pts) - 1):
        assert pts[i].latency < pts[i + 1].latency
        assert pts[i].quality < pts[i + 1].quality
    # every candidate is dominated by or equal to some frontier point
    for p in prof.points:
        assert any(q.latency <= p.latency and q.quality >= p.quality
                   for q in pts)


def test_bmpr_quality_mode_picks_best_within_budget():
    b = BMPR(get_profile())
    d = b.select(10.0)
    assert d.mode == "quality"
    assert d.fidelity == HIGHEST_QUALITY


def test_bmpr_speed_recovery_respects_floor():
    b = BMPR(get_profile())
    d = b.select(0.0)                   # impossible budget
    assert d.mode == "speed-recovery"
    assert d.quality >= b.frontier.q_floor
    # NOT simply the globally fastest config (which is below the floor)
    fastest = min(b.profile.points, key=lambda p: p.latency)
    assert d.latency > fastest.latency
    assert fastest.quality < b.frontier.q_floor


def test_bmpr_monotone_quality_in_budget():
    b = BMPR(get_profile())
    quals = [b.select(x).quality for x in (0.25, 0.4, 0.6, 0.9)]
    assert quals == sorted(quals)


def test_fixed_level_switcher_three_levels():
    f = FixedLevelSwitcher(get_profile())
    assert f.select(10.0).mode == "slow"
    assert f.select(0.05).mode == "fast"


def test_static_policy_constant():
    p = StaticFidelity()
    assert p.select(0.01).fidelity == p.select(10.0).fidelity


def test_fidelity_space_is_90():
    assert len(candidate_space()) == 90
