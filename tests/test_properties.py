"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import slack
from repro.core.bmpr import BMPR
from repro.core.types import Stream, Tier
from repro.kernels.fp8_matmul.ref import quantize_fp8_ref
from repro.kernels.ssd_scan.ref import ssd_decode_ref, ssd_ref
from repro.models import kvcache
from repro.profiler.profiles import get_profile
from repro.sched_sim.workloads import (WORKLOADS, burst, diurnal,
                                       flash_crowd, pause, prompt_switch,
                                       steady)

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# workload generators: determinism + shape invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(name=st.sampled_from(sorted(WORKLOADS)), n=st.integers(10, 120),
       rate=st.floats(0.5, 10.0), seed=st.integers(0, 99))
def test_workloads_deterministic_and_well_formed(name, n, rate, seed):
    a = WORKLOADS[name](n=n, rate=rate, seed=seed)
    b = WORKLOADS[name](n=n, rate=rate, seed=seed)
    assert a == b                                   # same seed, same specs
    assert len(a) == n
    assert [s.sid for s in a] == list(range(n))
    assert all(s.arrival >= 0.0 and s.chunks > 0 for s in a)


@settings(**SETTINGS)
@given(n=st.integers(20, 200), seed=st.integers(0, 99))
def test_burst_reassigns_exactly_three_tenths(n, seed):
    base = steady(n=n, seed=seed)
    specs = burst(n=n, seed=seed)
    n_b = n // 10
    moved = sum(1 for s, b in zip(specs, base) if s.arrival != b.arrival)
    # 3 burst points x n//10 reassignments (a reassigned stream keeps
    # its frames; a draw may land on its own arrival, hence <=)
    assert moved <= 3 * n_b
    from collections import Counter
    c = Counter(s.arrival for s in specs)
    assert sum(1 for v in c.values() if v >= n_b) >= 3
    assert [s.frames for s in specs] == [s.frames for s in base]


@settings(**SETTINGS)
@given(n=st.integers(5, 60), seed=st.integers(0, 99))
def test_switch_and_pause_events_inside_duration(n, seed):
    for s in prompt_switch(n=n, seed=seed):
        assert all(0.0 < t < s.duration for t in s.switches)
    for s in pause(n=n, seed=seed):
        for start, dur in s.pauses:
            assert 0.0 < start < s.duration
            assert dur == pytest.approx(0.2 * s.duration)


@settings(**SETTINGS)
@given(n=st.integers(100, 400), seed=st.integers(0, 99),
       period_frac=st.floats(0.05, 0.2))
def test_diurnal_peaks_at_mid_period(n, seed, period_frac):
    # period sized so the trace spans >= ~2 cycles (expected span is
    # ~n / (rate * mean lambda) = n / 2.4 at rate 4): a sub-cycle trace
    # sees only the leading trough and the invariant is vacuous
    period = n * period_frac
    specs = diurnal(n=n, rate=4.0, seed=seed, period=period)
    mid = edge = 0
    for s in specs:
        phase = (s.arrival % period) / period
        if 0.3 <= phase <= 0.7:
            mid += 1
        elif phase <= 0.1 or phase >= 0.9:
            edge += 1
    # the sinusoidal NHPP concentrates arrivals at mid-period: the
    # 40%-wide crest band must out-draw the 20%-wide trough band
    assert mid > edge


@settings(**SETTINGS)
@given(n=st.integers(50, 300), seed=st.integers(0, 99),
       spike_frac=st.floats(0.1, 0.5), width=st.floats(0.5, 4.0))
def test_flash_crowd_spike_mass(n, seed, spike_frac, width):
    specs = flash_crowd(n=n, rate=2.0, seed=seed,
                        spike_frac=spike_frac, spike_width=width)
    arrivals = sorted(s.arrival for s in specs)
    n_spike = int(spike_frac * n)
    # some width-window must hold at least the injected spike mass
    best = max(sum(1 for a in arrivals if t <= a <= t + width + 1e-9)
               for t in arrivals)
    assert best >= n_spike


# ---------------------------------------------------------------------------
# SSD: chunked == sequential for arbitrary shapes/chunks
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(s=st.integers(3, 40), h=st.integers(1, 3), p=st.integers(1, 8),
       n=st.integers(1, 8), chunk=st.integers(2, 16), seed=st.integers(0, 99))
def test_ssd_chunked_equals_sequential(s, h, p, n, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (1, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (1, s, 1, n))
    Cm = jax.random.normal(ks[4], (1, s, 1, n))
    y_c, f_c = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    state = jnp.zeros((1, h, p, n))
    ys = []
    for t in range(s):
        y, state = ssd_decode_ref(x[:, t], dt[:, t], A, Bm[:, t],
                                  Cm[:, t], state)
        ys.append(y)
    np.testing.assert_allclose(y_c, jnp.stack(ys, 1), rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(f_c, state, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# ring cache: ring_dest/place_prefill consistency
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(s=st.integers(1, 60), sink=st.integers(0, 8),
       window=st.integers(2, 20))
def test_ring_cache_holds_exactly_window_and_sink(s, sink, window):
    cap = kvcache.capacity(s, window, sink)
    assert cap <= s and cap <= sink + window
    # simulate writes token by token; cache must end holding the sink
    # tokens plus the last min(window, s - sink) tokens
    slots = -np.ones(cap, np.int64)
    for pos in range(s):
        d = int(kvcache.ring_dest(jnp.asarray(pos), cap, sink))
        assert 0 <= d < cap
        slots[d] = pos
    expected = set(range(min(sink, s)))
    ring = cap - sink
    expected |= set(range(max(min(sink, s), s - ring), s))
    assert set(slots[slots >= 0].tolist()) == expected

    # place_prefill puts the same tokens in the same slots
    k = jnp.arange(1, s + 1, dtype=jnp.float32).reshape(1, s, 1, 1)
    placed = np.asarray(kvcache.place_prefill(k, cap, sink, window))[0, :, 0, 0]
    for slot in range(cap):
        if slots[slot] >= 0:
            assert placed[slot] == slots[slot] + 1


# ---------------------------------------------------------------------------
# fp8 quantization error bound
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=st.integers(1, 16), k=st.integers(1, 64), seed=st.integers(0, 99),
       scale=st.floats(1e-3, 1e3))
def test_fp8_quant_relative_error(m, k, seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k)) * scale
    q, s = quantize_fp8_ref(x, axis=1)
    deq = q.astype(jnp.float32) * s
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    err = jnp.abs(deq - x)
    # e4m3 has >= 2 mantissa bits near amax: error <= amax/8 everywhere
    assert bool(jnp.all(err <= amax / 8.0 + 1e-9))


# ---------------------------------------------------------------------------
# service credit / tiers
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(ddl=st.floats(-10, 100), now=st.floats(0, 100),
       t_next=st.floats(0.01, 5), rem=st.floats(0, 5),
       running=st.booleans())
def test_service_credit_definition(ddl, now, t_next, rem, running):
    s = Stream(sid=0, arrival=0.0, target_chunks=1, chunk_seconds=0.75,
               home=0, ttfc_slack=1.0, next_deadline=ddl)
    s.t_next = t_next
    s.remaining = rem
    s.running_on = (0,) if running else None
    c = slack.service_credit(s, now)
    expected = (ddl - now) - ((rem if running else 0.0) + t_next)
    assert c == np.float64(expected)
    tier = slack.classify(c, t_next)
    if c < 2 * t_next:
        assert tier is Tier.URGENT
    elif c > 4 * t_next:
        assert tier is Tier.RELAXED
    else:
        assert tier is Tier.NORMAL


# ---------------------------------------------------------------------------
# BMPR: selection is Pareto-consistent for any budget
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(budget=st.floats(0.0, 3.0))
def test_bmpr_selection_invariants(budget):
    b = BMPR(get_profile())
    d = b.select(budget)
    assert d.quality >= b.frontier.q_floor
    if d.mode == "quality":
        assert d.latency <= budget
        # no frontier point within budget+floor has higher quality
        for p in b.frontier.points:
            if p.latency <= budget and p.quality >= b.frontier.q_floor:
                assert d.quality >= p.quality
    else:
        # infeasible budget: minimal latency above the floor
        for p in b.frontier.points:
            if p.quality >= b.frontier.q_floor:
                assert d.latency <= p.latency


# ---------------------------------------------------------------------------
# online-softmax merge is order-robust (flash attention foundation)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(seed=st.integers(0, 99), n_seg=st.integers(2, 5))
def test_online_softmax_merge_associativity(seed, n_seg):
    from repro.models.attention import (_init_acc, _merge, _segment_attn,
                                        _finalize)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, H, G, Q, D, S = 1, 1, 2, 4, 8, 8 * n_seg
    q = jax.random.normal(ks[0], (B, Q, H, G, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    full = _finalize(_merge(_init_acc(B, H, G, Q, D),
                            _segment_attn(q, k, v, None, 1.0)), jnp.float32)
    acc = _init_acc(B, H, G, Q, D)
    for i in range(n_seg):
        seg = slice(i * 8, (i + 1) * 8)
        acc = _merge(acc, _segment_attn(q, k[:, seg], v[:, seg], None, 1.0))
    np.testing.assert_allclose(_finalize(acc, jnp.float32), full,
                               rtol=1e-5, atol=1e-5)
