"""Attention-substrate semantics: block schedules, knobs, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (decode_attention, mha,
                                    sparse_keep_list)

pytestmark = pytest.mark.slow     # JAX-compiling attention sweeps: slow tier

KEY = jax.random.PRNGKey(1)


def naive_mha(q, k, v, n_kv, causal=True, q_offset=0, window=0, sink=0):
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    g = hq // n_kv
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(d)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= (k_pos[None, :] > q_pos[:, None] - window) | \
                    (k_pos[None, :] < sink)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("blocks", [(16, 16), (32, 64), (512, 512)])
def test_blocked_equals_naive_causal(blocks):
    bq, bkv = blocks
    q = jax.random.normal(KEY, (2, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 2, 16))
    out = mha(q, k, v, n_kv_heads=2, block_q=bq, block_kv=bkv)
    ref = naive_mha(q, k, v, 2)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window,sink", [(24, 8), (16, 0), (100, 4)])
def test_windowed_equals_naive(window, sink):
    q = jax.random.normal(KEY, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 1, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 1, 16))
    out = mha(q, k, v, n_kv_heads=1, window=window, sink=sink,
              block_q=16, block_kv=16)
    ref = naive_mha(q, k, v, 1, window=window, sink=sink)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_window_sink_overlap_regression():
    """Regression: rounding the window start below the sink must not
    double-count sink tokens (fixed in the blocked windowed path)."""
    q = jax.random.normal(KEY, (1, 128, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 1, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 1, 16))
    out = mha(q, k, v, n_kv_heads=1, window=48, sink=16,
              block_q=32, block_kv=32)
    ref = naive_mha(q, k, v, 1, window=48, sink=16)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_chunk_offset_cross_kv():
    """AR-DiT pattern: q for a chunk at offset, longer KV."""
    q = jax.random.normal(KEY, (1, 32, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 96, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 96, 4, 16))
    out = mha(q, k, v, n_kv_heads=4, q_offset=64)
    ref = naive_mha(q, k, v, 4, q_offset=64)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_decode_matches_full_attention():
    S = 17
    q_all = jax.random.normal(KEY, (2, S, 4, 8))
    k_all = jax.random.normal(jax.random.PRNGKey(2), (2, S, 2, 8))
    v_all = jax.random.normal(jax.random.PRNGKey(3), (2, S, 2, 8))
    full = naive_mha(q_all, k_all, v_all, 2)
    cache_k = jnp.pad(k_all, ((0, 0), (0, 7), (0, 0), (0, 0)))
    cache_v = jnp.pad(v_all, ((0, 0), (0, 7), (0, 0), (0, 0)))
    out = decode_attention(q_all[:, -1:], cache_k, cache_v, n_kv_heads=2,
                           cache_len=jnp.full((2,), S, jnp.int32))
    np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=2e-4,
                               atol=2e-4)


def test_decode_windowed_mask():
    S, W, SK = 20, 6, 2
    q = jax.random.normal(KEY, (1, 1, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 2, 8))
    out = decode_attention(q, k, v, n_kv_heads=2,
                           cache_len=jnp.array([S]), window=W, sink=SK)
    # manual: valid = pos < S and (pos > S-1-W or pos < SK)
    kk, vv = k[:, :S], v[:, :S]
    pos = jnp.arange(S)
    valid = (pos > S - 1 - W) | (pos < SK)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk[:, :, :, :]) / np.sqrt(8)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_sparse_keep_list_invariants():
    for n_kv in (1, 3, 10, 40):
        for rho in (0.0, 0.5, 0.9):
            keep = sparse_keep_list(1, [n_kv], rho)[0]
            assert 0 in keep                 # sink block always kept
            assert (n_kv - 1) in keep        # diagonal always kept
            assert keep == sorted(set(keep))
    # higher sparsity keeps fewer blocks
    k_lo = len(sparse_keep_list(1, [32], 0.3)[0])
    k_hi = len(sparse_keep_list(1, [32], 0.9)[0])
    assert k_hi < k_lo


def test_sparsity_reduces_to_dense_at_zero():
    q = jax.random.normal(KEY, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 2, 16))
    a = mha(q, k, v, n_kv_heads=2, sparsity=0.0, block_q=16, block_kv=16)
    b = mha(q, k, v, n_kv_heads=2, block_q=16, block_kv=16)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
