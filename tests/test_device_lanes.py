"""Device-backed lanes: transfer attribution, measured-move
calibration, batch-axis SP parity, warm-up hygiene, t_next units, and
the forced-device-count matrix (subprocess: the XLA device-count flag
must precede JAX initialization).

In-process tests run on the default single-device runtime — they pin
the accounting and the batch-axis SP *mechanism* (forced via
``sp_mode="batch"`` on one device, where solo SP is also available for
comparison); the subprocess harness re-runs migration/SP parity on
real forced device meshes (2 fast, 4 slow)."""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.fidelity import FidelityConfig
from repro.core.state_plane import AsyncTransferEngine
from repro.core.types import Stream
from repro.serve.lanes import LanePool

FID = FidelityConfig(2, 0.0, 2, "bf16")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def tiny_cfg(window_chunks=2):
    return dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=window_chunks)


def gen_chunks(ex, sid, n=1, fid=FID, sp=False):
    out = []
    for _ in range(n):
        ex.begin_chunk(sid, fid, 0.0)
        while sid in ex.inflight:
            ex.run_step([sid], sp_serve=sp)
        out.append(np.asarray(ex.chunks[sid][-1]))
    return out


# ---------------------------------------------------------------------------
# satellite: SP-expand transfer attribution (fail-pre-fix)
# ---------------------------------------------------------------------------

def test_sp_expand_bytes_attributed_src_out_dst_in():
    """Regression: ``sp_expand`` charged the mirror copy's bytes to the
    HOME pool's aggregate although the pages land in the DONOR pool —
    per-lane benchmark rows showed the donor receiving nothing.  The
    bytes must appear as home ``out`` and donor ``in``, once each."""
    lanes = LanePool(2, cfg=tiny_cfg(), max_streams=3)
    lanes.admit(0, 0, seed=0)
    gen_chunks(lanes.ex(0), 0, 1)
    home_pool, donor_pool = lanes.ex(0).pool, lanes.ex(1).pool
    assert home_pool.transfer_bytes == 0 == donor_pool.transfer_bytes
    assert lanes.sp_expand(0, 1)
    assert home_pool.transfer_bytes_out > 0
    assert donor_pool.transfer_bytes_in == home_pool.transfer_bytes_out, \
        "mirror bytes must land on the DONOR lane's inbound counter"
    assert home_pool.transfer_bytes_in == 0
    assert donor_pool.transfer_bytes_out == 0


def test_spill_restore_split_by_direction():
    """The back-compat aggregate is the sum of the new directional
    counters: a spill charges ``out``, its restore charges ``in``."""
    from repro.core.types import Stream as S
    lanes = LanePool(1, cfg=tiny_cfg(), max_streams=1)
    ex = lanes.ex(0)
    streams = {}
    for sid in (0, 1):
        s = S(sid=sid, arrival=0.0, target_chunks=4, chunk_seconds=1.0,
              home=0, ttfc_slack=1.0)
        s.credit = float(sid)
        streams[sid] = s
        ex.admit(sid, seed=sid, streams=streams)
    # admitting 1 evicted 0 (pool holds one stream)
    assert ex.pool.transfer_bytes_out > 0
    out_before = ex.pool.transfer_bytes_out
    assert ex.ensure_resident(0, streams)
    assert ex.pool.transfer_bytes_in > 0
    assert ex.pool.transfer_bytes == \
        ex.pool.transfer_bytes_in + ex.pool.transfer_bytes_out
    assert ex.pool.transfer_bytes_out > out_before    # 1 spilled out


# ---------------------------------------------------------------------------
# satellite: measured transfers calibrate the bandwidth model
# ---------------------------------------------------------------------------

def test_measured_moves_calibrate_bw_intra():
    eng = AsyncTransferEngine(bw_intra=200e9, n_layers=2)
    assert eng.measured_stats()["count"] == 0
    eng.record_measured(1000, 1e-6, kind="migration")   # 1e9 B/s
    # first observation replaces the offline constant
    assert eng.bw_intra == pytest.approx(1e9)
    assert eng.bw_intra_model == 200e9                  # model kept
    eng.record_measured(3000, 1e-6, kind="sp-expand")   # 3e9 B/s
    # EMA blend thereafter
    assert eng.bw_intra == pytest.approx(0.5 * 1e9 + 0.5 * 3e9)
    st = eng.measured_stats()
    assert st["count"] == 2 and st["bytes"] == 4000
    assert st["bytes_per_s"] == pytest.approx(4000 / 2e-6)
    # the modeled timeline now uses the calibrated value
    t = eng.transfer(0.0, 2_000_000, cross_node=False)
    assert t.total == pytest.approx(eng.overhead + 2_000_000 / eng.bw_intra)
    # opting out keeps the constants fixed
    frozen = AsyncTransferEngine(bw_intra=200e9, calibrate=False)
    frozen.record_measured(1000, 1e-6)
    assert frozen.bw_intra == 200e9
    assert len(frozen.measured) == 1


# ---------------------------------------------------------------------------
# satellite: t_next units audit (fail-pre-fix)
# ---------------------------------------------------------------------------

def test_t_next_is_a_validated_duration():
    """Regression: ``Stream.t_next`` silently accepted any float, so a
    caller storing an absolute completion time (or garbage) flew under
    the PR 5 ``t_next > 0`` release guard unnoticed.  The property now
    rejects values that cannot be a latency (negative / non-finite) —
    both writers (session ``_begin_if_needed`` and the simulator cost
    path) go through it."""
    s = Stream(sid=0, arrival=0.0, target_chunks=4, chunk_seconds=1.0,
               home=0, ttfc_slack=1.0)
    assert s.t_next == 0.0                 # "no estimate yet" default
    s.t_next = 0.25                        # a real T_u duration
    assert s.t_next == 0.25
    for bogus in (-0.1, float("inf"), float("nan"), -1e9):
        with pytest.raises(ValueError):
            s.t_next = bogus
    assert s.t_next == 0.25                # rejected writes don't stick


def test_t_next_guard_semantics_in_release_plan():
    """The release guard compares two DURATIONS: credit >= 2 * T_u.
    With t_next validated, an absolute-timestamp-sized value can only
    enter deliberately — and the guard math stays meaningful."""
    from repro.core import elastic_sp
    from repro.core.types import ClusterView, Worker
    s = Stream(sid=0, arrival=0.0, target_chunks=8, chunk_seconds=1.0,
               home=0, ttfc_slack=1.0)
    s.sp_donor = 1
    view = ClusterView({0: s}, [Worker(0, 0), Worker(1, 0)],
                       workers_per_node=2)
    view.workers[1].donated_to = 0
    s.t_next = 0.0                         # no estimate: guard must hold
    s.credit = 100.0
    assert not any(d.kind == "release"
                   for d in elastic_sp.plan_elastic_sp(view, 0.0))
    s.t_next = 0.5                         # T_u duration; C_u >= 2*T_u
    assert any(d.kind == "release"
               for d in elastic_sp.plan_elastic_sp(view, 0.0))


# ---------------------------------------------------------------------------
# satellite: warm-up calibration stream leaves no residue
# ---------------------------------------------------------------------------

def test_warmup_calibration_stream_fully_purged():
    """The sid -1 calibration chunk ran on lane 0 only; after
    ``retire(-1, drop_history=True)`` no per-stream state may survive
    and lane 0's priors must equal every other lane's — lane 0 starts
    bit-identical to its peers."""
    from repro.core.bmpr import StaticFidelity
    from repro.serve.session import SessionConfig, StreamingSession
    sess = StreamingSession(
        SessionConfig(lanes=2, model_cfg=tiny_cfg(), pool_streams=3,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    ex0, ex1 = sess.lanes.ex(0), sess.lanes.ex(1)
    for ex in (ex0, ex1):
        assert -1 not in ex.chunks, "generated chunks leaked"
        assert -1 not in ex.fidelity_log, "fidelity history leaked"
        assert -1 not in ex.chunk_seq
        assert -1 not in ex.inflight
        assert -1 not in ex._pending_wait
        assert -1 not in ex.pool.ledger.tables, "page table leaked"
        assert -1 not in ex.pool.ledger.chunks, "ledger count leaked"
        assert -1 not in ex.pool.ledger.spilled
        assert -1 not in ex.pool._dev_tables, "device table leaked"
        assert -1 not in ex.pool._spill
        ex.pool.ledger.check()
    # all pages back in the free list on the calibration lane
    assert ex0.pool.free_pages == ex0.pool.n_pages
    # priors symmetric: the one measured warm-up seeds EVERY lane
    assert ex0.latency_ema == ex1.latency_ema
    assert ex0.step_ema == ex1.step_ema
    assert sess.top_latency > 0.0


def test_sequential_warmup_purged():
    from repro.core.bmpr import StaticFidelity
    from repro.serve.session import SessionConfig, StreamingSession
    sess = StreamingSession(
        SessionConfig(executor="sequential", verbose=False),
        fidelity_policy=StaticFidelity(FID))
    ex = sess.executor
    assert -1 not in ex.streams and -1 not in ex.chunks
    assert -1 not in ex.fidelity_log and -1 not in ex.inflight
    assert sess.top_latency > 0.0


# ---------------------------------------------------------------------------
# batch-axis SP on one device (forced): parity + co-serve semantics
# ---------------------------------------------------------------------------

def test_batch_axis_sp_equals_solo_sp_and_sp1():
    """Forced ``sp_mode="batch"`` on one device: the borrowed stream is
    co-served as a donor batch row and its chunks are bit-identical to
    both the solo head-split path and plain SP1 — through expand,
    appends under SP, and release (home pool stays system of record)."""
    cfg = tiny_cfg()
    ref_ex = LanePool(1, cfg=cfg, max_streams=3).ex(0)
    ref_ex.admit(0, seed=0)
    ref = gen_chunks(ref_ex, 0, 4)                      # SP1 reference

    solo = LanePool(2, cfg=cfg, params=ref_ex.params, max_streams=3)
    solo.admit(0, 0, seed=0)
    got_solo = gen_chunks(solo.ex(0), 0, 1)
    assert solo.sp_expand(0, 1)
    assert solo.sp_link(0).mode == "solo"               # default on 1 dev
    got_solo += gen_chunks(solo.ex(0), 0, 2, sp=True)
    solo.sp_release(0)
    got_solo += gen_chunks(solo.ex(0), 0, 1)

    batch = LanePool(2, cfg=cfg, params=ref_ex.params, max_streams=3,
                     sp_mode="batch")
    batch.admit(0, 0, seed=0)
    batch.admit(9, 1, seed=9)                           # donor's own work
    got_batch = gen_chunks(batch.ex(0), 0, 1)
    assert batch.sp_expand(0, 1)
    link = batch.sp_link(0)
    assert link is not None and link.mode == "batch"
    assert 0 in batch.ex(1).sp_guests
    assert batch.serving_ex(0) is batch.ex(1)           # guest routed
    donor_ex = batch.ex(1)
    # ONE fused call co-serves the guest and the donor's own stream
    donor_ex.begin_chunk(0, FID, 0.0)
    donor_ex.begin_chunk(9, FID, 0.0)
    while 0 in donor_ex.inflight:
        donor_ex.run_step([0, 9])
    assert 9 not in donor_ex.inflight
    got_batch.append(np.asarray(donor_ex.chunks[0][-1]))
    got_batch += gen_chunks(donor_ex, 0, 1)
    # the home pool tracked every guest append (system of record):
    # full-head pages identical in both pools
    rows_h = batch.ex(0).pool.ledger.tables[0]
    rows_d = donor_ex.pool.ledger.tables[0]
    np.testing.assert_array_equal(
        np.asarray(batch.ex(0).pool.k[:, rows_h]),
        np.asarray(donor_ex.pool.k[:, rows_d]))
    batch.sp_release(0)
    assert 0 not in donor_ex.sp_guests
    assert 0 not in donor_ex.chunk_seq and 0 not in donor_ex.chunks
    donor_ex.pool.ledger.check()
    got_batch += gen_chunks(batch.ex(0), 0, 1)          # home continues
    for c in range(4):
        np.testing.assert_array_equal(
            ref[c], got_solo[c],
            err_msg=f"chunk {c}: solo SP2 diverged from SP1")
        np.testing.assert_array_equal(
            ref[c], got_batch[c],
            err_msg=f"chunk {c}: batch-axis SP diverged from SP1")


def test_batch_linked_stream_must_not_run_at_home():
    """The home lane stepping a batch-linked stream would desync the two
    page sets — the executor refuses."""
    cfg = tiny_cfg()
    lanes = LanePool(2, cfg=cfg, max_streams=3, sp_mode="batch")
    lanes.admit(0, 0, seed=0)
    gen_chunks(lanes.ex(0), 0, 1)
    assert lanes.sp_expand(0, 1)
    ex0 = lanes.ex(0)
    ex0.begin_chunk(0, FID, 0.0)
    with pytest.raises(AssertionError, match="donor lane"):
        ex0.run_step([0])
    ex0.abort_chunk(0)
    lanes.sp_release(0)


def test_batch_guest_protected_from_donor_eviction():
    """A batch-axis guest's donor pages (and the linked stream's home
    pages) are not eviction victims mid-borrow."""
    from repro.core.types import Stream as S
    cfg = tiny_cfg()
    lanes = LanePool(2, cfg=cfg, max_streams=2, sp_mode="batch")
    streams = {}
    for sid, lane, credit in ((0, 0, 9.0), (10, 1, 5.0), (11, 1, 4.0)):
        lanes.admit(sid, lane, seed=sid)
        s = S(sid=sid, arrival=0.0, target_chunks=8, chunk_seconds=1.0,
              home=lane, ttfc_slack=1.0)
        s.credit = credit
        streams[sid] = s
    gen_chunks(lanes.ex(0), 0, 1)
    assert lanes.sp_expand(0, 1, streams)
    assert lanes.ex(1).pool.resident(0)
    lanes.ex(1).admit(12, seed=12, streams=streams)
    streams[12] = streams[11]
    assert lanes.ex(1).pool.resident(0), \
        "batch-axis guest evicted from the donor pool mid-borrow"
    assert lanes.ex(0).pool.resident(0), \
        "linked stream's home pages evicted mid-borrow"
    gen_chunks(lanes.ex(1), 0, 1)                  # guest still serves
    lanes.sp_release(0)


def test_multi_lane_session_batch_mode_bit_identical():
    """End-to-end: a 2-lane session with ``sp_mode="batch"`` (guests
    rerouted through ``_dispatch_round`` onto the donor's micro-batch)
    completes bit-identical to the single-lane session under a forced
    expand."""
    from repro.core.bmpr import StaticFidelity
    from repro.core.elastic_sp import SPDecision
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     uniform_specs)
    cfg = tiny_cfg()
    n, chunks = 2, 3
    ref = StreamingSession(
        SessionConfig(lanes=1, model_cfg=cfg, pool_streams=n + 1,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        ref.submit(spec)
    ref.run()
    ref_chunks = {i: [np.asarray(c) for c in ref.handles[i].chunks]
                  for i in range(n)}

    sess = StreamingSession(
        SessionConfig(lanes=2, model_cfg=cfg, pool_streams=n + 1,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    sess.lanes.sp_mode = "batch"
    for spec in uniform_specs(n, chunks):
        sess.submit(spec)
    state = {"sp": False}
    orig_tick = sess.control.tick

    def tick(view, now):
        d = orig_tick(view, now)
        s1 = view.streams.get(1)
        if (not state["sp"] and s1 is not None and s1.chunks_done >= 1
                and not s1.done
                and sess.lanes.ex(sess.lanes.lane_of[1]).pool.resident(1)):
            d.sp_decisions.append(
                SPDecision(1, 1 - sess.lanes.lane_of[1], "expand"))
            state["sp"] = True
        return d

    sess.control.tick = tick
    res = sess.run()
    assert res.n_sp_expands_applied >= 1
    for i in range(n):
        got = [np.asarray(c) for c in sess.handles[i].chunks]
        assert len(got) == chunks
        for c in range(chunks):
            np.testing.assert_array_equal(
                ref_chunks[i][c], got[c],
                err_msg=f"stream {i} chunk {c} diverged under "
                        f"batch-axis SP serving")
    for ex in sess.lanes.executors:
        ex.pool.ledger.check()
        assert not ex.sp_guests and not ex.sp_links


# ---------------------------------------------------------------------------
# forced-device-count matrix (subprocess: flag precedes JAX init)
# ---------------------------------------------------------------------------

def _run_harness(n_devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{n_devices}").strip()
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "tests", "device_lane_harness.py"),
         str(n_devices)],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert proc.returncode == 0, \
        f"harness failed:\n{proc.stdout}\n{proc.stderr}"
    assert "DEVICE-LANES-OK" in proc.stdout, proc.stdout
    return proc.stdout


def test_forced_2_device_parity_matrix():
    """2 forced host devices: real cross-device migration (measured),
    batch-axis SP parity, and the full-session acceptance check."""
    out = _run_harness(2)
    assert '"devices": 2' in out


@pytest.mark.slow
def test_forced_4_device_parity_matrix():
    """4 forced host devices: the same matrix plus a far-lane move on
    the wider mesh."""
    out = _run_harness(4)
    assert '"devices": 4' in out
