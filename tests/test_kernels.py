"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_mha_pallas
from repro.kernels.flash_attention.ref import flash_mha_ref
from repro.kernels.paged_attention.kernel import paged_decode_attention_pallas
from repro.kernels.paged_attention.ref import paged_decode_attention_ref
from repro.kernels.fp8_matmul.kernel import fp8_matmul_pallas
from repro.kernels.fp8_matmul.ref import fp8_matmul_ref, quantize_fp8_ref
from repro.kernels.ssd_scan.kernel import ssd_pallas
from repro.kernels.ssd_scan.ref import ssd_ref, ssd_decode_ref

pytestmark = pytest.mark.slow     # Pallas/JAX-compiling kernel sweeps: slow tier

KEY = jax.random.PRNGKey(0)


def _qkv(B, Sq, Skv, Hq, Hkv, D, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, Sq, Hq, D), dtype),
            jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype),
            jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,D", [
        (2, 64, 64, 4, 2, 16),
        (1, 128, 128, 2, 2, 32),
        (1, 96, 96, 8, 1, 64),
    ])
    def test_causal_gqa(self, B, Sq, Skv, Hq, Hkv, D):
        q, k, v = _qkv(B, Sq, Skv, Hq, Hkv, D)
        out = flash_mha_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), block_q=32, block_kv=32,
                               interpret=True).swapaxes(1, 2)
        ref = flash_mha_ref(q, k, v, n_kv_heads=Hkv,
                            block_q=32, block_kv=32)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)

    def test_non_causal(self):
        q, k, v = _qkv(2, 64, 64, 4, 4, 16)
        out = flash_mha_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), causal=False,
                               block_q=32, block_kv=32,
                               interpret=True).swapaxes(1, 2)
        ref = flash_mha_ref(q, k, v, n_kv_heads=4, causal=False)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)

    def test_chunk_offset(self):
        q, k, v = _qkv(1, 64, 128, 4, 4, 16)
        out = flash_mha_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), q_offset=64,
                               block_q=32, block_kv=32,
                               interpret=True).swapaxes(1, 2)
        ref = flash_mha_ref(q, k, v, n_kv_heads=4, q_offset=64,
                            block_q=32, block_kv=32)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("window,sink", [(48, 16), (40, 0), (96, 32)])
    def test_sink_window(self, window, sink):
        q, k, v = _qkv(1, 128, 128, 2, 1, 16)
        out = flash_mha_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), window=window, sink=sink,
                               block_q=32, block_kv=32,
                               interpret=True).swapaxes(1, 2)
        ref = flash_mha_ref(q, k, v, n_kv_heads=1, window=window,
                            sink=sink, block_q=32, block_kv=32)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("rho", [0.6, 0.7, 0.9])
    def test_block_sparse(self, rho):
        q, k, v = _qkv(1, 256, 256, 4, 2, 16)
        out = flash_mha_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), sparsity=rho,
                               block_q=32, block_kv=32,
                               interpret=True).swapaxes(1, 2)
        ref = flash_mha_ref(q, k, v, n_kv_heads=2, sparsity=rho,
                            block_q=32, block_kv=32)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)

    def test_bf16(self):
        q, k, v = _qkv(1, 64, 64, 2, 2, 32, jnp.bfloat16)
        out = flash_mha_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                               v.swapaxes(1, 2), block_q=32, block_kv=32,
                               interpret=True).swapaxes(1, 2)
        ref = flash_mha_ref(q, k, v, n_kv_heads=2)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestPagedAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,D,page,npg,ptot", [
        (2, 4, 2, 16, 8, 4, 16),
        (3, 8, 8, 32, 16, 3, 12),
        (1, 4, 1, 64, 8, 6, 8),
    ])
    def test_vs_ref(self, B, Hq, Hkv, D, page, npg, ptot):
        ks = jax.random.split(KEY, 5)
        q = jax.random.normal(ks[0], (B, Hq, D))
        kp = jax.random.normal(ks[1], (ptot, page, Hkv, D))
        vp = jax.random.normal(ks[2], (ptot, page, Hkv, D))
        bt = jax.random.randint(ks[3], (B, npg), 0, ptot)
        lengths = jax.random.randint(ks[4], (B,), 1, npg * page + 1)
        out = paged_decode_attention_pallas(q, kp, vp, bt, lengths,
                                            interpret=True)
        ref = paged_decode_attention_ref(q, kp, vp, bt, lengths)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)

    def test_length_one(self):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 4, 16))
        kp = jax.random.normal(ks[1], (4, 8, 2, 16))
        vp = jax.random.normal(ks[2], (4, 8, 2, 16))
        bt = jnp.zeros((2, 2), jnp.int32)
        lengths = jnp.ones((2,), jnp.int32)
        out = paged_decode_attention_pallas(q, kp, vp, bt, lengths,
                                            interpret=True)
        ref = paged_decode_attention_ref(q, kp, vp, bt, lengths)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


class TestPagedChunkAttention:
    """Chunk-query generalization (q [B,Sq,Hq,D], token-granular page
    masks, partials out) — the serving executor's paged backend."""

    @pytest.mark.parametrize("B,Sq,Hq,Hkv,D,page,npg,ptot", [
        (2, 4, 4, 2, 16, 8, 4, 16),
        (3, 6, 8, 2, 8, 16, 3, 12),
        (1, 5, 4, 1, 64, 8, 6, 8),
    ])
    def test_vs_ref(self, B, Sq, Hq, Hkv, D, page, npg, ptot):
        from repro.kernels.paged_attention.kernel import \
            paged_chunk_attention_pallas
        from repro.kernels.paged_attention.ref import \
            paged_chunk_attention_ref
        ks = jax.random.split(KEY, 5)
        q = jax.random.normal(ks[0], (B, Sq, Hq, D))
        kp = jax.random.normal(ks[1], (ptot, page, Hkv, D))
        vp = jax.random.normal(ks[2], (ptot, page, Hkv, D))
        bt = jax.random.randint(ks[3], (B, npg), 0, ptot)
        mask = jax.random.uniform(ks[4], (B, npg * page)) < 0.6
        mask = mask.at[0, :page].set(False)     # a fully-masked page
        got = paged_chunk_attention_pallas(q, kp, vp, bt, mask,
                                           interpret=True)
        want = paged_chunk_attention_ref(q, kp, vp, bt, mask)
        for g, w, name in zip(got, want, ("m", "l", "acc")):
            np.testing.assert_allclose(g, w, rtol=2e-3, atol=2e-3,
                                       err_msg=name)


class TestFp8Matmul:
    @pytest.mark.parametrize("M,K,N", [(64, 64, 64), (128, 256, 64),
                                       (32, 32, 32)])
    def test_vs_ref(self, M, K, N):
        ks = jax.random.split(KEY, 2)
        x = jax.random.normal(ks[0], (M, K))
        w = jax.random.normal(ks[1], (K, N))
        xq, sx = quantize_fp8_ref(x, 1)
        wq, sw = quantize_fp8_ref(w, 0)
        out = fp8_matmul_pallas(xq, wq, sx, sw, block_m=32, block_n=32,
                                block_k=32, interpret=True)
        ref = fp8_matmul_ref(xq, wq, sx, sw)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_quantization_error_bounded(self):
        x = jax.random.normal(KEY, (64, 128))
        xq, sx = quantize_fp8_ref(x, 1)
        deq = xq.astype(jnp.float32) * sx
        # e4m3 relative error within a scaled block is < 2^-2 of the max
        err = jnp.max(jnp.abs(deq - x))
        amax = jnp.max(jnp.abs(x))
        assert float(err) < float(amax) * 0.07


class TestSSD:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 64, 4, 16, 8, 16),
        (1, 100, 2, 8, 16, 32),     # non-divisible padding path
        (2, 33, 3, 8, 4, 8),
    ])
    def test_vs_ref(self, B, S, H, P, N, chunk):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        Bm = jax.random.normal(ks[3], (B, S, 1, N))
        Cm = jax.random.normal(ks[4], (B, S, 1, N))
        y1, f1 = ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
        y2, f2 = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(f1, f2, rtol=5e-4, atol=5e-4)

    def test_init_state_continuation(self):
        ks = jax.random.split(KEY, 6)
        B, S, H, P, N = 2, 48, 2, 8, 4
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        Bm = jax.random.normal(ks[3], (B, S, 1, N))
        Cm = jax.random.normal(ks[4], (B, S, 1, N))
        s0 = jax.random.normal(ks[5], (B, H, P, N))
        y1, f1 = ssd_pallas(x, dt, A, Bm, Cm, chunk=16, init_state=s0,
                            interpret=True)
        y2, f2 = ssd_ref(x, dt, A, Bm, Cm, chunk=16, init_state=s0)
        np.testing.assert_allclose(y1, y2, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(f1, f2, rtol=5e-4, atol=5e-4)

    def test_chunked_equals_sequential(self):
        """SSD chunked scan == naive per-token recurrence."""
        ks = jax.random.split(KEY, 5)
        B, S, H, P, N = 1, 19, 2, 4, 4
        x = jax.random.normal(ks[0], (B, S, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)))
        Bm = jax.random.normal(ks[3], (B, S, 1, N))
        Cm = jax.random.normal(ks[4], (B, S, 1, N))
        st = jnp.zeros((B, H, P, N))
        ys = []
        for t in range(S):
            y, st = ssd_decode_ref(x[:, t], dt[:, t], A, Bm[:, t],
                                   Cm[:, t], st)
            ys.append(y)
        y_seq = jnp.stack(ys, 1)
        y_chunk, f_chunk = ssd_ref(x, dt, A, Bm, Cm, chunk=8)
        np.testing.assert_allclose(y_chunk, y_seq, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(f_chunk, st, rtol=2e-4, atol=2e-4)
