"""Sharding rules, logical axes, HLO cost analyzer, small-mesh lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ShapeConfig, get_config
from repro.distributed import sharding as shd
from repro.distributed.logical import resolve_spec
from repro.launch.hlo_cost import analyze_text

pytestmark = pytest.mark.slow     # JAX-lowering/compiling sharding tests: slow tier


class TestParamRules:
    def test_rank_padding_for_stacked_layers(self):
        # scan-stacked [L, d, f] weights get a leading None
        assert shd.param_pspec(("layers", "mlp", "w_gate"), 3) == \
            P(None, "data", "model")
        assert shd.param_pspec(("layers", "attn", "wo"), 3) == \
            P(None, "model", "data")

    def test_serve_rules_drop_fsdp(self):
        assert shd.param_pspec(("layers", "attn", "wq"), 3, serve=True) == \
            P(None, None, "model")

    def test_unknown_params_replicated(self):
        assert shd.param_pspec(("final_norm",), 1) == P(None)

    def test_vocab_padding_divisible(self):
        for arch in ("minicpm-2b", "whisper-medium", "mamba2-780m",
                     "granite-moe-1b-a400m", "internvl2-26b"):
            cfg = get_config(arch)
            assert cfg.padded_vocab % 256 == 0
            assert cfg.padded_vocab >= cfg.vocab_size


class TestLogicalRules:
    def test_duplicate_mesh_axis_dropped(self):
        spec = resolve_spec(["batch", None, "heads"],
                            {"batch": ("data",), "heads": ("data",)})
        assert spec == P("data", None, None)

    def test_multi_axis_batch(self):
        spec = resolve_spec(["batch", None],
                            {"batch": ("pod", "data")})
        assert spec == P(("pod", "data"), None)


class TestHloCost:
    def test_matmul_exact(self):
        M, N, K = 64, 32, 128
        c = jax.jit(lambda a, b: a @ b).lower(
            jnp.zeros((M, K)), jnp.zeros((K, N))).compile()
        assert analyze_text(c.as_text()).flops == 2 * M * N * K

    def test_scan_trip_count_multiplied(self):
        L, M = 5, 32
        def f(x, ws):
            return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]
        c = jax.jit(f).lower(jnp.zeros((M, M)),
                             jnp.zeros((L, M, M))).compile()
        assert analyze_text(c.as_text()).flops == L * 2 * M ** 3

    def test_nested_scan(self):
        L, R, M = 4, 3, 16
        def f(x, ws):
            def outer(h, w):
                h2, _ = jax.lax.scan(lambda a, _: (a @ w, None), h,
                                     None, length=R)
                return h2, None
            return jax.lax.scan(outer, x, ws)[0]
        c = jax.jit(f).lower(jnp.zeros((M, M)),
                             jnp.zeros((L, M, M))).compile()
        assert analyze_text(c.as_text()).flops == L * R * 2 * M ** 3

    def test_hbm_bytes_positive_and_scan_scaled(self):
        L, M = 8, 64
        def f(x, ws):
            return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]
        c = jax.jit(f).lower(jnp.zeros((M, M)),
                             jnp.zeros((L, M, M))).compile()
        cost = analyze_text(c.as_text())
        # traffic should be ~ L * (weight slice + activations), i.e.
        # far below L * full-stack bytes and above one iteration's
        lo = 2 * M * M * 4
        hi = 3 * L * (L * M * M * 4)
        assert lo < cost.hbm_bytes < hi


class TestSmallMeshLowering:
    """The full lowering path on a 1x1 debug mesh (reduced configs)."""

    @pytest.mark.parametrize("arch", ["minitron-8b", "mamba2-780m",
                                      "jamba-v0.1-52b"])
    def test_lower_train_reduced(self, arch):
        from repro.launch.lowering import lower_cell
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = get_config(arch).reduced()
        shape = ShapeConfig("t", "train", 32, 2)
        lowered = lower_cell(cfg, mesh, shape)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None

    @pytest.mark.parametrize("arch,kind", [("minitron-8b", "decode"),
                                           ("mamba2-780m", "decode"),
                                           ("whisper-medium", "prefill")])
    def test_lower_serving_reduced(self, arch, kind):
        from repro.launch.lowering import lower_cell
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = get_config(arch).reduced()
        shape = ShapeConfig("t", kind, 64, 2)
        compiled = lower_cell(cfg, mesh, shape).compile()
        assert compiled is not None
