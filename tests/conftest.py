"""Shared fixtures.  NOTE: no XLA device-count flags here — tests must
see the real (single) CPU device; only the dry-run forces 512."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
