"""Train substrate: optimizer, schedules, checkpoint/restart with
elastic resharding, gradient compression, data pipeline."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.data import pipeline as dp
from repro.models import registry
from repro.train import checkpoint as ckpt
from repro.train import compression as comp
from repro.train import loop as train_loop
from repro.train import optimizer as opt

pytestmark = pytest.mark.slow     # JAX-compiling train-step tests: slow tier

KEY = jax.random.PRNGKey(0)


def _small_state(arch="minicpm-2b"):
    cfg = get_config(arch).reduced()
    api = registry.get_api(cfg)
    params = api.init(cfg, KEY)
    return cfg, train_loop.TrainState(params, opt.init_opt_state(params))


class TestOptimizer:
    def test_loss_decreases(self):
        cfg, state = _small_state()
        ocfg = opt.OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
        step = jax.jit(train_loop.make_train_step(cfg, ocfg))
        tokens = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
        losses = []
        for _ in range(6):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]

    def test_microbatch_equivalence(self):
        """Grad accumulation over 2 microbatches == full batch step."""
        cfg, state = _small_state()
        ocfg = opt.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        tokens = jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
        s1, m1 = train_loop.make_train_step(cfg, ocfg, microbatches=1)(
            state, batch)
        s2, m2 = train_loop.make_train_step(cfg, ocfg, microbatches=2)(
            state, batch)
        # CE normalizes per-microbatch; losses agree, grads within tol
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=1e-3)
        for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                        jax.tree_util.tree_leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-5)

    def test_grad_clip(self):
        g = {"w": jnp.full((10,), 100.0)}
        p = {"w": jnp.zeros((10,))}
        st = opt.init_opt_state(p)
        cfg = opt.OptConfig(grad_clip=1.0, lr=1.0, warmup_steps=0,
                            total_steps=1)
        _, _, metrics = opt.adamw_update(cfg, p, g, st)
        assert float(metrics["grad_norm"]) > 100.0   # pre-clip norm logged

    def test_wsd_schedule_shape(self):
        cfg = opt.OptConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                            total_steps=100, decay_frac=0.2)
        lrs = [float(opt.lr_at(cfg, jnp.asarray(s)))
               for s in [0, 5, 10, 50, 79, 90, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == lrs[3] == pytest.approx(1.0)     # stable phase
        assert lrs[4] == pytest.approx(1.0, abs=0.05)
        assert lrs[5] < 1.0                                # decaying
        assert lrs[6] == pytest.approx(0.1, abs=0.02)     # floor

    def test_weight_decay_mask(self):
        assert opt._decay_mask([jax.tree_util.DictKey("wq")])
        assert not opt._decay_mask([jax.tree_util.DictKey("attn_norm")])
        assert not opt._decay_mask([jax.tree_util.DictKey("dt_bias")])


class TestCheckpoint:
    def test_roundtrip_and_latest(self):
        _, state = _small_state()
        with tempfile.TemporaryDirectory() as d:
            assert ckpt.latest_step(d) is None
            ckpt.save(d, 7, state)
            ckpt.save(d, 12, state)
            assert ckpt.latest_step(d) == 12
            restored = ckpt.restore(d, 12, state)
            for a, b in zip(jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self):
        _, state = _small_state()
        with tempfile.TemporaryDirectory() as d:
            t = ckpt.save(d, 3, state, blocking=False)
            t.join()
            assert ckpt.latest_step(d) == 3

    def test_elastic_resharding_restore(self):
        """Restore under a (trivially different) mesh sharding."""
        _, state = _small_state()
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        from repro.distributed import sharding as shd
        shardings = train_loop.TrainState(
            shd.param_shardings(state.params, mesh),
            opt.OptState(
                jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
                shd.param_shardings(state.opt_state.m, mesh),
                shd.param_shardings(state.opt_state.v, mesh)))
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, state)
            restored = ckpt.restore(d, 1, state, shardings=shardings)
            for a, b in zip(jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resume_is_bitwise(self):
        """Kill/restart equivalence: step k..n from a checkpoint equals
        an uninterrupted run (same data, same state)."""
        cfg, state = _small_state()
        shape = ShapeConfig("t", "train", 16, 4)
        ocfg = opt.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        step = jax.jit(train_loop.make_train_step(cfg, ocfg))

        def run(state, lo, hi):
            for s in range(lo, hi):
                state, _ = step(state, dp.global_batch(cfg, shape, s))
            return state

        full = run(state, 0, 4)
        with tempfile.TemporaryDirectory() as d:
            mid = run(state, 0, 2)
            ckpt.save(d, 2, mid)
            resumed = ckpt.restore(d, 2, mid)
            part = run(resumed, 2, 4)
        for a, b in zip(jax.tree_util.tree_leaves(full.params),
                        jax.tree_util.tree_leaves(part.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = jax.random.normal(KEY, (333, 7)) * 3.0
        q, scale, resid = comp.compress(g)
        deq = comp.decompress(q, scale, g.shape)
        np.testing.assert_allclose(deq + resid, g, rtol=1e-5, atol=1e-6)
        # per-block error <= scale/2 (round-to-nearest int8)
        assert float(jnp.max(jnp.abs(resid))) <= float(jnp.max(scale))

    def test_error_feedback_converges(self):
        """With EF, the accumulated applied update tracks the true sum
        of gradients (bias-free), unlike plain quantization."""
        gs = [jax.random.normal(jax.random.PRNGKey(i), (64,)) * 0.1
              for i in range(30)]
        err = jnp.zeros((64,))
        applied = jnp.zeros((64,))
        for g in gs:
            q, scale, err = comp.compress(g + err)
            applied += comp.decompress(q, scale, g.shape)
        true = sum(gs)
        # residual bounded by one quantization step, not O(T) drift
        assert float(jnp.max(jnp.abs(applied - true))) <= \
            float(jnp.max(jnp.abs(err))) + 1e-5


class TestData:
    def test_dp_layout_invariance(self):
        cfg = get_config("minitron-8b").reduced()
        shape = ShapeConfig("t", "train", 16, 8)
        full = dp.global_batch(cfg, shape, step=3)
        parts = [dp.global_batch(cfg, shape, step=3,
                                 rows=dp.shard_rows(8, r, 4))
                 for r in range(4)]
        np.testing.assert_array_equal(
            full["tokens"], np.concatenate([p["tokens"] for p in parts]))

    def test_steps_differ(self):
        cfg = get_config("minitron-8b").reduced()
        shape = ShapeConfig("t", "train", 16, 2)
        a = dp.global_batch(cfg, shape, step=0)
        b = dp.global_batch(cfg, shape, step=1)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_targets_are_shifted_tokens(self):
        cfg = get_config("minitron-8b").reduced()
        shape = ShapeConfig("t", "train", 16, 2)
        batch = dp.global_batch(cfg, shape, step=0)
        np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                      batch["targets"][:, :-1])
