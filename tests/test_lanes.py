"""Multi-lane real sessions: cross-lane migration (bit-exact KV move),
elastic SP2 (Ulysses head split, expand/release parity with the SP1
step), prompt-switch fresh conditioning, and the decision -> apply ->
metrics loop of the lane-aware StreamingSession.

All tests drive the jitted batched executor on a 2-layer config (same
budget as test_batcher/test_session)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.bmpr import StaticFidelity
from repro.core.elastic_sp import SPDecision
from repro.core.fidelity import FidelityConfig
from repro.core.rehoming import Migration
from repro.sched_sim.metrics import summarize, transfer_stats
from repro.serve.batcher import BatchedChunkExecutor
from repro.serve.lanes import LanePool
from repro.serve.session import (SessionConfig, StreamingSession,
                                 uniform_specs)

FID = FidelityConfig(2, 0.0, 2, "bf16")


def tiny_cfg(window_chunks=2):
    return dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=window_chunks)


def gen_chunks(ex, sid, n=1, fid=FID, sp=False):
    """Drive one stream through n whole chunks on one executor
    (``sp=True`` = a reserved SP2 dispatch, the head-split path)."""
    out = []
    for _ in range(n):
        ex.begin_chunk(sid, fid, 0.0)
        while sid in ex.inflight:
            ex.run_step([sid], sp_serve=sp)
        out.append(np.asarray(ex.chunks[sid][-1]))
    return out


# ---------------------------------------------------------------------------
# cross-lane migration: a real KV move, bit-exact
# ---------------------------------------------------------------------------

def test_cross_lane_migration_kv_bit_exact():
    """Migrating a stream moves its pages into the destination lane's
    pool verbatim, subsequent chunks are bit-identical to a never-
    migrated run, and the move shows up on the shared transfer
    engine."""
    cfg = tiny_cfg()
    ref_ex = LanePool(1, cfg=cfg, max_streams=3).ex(0)
    ref_ex.admit(5, seed=0)
    ref = gen_chunks(ref_ex, 5, 4)

    lanes = LanePool(2, cfg=cfg, params=ref_ex.params, max_streams=3)
    lanes.admit(5, 0, seed=0)
    got = gen_chunks(lanes.ex(0), 5, 2)
    ctx_before = np.asarray(lanes.ex(0).pool.gather([5], 2)[0])
    n_log = len(lanes.engine.log)

    assert lanes.migrate(5, 0, 1)
    assert lanes.lane_of[5] == 1
    assert not lanes.ex(0).pool.resident(5)
    assert lanes.ex(1).pool.resident(5)
    lanes.ex(0).pool.ledger.check()
    lanes.ex(1).pool.ledger.check()
    # ONE src->dst transfer charged on the shared engine
    assert len(lanes.engine.log) == n_log + 1
    # the pages landed bit-exactly (same gathered context)
    ctx_after = np.asarray(lanes.ex(1).pool.gather([5], 2)[0])
    np.testing.assert_array_equal(ctx_before, ctx_after)

    got += gen_chunks(lanes.ex(1), 5, 2)
    for c, (a, b) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"chunk {c} diverged across the migration")
    assert lanes.n_migrations == 1


def test_migration_refused_mid_chunk_or_wrong_lane():
    """The apply layer re-checks executor ground truth: a mid-chunk or
    wrongly-addressed migration decision is dropped, not applied."""
    cfg = tiny_cfg()
    lanes = LanePool(2, cfg=cfg, max_streams=3)
    lanes.admit(0, 0, seed=0)
    gen_chunks(lanes.ex(0), 0, 1)
    lanes.ex(0).begin_chunk(0, FID, 0.0)
    lanes.ex(0).run_step([0])                  # mid-chunk now
    assert not lanes.migrate(0, 0, 1)          # boundary only
    assert not lanes.migrate(0, 1, 0)          # stream is not on lane 1
    lanes.ex(0).abort_chunk(0)
    assert lanes.migrate(0, 0, 1)              # boundary: applies


# ---------------------------------------------------------------------------
# elastic SP2: head-split step parity, donor mirror, release
# ---------------------------------------------------------------------------

def test_sp2_expand_release_numerical_parity_with_sp1():
    """The Ulysses head-split SP2 step is bit-identical to the SP1 step
    (per-head attention never mixes heads and the donor's half mirrors
    the home pool verbatim), through expand, appends under SP, and
    release."""
    cfg = tiny_cfg()
    ref_ex = LanePool(1, cfg=cfg, max_streams=3).ex(0)
    ref_ex.admit(0, seed=0)
    ref = gen_chunks(ref_ex, 0, 4)

    lanes = LanePool(2, cfg=cfg, params=ref_ex.params, max_streams=3)
    ex0 = lanes.ex(0)
    lanes.admit(0, 0, seed=0)
    got = gen_chunks(ex0, 0, 1)
    assert lanes.sp_expand(0, 1)
    assert lanes.sp_link(0) is not None and lanes.sp_link(0).donor == 1
    # an UNRESERVED dispatch of a linked stream must stay on the SP1
    # step (donor compute is only consumed when the scheduler lent the
    # slot): the boundary it builds carries no SP marker
    ex0.begin_chunk(0, FID, 0.0)
    ex0.run_step([0])
    assert all(k[-1] is None for k in ex0._boundary_cache)
    ex0.abort_chunk(0)
    got += gen_chunks(ex0, 0, 2, sp=True)      # SP2 chunks (incl. appends)

    # donor mirror: the donor pool's page set holds exactly the home
    # pool's upper half heads (kept in lockstep by the SP append)
    h2 = cfg.n_kv_heads // 2
    rows_h = ex0.pool.ledger.tables[0]
    rows_d = lanes.ex(1).pool.ledger.tables[0]
    for pool_h, pool_d in ((ex0.pool.k, lanes.ex(1).pool.k),
                           (ex0.pool.v, lanes.ex(1).pool.v)):
        np.testing.assert_array_equal(
            np.asarray(pool_h[:, rows_h])[..., h2:, :],
            np.asarray(pool_d[:, rows_d])[..., h2:, :])

    lanes.sp_release(0)
    assert lanes.sp_link(0) is None
    lanes.ex(1).pool.ledger.check()            # donor pages freed cleanly
    got += gen_chunks(ex0, 0, 1)               # back on the SP1 step
    for c, (a, b) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(
            a, b, err_msg=f"chunk {c}: SP2 diverged from the SP1 step")
    assert lanes.n_sp_expands == 1 and lanes.n_sp_releases == 1


def test_sp_mirror_protected_from_donor_pool_eviction():
    """Regression: the donor lane's eviction paths saw a live SP
    half-head mirror as an ordinary (non-inflight) resident and could
    evict it mid-borrow, breaking the linked SP2 step."""
    from repro.core.types import Stream
    cfg = tiny_cfg()
    lanes = LanePool(2, cfg=cfg, max_streams=2)
    streams = {}
    for sid, lane, ddl in ((0, 0, 9.0), (10, 1, 5.0), (11, 1, 4.0)):
        lanes.admit(sid, lane, seed=sid)
        s = Stream(sid=sid, arrival=0.0, target_chunks=8,
                   chunk_seconds=1.0, home=lane, ttfc_slack=1.0)
        s.credit = ddl          # sid 0 has the HIGHEST credit: the
        streams[sid] = s        # pre-fix pick would evict its mirror
    gen_chunks(lanes.ex(0), 0, 1)
    # donor pool (lane 1) is full: expansion evicts a donor resident,
    # then mirrors stream 0's upper heads there
    assert lanes.sp_expand(0, 1, streams)
    assert 0 in lanes.ex(1).sp_mirrors
    assert lanes.ex(1).pool.resident(0)
    # fresh pressure on the donor pool must NOT pick the mirror
    lanes.ex(1).admit(12, seed=12, streams=streams)
    streams[12] = streams[11]
    assert lanes.ex(1).pool.resident(0), \
        "live SP mirror was evicted from the donor pool"
    # the SP2 step still runs (and the mirror is released cleanly)
    gen_chunks(lanes.ex(0), 0, 1, sp=True)
    lanes.sp_release(0)
    assert 0 not in lanes.ex(1).sp_mirrors
    lanes.ex(1).pool.ledger.check()


def test_deferred_sp_release_blocks_same_tick_donor_reuse():
    """Regression: a release deferred to the next safe boundary (its
    stream mid-chunk) left the donor physically borrowed, but the
    planner's same-tick rejoin could re-grant it — and the deferred
    apply_release would then clear the NEW borrower's donated_to."""
    from repro.core.control_plane import TickDecisions
    from repro.core import elastic_sp
    cfg = tiny_cfg()
    sess = StreamingSession(
        SessionConfig(lanes=2, model_cfg=cfg, pool_streams=3,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    sess._t0 = 0.0
    sess.submit(uniform_specs(2, 4)[0])
    sess.submit(uniform_specs(2, 4)[1])
    sess._drain_events(0.0)                   # admit both
    h0 = sess.view.streams[0].home
    donor = 1 - h0
    gen_chunks(sess.lanes.ex(h0), 0, 1)
    assert sess.lanes.sp_expand(0, donor, sess.view.streams)
    elastic_sp.apply_expand(sess.view, SPDecision(0, donor, "expand"))
    # stream 0 goes mid-chunk: its release must defer
    sess.lanes.ex(h0).begin_chunk(0, FID, 0.0)
    sess._apply_decisions(TickDecisions(
        migrations=[],
        sp_decisions=[SPDecision(0, donor, "release"),
                      SPDecision(1, donor, "expand")],
        control_time_s=0.0))
    # the deferred release is pending, the donor was NOT re-granted
    assert sess._pending_sp_release == {0: donor}
    assert sess.view.workers[donor].donated_to == 0
    assert sess.view.streams[1].sp_donor is None
    assert sess.lanes.sp_link(1) is None


def test_sp_expand_rejected_on_gather_backend():
    """The head split rides the paged step; on the gather backend the
    expand decision is dropped (and may be re-planned), never applied
    half-way."""
    lanes = LanePool(2, cfg=tiny_cfg(), max_streams=3,
                     context_backend="gather")
    lanes.admit(0, 0, seed=0)
    gen_chunks(lanes.ex(0), 0, 1)
    assert not lanes.sp_expand(0, 1)
    assert lanes.sp_link(0) is None


# ---------------------------------------------------------------------------
# prompt switch: fresh conditioning through KVPool.admit
# ---------------------------------------------------------------------------

def test_prompt_switch_serves_fresh_conditioning():
    """Regression (the old session kept the stale cond embedding): the
    post-switch chunk must differ from the no-switch chunk and match a
    fresh stream's first chunk under the new conditioning seed
    bit-exactly."""
    cfg = tiny_cfg()
    ex = BatchedChunkExecutor(cfg=cfg, max_streams=3)
    ex.admit(7, seed=7)
    gen_chunks(ex, 7, 1)
    assert ex.reset_condition(7, seed=777)
    ex.pool.ledger.check()
    post = gen_chunks(ex, 7, 1)[0]

    no_switch = BatchedChunkExecutor(cfg=cfg, params=ex.params,
                                     max_streams=3)
    no_switch.admit(7, seed=7)
    gen_chunks(no_switch, 7, 1)
    stale = gen_chunks(no_switch, 7, 1)[0]
    assert not np.array_equal(post, stale), \
        "post-switch chunk still serves the OLD conditioning"

    fresh = BatchedChunkExecutor(cfg=cfg, params=ex.params, max_streams=3)
    fresh.admit(7, seed=777)
    first = gen_chunks(fresh, 7, 1)[0]
    np.testing.assert_array_equal(
        post, first, err_msg="post-switch chunk is not bit-identical to "
                             "a fresh stream under the new conditioning")


def test_session_prompt_switch_resets_condition_and_completes():
    """Session wiring of the fix: a switch event re-encodes the cond
    (switch counter advances, seed derivable) and the stream still
    completes its chunk target."""
    from repro.sched_sim.workloads import StreamSpec
    sess = StreamingSession(
        SessionConfig(lanes=1, model_cfg=tiny_cfg(), pool_streams=3,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    # the switch lands 20 ms in — well inside a 4-chunk stream on any
    # host (a single tiny-model chunk takes longer than that)
    h = sess.submit(StreamSpec(0, 0.0, 48, switches=(0.02,)))
    sess.run()
    assert h.done and h.chunks_ready == 4
    assert sess._switches.get(0) == 1
    assert sess.switch_seed(0) == 0 + 100003


# ---------------------------------------------------------------------------
# the lane-aware session: decisions -> apply -> metrics, bit-identical
# ---------------------------------------------------------------------------

def test_multi_lane_session_applies_decisions_bit_identically():
    """A 2-lane session that REALLY migrates one stream and REALLY
    expands+releases SP on another produces, under a fixed fidelity,
    chunks bit-identical to the single-lane session — the acceptance
    bar for the real decision apply layer — and reports the applied
    counts on the metrics surface."""
    cfg = tiny_cfg()
    n, chunks = 2, 3
    ref = StreamingSession(
        SessionConfig(lanes=1, model_cfg=cfg, pool_streams=n + 1,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        ref.submit(spec)
    ref.run()
    ref_chunks = {i: [np.asarray(c) for c in ref.handles[i].chunks]
                  for i in range(n)}

    sess = StreamingSession(
        SessionConfig(lanes=2, model_cfg=cfg, pool_streams=n + 1,
                      verbose=False),
        fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        sess.submit(spec)

    # force one migration and one SP expand/release through the SAME
    # tick -> apply path the control plane uses (the planner's own
    # trigger conditions are load-dependent; the apply layer is what
    # this test pins)
    state = {"mig": False, "sp": False, "rel": False}
    orig_tick = sess.control.tick

    def tick(view, now):
        d = orig_tick(view, now)
        s0, s1 = view.streams.get(0), view.streams.get(1)
        if (not state["mig"] and s0 is not None and s0.chunks_done >= 1
                and not s0.done and not sess.lanes.is_inflight(0)):
            src = sess.lanes.lane_of[0]
            d.migrations.append(Migration(0, src, 1 - src,
                                          cross_node=False))
            state["mig"] = True
        if (not state["sp"] and s1 is not None and s1.chunks_done >= 1
                and not s1.done
                and sess.lanes.ex(sess.lanes.lane_of[1]).pool.resident(1)):
            d.sp_decisions.append(
                SPDecision(1, 1 - sess.lanes.lane_of[1], "expand"))
            state["sp"] = True
        elif (state["sp"] and not state["rel"] and s1 is not None
                and not s1.done and s1.sp_donor is not None
                and s1.chunks_done >= 2):
            d.sp_decisions.append(SPDecision(1, s1.sp_donor, "release"))
            state["rel"] = True
        return d

    sess.control.tick = tick
    res = sess.run()

    assert res.n_migrations_applied >= 1
    assert res.n_sp_expands_applied >= 1
    assert res.n_sp_releases_applied >= 1      # explicit or at retire
    # view bookkeeping followed the applies: stream 0 lives on its new
    # home lane, every donor was returned
    assert sess.lanes.lane_of[0] == 1 - res.streams[0].home or \
        res.streams[0].home == sess.lanes.lane_of[0]
    assert all(w.donated_to is None for w in sess.view.workers)
    for ex in sess.lanes.executors:
        ex.pool.ledger.check()
    for i in range(n):
        got = [np.asarray(c) for c in sess.handles[i].chunks]
        assert len(got) == chunks
        for c in range(chunks):
            np.testing.assert_array_equal(
                ref_chunks[i][c], got[c],
                err_msg=f"stream {i} chunk {c} diverged from the "
                        f"single-lane session")
    # one metrics surface: transfers (migration + SP half) on the
    # shared engine, Summary fields well-defined
    assert transfer_stats(res)["n"] == len(res.engine.log) >= 2
    s = summarize(res)
    assert s.n_chunks == n * chunks and 0.0 <= s.qoe <= 1.0


def test_multi_lane_session_oversubscribed_completes():
    """2 lanes x 2-resident pools serving 6 streams: per-lane
    credit-aware eviction keeps rotating everyone through and the
    session completes (the PR 2 oversubscription guarantee holds per
    lane)."""
    n, chunks = 6, 2
    sess = StreamingSession(
        SessionConfig(lanes=2, model_cfg=tiny_cfg(), pool_streams=2,
                      max_batch=2, verbose=False),
        fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        sess.submit(spec)
    res = sess.run()
    assert all(res.streams[i].chunks_done == chunks for i in range(n))
    assert len(sess.view.workers) == 2
    for ex in sess.lanes.executors:
        ex.pool.ledger.check()
