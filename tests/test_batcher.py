"""Batched multi-stream executor: micro-batch composition, ring-cache
mask mapping, join/leave at step boundaries, and batched-vs-sequential
numerical parity.

Pure-logic tests run in the fast tier; the parity test drives the eager
sequential path (slow tier)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.fidelity import FidelityConfig
from repro.models import ardit as A
from repro.models import kvcache
from repro.serve.batcher import BatchedChunkExecutor, compose_batch

KEY = jax.random.PRNGKey(0)

FID_HI = FidelityConfig(2, 0.0, 2, "bf16")
FID_LO = FidelityConfig(2, 0.9, 1, "fp8")


def tiny_cfg(window_chunks=2):
    """Two layers + short window: small compiles, fast wrap-around."""
    return dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=window_chunks)


def nondegenerate_params(cfg, key):
    """Fresh params have adaLN-ZERO gates: every residual branch is
    multiplied by 0, so outputs ignore the KV context entirely and any
    parity test would pass vacuously.  Open the gates with small random
    modulation weights so attention over the cache actually matters."""
    p = A.init_params(cfg, key)
    ks = jax.random.split(jax.random.PRNGKey(1234), 3)
    p["layers"]["mod"] = 0.2 * jax.random.normal(
        ks[0], p["layers"]["mod"].shape, p["layers"]["mod"].dtype)
    p["layers"]["mod_b"] = 0.5 + 0.2 * jax.random.normal(
        ks[1], p["layers"]["mod_b"].shape, p["layers"]["mod_b"].dtype)
    p["final_mod"] = 0.2 * jax.random.normal(
        ks[2], p["final_mod"].shape, p["final_mod"].dtype)
    return p


# ---------------------------------------------------------------------------
# micro-batch composition
# ---------------------------------------------------------------------------

def test_compose_batch_credit_order_and_grouping():
    fid_of = {0: FID_HI, 1: FID_LO, 2: FID_HI, 3: FID_LO, 4: FID_HI}.get
    # runnable set arrives credit-ordered; cap at 4 drops sid 4
    groups = compose_batch([1, 0, 3, 2, 4], fid_of, max_batch=4)
    assert groups == [[1, 3], [0, 2]]
    # first group contains the most urgent (lowest-credit) stream
    assert groups[0][0] == 1
    # same fidelity -> one group, order preserved
    assert compose_batch([2, 0, 4], fid_of, max_batch=8) == [[2, 0, 4]]
    assert compose_batch([], fid_of, max_batch=4) == []


# ---------------------------------------------------------------------------
# chunk-granular ring helpers
# ---------------------------------------------------------------------------

def test_chunk_slot_ring_layout():
    # window of 3 chunks of 5 tokens behind a 7-token sink
    slots = [int(kvcache.chunk_slot(jnp.asarray(c), 3, 7, 5))
             for c in range(7)]
    assert slots == [7, 12, 17, 7, 12, 17, 7]     # wraps every 3 chunks


def test_write_block_per_row_dest():
    cache = jnp.zeros((2, 6, 1, 1))
    new = jnp.arange(4, dtype=jnp.float32).reshape(2, 2, 1, 1) + 1.0
    out = kvcache.write_block(cache, new, jnp.asarray([0, 3]))
    got = np.asarray(out)[:, :, 0, 0]
    np.testing.assert_array_equal(got[0], [1, 2, 0, 0, 0, 0])
    np.testing.assert_array_equal(got[1], [0, 0, 0, 3, 4, 0])


def test_batched_context_mask_visibility():
    cfg = tiny_cfg(window_chunks=3)
    tc = A.chunk_tokens(cfg)
    sink = A.COND_TOKENS
    # streams at fills 0, 2, and 5 (wrapped) under window W=2
    mask = A.batched_context_mask(cfg, np.array([0, 2, 5]), window=2)
    # fill 0: sink only
    assert mask[0, :sink].all() and not mask[0, sink:].any()
    # fill 2: chunks 0,1 in slots 0,1 -> contiguous extent
    assert mask[1, :sink + 2 * tc].all() and not mask[1, sink + 2 * tc:].any()
    # fill 5, window 2: visible chunks 3,4 -> ring slots 3%3=0 and 4%3=1
    assert mask[2, :sink + 2 * tc].all() and not mask[2, sink + 2 * tc:].any()
    # fill 4, window 2: chunks 2,3 -> slots 2 and 0 (slot 1 hidden)
    m = A.batched_context_mask(cfg, np.array([4]), window=2)[0]
    assert m[:sink].all()
    assert m[sink:sink + tc].all()                      # slot 0 (chunk 3)
    assert not m[sink + tc:sink + 2 * tc].any()         # slot 1 (stale)
    assert m[sink + 2 * tc:sink + 3 * tc].all()         # slot 2 (chunk 2)


def test_batched_context_mask_sparsity_matches_sequential_keep():
    """The sparsity drop in the batched mask keeps exactly the token set
    ``cache_sparse_index`` gives the sequential path, mapped through the
    ring permutation."""
    # larger frame_tokens so the 128-aligned block drop actually fires
    # (at the reduced tc=48 every window fits in <=2 blocks, which the
    # sink/diagonal forcing always keeps); W=7 -> no wrap at n=4
    cfg = dataclasses.replace(get_config("ardit-self-forcing").reduced(),
                              ardit_frame_tokens=128)
    tc = A.chunk_tokens(cfg)
    n, window, rho = 4, 3, 0.8
    ctx_len = A.COND_TOKENS + window * tc
    keep = A.cache_sparse_index(cfg, ctx_len, rho)
    assert keep is not None and len(keep) < ctx_len
    mask = A.batched_context_mask(cfg, np.array([n]), window, rho)[0]
    # visible chunks are 1..3 in ring slots 1..3 (no wrap): sliced-ctx
    # token i >= sink maps to slot i + (n - window)*tc
    expect = np.zeros_like(mask)
    for i in keep:
        expect[i if i < A.COND_TOKENS else i + (n - window) * tc] = True
    np.testing.assert_array_equal(mask, expect)


# ---------------------------------------------------------------------------
# per-stream KV masking in attention (the mha extension batching rides on)
# ---------------------------------------------------------------------------

def test_mha_kv_mask_equals_slicing():
    from repro.models.attention import mha
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 2, 8))
    k = jax.random.normal(ks[1], (2, 10, 2, 8))
    v = jax.random.normal(ks[2], (2, 10, 2, 8))
    # row 0 sees the first 6 kv tokens, row 1 sees all 10
    kv_mask = jnp.asarray(np.array(
        [[True] * 6 + [False] * 4, [True] * 10]))
    out = mha(q, k, v, n_kv_heads=2, causal=False, kv_mask=kv_mask)
    ref0 = mha(q[:1], k[:1, :6], v[:1, :6], n_kv_heads=2, causal=False)
    ref1 = mha(q[1:], k[1:], v[1:], n_kv_heads=2, causal=False)
    np.testing.assert_allclose(out[0], ref0[0], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out[1], ref1[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# join/leave at step boundaries
# ---------------------------------------------------------------------------

def test_join_leave_at_step_boundaries():
    """Batch membership changes between denoise steps: a stream can be
    held out (preempted) mid-chunk and resume later; a new stream can
    join mid-flight of others.  Chunks complete correctly either way."""
    cfg = tiny_cfg()
    ex = BatchedChunkExecutor(cfg=cfg, max_streams=3)
    for sid in (0, 1, 2):
        ex.admit(sid, seed=sid)
    ex.begin_chunk(0, FID_HI, 0.0)
    ex.begin_chunk(1, FID_HI, 0.0)
    done, _ = ex.run_step([0, 1])              # both advance one step
    assert done == [] and ex.inflight[0].step == ex.inflight[1].step == 1
    # stream 1 preempted at the step boundary; 2 joins with a fresh chunk
    ex.begin_chunk(2, FID_HI, 0.0)
    done, _ = ex.run_step([0, 2])
    assert ex.inflight[0].step == 2 and ex.inflight[2].step == 1
    assert ex.inflight[1].step == 1            # untouched while held out
    # drive stream 0 to completion (steps=2 -> one clean pass remains)
    done, _ = ex.run_step([0])
    assert done == [0] and 0 not in ex.inflight
    assert len(ex.chunks[0]) == 1 and ex.pool.chunks[0] == 1
    # stream 1 resumes and finishes alongside 2 (both at step 1:
    # one denoise step + the clean pass remain)
    finished = []
    for _ in range(2):
        done, _ = ex.run_step([1, 2])
        finished += done
    assert sorted(finished) == [1, 2]
    assert len(ex.chunks[1]) == len(ex.chunks[2]) == 1
    # sub-batches must share one fidelity configuration
    ex.begin_chunk(0, FID_HI, 0.0)
    ex.begin_chunk(1, FID_LO, 0.0)
    with pytest.raises(AssertionError):
        ex.run_step([0, 1])


def test_pool_admit_defer_and_reuse():
    """A full pool no longer raises: the stream is parked host-side
    (evict-or-defer signal) and joins once pages free up."""
    cfg = tiny_cfg()
    ex = BatchedChunkExecutor(cfg=cfg, max_streams=2)
    assert ex.admit(0, seed=0) and ex.admit(1, seed=1)
    assert ex.pool.free_pages == 0 and not ex.pool.can_admit()
    assert not ex.admit(2, seed=2)             # deferred, NOT an error
    assert ex.pool.spilled(2) and not ex.pool.resident(2)
    ex.retire(0)
    assert ex.ensure_resident(2)               # pages reused
    assert ex.pool.chunks[2] == 0
    ex.pool.ledger.check()


def test_readmitted_sid_uses_fresh_cond():
    """Regression: retiring a stream and re-admitting the same sid must
    serve the NEW conditioning, not a stale cached context (the
    boundary cache is keyed by (sids, fills, fidelity), which collides
    across admissions)."""
    cfg = tiny_cfg()
    p = nondegenerate_params(cfg, KEY)
    ex = BatchedChunkExecutor(cfg=cfg, params=p, max_streams=1)

    def one_chunk():
        ex.begin_chunk(0, FID_HI, 0.0)
        while 0 in ex.inflight:
            ex.run_step([0])
        return np.asarray(ex.chunks[0][-1])

    ex.admit(0, seed=0)
    first = one_chunk()
    ex.retire(0)
    ex.admit(0, seed=42)                       # same sid, new cond
    second = one_chunk()
    assert not np.allclose(first, second), \
        "re-admitted stream served a stale cached context"


# ---------------------------------------------------------------------------
# numerical parity: batched == sequential per stream
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_batched_matches_sequential_per_stream():
    """Same params/cond/noise: ``serve_chunk_batched`` must reproduce
    the sequential ``serve_chunk`` per stream across fidelity switches,
    fp8 KV, sparsity, and ring wrap-around."""
    cfg = tiny_cfg(window_chunks=2)
    p = nondegenerate_params(cfg, KEY)
    B = 2
    cond = 0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                    (B, A.COND_TOKENS, cfg.d_model))
    tc = A.chunk_tokens(cfg)
    fids = [FID_HI, FID_LO, FidelityConfig(3, 0.6, 2, "bf16"), FID_HI]

    seq_caches = [A.init_cache(cfg, p, cond[i:i + 1]) for i in range(B)]
    bc = A.init_batched_cache(cfg, p, cond)
    for c, fid in enumerate(fids):             # wraps the 2-chunk ring
        # SAME noise for every stream: any cross-stream output
        # difference can only come from the per-stream conds/caches,
        # guarding against a degenerate model that ignores context
        noise = jax.random.normal(jax.random.PRNGKey(c * 100),
                                  (1, tc, A.LATENT_CH))
        noises = [noise for _ in range(B)]
        xb, bc = A.serve_chunk_batched(cfg, p, bc,
                                       jnp.concatenate(noises, 0), fid)
        assert not np.allclose(np.asarray(xb[0]), np.asarray(xb[1])), \
            "outputs ignore the KV context (degenerate adaLN gates?)"
        for i in range(B):
            xs, seq_caches[i] = A.serve_chunk(cfg, p, seq_caches[i],
                                              noises[i], fid)
            np.testing.assert_allclose(np.asarray(xb[i]),
                                       np.asarray(xs[0]),
                                       rtol=1e-4, atol=2e-4)
    assert list(bc["chunks"]) == [len(fids)] * B


@pytest.mark.slow
def test_staggered_join_matches_sequential():
    """A stream joining at a chunk boundary (heterogeneous fills in one
    sub-batch) stays numerically on the sequential trajectory."""
    cfg = tiny_cfg(window_chunks=3)
    p = nondegenerate_params(cfg, KEY)
    cond = 0.02 * jax.random.normal(jax.random.PRNGKey(7),
                                    (2, A.COND_TOKENS, cfg.d_model))
    tc = A.chunk_tokens(cfg)
    fid = FID_HI

    def noise(seed):
        return jax.random.normal(jax.random.PRNGKey(seed),
                                 (1, tc, A.LATENT_CH))

    s0 = A.init_cache(cfg, p, cond[0:1])
    s1 = A.init_cache(cfg, p, cond[1:2])
    bc = A.init_batched_cache(cfg, p, cond)
    # stream 0 runs two chunks alone (single-row sub-batch view)
    for c in range(2):
        xs, s0 = A.serve_chunk(cfg, p, s0, noise(c), fid)
        sub = {"k": bc["k"][:, :1], "v": bc["v"][:, :1],
               "chunks": bc["chunks"][:1]}
        xb, sub = A.serve_chunk_batched(cfg, p, sub, noise(c), fid)
        bc["k"] = bc["k"].at[:, :1].set(sub["k"])
        bc["v"] = bc["v"].at[:, :1].set(sub["v"])
        bc["chunks"][:1] = sub["chunks"]
        np.testing.assert_allclose(np.asarray(xb[0]), np.asarray(xs[0]),
                                   rtol=1e-4, atol=2e-4)
    # stream 1 joins: fills (2, 0) in ONE batch
    x0, s0 = A.serve_chunk(cfg, p, s0, noise(10), fid)
    x1, s1 = A.serve_chunk(cfg, p, s1, noise(11), fid)
    xb, bc = A.serve_chunk_batched(
        cfg, p, bc, jnp.concatenate([noise(10), noise(11)], 0), fid)
    np.testing.assert_allclose(np.asarray(xb[0]), np.asarray(x0[0]),
                               rtol=1e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(xb[1]), np.asarray(x1[0]),
                               rtol=1e-4, atol=2e-4)
