"""Page-granular KV pool conformance suite.

Four angles on the paged executor (serve/batcher.py KVPool):
  * layout parity — gather-by-page-table reassembles EXACTLY (bitwise)
    the stacked chunk-ring context the whole-slot pool kept per stream;
  * model parity — ``serve_chunk_batched`` from a page-table-assembled
    cache is bitwise-identical to the stacked-ring layout across window
    sizes, fp8/bf16 KV, and join/leave sequences;
  * oversubscription conformance — an executor whose pool holds half
    the streams completes all of them with bit-identical chunks to the
    fully-resident run (spill/restore loses nothing);
  * pool invariants — hypothesis-driven admit/evict/restore/append/
    release sequences preserve page conservation, unique ownership,
    release idempotence, and page-table/mask consistency.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.fidelity import FidelityConfig
from repro.models import ardit as A
from repro.models import kvcache
from repro.serve.batcher import BatchedChunkExecutor, KVPool, PageLedger

from test_batcher import nondegenerate_params, tiny_cfg

KEY = jax.random.PRNGKey(0)


def mk_pool(cfg, params, max_streams, conds):
    pool = KVPool(cfg, params, max_streams)
    for i in range(conds.shape[0]):
        assert pool.admit(i, conds[i:i + 1])
    return pool


def full_view(pool, sids):
    """Full-capacity stacked-layout view assembled through page tables."""
    k, v = pool.gather(sids, n_ring=pool.cfg.ardit_window_chunks)
    return k, v


# ---------------------------------------------------------------------------
# layout parity: gather/write through page tables == stacked chunk ring
# ---------------------------------------------------------------------------

def test_gather_pages_matches_manual_assembly():
    """Pure-layout check: gather_pages is the exact sink+ring
    permutation, independent of the model."""
    L, n_pages, P, H, D = 2, 8, 7, 1, 3
    sink, tc = 5, 7
    pool = jnp.asarray(
        np.random.default_rng(0).normal(size=(L, n_pages, P, H, D)),
        jnp.float32)
    tables = np.array([[0, 3, 5], [2, 6, 1]])
    for n_ring in range(3):
        got = np.asarray(kvcache.gather_pages(
            pool, jnp.asarray(tables, jnp.int32), sink, tc, n_ring))
        pn = np.asarray(pool)
        for b, tab in enumerate(tables):
            parts = [pn[:, tab[0], :sink]]
            parts += [pn[:, tab[1 + r], :tc] for r in range(n_ring)]
            np.testing.assert_array_equal(
                got[:, b], np.concatenate(parts, axis=1))


@pytest.mark.parametrize("quant", ["bf16", "fp8"])
def test_paged_pool_tracks_stacked_ring_bitwise(quant):
    """Appending chunks through the page pool reproduces the stacked
    ring cache (init_batched_cache + append_chunk_kv_batched) bit for
    bit, through ring wrap-around, for both KV dtypes."""
    cfg = tiny_cfg(window_chunks=2)
    p = nondegenerate_params(cfg, KEY)
    B, w = 2, cfg.ardit_window_chunks
    cond = 0.02 * jax.random.normal(jax.random.PRNGKey(3),
                                    (B, A.COND_TOKENS, cfg.d_model))
    tc = A.chunk_tokens(cfg)
    ring = A.init_batched_cache(cfg, p, cond)
    pool = mk_pool(cfg, p, B, cond)
    cap = A.cache_capacity(cfg)
    for c in range(w + 2):                     # wraps the ring twice
        kv = {n: jax.random.normal(
                  jax.random.PRNGKey(10 * c + i),
                  (cfg.n_layers, B, tc, cfg.n_kv_heads, cfg.head_dim))
              for i, n in enumerate(("k", "v"))}
        if quant == "fp8":
            kv = {n: a.astype(jnp.float8_e4m3fn) for n, a in kv.items()}
        ring = A.append_chunk_kv_batched(cfg, ring, kv)
        pool.append([0, 1], kv, quant="bf16")  # kv already cast above
        kf, vf = full_view(pool, [0, 1])
        assert kf.shape == (cfg.n_layers, B, cap, cfg.n_kv_heads,
                            cfg.head_dim)
        np.testing.assert_array_equal(np.asarray(kf),
                                      np.asarray(ring["k"]))
        np.testing.assert_array_equal(np.asarray(vf),
                                      np.asarray(ring["v"]))
        assert [pool.chunks[i] for i in range(B)] \
            == list(np.asarray(ring["chunks"]))
    pool.ledger.check()


def test_spill_restore_is_bitexact():
    """Evict -> (pages get dirtied by another stream) -> restore must
    reproduce the stream's context bit for bit."""
    cfg = tiny_cfg(window_chunks=2)
    p = nondegenerate_params(cfg, KEY)
    cond = 0.02 * jax.random.normal(jax.random.PRNGKey(5),
                                    (2, A.COND_TOKENS, cfg.d_model))
    tc = A.chunk_tokens(cfg)
    pool = KVPool(cfg, p, max_streams=1)       # room for ONE stream
    assert pool.admit(0, cond[0:1])
    kv = {n: jax.random.normal(jax.random.PRNGKey(i),
                               (cfg.n_layers, 1, tc, cfg.n_kv_heads,
                                cfg.head_dim))
          for i, n in enumerate(("k", "v"))}
    pool.append([0], kv, quant="bf16")
    k_before, v_before = full_view(pool, [0])
    k_before, v_before = np.asarray(k_before), np.asarray(v_before)

    pool.evict(0)
    assert pool.spilled(0) and not pool.resident(0)
    # dirty the recycled pages with a different stream's KV
    assert pool.admit(1, cond[1:2])
    dirty = {n: 7.0 + a for n, a in kv.items()}
    pool.append([1], dirty, quant="bf16")
    pool.release(1)

    assert pool.restore(0)
    assert pool.chunks[0] == 1
    k_after, v_after = full_view(pool, [0])
    np.testing.assert_array_equal(np.asarray(k_after), k_before)
    np.testing.assert_array_equal(np.asarray(v_after), v_before)
    pool.ledger.check()


# ---------------------------------------------------------------------------
# model parity: serve_chunk_batched from a paged view == stacked ring
# ---------------------------------------------------------------------------

def _paged_serve_chunk(cfg, p, pool, sids, noise, fid):
    """Run ``serve_chunk_batched`` from a page-table-assembled cache and
    ring-write the produced chunk KV back into the pool (the paged
    executor's data path, expressed through the reference entry point)."""
    w = cfg.ardit_window_chunks
    tc = A.chunk_tokens(cfg)
    chunks = np.asarray([pool.chunks[s] for s in sids], np.int64)
    kf, vf = pool.gather(sids, n_ring=w)
    cache = {"k": kf, "v": vf, "chunks": chunks}
    x, cache2 = A.serve_chunk_batched(cfg, p, cache, noise, fid)
    # extract the appended chunk (already in pool dtype) and page it in
    slots = np.asarray(kvcache.chunk_slot(chunks, w, A.COND_TOKENS, tc))
    nk = jnp.stack([cache2["k"][:, i, s:s + tc]
                    for i, s in enumerate(slots)], axis=1)
    nv = jnp.stack([cache2["v"][:, i, s:s + tc]
                    for i, s in enumerate(slots)], axis=1)
    pool.append(sids, {"k": nk, "v": nv}, quant="bf16")
    return x


@pytest.mark.slow
@pytest.mark.parametrize("window_chunks", [2, 3])
def test_serve_chunk_batched_paged_vs_ring_bitwise(window_chunks):
    """The tentpole parity claim: page-table layout == stacked-ring
    layout, bitwise, across fidelity windows, fp8/bf16 KV, sparsity,
    and ring wrap-around."""
    cfg = tiny_cfg(window_chunks=window_chunks)
    p = nondegenerate_params(cfg, KEY)
    B = 2
    cond = 0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                    (B, A.COND_TOKENS, cfg.d_model))
    tc = A.chunk_tokens(cfg)
    fids = [FidelityConfig(2, 0.0, 2, "bf16"),
            FidelityConfig(2, 0.9, 1, "fp8"),
            FidelityConfig(2, 0.6, window_chunks, "bf16"),
            FidelityConfig(2, 0.0, 2, "bf16")]  # wraps the ring

    ring = A.init_batched_cache(cfg, p, cond)
    pool = mk_pool(cfg, p, B, cond)
    for c, fid in enumerate(fids):
        noise = jnp.concatenate(
            [jax.random.normal(jax.random.PRNGKey(c * 100),
                               (1, tc, A.LATENT_CH))] * B, axis=0)
        x_ring, ring = A.serve_chunk_batched(cfg, p, ring, noise, fid)
        x_paged = _paged_serve_chunk(cfg, p, pool, [0, 1], noise, fid)
        # exact match: same executable over bit-identical caches
        np.testing.assert_array_equal(np.asarray(x_paged),
                                      np.asarray(x_ring))
        kf, vf = full_view(pool, [0, 1])
        np.testing.assert_array_equal(np.asarray(kf),
                                      np.asarray(ring["k"]))
        np.testing.assert_array_equal(np.asarray(vf),
                                      np.asarray(ring["v"]))


@pytest.mark.slow
def test_paged_join_leave_matches_ring_bitwise():
    """Join/leave sequence: stream 0 runs two chunks alone
    (heterogeneous fills), then stream 1 joins — the paged path must
    stay bitwise on the stacked-ring trajectory throughout."""
    cfg = tiny_cfg(window_chunks=3)
    p = nondegenerate_params(cfg, KEY)
    cond = 0.02 * jax.random.normal(jax.random.PRNGKey(7),
                                    (2, A.COND_TOKENS, cfg.d_model))
    tc = A.chunk_tokens(cfg)
    fid = FidelityConfig(2, 0.0, 2, "bf16")

    def noise(seed, b=1):
        one = jax.random.normal(jax.random.PRNGKey(seed),
                                (1, tc, A.LATENT_CH))
        return jnp.concatenate([one] * b, axis=0)

    ring = A.init_batched_cache(cfg, p, cond)
    pool = mk_pool(cfg, p, 2, cond)
    for c in range(2):                         # stream 0 alone
        sub = {"k": ring["k"][:, :1], "v": ring["v"][:, :1],
               "chunks": ring["chunks"][:1]}
        x_r, sub = A.serve_chunk_batched(cfg, p, sub, noise(c), fid)
        ring["k"] = ring["k"].at[:, :1].set(sub["k"])
        ring["v"] = ring["v"].at[:, :1].set(sub["v"])
        ring["chunks"][:1] = sub["chunks"]
        x_p = _paged_serve_chunk(cfg, p, pool, [0], noise(c), fid)
        np.testing.assert_array_equal(np.asarray(x_p), np.asarray(x_r))
    # stream 1 joins: fills (2, 0) in ONE sub-batch
    x_r, ring = A.serve_chunk_batched(cfg, p, ring, noise(10, b=2), fid)
    x_p = _paged_serve_chunk(cfg, p, pool, [0, 1], noise(10, b=2), fid)
    np.testing.assert_array_equal(np.asarray(x_p), np.asarray(x_r))
    kf, vf = full_view(pool, [0, 1])
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(ring["k"]))
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(ring["v"]))


# ---------------------------------------------------------------------------
# oversubscription conformance: spill/restore loses nothing
# ---------------------------------------------------------------------------

def _drive_round_robin(ex, sids, n_chunks, fid, streams=None):
    """One stream at a time (single-row sub-batches keep the jitted
    shapes identical between runs) with eviction-aware residency."""
    for _ in range(n_chunks):
        for sid in sids:
            if streams is not None:
                for s in sids:
                    streams[s].credit = float(len(ex.chunks[s]))
            assert ex.ensure_resident(sid, streams, protect=[sid])
            ex.begin_chunk(sid, fid, 0.0)
            while sid in ex.inflight:
                ex.run_step([sid])
    return {sid: [np.asarray(c) for c in ex.chunks[sid]] for sid in sids}


@pytest.mark.slow
def test_oversubscribed_executor_matches_unconstrained():
    """2x pool capacity streams complete through eviction/restore with
    chunks bitwise-identical to the everyone-resident run — the
    acceptance bar for credit-aware oversubscription."""
    from repro.core.types import Stream
    cfg = tiny_cfg(window_chunks=2)
    p = nondegenerate_params(cfg, KEY)
    fid = FidelityConfig(2, 0.0, 2, "bf16")
    sids = [0, 1, 2, 3]
    n_chunks = 2

    full = BatchedChunkExecutor(cfg=cfg, params=p, max_streams=4)
    for sid in sids:
        assert full.admit(sid, seed=sid)
    want = _drive_round_robin(full, sids, n_chunks, fid)

    over = BatchedChunkExecutor(cfg=cfg, params=p, max_streams=2)
    streams = {sid: Stream(sid=sid, arrival=0.0, target_chunks=n_chunks,
                           chunk_seconds=1.0, home=0, ttfc_slack=1e9)
               for sid in sids}
    admitted = [over.admit(sid, seed=sid) for sid in sids]
    assert admitted == [True, True, False, False]   # overflow defers
    got = _drive_round_robin(over, sids, n_chunks, fid, streams=streams)

    assert over.evictions > 0 and over.restores > 0
    for sid in sids:
        assert len(got[sid]) == n_chunks
        for a, b in zip(got[sid], want[sid]):
            np.testing.assert_array_equal(a, b)
    over.pool.ledger.check()


# ---------------------------------------------------------------------------
# hypothesis: pool invariants under arbitrary op sequences
# ---------------------------------------------------------------------------
# Guarded import (as in test_properties.py) — but only these two tests
# depend on hypothesis, so the parity suite above must still run when
# it is absent: skip the tests, not the module.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                            # pragma: no cover
    given = None


def _ledger_invariants(ops, w, cap_streams):
    """Page conservation (used + free == n_pages, mirrored accounting
    agrees), unique page ownership, no double-free, release idempotence,
    and append landing in the table entry ``1 + c % W``."""
    pps = kvcache.pages_per_stream(w)
    led = PageLedger(cap_streams * pps, pps)
    for op, sid in ops:
        if op == "admit" and not led.resident(sid) \
                and sid not in led.spilled:
            if led.can_admit():
                table = led.take(sid)
                assert len(table) == pps
            else:
                led.spilled.add(sid)           # parked (defer signal)
                led.chunks[sid] = 0
        elif op == "evict" and led.resident(sid):
            freed = led.drop(sid, spill=True)
            assert freed is not None and len(freed) == pps
            assert sid in led.spilled
        elif op == "restore" and sid in led.spilled and led.can_admit():
            led.take(sid, chunks=led.chunks[sid])
        elif op == "append" and led.resident(sid):
            page = led.append_page(sid)
            assert page == led.tables[sid][1 + led.chunks[sid] % w]
            led.chunks[sid] += 1
        elif op == "release":
            led.drop(sid, spill=False)
            assert not led.resident(sid) and sid not in led.spilled
        elif op == "double_release":
            led.drop(sid, spill=False)
            assert led.drop(sid, spill=False) is None   # idempotent
        led.check()                            # invariants after EVERY op
    # full teardown returns every page
    for sid in list(led.tables) + list(led.spilled):
        led.drop(sid, spill=False)
    led.check()
    assert led.free_pages == led.n_pages


def _mask_within_extent(n, w, window):
    """Page-table/mask consistency: every token
    ``batched_context_mask`` marks visible lies inside the extent the
    executor gathers (sink + min(fill, W) ring slots) — the property
    that makes extent-sliced page gathering safe."""
    cfg = dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=w)
    tc = A.chunk_tokens(cfg)
    mask = A.batched_context_mask(cfg, np.array([n]), window)[0]
    extent = A.COND_TOKENS + min(n, w) * tc
    assert not mask[extent:].any()
    # the visible ring slots are exactly the pages holding the last
    # min(window, n, W) chunks
    visible_chunks = range(max(0, n - min(window, n, w)), n)
    expect_slots = {c % w for c in visible_chunks}
    got_slots = {int(i) // tc
                 for i in np.flatnonzero(mask[A.COND_TOKENS:])}
    assert got_slots <= expect_slots
    if min(window, n, w) == min(n, w):         # full-window visibility
        assert got_slots == expect_slots


if given is not None:
    SETTINGS = dict(max_examples=50, deadline=None)
    OPS = st.lists(
        st.tuples(st.sampled_from(["admit", "evict", "restore", "append",
                                   "release", "double_release"]),
                  st.integers(0, 5)),
        max_size=60)

    @settings(**SETTINGS)
    @given(ops=OPS, w=st.integers(1, 4), cap_streams=st.integers(1, 3))
    def test_ledger_invariants_under_arbitrary_sequences(ops, w,
                                                         cap_streams):
        _ledger_invariants(ops, w, cap_streams)

    @settings(**SETTINGS)
    @given(n=st.integers(0, 12), w=st.integers(1, 6),
           window=st.integers(1, 7))
    def test_mask_stays_within_gathered_extent(n, w, window):
        _mask_within_extent(n, w, window)
else:
    # deterministic fallback so the invariants still get SOME coverage
    # (and the suite reports the missing dependency) when hypothesis is
    # not installed
    @pytest.mark.parametrize("w,cap_streams", [(1, 1), (2, 2), (4, 3)])
    def test_ledger_invariants_deterministic(w, cap_streams):
        rng = np.random.default_rng(w * 10 + cap_streams)
        ops = [(str(rng.choice(["admit", "evict", "restore", "append",
                                "release", "double_release"])),
                int(rng.integers(0, 6))) for _ in range(120)]
        _ledger_invariants(ops, w, cap_streams)

    @pytest.mark.parametrize("n", [0, 1, 3, 5, 12])
    @pytest.mark.parametrize("w", [1, 2, 3, 6])
    @pytest.mark.parametrize("window", [1, 2, 7])
    def test_mask_stays_within_gathered_extent(n, w, window):
        _mask_within_extent(n, w, window)
