"""Sim-vs-real calibration loop + session front door.

The fit is exercised two ways: unit-level (ratio/alpha recovery from
synthetic measurements) and end-to-end (a real tiny-model session is
fitted, the SAME specs replay through the calibrated simulator, and the
QoE/TTFC agreement must land inside the pinned CI tolerances — the
check ``check_bench.py --fleet`` gates nightly)."""
import dataclasses

import pytest

from repro.core.fidelity import HIGHEST_QUALITY
from repro.profiler.profiles import CalibratedProfile, get_profile
from repro.sched_sim.calibration import (QOE_ABS_TOL, TTFC_REL_TOL,
                                         agreement, fit_batch_alpha,
                                         fit_ratios, fit_session)
from repro.sched_sim.metrics import summarize
from repro.sched_sim.policies import make_policy
from repro.sched_sim.simulator import SimConfig, Simulator
from repro.sched_sim.workloads import steady
from repro.serve.session import cap_specs
from test_session import make_session

from repro.sched_sim.frontdoor import FrontDoorConfig


# ---------------------------------------------------------------------------
# fit primitives
# ---------------------------------------------------------------------------

def test_fit_ratios_recovers_known_slowdown():
    profile = get_profile()
    measured = {p.fidelity.key: 1.7 * p.latency
                for p in profile.points[:4]}
    ratios = fit_ratios(measured, profile)
    assert set(ratios) == set(measured)
    for r in ratios.values():
        assert r == pytest.approx(1.7)
    # unknown keys and non-measurements are dropped, not guessed
    assert fit_ratios({"bogus": 1.0,
                       profile.points[0].fidelity.key: 0.0},
                      profile) == {}


def test_calibrated_profile_applies_ratios_and_scale():
    base = get_profile()
    key = base.points[0].fidelity.key
    from repro.profiler.profiles import calibrate_profile
    cal = calibrate_profile(base, {key: 2.0}, scale=3.0)
    p0 = base.by_key[key]
    assert cal.latency(p0.fidelity) == pytest.approx(2.0 * p0.latency)
    other = base.points[1]
    assert cal.latency(other.fidelity) == pytest.approx(
        3.0 * other.latency)


def test_fit_batch_alpha_exact_recovery():
    # t_b = t1 * (1 + alpha (b - 1)) with alpha = 0.15
    t1, alpha = 0.5, 0.15
    times = {b: t1 * (1.0 + alpha * (b - 1)) for b in (1, 2, 3, 4)}
    assert fit_batch_alpha(times) == pytest.approx(alpha)
    assert fit_batch_alpha({2: 1.0}) is None          # no t_1
    assert fit_batch_alpha({1: 1.0}) is None          # no b > 1 point
    # superlinear "speedup" is clamped to zero, not extrapolated
    assert fit_batch_alpha({1: 1.0, 4: 0.5}) == 0.0


def test_agreement_tolerance_gate():
    @dataclasses.dataclass
    class S:
        qoe: float
        ttfc: float
    ok = agreement(S(0.9, 2.0), S(0.8, 3.0))
    assert ok["ok"] and ok["qoe_delta"] == pytest.approx(0.1)
    assert ok["ttfc_rel_err"] == pytest.approx(0.5)
    bad = agreement(S(0.9, 2.0), S(0.5, 2.0))
    assert not bad["ok"]                # qoe delta 0.4 > 0.25
    far = agreement(S(0.9, 1.0), S(0.9, 3.0))
    assert not far["ok"]                # ttfc rel 2.0 > 1.0


# ---------------------------------------------------------------------------
# end-to-end: real tiny session -> fitted report -> calibrated replay
# ---------------------------------------------------------------------------

def _small_specs():
    # the fleet benchmark's calibration cell (seed 7, 3 chunks): long
    # enough that TTFC is queueing-dominated on both sides — ultra-short
    # runs leave the real session's lockstep-batch service discipline
    # (which the sequential single-worker sim does not model) as the
    # only signal, and agreement is then about luck, not calibration
    return cap_specs(steady(n=3, rate=2.0, seed=7), 3)


def test_fit_session_and_calibrated_replay_agree():
    specs = _small_specs()
    sess = make_session(executor="batched")
    for spec in specs:
        sess.submit(spec)
    real = summarize(sess.run())
    report = fit_session(sess)
    # the session measured at least the top config; scale is its ratio
    assert HIGHEST_QUALITY.key in report.ratios
    assert report.scale == pytest.approx(
        report.ratios[HIGHEST_QUALITY.key])
    assert report.chunk_seconds == pytest.approx(sess.chunk_seconds)

    cfg = report.sim_config(n_workers=1, workers_per_node=1)
    assert cfg.profile is not None and cfg.chunk_seconds > 0.0
    sim = summarize(Simulator(cfg, specs, make_policy(
        "slackserve", model=report.model,
        profile=report.profile())).run())
    agr = agreement(real, sim)
    assert agr["ok"], agr               # the pinned CI tolerance
    assert agr["qoe_tol"] == QOE_ABS_TOL
    assert agr["ttfc_rel_tol"] == TTFC_REL_TOL


def test_fit_session_batch_alpha_passthrough():
    sess = make_session(executor="batched")
    for spec in _small_specs():
        sess.submit(spec)
    sess.run()
    report = fit_session(sess, batch_step_times={1: 0.2, 2: 0.24})
    assert report.batch_alpha == pytest.approx(0.2)
    cfg = report.sim_config()
    assert cfg.batch_alpha == pytest.approx(0.2)


# ---------------------------------------------------------------------------
# session front door: admission gating in the REAL driver
# ---------------------------------------------------------------------------

def test_session_front_door_accounts_every_arrival():
    """An overloaded live session with a tiny queue must shed load
    through the front door — and every submitted stream must end
    accounted: served or deliberately rejected, never lost."""
    specs = cap_specs(steady(n=6, rate=50.0, seed=1), 2)
    sess = make_session(
        executor="batched",
        front_door=FrontDoorConfig(slo_ttfc_factor=0.5, queue_limit=1,
                                   max_queue_wait=0.5))
    for spec in specs:
        sess.submit(spec)
    res = sess.run()
    adm = res.admission
    assert adm["waiting_at_end"] == 0
    assert adm["admitted"] + adm["rejected"] == len(specs)
    assert adm["rejected"] > 0          # the tight SLO really shed load
    assert len(res.streams) == adm["admitted"]
    assert all(s.done for s in res.streams.values())
    for s in res.streams.values():
        assert len(s.ready_times) == s.target_chunks


def test_session_front_door_admits_everyone_when_idle():
    specs = cap_specs(steady(n=2, rate=1.0, seed=0), 2)
    sess = make_session(executor="batched",
                        front_door=FrontDoorConfig())
    for spec in specs:
        sess.submit(spec)
    res = sess.run()
    assert res.admission["admitted"] == len(specs)
    assert res.admission["rejected"] == 0
    # live sessions cannot provision hardware: autoscale forced off
    assert res.admission["scale_outs"] == 0
    assert sess.front_door.cfg.autoscale is False
