"""Content-adaptive step cache (``models/stepcache.py``) — the fifth
fidelity axis.

Fast tier: candidate-space / fidelity-key invariants, the
permutation-deterministic Pareto frontier, BMPR routing over cache-on
points under the quality floor, the analytic latency/quality pricing,
calibration's measured cache-speedup fit, and the ``StepCacheManager``
threshold / motion-regularizer / consecutive-cap state machine on
synthetic latents.

Slow tier (JAX-compiling): the real ``BatchedChunkExecutor`` —
``cache=off`` never constructs the manager, ``cache=aggressive`` hits
and skips whole jitted launches with bounded output drift, a cache-on
row sharing a fused group leaves its cache-off neighbors bit-exact, and
spill/export/retire drop cache state safely mid-run.
"""
import dataclasses
import random

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.bmpr import BMPR, pareto_frontier
from repro.core.fidelity import (CACHE_LEVELS, HIGHEST_QUALITY,
                                 FidelityConfig, candidate_space)
from repro.models import ardit as A
from repro.models.stepcache import (MAX_CONSECUTIVE, THRESHOLDS,
                                    StepCacheManager)
from repro.profiler.profiles import (A_CACHE, ModelProfile,
                                     calibrate_profile, chunk_latency,
                                     chunk_quality, get_profile,
                                     step_cache_latency_factor)
from repro.sched_sim.calibration import fit_cache_speedups
from repro.serve.batcher import BatchedChunkExecutor, compose_batch

KEY = jax.random.PRNGKey(0)

OFF = FidelityConfig(4, 0.0, 3, "bf16")
AGG = OFF._replace(cache="aggressive")


# ---------------------------------------------------------------------------
# fidelity axis + profile surfaces
# ---------------------------------------------------------------------------

def test_candidate_space_sizes_and_keys():
    base = candidate_space()
    full = candidate_space(step_cache=True)
    assert len(base) == 90 and len(full) == 90 * len(CACHE_LEVELS)
    assert len({c.key for c in full}) == len(full)
    # every base config is the cache=off member of the full space,
    # with its key (and therefore every existing EMA/ratio) unchanged
    assert all(c.cache == "off" for c in base)
    assert set(base) <= set(full)
    assert FidelityConfig().key == "S4_r0.0_W7_bf16"
    assert AGG.key == OFF.key + "_ca"
    assert OFF._replace(cache="conservative").key == OFF.key + "_cc"


def test_cache_pricing_faster_and_lower_quality():
    for level in ("conservative", "aggressive"):
        cfg = OFF._replace(cache=level)
        assert chunk_latency(cfg) < chunk_latency(OFF)
        assert chunk_latency(cfg) == pytest.approx(
            chunk_latency(OFF) * step_cache_latency_factor(level, OFF.steps))
        assert chunk_quality(cfg) == pytest.approx(
            chunk_quality(OFF) - A_CACHE[level])
    # aggressive hits more often than conservative: strictly faster
    assert chunk_latency(AGG) < chunk_latency(
        OFF._replace(cache="conservative"))
    # a 1-step chunk has no cacheable step: factor degenerates to 1
    assert step_cache_latency_factor("aggressive", 1) == 1.0


def test_pareto_frontier_deterministic_under_permutation():
    prof = get_profile(step_cache=True)
    ref = pareto_frontier(prof)
    rng = random.Random(0)
    for _ in range(5):
        pts = list(prof.points)
        rng.shuffle(pts)
        got = pareto_frontier(ModelProfile(prof.model, tuple(pts)))
        assert [p.fidelity.key for p in got.points] == \
            [p.fidelity.key for p in ref.points]
        assert got.q_floor == ref.q_floor


def test_bmpr_routes_cache_under_tight_budget_with_floor():
    router = BMPR(get_profile(step_cache=True))
    floor = router.frontier.q_floor
    # slack-rich: the top-quality point is cache=off (cache only costs
    # quality when latency is no object)
    assert router.select(10.0).fidelity.cache == "off"
    # some budget band must be served by a cache-on point at or above
    # the quality floor — the axis actually participates in routing
    cache_on = [p for p in router.eligible_points()
                if p.fidelity.cache != "off"]
    assert cache_on, "no cache-on point survived the frontier + floor"
    d = router.select(cache_on[0].latency)
    assert d.fidelity.cache != "off"
    assert d.quality >= floor and d.mode == "quality"


def test_fit_cache_speedups_and_calibrated_fallback():
    off_key, ca_key = OFF.key, AGG.key
    cc = OFF._replace(cache="conservative")
    measured = {off_key: 0.50, ca_key: 0.35, cc.key: 0.45,
                "S2_r0.5_W5_bf16": 0.30}        # no cache sibling: ignored
    sp = fit_cache_speedups(measured)
    assert sp == {"aggressive": pytest.approx(0.70),
                  "conservative": pytest.approx(0.90)}
    # fallback chain: a cache-on config the run never measured prices
    # as its measured off sibling times the fitted speedup
    prof = calibrate_profile(get_profile(step_cache=True),
                             {off_key: 2.0}, scale=2.0, cache_speedups=sp)
    assert prof.latency(AGG) == pytest.approx(
        chunk_latency(OFF) * 2.0 * 0.70)
    # and with no fitted speedup, the analytic factor
    prof2 = calibrate_profile(get_profile(step_cache=True),
                              {off_key: 2.0}, scale=2.0)
    assert prof2.latency(AGG) == pytest.approx(
        chunk_latency(OFF) * 2.0
        * step_cache_latency_factor("aggressive", OFF.steps))


# ---------------------------------------------------------------------------
# StepCacheManager state machine (synthetic latents, no model)
# ---------------------------------------------------------------------------

def _manager(tokens=8, ch=4, layers=2, slots=2):
    return StepCacheManager(slots, tokens, ch, layers)


def _feed(mgr, sid, velocities, dt=0.25):
    """Record a sequence of computed steps with the given velocities."""
    x = jax.numpy.zeros((1, 8, 4))
    k = jax.numpy.ones((2, 8, 1, 2))
    for v in velocities:
        x_new = x - dt * v
        mgr.record_step(sid, x, x_new, dt, k)
        x = x_new


def test_manager_hits_on_stable_misses_on_changing_residuals():
    ones = jax.numpy.ones((1, 8, 4))
    # low motion content: identical velocities -> delta 0 -> hit
    mgr = _manager()
    mgr.begin_chunk(0, None)
    assert not mgr.should_hit(0, "aggressive")     # no delta yet
    _feed(mgr, 0, [ones, ones])
    assert mgr.should_hit(0, "conservative")
    # high residual change: delta >> threshold -> miss
    mgr2 = _manager()
    mgr2.begin_chunk(1, None)
    _feed(mgr2, 1, [ones, 3.0 * ones])
    assert not mgr2.should_hit(1, "aggressive")
    assert mgr.stats()["hits"] == 1 and mgr.stats()["misses"] == 1


def test_manager_consecutive_cap_forces_recompute():
    ones = jax.numpy.ones((1, 8, 4))
    mgr = _manager()
    mgr.begin_chunk(0, None)
    _feed(mgr, 0, [ones, ones])
    x = jax.numpy.zeros((1, 8, 4))
    for _ in range(MAX_CONSECUTIVE["aggressive"]):
        assert mgr.should_hit(0, "aggressive")
        x = mgr.apply_hit(0, x, 0.25)
    assert not mgr.should_hit(0, "aggressive")     # cap reached
    # the hit step really is the AXPY x - dt * v
    np.testing.assert_allclose(np.asarray(x),
                               np.asarray(-0.5 * ones), rtol=1e-6)
    # a computed step resets the run of reuses
    _feed(mgr, 0, [ones])
    assert mgr.should_hit(0, "aggressive")


def test_manager_motion_regularizer_scales_threshold_down():
    mgr = _manager()
    base = mgr.effective_threshold("aggressive", 0.0)
    assert base == THRESHOLDS["aggressive"]
    assert mgr.effective_threshold("aggressive", 1.0) < base / 4
    # borderline delta: hits on static history, misses on high-motion
    ones = jax.numpy.ones((1, 8, 4))
    drift = 1.3 * ones                  # rel delta 0.3 < 0.5 base
    static = [jax.numpy.zeros((1, 8, 4)), jax.numpy.zeros((1, 8, 4))]
    moving = [jax.numpy.zeros((1, 8, 4)), 5 * jax.numpy.ones((1, 8, 4))]
    lo, hi = _manager(), _manager()
    lo.begin_chunk(0, static)
    hi.begin_chunk(0, moving)
    assert lo.states[0].motion == 0.0 and hi.states[0].motion > 1.0
    _feed(lo, 0, [ones, drift])
    _feed(hi, 0, [ones, drift])
    assert lo.should_hit(0, "aggressive")
    assert not hi.should_hit(0, "aggressive")


def test_manager_lifecycle_drop_and_reset():
    ones = jax.numpy.ones((1, 8, 4))
    mgr = _manager(slots=1)
    mgr.begin_chunk(0, None)
    _feed(mgr, 0, [ones, ones])
    assert mgr.should_hit(0, "aggressive")
    # reset (abort / prompt switch) keeps the slot but forgets the chunk
    mgr.reset_chunk(0)
    assert not mgr.should_hit(0, "aggressive")
    # drop frees the slot for another stream; slot exhaustion never hits
    mgr.drop(0)
    assert 0 not in mgr.states
    mgr.begin_chunk(1, None)
    mgr.begin_chunk(2, None)            # no slot left: silently untracked
    assert 1 in mgr.states and 2 not in mgr.states
    _feed(mgr, 2, [ones, ones])         # record on untracked sid: no-op
    assert not mgr.should_hit(2, "aggressive")


# ---------------------------------------------------------------------------
# executor integration (slow: compiles the reduced AR-DiT)
# ---------------------------------------------------------------------------

def tiny_cfg(window_chunks=3):
    return dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=window_chunks)


def nondegenerate_params(cfg, key):
    p = A.init_params(cfg, key)
    ks = jax.random.split(jax.random.PRNGKey(1234), 3)
    p["layers"]["mod"] = 0.2 * jax.random.normal(
        ks[0], p["layers"]["mod"].shape, p["layers"]["mod"].dtype)
    p["layers"]["mod_b"] = 0.5 + 0.2 * jax.random.normal(
        ks[1], p["layers"]["mod_b"].shape, p["layers"]["mod_b"].dtype)
    p["final_mod"] = 0.2 * jax.random.normal(
        ks[2], p["final_mod"].shape, p["final_mod"].dtype)
    return p


def _drive(ex, fid_of, targets, *, max_batch=8):
    sids = sorted(targets)
    while any(len(ex.chunks[s]) < targets[s] for s in sids):
        runnable = [s for s in sids if len(ex.chunks[s]) < targets[s]]
        for s in runnable:
            if s not in ex.inflight:
                ex.begin_chunk(s, fid_of(s), 0.0)
        for grp in compose_batch(runnable,
                                 lambda s: ex.inflight[s].fidelity,
                                 max_batch, fuse=True):
            ex.run_step(grp)


def _make_ex(cfg, params, n, **kw):
    ex = BatchedChunkExecutor(cfg=cfg, params=params,
                              max_streams=n + 1, **kw)
    for sid in range(n):
        assert ex.admit(sid, seed=sid)
    return ex


@pytest.mark.slow
def test_cache_off_never_constructs_manager():
    """The default path must not even instantiate the cache — off is
    bit-identical to the pre-cache executor by construction."""
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    ex = _make_ex(cfg, params, 2)
    _drive(ex, lambda s: OFF, {0: 2, 1: 2})
    assert ex.stepcache is None
    assert ex.cache_skipped_launches == 0


@pytest.mark.slow
def test_cache_aggressive_hits_skips_launches_bounded_drift():
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    targets = {0: 3}
    off = _make_ex(cfg, params, 1)
    _drive(off, lambda s: OFF, targets)
    agg = _make_ex(cfg, params, 1)
    _drive(agg, lambda s: AGG, targets)

    sc = agg.stepcache
    assert sc is not None and sc.hits > 0
    assert agg.cache_skipped_launches > 0
    # skipped launches are real: fewer jitted dispatches for the same
    # number of chunks (the throughput claim the bench gate holds)
    assert agg.dispatch_count < off.dispatch_count
    assert 0.0 < sc.stats()["hit_rate"] <= 0.5    # S=4: at most 2 of 4
    # reused velocities drift the output only boundedly (the modeled
    # A_CACHE quality cost), never wildly
    for a, b in zip(agg.chunks[0], off.chunks[0]):
        assert np.asarray(a).shape == np.asarray(b).shape
        assert float(np.max(np.abs(np.asarray(a) - np.asarray(b)))) < 0.5
    # EMAs attribute to the cache-on key, not the off sibling
    assert AGG.key in agg.latency_ema and OFF.key not in agg.latency_ema


@pytest.mark.slow
def test_cache_row_leaves_off_neighbors_bit_exact():
    """A cache-on row riding the same fused group must not perturb its
    cache-off neighbors: same launches, same bits for the off rows."""
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    targets = {0: 2, 1: 2}
    ref = _make_ex(cfg, params, 2)
    _drive(ref, lambda s: OFF, targets)                  # both off
    mix = _make_ex(cfg, params, 2)
    _drive(mix, lambda s: AGG if s == 1 else OFF, targets)
    assert mix.stepcache is not None and mix.stepcache.hits > 0
    # hit rows ride as shape-stable no-ops: the off row's launches are
    # unchanged, so its chunks are bit-exact
    for a, b in zip(mix.chunks[0], ref.chunks[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_export_retire_drop_cache_state_mid_run():
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    ex = _make_ex(cfg, params, 2)
    _drive(ex, lambda s: AGG, {0: 1, 1: 1})
    sc = ex.stepcache
    assert 0 in sc.states and 1 in sc.states
    # migration export drops cache state (deliberately not carried) but
    # carries the effective-window history
    state = ex.export_stream(0)
    assert 0 not in sc.states
    assert "effective_window_log" in state
    ex.import_stream(0, state)
    assert 0 not in sc.states            # re-tracks at its next chunk
    # retire frees the slot too
    ex.retire(1)
    assert 1 not in sc.states
    # the re-imported stream rejoins through the normal (bit-exact)
    # restore path and keeps serving chunks, cache re-engaging
    assert ex.ensure_resident(0)
    _drive(ex, lambda s: AGG, {0: 3})
    assert len(ex.chunks[0]) == 3
    assert 0 in sc.states
