"""Cluster simulator end-to-end: workloads, policies, metrics."""
import pytest

from repro.sched_sim import cost_model as cm
from repro.sched_sim.metrics import (stall_histogram, summarize,
                                     transfer_stats)
from repro.sched_sim.policies import SDV2Policy, make_policy
from repro.sched_sim.simulator import SimConfig, Simulator
from repro.sched_sim.workloads import (WORKLOADS, burst, pause,
                                       prompt_switch, steady, trace)


class TestWorkloads:
    def test_steady_counts_and_rate(self):
        specs = steady(n=200, rate=2.0, seed=1)
        assert len(specs) == 200
        assert all(s.frames in cm.STREAM_FRAMES for s in specs)
        # Poisson(2/s): 200 arrivals in ~100s
        assert 60 < specs[-1].arrival < 160

    def test_burst_preserves_total_and_synchronizes(self):
        specs = burst(n=200, seed=0)
        assert len(specs) == 200
        from collections import Counter
        c = Counter(s.arrival for s in specs)
        peaks = [v for v in c.values() if v >= 10]
        assert len(peaks) == 3                     # three burst points

    def test_prompt_switch_counts_by_length(self):
        specs = prompt_switch(n=100, seed=0)
        for s in specs:
            want = {81: 1, 129: 2, 161: 2, 241: 3}[s.frames]
            assert len(s.switches) == want
            assert all(0 < t < s.duration for t in s.switches)

    def test_pause_duration_fraction(self):
        specs = pause(n=50, seed=0)
        for s in specs:
            for (_, dur) in s.pauses:
                assert dur == pytest.approx(0.2 * s.duration)

    def test_trace_nonstationary(self):
        specs = trace(n=300, seed=0)
        assert len(specs) == 300
        gaps = [specs[i + 1].arrival - specs[i].arrival
                for i in range(len(specs) - 1)]
        assert max(gaps) > 5.0                     # idle gaps exist
        assert min(gaps) == 0.0                    # bursts exist


class TestEndToEnd:
    def _run(self, policy_name, n=120, workload="steady"):
        specs = WORKLOADS[workload](n=n, rate=1.0, seed=0)
        cfg = (SDV2Policy.sim_config() if policy_name == "sdv2"
               else SimConfig())
        return Simulator(cfg, specs, make_policy(policy_name)).run()

    def test_slackserve_beats_baselines(self):
        scores = {}
        for p in ("slackserve", "sdv2", "ts", "ts-chunk"):
            scores[p] = summarize(self._run(p)).qoe
        assert scores["slackserve"] > 0.8
        for p in ("sdv2", "ts", "ts-chunk"):
            assert scores["slackserve"] > scores[p], scores

    def test_ablation_order(self):
        """Fig. 12: each mechanism adds QoE."""
        qoe = {}
        for p in ("credit-only", "credit+bmpr", "credit+bmpr+rehome",
                  "slackserve"):
            qoe[p] = summarize(self._run(p)).qoe
        assert qoe["credit-only"] < qoe["credit+bmpr"]
        assert qoe["credit+bmpr"] <= qoe["credit+bmpr+rehome"] + 0.02
        assert qoe["slackserve"] >= qoe["credit+bmpr"] - 0.02

    def test_all_streams_complete(self):
        res = self._run("slackserve", n=60)
        assert all(s.done for s in res.streams.values())
        for s in res.streams.values():
            assert len(s.ready_times) == s.target_chunks
            assert len(s.deadlines) == s.target_chunks

    def test_quality_floor_bounds_degradation(self):
        """SS7.5: BMPR bounds quality loss even under pressure."""
        res = self._run("slackserve", n=150)
        s = summarize(res)
        assert s.quality > 0.985 * 81.3            # < 1.5% drop

    def test_pause_accumulates_slack(self):
        q_st = summarize(self._run("slackserve", workload="steady")).qoe
        q_pa = summarize(self._run("slackserve", workload="pause")).qoe
        assert q_pa >= q_st - 0.01                 # pause least adversarial

    def test_transfer_protocol_ordering(self):
        """Fig. 13: async-stream >= async-nostream >= sync on QoE."""
        specs = WORKLOADS["steady"](n=120, rate=1.0, seed=0)
        qoe = {}
        for proto in ("sync", "async-nostream", "async-stream"):
            res = Simulator(SimConfig(transfer_protocol=proto), specs,
                            make_policy("slackserve")).run()
            qoe[proto] = summarize(res).qoe
        assert qoe["async-stream"] >= qoe["sync"] - 0.02
        st = transfer_stats(res)
        assert st["avg_residual_ms"] <= st["avg_ms"]

    def test_stall_histogram_consistency(self):
        res = self._run("ts", n=80)
        hist = stall_histogram(res)
        total_events = sum(len(s.stall_events)
                           for s in res.streams.values())
        assert sum(hist.values()) == total_events

    def test_elastic_sp_invariants(self):
        """A donor serves at most one borrowed stream; donors and homes
        are disjoint at any dispatch."""
        specs = WORKLOADS["burst"](n=100, rate=1.0, seed=0)
        sim = Simulator(SimConfig(), specs, make_policy("slackserve"))
        res = sim.run()
        # post-run: all donations released for finished streams
        for s in res.streams.values():
            if s.done:
                assert s.sp_donor is None
