"""Cluster simulator end-to-end: workloads, policies, metrics."""
import types

import pytest

from repro.core.types import ClusterView, Stream, Worker
from repro.profiler.profiles import get_profile
from repro.sched_sim import cost_model as cm
from repro.sched_sim.frontdoor import FrontDoor, FrontDoorConfig
from repro.sched_sim.metrics import (stall_histogram, summarize,
                                     transfer_stats)
from repro.sched_sim.policies import SDV2Policy, make_policy
from repro.sched_sim.simulator import SimConfig, Simulator
from repro.sched_sim.workloads import (WORKLOADS, StreamSpec, burst,
                                       diurnal, flash_crowd, pause,
                                       prompt_switch, steady, trace)


class TestWorkloads:
    def test_steady_counts_and_rate(self):
        specs = steady(n=200, rate=2.0, seed=1)
        assert len(specs) == 200
        assert all(s.frames in cm.STREAM_FRAMES for s in specs)
        # Poisson(2/s): 200 arrivals in ~100s
        assert 60 < specs[-1].arrival < 160

    def test_burst_preserves_total_and_synchronizes(self):
        specs = burst(n=200, seed=0)
        assert len(specs) == 200
        from collections import Counter
        c = Counter(s.arrival for s in specs)
        peaks = [v for v in c.values() if v >= 10]
        assert len(peaks) == 3                     # three burst points

    def test_prompt_switch_counts_by_length(self):
        specs = prompt_switch(n=100, seed=0)
        for s in specs:
            want = {81: 1, 129: 2, 161: 2, 241: 3}[s.frames]
            assert len(s.switches) == want
            assert all(0 < t < s.duration for t in s.switches)

    def test_pause_duration_fraction(self):
        specs = pause(n=50, seed=0)
        for s in specs:
            for (_, dur) in s.pauses:
                assert dur == pytest.approx(0.2 * s.duration)

    def test_trace_nonstationary(self):
        specs = trace(n=300, seed=0)
        assert len(specs) == 300
        gaps = [specs[i + 1].arrival - specs[i].arrival
                for i in range(len(specs) - 1)]
        assert max(gaps) > 5.0                     # idle gaps exist
        assert min(gaps) == 0.0                    # bursts exist


class TestEndToEnd:
    def _run(self, policy_name, n=120, workload="steady"):
        specs = WORKLOADS[workload](n=n, rate=1.0, seed=0)
        cfg = (SDV2Policy.sim_config() if policy_name == "sdv2"
               else SimConfig())
        return Simulator(cfg, specs, make_policy(policy_name)).run()

    def test_slackserve_beats_baselines(self):
        scores = {}
        for p in ("slackserve", "sdv2", "ts", "ts-chunk"):
            scores[p] = summarize(self._run(p)).qoe
        assert scores["slackserve"] > 0.8
        for p in ("sdv2", "ts", "ts-chunk"):
            assert scores["slackserve"] > scores[p], scores

    def test_ablation_order(self):
        """Fig. 12: each mechanism adds QoE."""
        qoe = {}
        for p in ("credit-only", "credit+bmpr", "credit+bmpr+rehome",
                  "slackserve"):
            qoe[p] = summarize(self._run(p)).qoe
        assert qoe["credit-only"] < qoe["credit+bmpr"]
        assert qoe["credit+bmpr"] <= qoe["credit+bmpr+rehome"] + 0.02
        assert qoe["slackserve"] >= qoe["credit+bmpr"] - 0.02

    def test_all_streams_complete(self):
        res = self._run("slackserve", n=60)
        assert all(s.done for s in res.streams.values())
        for s in res.streams.values():
            assert len(s.ready_times) == s.target_chunks
            assert len(s.deadlines) == s.target_chunks

    def test_quality_floor_bounds_degradation(self):
        """SS7.5: BMPR bounds quality loss even under pressure."""
        res = self._run("slackserve", n=150)
        s = summarize(res)
        assert s.quality > 0.985 * 81.3            # < 1.5% drop

    def test_pause_accumulates_slack(self):
        q_st = summarize(self._run("slackserve", workload="steady")).qoe
        q_pa = summarize(self._run("slackserve", workload="pause")).qoe
        assert q_pa >= q_st - 0.01                 # pause least adversarial

    def test_transfer_protocol_ordering(self):
        """Fig. 13: async-stream >= async-nostream >= sync on QoE."""
        specs = WORKLOADS["steady"](n=120, rate=1.0, seed=0)
        qoe = {}
        for proto in ("sync", "async-nostream", "async-stream"):
            res = Simulator(SimConfig(transfer_protocol=proto), specs,
                            make_policy("slackserve")).run()
            qoe[proto] = summarize(res).qoe
        assert qoe["async-stream"] >= qoe["sync"] - 0.02
        st = transfer_stats(res)
        assert st["avg_residual_ms"] <= st["avg_ms"]

    def test_stall_histogram_consistency(self):
        res = self._run("ts", n=80)
        hist = stall_histogram(res)
        total_events = sum(len(s.stall_events)
                           for s in res.streams.values())
        assert sum(hist.values()) == total_events

    def test_elastic_sp_invariants(self):
        """A donor serves at most one borrowed stream; donors and homes
        are disjoint at any dispatch."""
        specs = WORKLOADS["burst"](n=100, rate=1.0, seed=0)
        sim = Simulator(SimConfig(), specs, make_policy("slackserve"))
        res = sim.run()
        # post-run: all donations released for finished streams
        for s in res.streams.values():
            if s.done:
                assert s.sp_donor is None


class TestSimulatorBugfixes:
    """Fail-pre-fix regressions for the simulator/metrics bug sweep."""

    def test_restore_schedules_worker_unblock(self):
        """A sync-protocol restore blocks the worker until
        ``timing.complete``; without a ``worker_unblock`` event the
        dispatcher idled until the next 3 s control tick (migrate()
        always scheduled the wake-up, _restore forgot to)."""
        sim = Simulator(SimConfig(n_workers=1,
                                  transfer_protocol="sync"),
                        [StreamSpec(0, 0.0, 81)],
                        make_policy("slackserve"))
        s = Stream(sid=0, arrival=0.0, target_chunks=7,
                   chunk_seconds=0.75, home=0, ttfc_slack=4.0)
        s.chunks_done = 2                  # evicted mid-serve, has state
        sim.view.streams[0] = s
        sim._restore(0, 0)
        assert sim.blocked_until[0] > 0.0  # the restore DID block w0
        wakeups = [(t, p) for (t, _, k, p) in sim._heap
                   if k == "worker_unblock"]
        assert (sim.blocked_until[0], 0) in wakeups

    def test_prompt_switch_aborts_inflight_batch(self):
        """A prompt switch must invalidate the in-flight batch: the
        pending step_done event still matched ``batch[wid]``, so the
        aborted chunk was credited a stale denoise step and finished
        one step EARLY under the new prompt."""
        profile = get_profile()
        specs = [StreamSpec(0, 0.0, 81, switches=(0.3,))]
        res = Simulator(SimConfig(n_workers=1), specs,
                        make_policy("slackserve")).run()
        s = res.streams[0]
        # full restart at t=0.3: the first chunk under the new prompt
        # costs the complete top-fidelity latency again (pre-fix it
        # landed one step early at 0.3 + lat - lat/steps)
        lat = profile.by_key[s.fidelity_log[0]].latency
        assert s.ready_times[0] == pytest.approx(0.3 + lat)

    def test_trace_rate_scales_intensity(self):
        """``trace`` accepted a ``rate`` argument and silently ignored
        it; now it compresses the whole trace without reshaping it."""
        t1 = trace(n=300, rate=1.0, seed=0)[-1].arrival
        t2 = trace(n=300, rate=2.0, seed=0)[-1].arrival
        assert t2 < 0.7 * t1
        # shape preserved: same stream count, same length sampling
        assert ([s.frames for s in trace(n=100, rate=3.0, seed=5)]
                == [s.frames for s in trace(n=100, rate=1.0, seed=5)])
        with pytest.raises(ValueError):
            trace(n=10, rate=0.0)

    def test_summarize_counts_unserved_streams(self):
        """An admitted stream with zero ready chunks (overload /
        max_time truncation) was silently skipped, inflating QoE;
        it must count as CPR 0 and appear in ``n_unserved``."""
        served = Stream(sid=0, arrival=0.0, target_chunks=1,
                        chunk_seconds=0.75, home=0, ttfc_slack=1.0)
        served.ready_times = [0.5]
        served.deadlines = [1.0]
        served.first_chunk_time = 0.5
        served.qualities = [80.0]
        unserved = Stream(sid=1, arrival=0.0, target_chunks=1,
                          chunk_seconds=0.75, home=0, ttfc_slack=1.0)
        res = types.SimpleNamespace(streams={0: served, 1: unserved},
                                    n_rehomings=0, n_sp_events=0)
        s = summarize(res)
        assert s.n_streams == 2
        assert s.n_unserved == 1
        assert s.qoe == pytest.approx(0.5)       # (1.0 + 0.0) / 2
        assert s.ttfc == pytest.approx(0.5)      # served-streams mean


class TestVectorizedParity:
    def test_scalar_vs_vectorized_bit_exact(self):
        """The numpy-batched control tick must not change a single
        result bit: same per-stream timelines, same fidelity log, same
        planner decisions."""
        specs = WORKLOADS["burst"](n=120, rate=1.0, seed=3)

        def signature(vectorized):
            res = Simulator(SimConfig(vectorized=vectorized), specs,
                            make_policy("slackserve")).run()
            per_stream = sorted(
                (s.sid, tuple(s.ready_times), tuple(s.deadlines),
                 tuple(s.fidelity_log), s.stall_time)
                for s in res.streams.values())
            return (per_stream, res.fidelity_counts,
                    res.worker_tier_samples, res.n_rehomings,
                    res.n_sp_events)

        assert signature(False) == signature(True)


class TestFrontDoor:
    def _view(self, n_workers=2, load=0):
        workers = [Worker(w, node=0) for w in range(n_workers)]
        for w in workers:
            w.queue = list(range(load))      # load() counts queue depth
        return ClusterView({}, workers, n_workers)

    def test_admits_when_fleet_has_slack(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        dec = fd.on_arrival(self._view(load=0), 0.0, 1.0, sid=0)
        assert dec.action == "admit" and dec.slack >= 0.0
        assert fd.stats()["admitted"] == 1

    def test_queues_and_scales_under_pressure(self):
        fd = FrontDoor(FrontDoorConfig(scale_step=4),
                       first_chunk_estimate=1.0)
        # predicted = load * ema + first_est = 9 > SLO = 4
        dec = fd.on_arrival(self._view(load=8), 0.0, 1.0, sid=0)
        assert dec.action == "queue"
        assert dec.scale_workers == 4
        # cooldown: the next arrival queues but does NOT scale again
        dec2 = fd.on_arrival(self._view(load=8), 1.0, 1.0, sid=1)
        assert dec2.action == "queue" and dec2.scale_workers == 0
        st = fd.stats()
        assert st["queued"] == 2 and st["scale_outs"] == 1
        assert st["workers_added"] == 4

    def test_scale_respects_max_workers(self):
        fd = FrontDoor(FrontDoorConfig(max_workers=3, scale_step=4),
                       first_chunk_estimate=1.0)
        dec = fd.on_arrival(self._view(n_workers=2, load=8),
                            0.0, 1.0, sid=0)
        assert dec.scale_workers == 1          # clamped to the headroom

    def test_rejects_when_queue_full(self):
        fd = FrontDoor(FrontDoorConfig(queue_limit=1, autoscale=False),
                       first_chunk_estimate=1.0)
        v = self._view(load=8)
        assert fd.on_arrival(v, 0.0, 1.0, sid=0).action == "queue"
        assert fd.on_arrival(v, 0.1, 1.0, sid=1).action == "reject"
        assert fd.stats()["rejected"] == 1

    def test_fifo_no_queue_jumping(self):
        fd = FrontDoor(FrontDoorConfig(autoscale=False),
                       first_chunk_estimate=1.0)
        fd.on_arrival(self._view(load=8), 0.0, 1.0, sid=0)
        # fleet now idle, but sid=1 may not jump the waiting sid=0
        dec = fd.on_arrival(self._view(load=0), 1.0, 1.0, sid=1)
        assert dec.action == "queue"
        admits, rejects = fd.drain(self._view(load=0), 1.0)
        assert [sid for sid, _ in admits] == [0, 1] and not rejects

    def test_drain_promotes_with_original_arrival(self):
        fd = FrontDoor(FrontDoorConfig(autoscale=False),
                       first_chunk_estimate=1.0)
        fd.on_arrival(self._view(load=8), 0.0, 1.0, sid=7)
        admits, rejects = fd.drain(self._view(load=0), 2.0)
        assert admits == [(7, 0.0)] and not rejects
        assert fd.stats()["waiting_at_end"] == 0

    def test_drain_sheds_on_queue_timeout(self):
        fd = FrontDoor(FrontDoorConfig(autoscale=False,
                                       max_queue_wait=5.0),
                       first_chunk_estimate=1.0)
        fd.on_arrival(self._view(load=8), 0.0, 1.0, sid=0)
        # fleet still overloaded past the wait bound: shed, don't stall
        admits, rejects = fd.drain(self._view(load=8), 6.0)
        assert not admits and rejects == [0]
        st = fd.stats()
        assert st["queue_timeouts"] == 1 and st["rejected"] == 1

    def test_tick_autoscale_needs_backlog(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        assert fd.autoscale(self._view(load=8), 0.0) == 0   # no backlog
        fd.on_arrival(self._view(load=8), 0.0, 1.0, sid=0)  # queues+scales
        assert fd.autoscale(self._view(load=8), 1.0) == 0   # cooldown
        assert fd.autoscale(self._view(load=8), 20.0) == 4  # backlog+cool

    def test_flash_crowd_end_to_end(self):
        """Fleet-level acceptance: a flash crowd through the front door
        finishes with ZERO arrivals lost — every stream is either served
        to completion or deliberately shed — and the fleet scaled out."""
        specs = flash_crowd(n=400, rate=8.0, seed=7)
        cfg = SimConfig(n_workers=16, front_door=FrontDoorConfig())
        res = Simulator(cfg, specs, make_policy("slackserve")).run()
        adm = res.admission
        assert adm["waiting_at_end"] == 0
        assert adm["admitted"] + adm["rejected"] == len(specs)
        assert len(res.streams) == adm["admitted"]
        assert all(s.done for s in res.streams.values())
        assert res.n_workers_final > 16 and adm["scale_outs"] > 0

    def test_front_door_improves_overloaded_qoe(self):
        """Shedding + scale-out must beat admitting every arrival into
        a drowning fleet."""
        specs = flash_crowd(n=400, rate=8.0, seed=7)
        base = summarize(Simulator(SimConfig(n_workers=16), specs,
                                   make_policy("slackserve")).run())
        fd = summarize(Simulator(
            SimConfig(n_workers=16, front_door=FrontDoorConfig()),
            specs, make_policy("slackserve")).run())
        assert fd.qoe >= base.qoe


class TestScaleIn:
    def _view(self, loads, retired=()):
        workers = [Worker(w, node=0) for w in range(len(loads))]
        for w, n in zip(workers, loads):
            w.queue = list(range(n))
        for w in retired:
            workers[w].retired = True
        return ClusterView({}, workers, len(workers))

    def test_scale_in_retires_idle_with_slack(self):
        fd = FrontDoor(FrontDoorConfig(scale_in_step=2, min_workers=1),
                       first_chunk_estimate=1.0)
        # two idle workers, one busy survivor with load 1: predicted
        # TTFC for survivors is still 0*ema+1 (an idle survivor stays)
        assert fd.maybe_scale_in(self._view([0, 0, 0, 1]), 0.0) == 2
        st = fd.stats()
        assert st["scale_ins"] == 1 and st["workers_retired"] == 2

    def test_scale_in_cooldown_and_floor(self):
        fd = FrontDoor(FrontDoorConfig(scale_in_step=1, min_workers=2),
                       first_chunk_estimate=1.0)
        assert fd.maybe_scale_in(self._view([0, 0, 0]), 0.0) == 1
        # cooldown gates the next decision
        assert fd.maybe_scale_in(self._view([0, 0, 0]), 1.0) == 0
        # min_workers floor: 2 active left, may not drop below 2
        later = fd.cfg.scale_in_cooldown + 1.0
        assert fd.maybe_scale_in(
            self._view([0, 0, 0], retired=(0,)), later) == 0

    def test_scale_in_needs_empty_queue_and_slack(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        fd.on_arrival(self._view([8, 8]), 0.0, 1.0, sid=0)   # queues
        assert fd.waiting
        assert fd.maybe_scale_in(self._view([0, 0]), 100.0) == 0
        fd.waiting.clear()
        # survivors too loaded: predicted * factor exceeds the SLO
        assert fd.maybe_scale_in(self._view([0, 8, 8]), 100.0) == 0

    def test_scale_out_sets_scale_in_hysteresis(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        fd.on_arrival(self._view([8, 8]), 0.0, 1.0, sid=0)   # scales out
        fd.waiting.clear()
        # scale-in is cooldown-gated by the scale-out that just fired
        assert fd.maybe_scale_in(self._view([0, 0]), 1.0) == 0

    def test_predict_ttfc_ignores_retired_workers(self):
        fd = FrontDoor(FrontDoorConfig(), first_chunk_estimate=1.0)
        # the idle worker is retired: prediction must use the busy one
        v = self._view([0, 5], retired=(0,))
        assert fd.predict_ttfc(v) == 5 * fd.chunk_service_ema + 1.0

    def test_simulator_scale_in_drains_and_retires(self):
        specs = steady(n=6, rate=50.0, seed=3)
        cfg = SimConfig(n_workers=4, front_door=FrontDoorConfig())
        sim = Simulator(cfg, specs, make_policy("slackserve"))
        res = sim.run()
        assert all(s.done for s in res.streams.values())
        # direct scale-in on the finished fleet: everyone idle now
        retired = sim.scale_in(2)
        assert retired == 2
        assert sum(1 for w in sim.view.workers if w.retired) == 2
        assert all(not w.queue and w.running is None
                   for w in sim.view.workers if w.retired)
        # scale_out revives retired slots before growing the arrays
        n_before = len(sim.view.workers)
        sim.scale_out(1)
        assert len(sim.view.workers) == n_before
        assert sum(1 for w in sim.view.workers if w.retired) == 1

    def test_scale_in_end_to_end_burst_then_drain(self):
        """A burst scales the fleet out; once the backlog drains, the
        cooldown-gated scale-in retires surplus workers — with every
        stream still served (conservation unchanged)."""
        specs = flash_crowd(n=150, rate=8.0, seed=7)
        fd_cfg = FrontDoorConfig(scale_in_cooldown=6.0, scale_in_step=4,
                                 min_workers=4)
        cfg = SimConfig(n_workers=4, front_door=fd_cfg)
        sim = Simulator(cfg, specs, make_policy("slackserve"))
        res = sim.run()
        adm = res.admission
        assert adm["admitted"] + adm["rejected"] == len(specs)
        assert all(s.done for s in res.streams.values())
        assert adm["scale_outs"] > 0
        assert adm["scale_ins"] > 0 and adm["workers_retired"] > 0
        assert res.n_workers_final == sum(
            1 for w in sim.view.workers if not w.retired)
        assert res.n_workers_final >= fd_cfg.min_workers
        # retired workers hold no work
        assert all(not w.queue and w.running is None
                   for w in sim.view.workers if w.retired)
