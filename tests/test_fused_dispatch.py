"""Fused heterogeneous-fidelity dispatch + partial-window residency.

Fused dispatch (``compose_batch(..., fuse=True)``) groups micro-batches
by KV quantization dtype only and serves mixed fidelities in one jitted
launch per dtype — these tests pin that every stream's chunks stay
BIT-IDENTICAL to the legacy per-fidelity-key split dispatch across the
fidelity matrix (steps x sparsity x window, both dtypes, join/leave),
that per-fidelity EMA attribution survives fusion, and that the
dispatch count really drops.

Partial-window residency (``page_evict=True``) trades single ring pages
away under pool pressure before whole-stream spill; the oversubscription
test pins smooth degradation (effective window reduced, run completes,
ledger conservation and directional transfer accounting intact).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.fidelity import FidelityConfig
from repro.core.types import Stream
from repro.models import ardit as A
from repro.serve.batcher import BatchedChunkExecutor, compose_batch

KEY = jax.random.PRNGKey(0)

# the fidelity matrix: steps x sparsity x window, both KV dtypes
MATRIX = [
    FidelityConfig(3, 0.0, 3, "bf16"),
    FidelityConfig(2, 0.0, 1, "bf16"),
    FidelityConfig(2, 0.9, 2, "bf16"),
    FidelityConfig(1, 0.5, 3, "bf16"),
    FidelityConfig(3, 0.0, 2, "fp8"),
    FidelityConfig(2, 0.9, 1, "fp8"),
]


def tiny_cfg(window_chunks=3):
    return dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=window_chunks)


def nondegenerate_params(cfg, key):
    """Open the adaLN-zero gates so attention over the cache matters
    (fresh params would make any parity test pass vacuously)."""
    p = A.init_params(cfg, key)
    ks = jax.random.split(jax.random.PRNGKey(1234), 3)
    p["layers"]["mod"] = 0.2 * jax.random.normal(
        ks[0], p["layers"]["mod"].shape, p["layers"]["mod"].dtype)
    p["layers"]["mod_b"] = 0.5 + 0.2 * jax.random.normal(
        ks[1], p["layers"]["mod_b"].shape, p["layers"]["mod_b"].dtype)
    p["final_mod"] = 0.2 * jax.random.normal(
        ks[2], p["final_mod"].shape, p["final_mod"].dtype)
    return p


def _drive(ex, fid_of, targets, *, fuse, max_batch=8, delay_join=()):
    """Serve every stream to its target chunk count, recomposing the
    micro-batch at every step boundary exactly like the session loop.
    Streams in ``delay_join`` sit out until stream ``min(targets)`` has
    a completed chunk (join mid-run); streams with smaller targets
    leave the batch early."""
    sids = sorted(targets)
    while any(len(ex.chunks[s]) < targets[s] for s in sids):
        runnable = []
        for s in sids:
            if len(ex.chunks[s]) >= targets[s]:
                continue
            if s in delay_join and not ex.chunks[min(sids)]:
                continue
            runnable.append(s)
        for s in runnable:
            if s not in ex.inflight:
                ex.begin_chunk(s, fid_of(s), 0.0)
        for grp in compose_batch(runnable,
                                 lambda s: ex.inflight[s].fidelity,
                                 max_batch, fuse=fuse):
            ex.run_step(grp)


def _make_ex(cfg, params, n, **kw):
    ex = BatchedChunkExecutor(cfg=cfg, params=params,
                              max_streams=n + 1, **kw)
    for sid in range(n):
        assert ex.admit(sid, seed=sid)
    return ex


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["paged", "gather"])
def test_fused_matches_split_across_matrix(backend):
    """Every stream of a mixed-fidelity population generates the same
    chunks under fused (per-dtype) and split (per-key) dispatch across
    the full matrix, including heterogeneous fills (different step
    counts de-sync the chunk boundaries) and ring wrap-around.

    Tolerance note: fusing changes the LAUNCH SHAPE (batch 4 instead of
    4x batch 1), and XLA tiles a different batch dimension differently,
    so per-row bits drift by ~1 ULP — the exact slack the repo's
    batched-vs-sequential parity tests already carry (rtol 1e-5,
    ``test_batcher.py``).  Bit-identity proper is pinned by
    ``test_fused_bit_identical_when_grouping_unchanged`` below, where
    fusion leaves the launch shape alone."""
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    n = len(MATRIX)
    fid_of = lambda s: MATRIX[s]
    targets = {s: 3 for s in range(n)}

    split = _make_ex(cfg, params, n, context_backend=backend)
    _drive(split, fid_of, targets, fuse=False)
    fused = _make_ex(cfg, params, n, context_backend=backend)
    _drive(fused, fid_of, targets, fuse=True)

    assert fused.dispatch_count < split.dispatch_count
    for s in range(n):
        assert len(fused.chunks[s]) == len(split.chunks[s]) == 3
        for c, (a, b) in enumerate(zip(fused.chunks[s], split.chunks[s])):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5,
                err_msg=f"stream {s} ({MATRIX[s].key}) chunk {c} "
                        f"diverged under fused dispatch")


@pytest.mark.slow
def test_fused_bit_identical_when_grouping_unchanged():
    """When every stream shares one fidelity key, fuse=True composes
    the exact same groups as fuse=False — and the per-row mask /
    per-row sigma-grid machinery of the fused path must then be
    BIT-IDENTICAL to the split path (same launches, same bits)."""
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    fid = FidelityConfig(2, 0.5, 2, "bf16")
    targets = {s: 3 for s in range(3)}
    split = _make_ex(cfg, params, 3)
    _drive(split, lambda s: fid, targets, fuse=False)
    fused = _make_ex(cfg, params, 3)
    _drive(fused, lambda s: fid, targets, fuse=True)
    assert fused.dispatch_count == split.dispatch_count
    for s in targets:
        for a, b in zip(fused.chunks[s], split.chunks[s]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_fused_matches_split_with_join_leave():
    """Join/leave mid-run: a late joiner and early leavers recompose
    the fused groups between steps without perturbing anyone's chunks."""
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    fids = [FidelityConfig(2, 0.0, 3, "bf16"),
            FidelityConfig(3, 0.5, 2, "bf16"),
            FidelityConfig(1, 0.0, 1, "bf16")]
    fid_of = lambda s: fids[s]
    targets = {0: 3, 1: 2, 2: 1}        # early leavers
    split = _make_ex(cfg, params, 3)
    _drive(split, fid_of, targets, fuse=False, delay_join=(2,))
    fused = _make_ex(cfg, params, 3)
    _drive(fused, fid_of, targets, fuse=True, delay_join=(2,))
    for s in targets:
        assert len(fused.chunks[s]) == len(split.chunks[s]) == targets[s]
        for a, b in zip(fused.chunks[s], split.chunks[s]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_fused_ema_attribution_per_fidelity_key():
    """Satellite: a fused launch's measured latency lands on each
    member's OWN fidelity key (weighted by the steps it was live for),
    so BMPR budgets keyed per fidelity don't drift when groups merge."""
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    fids = [FidelityConfig(3, 0.0, 3, "bf16"),
            FidelityConfig(1, 0.9, 1, "bf16")]
    fid_of = lambda s: fids[s]
    ex = _make_ex(cfg, params, 2)
    _drive(ex, fid_of, {0: 2, 1: 2}, fuse=True)
    assert set(ex.latency_ema) == {f.key for f in fids}
    assert set(ex.step_ema) == {f.key for f in fids}
    # the cheap fidelity (fewer live steps) must not inherit the
    # expensive one's whole-launch latency: its per-chunk EMA is
    # bounded by its own share of the fused launches
    assert ex.latency_ema[fids[1].key] <= ex.latency_ema[fids[0].key]


def test_compose_batch_fuse_groups_by_dtype():
    hi = FidelityConfig(4, 0.0, 7, "bf16")
    mid = FidelityConfig(2, 0.5, 3, "bf16")
    lo = FidelityConfig(2, 0.9, 1, "fp8")
    fid_of = {0: hi, 1: mid, 2: lo, 3: hi}.get
    # split: three fidelity keys -> three groups
    assert compose_batch([0, 1, 2, 3], fid_of, 4) == [[0, 3], [1], [2]]
    # fused: two dtypes -> two groups, credit order preserved
    assert compose_batch([0, 1, 2, 3], fid_of, 4, fuse=True) == \
        [[0, 1, 3], [2]]


def test_run_step_rejects_mixed_dtype_group():
    cfg = tiny_cfg()
    params = nondegenerate_params(cfg, KEY)
    ex = _make_ex(cfg, params, 2)
    ex.begin_chunk(0, FidelityConfig(2, 0.0, 2, "bf16"), 0.0)
    ex.begin_chunk(1, FidelityConfig(2, 0.9, 1, "fp8"), 0.0)
    with pytest.raises(AssertionError):
        ex.run_step([0, 1])


# ---------------------------------------------------------------------------
# partial-window residency (page-granular eviction)
# ---------------------------------------------------------------------------

def _credit_view(ex, sids):
    streams = {}
    for sid in sids:
        streams[sid] = Stream(sid=sid, arrival=0.0, target_chunks=3,
                              chunk_seconds=1.0, home=0, ttfc_slack=1e9)
        streams[sid].credit = float(len(ex.chunks.get(sid, ())))
    return streams


@pytest.mark.slow
def test_oversubscribed_page_eviction_degrades_smoothly():
    """2x oversubscription under ``page_evict=True``: the run completes
    with zero admission hard-failures, at least one stream trades its
    effective window down page-wise instead of spilling whole, the
    ledger conserves pages throughout, and the directional transfer
    counters only record genuine whole-stream movement (page eviction
    discards KV locally — it never touches the wire)."""
    cfg = tiny_cfg(window_chunks=3)
    params = nondegenerate_params(cfg, KEY)
    fid = FidelityConfig(2, 0.0, 3, "bf16")
    n, chunks = 4, 3
    ex = BatchedChunkExecutor(cfg=cfg, params=params, max_streams=2,
                              page_evict=True)
    streams = _credit_view(ex, range(n))
    for sid in range(n):
        ex.admit(sid, seed=sid, streams=streams)
        ex.pool.ledger.check()
    assert ex.page_evictions >= 1, \
        "pool pressure should engage the page-eviction rung first"

    while any(len(ex.chunks[s]) < chunks for s in range(n)):
        for sid in range(n):
            streams[sid].credit = float(len(ex.chunks[sid]))
        runnable = sorted(
            (s for s in range(n) if len(ex.chunks[s]) < chunks),
            key=lambda s: (streams[s].credit, s))   # scheduler order
        batch = []
        for sid in runnable:
            if ex.ensure_resident(sid, streams, protect=batch + [sid]):
                batch.append(sid)
            if len(batch) >= 2:
                break
        assert batch, "oversubscribed batch starved (admission failure)"
        for sid in batch:
            if sid not in ex.inflight:
                ex.begin_chunk(sid, fid, 0.0)
        ex.run_step(batch)
        ex.pool.ledger.check()

    # zero hard failures: every stream served every chunk
    assert all(len(ex.chunks[s]) == chunks for s in range(n))
    # at least one stream degraded page-wise: its recorded effective
    # window dips below the nominal min(fidelity window, fill)
    degraded = [
        s for s in range(n)
        if any(eff < min(fid.window, c)
               for c, eff in enumerate(ex.effective_window_log[s]))]
    assert degraded, "no stream recorded a page-wise degraded window"
    # per-stream effective-window history has one entry per chunk
    assert all(len(ex.effective_window_log[s]) == chunks
               for s in range(n))
    # directional accounting intact: only whole-stream spill/restore
    # bytes on the wire, page evictions charged nothing
    pool = ex.pool
    assert pool.transfer_bytes == (pool.transfer_bytes_in
                                   + pool.transfer_bytes_out)
    wire = sum(t.bytes for t in pool.engine.log)
    assert wire == pool.transfer_bytes
    ex.pool.ledger.check()


def test_page_ledger_evict_heal_cycle():
    """Ledger-level invariants of the evict -> hole -> append-heal
    cycle: victim preference, the one-ring-page floor, free-list heal,
    and pruning of dropped chunks that age out of the ring."""
    from repro.serve.batcher import PageLedger
    led = PageLedger(n_pages=8, pages_per_stream=4)       # W=3
    led.take(0)
    led.take(1)
    led.chunks[0] = 3          # ring full: entries hold chunks 0,1,2
    # evict one page: the oldest retained chunk (0) is dropped; the
    # newest (always visible) is never the victim
    assert led.evict_page(0) == 0
    assert led.dropped[0] == {0}
    assert led.free_pages == 1
    led.check()
    # stream 1 (fill 0): unwritten entries evict at zero quality cost,
    # down to the one-ring-page floor
    assert led.evict_page(1) == -1
    assert led.evict_page(1) == -1
    assert led.page_eviction_entry(1) is None     # at floor
    assert led.evict_page(1) is None
    led.check()
    # append into the hole heals from the free list
    assert led.append_page(0) >= 0
    assert (np.asarray(led.tables[0]) >= 0).sum() == 4
    led.chunks[0] += 1
    # ...and the healed chunk ages the dropped one out of the ring
    led.prune_dropped(0)
    assert 0 not in led.dropped
    led.check()


def test_page_ledger_steal_when_free_list_dry():
    """A hole-append under a dry free list steals the stream's own
    least-valuable sibling page (its chunk joins ``dropped``) — the
    floor guarantees a donor always exists."""
    from repro.serve.batcher import PageLedger
    led = PageLedger(n_pages=4, pages_per_stream=4)       # one stream
    led.take(0)
    led.chunks[0] = 3
    assert led.evict_page(0) == 0         # hole at chunk 0's entry
    # another consumer takes the freed page (simulated admission)
    led._free.pop()
    led.accounting.alloc(99, 1)
    # chunk 3 lands on chunk 0's old entry (3 % 3 == 0): the hole is
    # its own target, heal steals the oldest sibling (chunk 1)
    assert led.append_page(0) >= 0
    assert led.dropped[0] == {0, 1}
    assert (np.asarray(led.tables[0]) >= 0).sum() == 3
    led.chunks[0] += 1
