"""Per-architecture smoke tests (reduced configs, CPU) + serving
consistency: every assigned arch runs a forward/train step asserting
output shapes and no NaNs; decoder families check prefill+decode ==
full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models import registry

pytestmark = pytest.mark.slow     # JAX-compiling per-arch model tests: slow tier

KEY = jax.random.PRNGKey(0)
ASSIGNED = [a for a in list_archs() if not a.startswith("ardit")]


def _batch_for(cfg, B=2, S=24):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.family == "vlm":
        batch["img_embeds"] = 0.02 * jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["audio_embeds"] = 0.02 * jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    api = registry.get_api(cfg)
    params = api.init(cfg, KEY)
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: api.loss(cfg, p, batch))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ["ardit-self-forcing",
                                  "ardit-causal-forcing"])
def test_smoke_ardit_train(arch):
    from repro.models import ardit as A
    cfg = get_config(arch).reduced()
    tc = A.chunk_tokens(cfg)
    params = A.init_params(cfg, KEY)
    batch = {
        "latents": jax.random.normal(KEY, (2, 2, tc, A.LATENT_CH)),
        "cond": jax.random.normal(KEY, (2, A.COND_TOKENS, cfg.d_model)),
        "t": jax.random.uniform(KEY, (2, 2)),
        "noise": jax.random.normal(jax.random.PRNGKey(5),
                                   (2, 2, tc, A.LATENT_CH)),
    }
    loss = A.train_loss(cfg, params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # capacity drops are sequence-length dependent; lift the cap so
        # the teacher-forced forward and incremental decode agree
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    api = registry.get_api(cfg)
    params = api.init(cfg, KEY)
    B, S = 2, 20
    batch = _batch_for(cfg, B, S)
    kw = {}
    max_len = S + 4
    if cfg.family == "vlm":
        kw["img_embeds"] = batch["img_embeds"]
        max_len += cfg.n_frontend_tokens      # image tokens prepend
    if cfg.family == "encdec":
        kw["audio_embeds"] = batch["audio_embeds"]
    logits_p, cache, clen = api.prefill(cfg, params, batch["tokens"],
                                        max_len=max_len, **kw)
    assert logits_p.shape == (B, cfg.padded_vocab)
    tok2 = jnp.argmax(logits_p[:, :cfg.vocab_size], -1)[:, None]
    logits_d, cache = api.decode_step(cfg, params, cache, tok2, clen)
    assert bool(jnp.isfinite(logits_d).all())

    # reference: full forward over the extended sequence
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as M
        ext = jnp.concatenate([batch["tokens"], tok2], 1)
        h, _ = M.forward(cfg, params, ext, img_embeds=kw.get("img_embeds"))
        ref = M._unembed(cfg, params, h[:, -1:])[:, 0]
    elif cfg.family == "ssm":
        from repro.models import ssm as M
        ext = jnp.concatenate([batch["tokens"], tok2], 1)
        ref = M._unembed(cfg, params, M.forward(cfg, params,
                                                ext)[:, -1:])[:, 0]
    elif cfg.family == "hybrid":
        from repro.models import hybrid as M
        ext = jnp.concatenate([batch["tokens"], tok2], 1)
        h, _ = M.forward(cfg, params, ext)
        ref = M._unembed(cfg, params, h[:, -1:])[:, 0]
    else:
        from repro.models import encdec as M
        ext = jnp.concatenate([batch["tokens"], tok2], 1)
        h = M.forward(cfg, params, ext, batch["audio_embeds"])
        ref = M._unembed(cfg, params, h[:, -1:])[:, 0]
    np.testing.assert_allclose(logits_d, ref, rtol=2e-3, atol=2e-3)


def test_windowed_ring_cache_decode():
    """Dense windowed adaptation: ring-buffer decode == windowed full
    attention once positions roll past the window."""
    from repro.models import transformer as M
    cfg = get_config("minitron-8b").reduced().with_window(8, sink=4)
    params = M.init_params(cfg, KEY)
    S = 12
    tokens = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    logits_p, cache, clen = M.prefill(cfg, params, tokens, max_len=24)
    assert cache["k"].shape[2] == 12          # sink+window capacity
    # decode several tokens past the window; compare vs windowed forward
    toks = [tokens]
    pos = clen
    logits = logits_p
    for i in range(6):
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None]
        toks.append(nxt)
        logits, cache = M.decode_step(cfg, params, cache, nxt, pos)
        pos = pos + 1
    ext = jnp.concatenate(toks, 1)
    h, _ = M.forward(cfg, params, ext)
    ref = M._unembed(cfg, params, h[:, -1:])[:, 0]
    np.testing.assert_allclose(logits, ref, rtol=3e-3, atol=3e-3)


def test_ardit_serving_knobs():
    """All four fidelity knobs run and roll the cache correctly."""
    from repro.models import ardit as A
    from repro.core.fidelity import FidelityConfig
    cfg = get_config("ardit-self-forcing").reduced()
    params = A.init_params(cfg, KEY)
    cond = 0.02 * jax.random.normal(KEY, (1, A.COND_TOKENS, cfg.d_model))
    cache = A.init_cache(cfg, params, cond)
    tc = A.chunk_tokens(cfg)
    for i, fid in enumerate([FidelityConfig(4, 0.0, 7, "bf16"),
                             FidelityConfig(2, 0.9, 1, "fp8"),
                             FidelityConfig(3, 0.6, 3, "bf16")]):
        noise = jax.random.normal(jax.random.PRNGKey(i),
                                  (1, tc, A.LATENT_CH))
        chunk, cache = A.serve_chunk(cfg, params, cache, noise, fid)
        assert chunk.shape == (1, tc, A.LATENT_CH)
        assert bool(jnp.isfinite(chunk).all())
    assert cache["chunks"] == 3
    # roll past the window: capacity bounded
    for i in range(cfg.ardit_window_chunks):
        noise = jax.random.normal(jax.random.PRNGKey(100 + i),
                                  (1, tc, A.LATENT_CH))
        _, cache = A.serve_chunk(cfg, params, cache, noise)
    assert cache["len"] <= A.cache_capacity(cfg)


@pytest.mark.parametrize("arch", list_archs())
def test_registry_smoke_every_config(arch):
    """Co-serving floor: EVERY registry config builds params through the
    registry's ``init_fn`` and survives one reduced step — a denoise
    chunk for ardit family (the live co-serve path), a prefill forward
    for everything else (the simulated co-serve families)."""
    cfg = get_config(arch).reduced()
    params = registry.init_fn(cfg)(KEY)
    assert jax.tree_util.tree_leaves(params), arch
    if cfg.family == "ardit":
        from repro.models import ardit as A
        cond = 0.02 * jax.random.normal(KEY,
                                        (1, A.COND_TOKENS, cfg.d_model))
        cache = A.init_cache(cfg, params, cond)
        tc = A.chunk_tokens(cfg)
        noise = jax.random.normal(KEY, (1, tc, A.LATENT_CH))
        chunk, cache = A.serve_chunk(cfg, params, cache, noise)
        assert chunk.shape == (1, tc, A.LATENT_CH)
        assert bool(jnp.isfinite(chunk).all()), arch
        assert cache["chunks"] == 1
    else:
        api = registry.get_api(cfg)
        batch = _batch_for(cfg, B=1, S=16)
        kw = {k: batch[k] for k in ("img_embeds", "audio_embeds")
              if k in batch}
        max_len = 20 + (cfg.n_frontend_tokens if cfg.family == "vlm"
                        else 0)
        logits, cache, clen = api.prefill(cfg, params, batch["tokens"],
                                          max_len=max_len, **kw)
        assert logits.shape == (1, cfg.padded_vocab)
        assert bool(jnp.isfinite(logits).all()), arch


def test_param_count_analytic_close():
    """active_params analytic model tracks real init within 12%."""
    from repro.launch.analysis import active_params
    for arch in ("minitron-8b", "internlm2-20b"):
        cfg = get_config(arch)
        n = active_params(cfg)
        # dense: compare to exact init-based count on reduced config
        red = cfg.reduced()
        api = registry.get_api(red)
        params = api.init(red, KEY)
        exact = sum(int(np.prod(x.shape))
                    for x in jax.tree_util.tree_leaves(params))
        approx = active_params(red)
        assert abs(approx - exact) / exact < 0.12, (arch, approx, exact)
