"""Unified StreamingSession API: handle lifecycle, sim-vs-real metrics
parity over the same StreamSpec workload, back-compat bit-identity of
the legacy ``serve_session*`` wrappers, and oversubscribed online
serving through the shared control plane.

Fast-tier tests drive the jitted batched executor on a 2-layer config
(same budget as test_batcher); the eager sequential wrapper parity test
is slow-tier."""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.bmpr import StaticFidelity
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.sched_sim import cost_model as cm
from repro.sched_sim.metrics import (Summary, stall_histogram, summarize,
                                     transfer_stats)
from repro.sched_sim.policies import make_policy
from repro.sched_sim.simulator import SimConfig, Simulator
from repro.sched_sim.workloads import StreamSpec, steady
from repro.serve.batcher import BatchedChunkExecutor
from repro.serve.executor import ChunkExecutor, SequentialChunkExecutor
from repro.serve.session import (SessionConfig, StreamingSession,
                                 cap_specs, uniform_specs)

KEY = jax.random.PRNGKey(0)
FID = FidelityConfig(2, 0.0, 2, "bf16")


def tiny_cfg(window_chunks=2):
    return dataclasses.replace(
        get_config("ardit-self-forcing").reduced(),
        n_layers=2, ardit_window_chunks=window_chunks)


def make_session(n_pool=4, fidelity_policy=None, **cfg_kw):
    ex = BatchedChunkExecutor(cfg=tiny_cfg(), max_streams=n_pool)
    cfg_kw.setdefault("verbose", False)
    return StreamingSession(SessionConfig(**cfg_kw), executor=ex,
                            fidelity_policy=fidelity_policy)


# ---------------------------------------------------------------------------
# workload spec plumbing
# ---------------------------------------------------------------------------

def test_uniform_specs_exact_chunk_counts():
    specs = uniform_specs(3, 5)
    assert [s.sid for s in specs] == [0, 1, 2]
    assert all(s.arrival == 0.0 and s.chunks == 5 for s in specs)
    # capping a workload trims chunk counts without dropping streams
    for s in cap_specs(steady(n=4, rate=10.0, seed=0), 2):
        assert s.chunks == 2 and s.arrival > 0.0


# ---------------------------------------------------------------------------
# handle lifecycle: submit -> tick -> dispatch -> chunk-ready
# ---------------------------------------------------------------------------

def test_handle_lifecycle():
    sess = make_session(fidelity_policy=StaticFidelity(FID))
    handles = [sess.submit(spec) for spec in uniform_specs(2, 2)]
    # before run(): registered but not yet arrived
    for h in handles:
        assert h.record is None and h.chunks_ready == 0 and not h.done
    with pytest.raises(AssertionError):       # duplicate sid rejected
        sess.submit(StreamSpec(0, 0.0, 24))
    res = sess.run()
    for h in handles:
        assert h.done and h.chunks_ready == 2
        assert len(h.chunks) == 2 and h.chunks[0].shape[0] == 1
        assert h.fidelity_log == [FID.key] * 2
        r = h.record
        assert r.chunks_done == 2 and r.done
        assert len(r.ready_times) == len(r.deadlines) == 2
        # the ServedStream view is assembled from the record — one
        # bookkeeping path, no duplicated deadline state
        sv = h.served_stream()
        assert sv.next_deadline == r.next_deadline
        assert sv.fidelity_log == r.fidelity_log
        assert len(sv.chunks) == 2
    assert set(res.streams) == {0, 1}
    assert res.fidelity_counts == {FID.key: 4}


def test_online_arrivals_pause_and_prompt_switch():
    sess = make_session(fidelity_policy=StaticFidelity(FID),
                        arrival_scale=0.2)
    specs = [StreamSpec(0, 0.0, 24),
             StreamSpec(1, 0.3, 24, switches=(0.5,),
                        pauses=((0.2, 0.4),))]
    handles = [sess.submit(s) for s in specs]
    res = sess.run()
    assert all(h.done and h.chunks_ready == 2 for h in handles)
    r1 = res.streams[1]
    # arrival honored: stream 1's record carries its scheduled arrival
    assert r1.arrival == pytest.approx(0.3 * 0.2)
    assert r1.first_chunk_time is not None
    assert r1.first_chunk_time >= r1.arrival


# ---------------------------------------------------------------------------
# sim-vs-real metrics parity (one workload, one Summary definition)
# ---------------------------------------------------------------------------

def test_sim_vs_real_summary_parity():
    """The same StreamSpec list through the discrete-event Simulator and
    the real StreamingSession yields Summary objects with identically
    defined fields."""
    specs = cap_specs(steady(n=3, rate=50.0, seed=1), 2)

    sess = make_session(arrival_scale=0.1)
    for s in specs:
        sess.submit(s)
    res_real = sess.run()
    s_real = summarize(res_real)

    res_sim = Simulator(SimConfig(), specs,
                        make_policy("slackserve")).run()
    s_sim = summarize(res_sim)

    for s in (s_real, s_sim):
        assert isinstance(s, Summary)
        assert 0.0 <= s.qoe <= 1.0
        assert s.ttfc > 0.0 and math.isfinite(s.ttfc)
        assert s.n_streams == len(specs)
        assert s.n_chunks == sum(sp.chunks for sp in specs)
        assert s.quality > 0.0
        assert s.stalls_per_stream >= 0.0 and s.avg_stall_ms >= 0.0
    # stall accounting is consistent on the REAL side too (the old
    # batched loop recorded stall_time but never stall_events)
    for rec in res_real.streams.values():
        late = sum(1 for r, d in zip(rec.ready_times, rec.deadlines)
                   if r > d)
        assert len(rec.stall_events) == late
        assert sum(rec.stall_events) == pytest.approx(rec.stall_time)
    # the full metrics surface works on either result object
    assert set(stall_histogram(res_real)) == set(stall_histogram(res_sim))
    assert set(transfer_stats(res_real)) == set(transfer_stats(res_sim))


# ---------------------------------------------------------------------------
# back-compat: wrappers reproduce the seed executors bit-exactly
# ---------------------------------------------------------------------------

def test_session_batched_chunks_bit_identical_to_executor():
    """Session-driven serving must not perturb the numerics: with a
    fixed fidelity, the chunks equal driving the BatchedChunkExecutor
    directly in lockstep (the legacy serve_session_batched composition:
    warm-up stream, admit seeds = sids, full-batch steps)."""
    cfg = tiny_cfg()
    n, chunks = 2, 2

    ex1 = BatchedChunkExecutor(cfg=cfg, max_streams=n + 1)
    sess = StreamingSession(SessionConfig(verbose=False), executor=ex1,
                            fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        sess.submit(spec)
    sess.run()
    got = {i: [np.asarray(c) for c in sess.handles[i].chunks]
           for i in range(n)}

    ex2 = BatchedChunkExecutor(cfg=cfg, params=ex1.params,
                               max_streams=n + 1)
    ex2.admit(-1, seed=999)                   # same warm-up sequence
    ex2.begin_chunk(-1, HIGHEST_QUALITY, 0.0)
    while -1 in ex2.inflight:
        ex2.run_step([-1])
    ex2.retire(-1)
    for i in range(n):
        ex2.admit(i, seed=i)
    for _ in range(chunks):
        for i in range(n):
            ex2.begin_chunk(i, FID, 0.0)
        while ex2.inflight:
            ex2.run_step(list(range(n)))
    for i in range(n):
        assert len(got[i]) == chunks
        for c in range(chunks):
            np.testing.assert_array_equal(
                got[i][c], np.asarray(ex2.chunks[i][c]),
                err_msg=f"stream {i} chunk {c} diverged from the "
                        f"executor-driven reference")


@pytest.mark.slow
def test_session_sequential_chunks_bit_identical_to_executor():
    """Same guarantee for the whole-chunk-atomic sequential adapter vs
    the eager ChunkExecutor path the legacy serve_session used."""
    cfg = tiny_cfg()
    ex1 = SequentialChunkExecutor(cfg=cfg)
    sess = StreamingSession(
        SessionConfig(executor="sequential", verbose=False),
        executor=ex1, fidelity_policy=StaticFidelity(FID))
    sess.submit(StreamSpec(0, 0.0, 2 * cm.PIXEL_FRAMES_PER_CHUNK))
    sess.run()

    ref = ChunkExecutor(cfg=cfg, params=ex1.params)
    st = ref.open_stream(0, 2, now=0.0, ttfc_slack=1e9, seed=0)
    for _ in range(2):
        ref.generate_chunk(st, FID)
    for c in range(2):
        np.testing.assert_array_equal(
            np.asarray(sess.handles[0].chunks[c]),
            np.asarray(st.chunks[c]))


# ---------------------------------------------------------------------------
# oversubscription under the session driver
# ---------------------------------------------------------------------------

def test_oversubscribed_session_completes_all_streams():
    """More streams than the page pool holds: the session's residency
    fill (credit-aware eviction, bit-exact spill/restore) rotates
    everyone through to completion, and the spill traffic shows up on
    the shared transfer-engine metrics surface."""
    n, chunks = 4, 2
    ex = BatchedChunkExecutor(cfg=tiny_cfg(), max_streams=2)
    sess = StreamingSession(SessionConfig(max_batch=2, verbose=False),
                            executor=ex,
                            fidelity_policy=StaticFidelity(FID))
    for spec in uniform_specs(n, chunks):
        sess.submit(spec)
    res = sess.run()
    assert all(res.streams[i].chunks_done == chunks for i in range(n))
    assert ex.evictions > 0 and ex.restores > 0
    tr = transfer_stats(res)
    assert tr["n"] == len(res.engine.log) > 0
    s = summarize(res)
    assert s.n_chunks == n * chunks and 0.0 <= s.qoe <= 1.0
