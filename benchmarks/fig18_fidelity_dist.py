"""Fig. 18: fidelity configurations selected by BMPR under Steady and
Burst — top-5 concentration and the shift toward faster configs."""
from benchmarks.common import run_cell


def main(quick: bool = False) -> dict:
    out = {}
    for wl in ("steady", "burst"):
        res, s = run_cell("slackserve", wl)
        total = sum(res.fidelity_counts.values())
        top = sorted(res.fidelity_counts.items(), key=lambda kv: -kv[1])
        top5 = sum(v for _, v in top[:5]) / max(total, 1)
        out[wl] = (top[:5], top5)
        print(f"{wl}: top-5 configs cover {100*top5:.1f}% of selections")
        for k, v in top[:5]:
            print(f"    {k:24s} {100*v/total:5.1f}%")
    return out


if __name__ == "__main__":
    main()
