"""Kernel bench: interpret-mode correctness vs oracle + analytic
roofline characteristics (arithmetic intensity per knob setting)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.kernel import flash_mha_pallas
from repro.kernels.flash_attention.ref import flash_mha_ref
from repro.kernels.ssd_scan.kernel import ssd_pallas
from repro.kernels.ssd_scan.ref import ssd_ref

KEY = jax.random.PRNGKey(0)


def flash_intensity(S, H, D, window=0, sparsity=0.0, block=128):
    """FLOPs/byte of the flash kernel at the given knobs (bf16 IO)."""
    n_blocks = S // block
    if window:
        vis = min(window // block + 1, n_blocks)
    else:
        vis = (n_blocks + 1) / 2
    vis = vis * (1.0 - sparsity)
    flops = 2 * 2 * S * (vis * block) * H * D          # qk + pv
    io = (3 * S * H * D + S * H * D) * 2               # q,k,v in + o out
    return flops / io


def main(quick: bool = False) -> dict:
    out = {}
    print("flash attention: correctness + arithmetic intensity")
    for knobs in ({}, {"window": 64, "sink": 16}, {"sparsity": 0.8}):
        q = jax.random.normal(KEY, (1, 128, 4, 32))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 32))
        v = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 2, 32))
        o = flash_mha_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                             v.swapaxes(1, 2), block_q=32, block_kv=32,
                             interpret=True, **knobs).swapaxes(1, 2)
        r = flash_mha_ref(q, k, v, n_kv_heads=2, block_q=32, block_kv=32,
                          **knobs)
        err = float(jnp.max(jnp.abs(o - r)))
        ai = flash_intensity(4096, 16, 128, knobs.get("window", 0),
                             knobs.get("sparsity", 0.0))
        print(f"  {str(knobs):32s} max_err={err:.2e}  "
              f"AI@4k={ai:6.1f} flop/B")
        out[str(knobs)] = err
        assert err < 5e-3

    print("ssd scan: correctness across chunk sizes")
    for chunk in (16, 32, 64):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (1, 96, 2, 16))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 96, 2)))
        Am = -jnp.exp(jax.random.normal(ks[2], (2,)))
        Bm = jax.random.normal(ks[3], (1, 96, 1, 8))
        Cm = jax.random.normal(ks[4], (1, 96, 1, 8))
        y1, f1 = ssd_pallas(x, dt, Am, Bm, Cm, chunk=chunk, interpret=True)
        y2, f2 = ssd_ref(x, dt, Am, Bm, Cm, chunk=chunk)
        err = float(jnp.max(jnp.abs(y1 - y2)))
        print(f"  chunk={chunk:3d}  max_err={err:.2e}")
        out[f"ssd_{chunk}"] = err
        assert err < 5e-3
    return out


if __name__ == "__main__":
    main()
