"""Benchmark orchestrator: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

--full uses the 946-prompt workloads and all models/workloads (slower);
the default quick mode reproduces every trend in a few minutes.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SECTIONS = [
    ("App. A  - Pareto frontier (90 fidelity configs)", "figA_pareto"),
    ("Fig. 10 - KV quality propagation (real tiny AR-DiT)",
     "fig10_kv_propagation"),
    ("Fig. 11 - end-to-end: models x workloads x systems",
     "fig11_end_to_end"),
    ("Fig. 12 - technique ablation", "fig12_ablation"),
    ("Fig. 13 - State-Plane transfer protocols", "fig13_transfer"),
    ("Fig. 14 - stall distribution", "fig14_stalls"),
    ("Fig. 15 - worker-type imbalance", "fig15_imbalance"),
    ("Fig. 16 - BMPR vs fixed-level switching", "fig16_bmpr_vs_fixed"),
    ("Fig. 17 - re-homing / elastic-SP triggers", "fig17_triggers"),
    ("Fig. 18 - selected fidelity configurations", "fig18_fidelity_dist"),
    ("Table 3 - sensitivity (alpha, arrival rate)", "table3_sensitivity"),
    ("Table 4 - Control-Plane scalability (real wall time)",
     "table4_controller"),
    ("Table 5 - State-Plane overheads", "table5_state_plane"),
    ("Kernels - correctness + arithmetic intensity", "kernel_bench"),
    ("Roofline - dry-run terms per (arch x shape x mesh)", "roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="run a single section by module name")
    args = ap.parse_args()
    if args.full:
        os.environ["REPRO_FULL_SCALE"] = "1"
    quick = not args.full

    import importlib
    t0 = time.time()
    for title, mod_name in SECTIONS:
        if args.only and args.only != mod_name:
            continue
        print(f"\n{'='*78}\n{title}\n{'='*78}")
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        t1 = time.time()
        try:
            mod.main(quick=quick)
        except Exception as e:          # keep the report going
            print(f"SECTION FAILED: {type(e).__name__}: {e}")
        print(f"[{mod_name}: {time.time()-t1:.1f}s]")
    print(f"\ntotal: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
