"""Table 4: Control-Plane scalability — REAL wall-clock time of one
control tick at 64-1024 active streams (replayed controller states on
a 16-worker view), as a fraction of the 3 s tick interval."""
import random
import time

from repro.core.control_plane import ControlPlane
from repro.core.types import ClusterView, Stream, Worker


def synth_view(n_streams: int, n_workers: int = 16,
               seed: int = 0) -> ClusterView:
    rng = random.Random(seed)
    view = ClusterView({}, [Worker(w, node=w // 8)
                            for w in range(n_workers)], 8)
    for sid in range(n_streams):
        home = rng.randrange(n_workers)
        s = Stream(sid=sid, arrival=0.0, target_chunks=20,
                   chunk_seconds=0.75, home=home, ttfc_slack=2.9,
                   next_deadline=rng.uniform(-1.0, 8.0))
        s.t_next = 0.72
        view.streams[sid] = s
        view.workers[home].queue.append(sid)
    return view


def main(quick: bool = False) -> dict:
    sizes = (64, 256, 1024) if quick else (64, 128, 256, 512, 1024)
    out = {}
    print(f"{'#streams':>9s} {'avg tick (ms)':>14s} {'% of 3s tick':>13s}")
    for n in sizes:
        times = []
        for rep in range(5):
            view = synth_view(n, seed=rep)
            cp = ControlPlane()
            t0 = time.perf_counter()
            cp.tick(view, now=0.0)
            times.append(time.perf_counter() - t0)
        avg_ms = 1000 * sum(times) / len(times)
        out[n] = avg_ms
        print(f"{n:9d} {avg_ms:14.2f} {100*avg_ms/3000:12.2f}%")
    assert out[1024] < 3000, "tick must fit the interval"
    return out


if __name__ == "__main__":
    main()
