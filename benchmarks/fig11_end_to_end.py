"""Fig. 11: end-to-end comparison — 2 AR-DiT models x 5 workloads x
4 systems (SlackServe / SDV2 / TS / TS-chunk): QoE, TTFC, quality."""
from benchmarks.common import fmt_row, run_cell


def main(quick: bool = False) -> dict:
    models = ["causal-forcing"] if quick else ["causal-forcing",
                                               "self-forcing"]
    workloads = ["steady", "burst"] if quick else \
        ["steady", "burst", "prompt_switch", "pause", "trace"]
    out = {}
    ratios = []
    for model in models:
        for wl in workloads:
            rows = {}
            for pol in ("slackserve", "sdv2", "ts", "ts-chunk"):
                _, s = run_cell(pol, wl, model=model)
                rows[pol] = s
                print(fmt_row(f"{model[:6]}/{wl}/{pol}", s))
            out[(model, wl)] = rows
            for base in ("sdv2", "ts", "ts-chunk"):
                if rows[base].qoe > 0:
                    ratios.append(rows["slackserve"].qoe / rows[base].qoe)
    if ratios:
        print(f"\nQoE improvement over baselines: "
              f"{min(ratios):.2f}x - {max(ratios):.2f}x "
              f"(paper: 1.64x-3.29x)")
    return out


if __name__ == "__main__":
    main()
