"""Streams-served-per-second: sequential vs batched chunk executor,
per context backend.

All paths run the REAL reduced AR-DiT at a fixed fidelity with
identical seeds.  The sequential path is the repo's pre-existing
executor (``ChunkExecutor``: one stream at a time, eager op-by-op
forwards); the batched paths are ``BatchedChunkExecutor`` with each
context backend: ``gather`` materializes a contiguous
[L, b, COND+W*tc, ...] context per chunk boundary, ``paged`` (the
serving default) consumes (pool, block tables, page masks) directly —
no context materialization on the hot path.  Each path is measured
twice with fresh streams; the cold pass is reported so compile
amortization stays visible.  Per backend the peak bytes of staged
per-sub-batch context state are reported (the paged backend stages
only tables + masks).

The oversubscription scenario serves MORE streams than the page pool
holds (streams = 2 x pool capacity): admission never fails — extra
streams park host-side and the executor evicts the highest-credit
resident (credit-aware, bit-exact spill/restore) to rotate everyone
through.  Spill/restore traffic is routed through the state plane's
``AsyncTransferEngine``, so the report includes modeled transfer time
(async-stream protocol: total wire time and the dispatcher wait
actually charged into the latency EMAs) next to eviction/restore
counts.

``--lanes N`` adds the multi-lane session scenario; when more than one
device is visible (e.g. the runner sets
``XLA_FLAGS=--xla_force_host_platform_device_count=2``) each lane's
pool is committed to its own device, cross-lane moves are real
``jax.device_put`` copies, and the lanes row carries the MEASURED
transfer bandwidth (``transfer_measured``: count/bytes/seconds/
bytes_per_s plus the model -> calibrated ``bw_intra`` pair) next to the
per-lane directional byte attribution (``lane_transfer_bytes``).

Results are also written as machine-readable JSON (``--json PATH``,
default ``BENCH_batched_executor.json``) so CI can track the perf
trajectory as an artifact.

    PYTHONPATH=src python benchmarks/batched_executor.py \
        [--streams 4] [--chunks 8] [--max-batch N] [--pool N] \
        [--context-backend gather|paged] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.fidelity import FidelityConfig
from repro.core.types import Stream
from repro.serve.batcher import BatchedChunkExecutor, compose_batch
from repro.serve.executor import ChunkExecutor

FIDELITY = FidelityConfig(4, 0.0, 7, "bf16")

# mixed-fidelity population: four keys spanning steps x sparsity x
# window, all one KV dtype so fused dispatch collapses them into a
# single launch per step (the dtype split is the only hard boundary)
MIXED_FIDELITIES = [
    FidelityConfig(4, 0.0, 7, "bf16"),
    FidelityConfig(2, 0.5, 5, "bf16"),
    FidelityConfig(2, 0.9, 3, "bf16"),
    FidelityConfig(1, 0.9, 2, "bf16"),
]


def run_sequential(ex: ChunkExecutor, n_streams: int, chunks: int,
                   base_sid: int) -> float:
    streams = [ex.open_stream(base_sid + i, chunks, now=0.0,
                              ttfc_slack=1e9, seed=i)
               for i in range(n_streams)]
    t0 = time.perf_counter()
    for _ in range(chunks):                    # round-robin, like a queue
        for s in streams:
            ex.generate_chunk(s, FIDELITY)
    return time.perf_counter() - t0


def run_batched(ex: BatchedChunkExecutor, n_streams: int, chunks: int,
                max_batch: int, base_sid: int) -> float:
    for i in range(n_streams):
        ex.admit(base_sid + i, seed=i)
    sids = [base_sid + i for i in range(n_streams)]
    t0 = time.perf_counter()
    while any(len(ex.chunks[sid]) < chunks for sid in sids):
        runnable = [sid for sid in sids if len(ex.chunks[sid]) < chunks]
        # least-progress first keeps the batch full (stand-in for the
        # control plane's credit order in this fixed-fidelity benchmark)
        runnable.sort(key=lambda sid: (len(ex.chunks[sid]),
                                       ex.inflight[sid].step
                                       if sid in ex.inflight else 0))
        for sid in runnable[:max_batch]:
            if sid not in ex.inflight:
                ex.begin_chunk(sid, FIDELITY, 0.0)
        for grp in compose_batch(runnable[:max_batch],
                                 lambda sid: ex.inflight[sid].fidelity,
                                 max_batch):
            ex.run_step(grp)
    dt = time.perf_counter() - t0
    for sid in sids:
        ex.retire(sid)
    return dt


def run_oversubscribed(ex: BatchedChunkExecutor, n_streams: int,
                       chunks: int, max_batch: int,
                       base_sid: int) -> float:
    """Serve ``n_streams`` through a pool that holds fewer: admission
    parks the overflow host-side, and every dispatch tick evicts the
    highest-credit (most-progressed) resident to rotate spilled streams
    in.  Completes all streams with ZERO admission failures."""
    sids = [base_sid + i for i in range(n_streams)]
    # minimal credit view for queues.pick_eviction: progress == credit,
    # so the least-advanced stream is always protected longest
    streams = {sid: Stream(sid=sid, arrival=0.0, target_chunks=chunks,
                           chunk_seconds=1.0, home=0, ttfc_slack=1e9)
               for sid in sids}
    for i, sid in enumerate(sids):
        ex.admit(sid, seed=i)                  # overflow defers, no raise
    t0 = time.perf_counter()
    while any(len(ex.chunks[sid]) < chunks for sid in sids):
        runnable = [sid for sid in sids if len(ex.chunks[sid]) < chunks]
        runnable.sort(key=lambda sid: (len(ex.chunks[sid]),
                                       ex.inflight[sid].step
                                       if sid in ex.inflight else 0))
        for sid in sids:
            streams[sid].credit = float(len(ex.chunks[sid]))
        # fill the batch from the FULL runnable list: a spilled stream
        # that cannot displace anyone (all residents mid-chunk) is
        # skipped, not allowed to starve the batch
        batch = []
        for sid in runnable:
            if len(batch) >= max_batch:
                break
            if ex.ensure_resident(sid, streams, protect=batch + [sid]):
                batch.append(sid)
        assert batch, "admission stalled: nothing resident nor evictable"
        for sid in batch:
            if sid not in ex.inflight:
                ex.begin_chunk(sid, FIDELITY, 0.0)
        for grp in compose_batch(batch, lambda s: ex.inflight[s].fidelity,
                                 max_batch):
            ex.run_step(grp)
    dt = time.perf_counter() - t0
    for sid in sids:
        ex.retire(sid)
    return dt


def run_mixed_fidelity(ex: BatchedChunkExecutor, n_streams: int,
                       chunks: int, max_batch: int, base_sid: int,
                       fuse: bool) -> tuple:
    """Serve a mixed-fidelity population (``MIXED_FIDELITIES`` round-
    robin) and measure elapsed time plus the number of jitted step
    launches.  ``fuse=False`` is the legacy per-fidelity-key split,
    ``fuse=True`` the per-dtype fused dispatch — same streams, same
    schedule, strictly fewer launches fused."""
    sids = [base_sid + i for i in range(n_streams)]
    fid_of = {sid: MIXED_FIDELITIES[i % len(MIXED_FIDELITIES)]
              for i, sid in enumerate(sids)}
    for i, sid in enumerate(sids):
        ex.admit(sid, seed=i)
    d0 = ex.dispatch_count
    t0 = time.perf_counter()
    while any(len(ex.chunks[sid]) < chunks for sid in sids):
        runnable = [sid for sid in sids if len(ex.chunks[sid]) < chunks]
        runnable.sort(key=lambda sid: (len(ex.chunks[sid]),
                                       ex.inflight[sid].step
                                       if sid in ex.inflight else 0))
        for sid in runnable[:max_batch]:
            if sid not in ex.inflight:
                ex.begin_chunk(sid, fid_of[sid], 0.0)
        for grp in compose_batch(runnable[:max_batch],
                                 lambda s: ex.inflight[s].fidelity,
                                 max_batch, fuse=fuse):
            ex.run_step(grp)
    dt = time.perf_counter() - t0
    dispatches = ex.dispatch_count - d0
    for sid in sids:
        ex.retire(sid)
    return dt, dispatches


def run_step_cache(ex: BatchedChunkExecutor, n_streams: int, chunks: int,
                   max_batch: int, base_sid: int,
                   fidelity: FidelityConfig) -> dict:
    """Serve a uniform population at ``fidelity`` and report elapsed
    time plus the step cache's own accounting (hit rate, launches the
    cache skipped outright, jitted dispatches actually run)."""
    sids = [base_sid + i for i in range(n_streams)]
    for i, sid in enumerate(sids):
        ex.admit(sid, seed=i)
    d0 = ex.dispatch_count
    s0 = ex.cache_skipped_launches
    h0 = (ex.stepcache.hits, ex.stepcache.misses) \
        if ex.stepcache is not None else (0, 0)
    t0 = time.perf_counter()
    while any(len(ex.chunks[sid]) < chunks for sid in sids):
        runnable = [sid for sid in sids if len(ex.chunks[sid]) < chunks]
        runnable.sort(key=lambda sid: (len(ex.chunks[sid]),
                                       ex.inflight[sid].step
                                       if sid in ex.inflight else 0))
        for sid in runnable[:max_batch]:
            if sid not in ex.inflight:
                ex.begin_chunk(sid, fidelity, 0.0)
        for grp in compose_batch(runnable[:max_batch],
                                 lambda s: ex.inflight[s].fidelity,
                                 max_batch, fuse=True):
            ex.run_step(grp)
    dt = time.perf_counter() - t0
    hits = misses = 0
    if ex.stepcache is not None:
        hits = ex.stepcache.hits - h0[0]
        misses = ex.stepcache.misses - h0[1]
    for sid in sids:
        ex.retire(sid)
    return {
        "elapsed_s": round(dt, 4),
        "streams_per_s": round(n_streams / dt, 4),
        "hit_rate": round(hits / (hits + misses), 4)
        if (hits + misses) else 0.0,
        "skipped_launches": ex.cache_skipped_launches - s0,
        "dispatch_count": ex.dispatch_count - d0,
    }


def run_lanes_session(n_lanes: int, n_streams: int, chunks: int,
                      seed: int = 0) -> dict:
    """Multi-lane session scenario: a burst workload served through
    ``n_lanes`` device lanes under the full control plane (re-homing +
    elastic SP live).  Reports end-to-end streams/s plus the counts of
    cross-lane decisions actually applied — the nightly signal that the
    decision -> apply loop keeps engaging."""
    import jax

    from repro.sched_sim.metrics import summarize
    from repro.sched_sim.workloads import WORKLOADS
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     scale_specs)
    specs = scale_specs(WORKLOADS["burst"](n=n_streams, rate=1.0,
                                           seed=seed), chunks)
    session = StreamingSession(SessionConfig(
        lanes=n_lanes, max_batch=3, pool_streams=n_streams + 1,
        budget_factor=2.0, verbose=False))
    for s in specs:
        session.submit(s)
    t0 = time.perf_counter()
    res = session.run()
    dt = time.perf_counter() - t0
    s = summarize(res)
    # per-lane directional byte attribution (out = sent, in = received)
    lane_bytes = [{"out": ex.pool.transfer_bytes_out,
                   "in": ex.pool.transfer_bytes_in}
                  for ex in session.lanes.executors]
    return {
        "lanes": n_lanes, "streams": n_streams,
        "devices": jax.local_device_count(),
        "chunks_total": s.n_chunks,
        "elapsed_s": round(dt, 4),
        "streams_per_s": round(n_streams / dt, 4),
        "qoe": round(s.qoe, 4),
        "migrations": res.n_migrations_applied,
        "sp_expands": res.n_sp_expands_applied,
        "sp_releases": res.n_sp_releases_applied,
        "rehomings_planned": res.n_rehomings,
        "sp_planned": res.n_sp_events,
        "lane_transfer_bytes": lane_bytes,
        # measured wall time of real cross-device jax.device_put moves
        # (zeros on a single visible device: lanes share it and moves
        # are byte-charged but not device-copied)
        "transfer_measured": res.engine.measured_stats(),
    }


def run_co_serve(models: list, n_streams: int, chunks: int,
                 seed: int = 0) -> dict:
    """Heterogeneous co-serving scenario: serve a round-robin model mix
    through ONE lane pool (one paged KV pool + jit cache per bundle),
    next to per-model SOLO baselines over exactly the streams each
    model received in the mix.  Reports per-model and aggregate
    streams/s; ``check_bench.py`` gates the co-served aggregate against
    the load-weighted serial composition of the solo rates."""
    import dataclasses as _dc

    from repro.sched_sim.metrics import summarize
    from repro.sched_sim.workloads import WORKLOADS
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     cap_specs)

    def _run(model_list: list, specs: list) -> tuple:
        session = StreamingSession(SessionConfig(
            executor="batched", models=model_list, max_batch=4,
            pool_streams=len(specs) + 1, arrival_scale=0.2,
            seed=seed, verbose=False))
        for sp in specs:
            session.submit(sp)
        t0 = time.perf_counter()
        res = session.run()
        dt = time.perf_counter() - t0
        return summarize(res), res, dt

    base = cap_specs(WORKLOADS["steady"](n=n_streams, rate=1.0,
                                         seed=seed), chunks)
    tagged = [_dc.replace(sp, model=models[i % len(models)])
              for i, sp in enumerate(base)]
    solo = {}
    for m in models:
        specs_m = [sp for sp in tagged if sp.model == m]
        _, _, dt = _run([m], specs_m)
        solo[m] = {"streams": len(specs_m), "elapsed_s": round(dt, 4),
                   "streams_per_s": round(len(specs_m) / dt, 4)}
    summ, res, dt = _run(models, tagged)
    return {
        "models": models, "streams": n_streams, "chunks": chunks,
        "solo": solo,
        "per_model": summ.by_model,
        "aggregate_streams_per_s": round(n_streams / dt, 4),
        "elapsed_s": round(dt, 4),
        "qoe": round(summ.qoe, 4),
        "n_unserved": summ.n_unserved,
    }


def transfer_report(ex: BatchedChunkExecutor) -> dict:
    log = ex.pool.engine.log
    return {
        "count": len(log),
        "bytes": ex.pool.transfer_bytes,
        "bytes_out": ex.pool.transfer_bytes_out,
        "bytes_in": ex.pool.transfer_bytes_in,
        "total_s": round(sum(t.total for t in log), 6),
        "dispatcher_wait_s": round(ex.transfer_wait_s, 6),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--chunks", type=int, default=8,
                    help="chunks per stream (8 fills and wraps the W=7 "
                         "ring, the steady streaming regime)")
    ap.add_argument("--max-batch", type=int, default=0,
                    help="0 -> batch all streams")
    ap.add_argument("--pool", type=int, default=0,
                    help="resident-stream capacity of the page pool for "
                         "the oversubscription scenario (0 -> streams/2)")
    ap.add_argument("--context-backend", choices=("gather", "paged"),
                    default=None,
                    help="measure only one backend (default: both)")
    ap.add_argument("--mixed-streams", type=int, default=8,
                    help="stream count of the mixed-fidelity fused-vs-"
                         "split scenario (0 disables; spans "
                         f"{len(MIXED_FIDELITIES)} fidelity keys)")
    ap.add_argument("--step-cache", action="store_true",
                    help="also run the step-cache scenario: the same "
                         "uniform population uncached vs cache="
                         "aggressive, reporting streams/s, hit rate and "
                         "launches skipped outright")
    ap.add_argument("--co-serve", action="store_true",
                    help="also run the heterogeneous co-serving "
                         "scenario: a 2-model mix through one lane "
                         "pool vs per-model solo baselines, per-model "
                         "and aggregate streams/s into the JSON "
                         "(gated by check_bench.py)")
    ap.add_argument("--co-serve-models",
                    default="ardit-self-forcing,ardit-causal-forcing",
                    help="comma-separated registry configs for "
                         "--co-serve")
    ap.add_argument("--co-serve-streams", type=int, default=6,
                    help="total stream count of the --co-serve mix")
    ap.add_argument("--lanes", type=int, default=0,
                    help="also run the multi-lane session scenario "
                         "with this many lanes (0 disables)")
    ap.add_argument("--lane-streams", type=int, default=15,
                    help="stream count of the --lanes scenario (odd "
                         "and > lanes*max_batch keeps the cross-lane "
                         "mechanisms engaged)")
    ap.add_argument("--json", default="BENCH_batched_executor.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()
    n, chunks = args.streams, args.chunks
    max_batch = args.max_batch or n
    backends = ([args.context_backend] if args.context_backend
                else ["gather", "paged"])

    seq_ex = ChunkExecutor()
    seq_cold = run_sequential(seq_ex, n, chunks, base_sid=0)
    seq_warm = run_sequential(seq_ex, n, chunks, base_sid=100)

    results = {
        "config": {"streams": n, "chunks": chunks, "max_batch": max_batch,
                   "fidelity": FIDELITY.key},
        "sequential": {"cold_s": round(seq_cold, 4),
                       "warm_s": round(seq_warm, 4),
                       "streams_per_s": round(n / seq_warm, 4)},
        "batched": {},
        "oversubscribed": {},
    }

    print(f"\n{n} streams x {chunks} chunks, fidelity {FIDELITY.key}, "
          f"max_batch={max_batch}")
    print(f"  {'sequential':16s} cold={seq_cold:6.2f}s "
          f"warm={seq_warm:6.2f}s -> {n / seq_warm:5.2f} streams/s "
          f"({n * chunks / seq_warm:5.1f} chunks/s)")
    for backend in backends:
        ex = BatchedChunkExecutor(cfg=seq_ex.cfg, params=seq_ex.params,
                                  max_streams=n, context_backend=backend)
        cold = run_batched(ex, n, chunks, max_batch, base_sid=0)
        warm = run_batched(ex, n, chunks, max_batch, base_sid=100)
        results["batched"][backend] = {
            "cold_s": round(cold, 4), "warm_s": round(warm, 4),
            "streams_per_s": round(n / warm, 4),
            "peak_ctx_bytes": ex.peak_ctx_bytes,
        }
        name = f"batched/{backend}"
        print(f"  {name:16s} cold={cold:6.2f}s warm={warm:6.2f}s "
              f"-> {n / warm:5.2f} streams/s "
              f"({n * chunks / warm:5.1f} chunks/s) "
              f"peak_ctx={ex.peak_ctx_bytes}B")
        print(f"  {'':16s} speedup vs sequential (warm): "
              f"{seq_warm / warm:.2f}x")

    # oversubscription: 2x the pool's resident capacity, zero admission
    # failures (overflow spills to host and rotates back in)
    pool = args.pool or max(1, n // 2)
    for backend in backends:
        over_ex = BatchedChunkExecutor(cfg=seq_ex.cfg,
                                       params=seq_ex.params,
                                       max_streams=pool,
                                       context_backend=backend)
        over = run_oversubscribed(over_ex, 2 * pool, chunks,
                                  min(max_batch, pool), base_sid=200)
        # measured, not asserted: a stream that never got (back) in would
        # still hold an incomplete chunk list here
        failures = sum(len(over_ex.chunks[200 + i]) < chunks
                       for i in range(2 * pool))
        tr = transfer_report(over_ex)
        results["oversubscribed"][backend] = {
            "streams": 2 * pool, "pool_streams": pool,
            "elapsed_s": round(over, 4),
            "streams_per_s": round(2 * pool / over, 4),
            "evictions": over_ex.evictions,
            "restores": over_ex.restores,
            "deferred_ticks": over_ex.deferrals,
            "admission_failures": failures,
            "transfers": tr,
        }
        print(f"\noversubscribed/{backend}: {2 * pool} streams through "
              f"a {pool}-stream page pool "
              f"({over_ex.pool.n_pages} pages x "
              f"{over_ex.pool.page_tokens} tokens)")
        print(f"  completed in {over:6.2f}s -> {2 * pool / over:5.2f} "
              f"streams/s ({2 * pool * chunks / over:5.1f} chunks/s)")
        print(f"  evictions={over_ex.evictions} "
              f"restores={over_ex.restores} "
              f"deferred_ticks={over_ex.deferrals} "
              f"admission_failures={failures}")
        print(f"  transfers={tr['count']} ({tr['bytes']} B) "
              f"total={tr['total_s']:.4f}s "
              f"dispatcher_wait={tr['dispatcher_wait_s']:.4f}s "
              f"(async-stream)")

    # mixed-fidelity: split (one launch per fidelity key) vs fused (one
    # launch per KV dtype) over the same population and schedule
    if args.mixed_streams:
        mn = args.mixed_streams
        results["mixed_fidelity"] = {
            "streams": mn, "chunks": chunks,
            "fidelity_keys": [f.key for f in MIXED_FIDELITIES],
        }
        print(f"\nmixed_fidelity: {mn} streams over "
              f"{len(MIXED_FIDELITIES)} fidelity keys")
        for mode, fuse in (("split", False), ("fused", True)):
            mex = BatchedChunkExecutor(cfg=seq_ex.cfg,
                                       params=seq_ex.params,
                                       max_streams=mn)
            cold, disp = run_mixed_fidelity(mex, mn, chunks, mn,
                                            base_sid=400, fuse=fuse)
            warm, disp_w = run_mixed_fidelity(mex, mn, chunks, mn,
                                              base_sid=500, fuse=fuse)
            results["mixed_fidelity"][mode] = {
                "cold_s": round(cold, 4), "warm_s": round(warm, 4),
                "streams_per_s": round(mn / warm, 4),
                "dispatch_count": disp_w,
            }
            print(f"  {mode:6s} cold={cold:6.2f}s warm={warm:6.2f}s "
                  f"-> {mn / warm:5.2f} streams/s, "
                  f"{disp_w} launches/pass")
        sp = results["mixed_fidelity"]
        print(f"  fused vs split: "
              f"{sp['fused']['streams_per_s'] / sp['split']['streams_per_s']:.2f}x "
              f"streams/s, {sp['split']['dispatch_count']} -> "
              f"{sp['fused']['dispatch_count']} launches")

    # step cache: same uniform population with the residual cache off vs
    # aggressive — cached must serve at least as many streams/s whenever
    # it actually hits (check_bench.py gates exactly that)
    if args.step_cache:
        cex = BatchedChunkExecutor(cfg=seq_ex.cfg, params=seq_ex.params,
                                   max_streams=n)
        results["step_cache"] = {"streams": n, "chunks": chunks}
        print(f"\nstep_cache: {n} streams x {chunks} chunks, "
              f"uncached vs cache=aggressive")
        for mode, fid in (("uncached", FIDELITY),
                          ("cached", FIDELITY._replace(cache="aggressive"))):
            run_step_cache(cex, n, chunks, max_batch,      # compile pass
                           base_sid=600, fidelity=fid)
            row = run_step_cache(cex, n, chunks, max_batch,
                                 base_sid=700, fidelity=fid)
            row["fidelity"] = fid.key
            results["step_cache"][mode] = row
            print(f"  {mode:8s} {row['elapsed_s']:6.2f}s "
                  f"-> {row['streams_per_s']:5.2f} streams/s "
                  f"hit_rate={row['hit_rate']:.2f} "
                  f"skipped={row['skipped_launches']} "
                  f"launches={row['dispatch_count']}")
        sc = results["step_cache"]
        print(f"  cached vs uncached: "
              f"{sc['cached']['streams_per_s'] / sc['uncached']['streams_per_s']:.2f}x "
              f"streams/s at hit_rate={sc['cached']['hit_rate']:.2f}")

    if args.co_serve:
        co_models = [m.strip() for m in args.co_serve_models.split(",")
                     if m.strip()]
        row = run_co_serve(co_models, args.co_serve_streams, args.chunks)
        results["co_serve"] = row
        print(f"\nco_serve: {row['streams']} streams over "
              f"{len(co_models)} models through one lane pool")
        for m, sr in sorted(row["solo"].items()):
            print(f"  solo {m}: {sr['streams']} streams in "
                  f"{sr['elapsed_s']:6.2f}s "
                  f"-> {sr['streams_per_s']:5.2f} streams/s")
        for m, pr in sorted(row["per_model"].items()):
            print(f"  co   {m}: CPR={pr['cpr']:.3f} "
                  f"TTFC={pr['ttfc']:.2f}s "
                  f"streams/s={pr['streams_per_s']:.3f}")
        print(f"  aggregate: {row['streams']} streams in "
              f"{row['elapsed_s']:6.2f}s "
              f"-> {row['aggregate_streams_per_s']:5.2f} streams/s "
              f"QoE={row['qoe']:.3f} unserved={row['n_unserved']}")

    if args.lanes:
        row = run_lanes_session(args.lanes, args.lane_streams,
                                args.chunks)
        results["lanes"] = {str(args.lanes): row}
        print(f"\nlanes/{args.lanes}: {row['streams']} streams through "
              f"{args.lanes} lanes in {row['elapsed_s']:6.2f}s "
              f"-> {row['streams_per_s']:5.2f} streams/s "
              f"QoE={row['qoe']:.3f}")
        print(f"  applied: migrations={row['migrations']} "
              f"sp_expands={row['sp_expands']} "
              f"sp_releases={row['sp_releases']} "
              f"(planned: rehomings={row['rehomings_planned']} "
              f"sp={row['sp_planned']})")
        ms = row["transfer_measured"]
        if ms["count"]:
            print(f"  measured moves: n={ms['count']} "
                  f"bytes={ms['bytes']} bw={ms['bytes_per_s']:.3g} B/s "
                  f"(model {ms['bw_intra_model']:.3g} -> "
                  f"calibrated {ms['bw_intra_calibrated']:.3g}) "
                  f"on {row['devices']} devices")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
