"""Table 5: State-Plane overheads on Steady — KV-transfer time
distribution and residual dispatch wait under layer-wise streaming."""
from benchmarks.common import run_cell


def _hist(vals_ms, edges=(5, 10, 15, 20, 30, 40, 60, 80, 120)):
    out = {}
    lo = 0.0
    for e in edges:
        out[f"{lo:.0f}-{e}ms"] = sum(1 for v in vals_ms if lo <= v < e)
        lo = e
    out[f"{edges[-1]}ms+"] = sum(1 for v in vals_ms if v >= edges[-1])
    return out


def main(quick: bool = False) -> dict:
    res, s = run_cell("slackserve", "steady")
    log = res.engine.log
    totals = sorted(1000 * t.total for t in log)
    waits = sorted(1000 * t.residual_wait for t in log)
    if not totals:
        print("no transfers recorded")
        return {}

    def p95(xs):
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]
    print(f"KV transfers: n={len(totals)}  "
          f"avg={sum(totals)/len(totals):.1f}ms  p95={p95(totals):.1f}ms")
    print(f"  distribution: {_hist(totals)}")
    print(f"residual dispatch wait: avg={sum(waits)/len(waits):.1f}ms  "
          f"p95={p95(waits):.1f}ms")
    frac = (sum(waits) / len(waits)) / (sum(totals) / len(totals))
    print(f"  {100*frac:.1f}% of transfer latency on the critical path "
          f"(paper: 13.8%)")
    return {"avg_ms": sum(totals) / len(totals), "p95_ms": p95(totals),
            "avg_residual_ms": sum(waits) / len(waits),
            "critical_path_frac": frac}


if __name__ == "__main__":
    main()
