"""Fleet-scale front-door benchmark: vectorized tick + admission control.

Three cells over one fleet scenario (flash-crowd, 2000 streams, 64
workers — the scale the vectorized control tick exists for):

  1. baseline     — the scalar control tick (``vectorized=False``),
                    ticks/s from the per-tick wall clock
  2. vectorized   — the numpy-batched tick; reported speedup is the
                    acceptance gate (>= 5x), and the per-stream result
                    signature is checked bit-identical to the baseline
                    (``parity_ok`` — the speed must cost nothing)
  3. front_door   — vectorized + SLO-aware admission/autoscaling:
                    admit/queue/reject outcomes, workers added, and
                    ``hard_failures`` (streams still waiting at drain —
                    the gate requires ZERO: every arrival is either
                    served or deliberately shed, never lost)

``--calibrate`` adds a sim-vs-real cell: a small REAL session on this
host (tiny AR-DiT), ``calibration.fit_session`` of its measured EMAs,
then the SAME specs replayed through the calibrated simulator; the
QoE/TTFC agreement (pinned tolerances) lands in the JSON for
``check_bench.py --fleet`` to gate.

Results go to ``BENCH_fleet_sim.json`` (``--json PATH``) so nightly CI
tracks ticks/s, admission outcomes and calibration drift as artifacts.

    PYTHONPATH=src python benchmarks/fleet_sim.py \
        [--streams 2000] [--workers 64] [--rate 20] [--seed 7] \
        [--calibrate] [--json PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sched_sim.frontdoor import FrontDoorConfig
from repro.sched_sim.metrics import summarize
from repro.sched_sim.policies import make_policy
from repro.sched_sim.simulator import SimConfig, Simulator
from repro.sched_sim.workloads import WORKLOADS


def signature(res):
    """Per-stream result signature for scalar-vs-vectorized parity."""
    per_stream = sorted(
        (s.sid, tuple(s.ready_times), tuple(s.deadlines),
         tuple(s.fidelity_log), s.stall_time)
        for s in res.streams.values())
    return (per_stream, res.fidelity_counts, res.worker_tier_samples,
            res.n_rehomings, res.n_sp_events)


def run_cell(specs, n_workers: int, *, vectorized: bool,
             front_door=None):
    cfg = SimConfig(n_workers=n_workers, vectorized=vectorized,
                    front_door=front_door)
    sim = Simulator(cfg, specs, make_policy("slackserve"))
    t0 = time.perf_counter()
    res = sim.run()
    wall = time.perf_counter() - t0
    ticks = len(res.tick_wall)
    tick_time = sum(res.tick_wall)
    return res, {
        "wall_s": round(wall, 3),
        "n_ticks": ticks,
        "tick_time_s": round(tick_time, 3),
        "ticks_per_s": round(ticks / tick_time, 1) if tick_time else None,
        "qoe": round(summarize(res).qoe, 4),
    }


def run_calibration(n_streams: int, chunks: int, seed: int):
    """Small REAL session -> fitted cost model -> calibrated sim replay
    of the SAME specs -> pinned-tolerance agreement."""
    from repro.sched_sim.calibration import agreement, fit_session
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     cap_specs)
    specs = cap_specs(WORKLOADS["steady"](n=n_streams, rate=2.0,
                                          seed=seed), chunks)
    session = StreamingSession(SessionConfig(executor="batched",
                                             verbose=False))
    for spec in specs:
        session.submit(spec)
    real = summarize(session.run())
    report = fit_session(session)
    sim_cfg = report.sim_config(n_workers=1, workers_per_node=1)
    sim_res = Simulator(sim_cfg, specs, make_policy(
        "slackserve", model=report.model,
        profile=report.profile())).run()
    agr = agreement(real, summarize(sim_res))
    return {
        "n_streams": n_streams, "chunks": chunks,
        "scale": round(report.scale, 4),
        "ratios": {k: round(v, 4) for k, v in report.ratios.items()},
        "bw_intra": report.bw_intra,
        "agreement": agr, "ok": agr["ok"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=2000)
    ap.add_argument("--workers", type=int, default=64)
    ap.add_argument("--workload", default="flash_crowd")
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--calibrate", action="store_true",
                    help="add the sim-vs-real calibration cell "
                         "(runs a small real session on this host)")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_fleet_sim.json"))
    args = ap.parse_args()

    specs = WORKLOADS[args.workload](n=args.streams, rate=args.rate,
                                     seed=args.seed)
    out = {"scenario": {
        "workload": args.workload, "streams": args.streams,
        "workers": args.workers, "rate": args.rate, "seed": args.seed,
    }}

    print(f"fleet: {args.workload} n={args.streams} rate={args.rate} "
          f"workers={args.workers}")
    res_s, out["scalar"] = run_cell(specs, args.workers,
                                    vectorized=False)
    print(f"  scalar     : {out['scalar']}")
    res_v, out["vectorized"] = run_cell(specs, args.workers,
                                        vectorized=True)
    print(f"  vectorized : {out['vectorized']}")

    speedup = (out["vectorized"]["ticks_per_s"]
               / out["scalar"]["ticks_per_s"])
    parity_ok = signature(res_s) == signature(res_v)
    out["speedup"] = round(speedup, 2)
    out["parity_ok"] = parity_ok
    print(f"  speedup    : {speedup:.2f}x  parity={'OK' if parity_ok else 'BROKEN'}")

    res_f, fd_cell = run_cell(specs, args.workers, vectorized=True,
                              front_door=FrontDoorConfig())
    adm = dict(res_f.admission)
    out["front_door"] = {
        **fd_cell, **adm,
        "hard_failures": adm.get("waiting_at_end", 0),
        "n_workers_final": res_f.n_workers_final,
    }
    print(f"  front_door : admitted={adm.get('admitted')} "
          f"queued={adm.get('queued')} rejected={adm.get('rejected')} "
          f"scale_outs={adm.get('scale_outs')} "
          f"workers {args.workers}->{res_f.n_workers_final} "
          f"hard_failures={out['front_door']['hard_failures']} "
          f"qoe={fd_cell['qoe']}")

    if args.calibrate:
        out["calibration"] = run_calibration(n_streams=3, chunks=3,
                                             seed=args.seed)
        agr = out["calibration"]["agreement"]
        print(f"  calibration: qoe {agr['qoe_sim']} vs {agr['qoe_real']}"
              f" ttfc {agr['ttfc_sim_s']}s vs {agr['ttfc_real_s']}s "
              f"-> {'OK' if agr['ok'] else 'DISAGREE'}")

    path = os.path.abspath(args.json)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
