"""Fig. 13: State-Plane transfer protocols — Sync / Async-NoStream /
Async-Stream (layer-wise streaming + atomic readiness)."""
from benchmarks.common import fmt_row, run_cell
from repro.sched_sim.metrics import transfer_stats


def main(quick: bool = False) -> dict:
    out = {}
    for proto in ("sync", "async-nostream", "async-stream"):
        res, s = run_cell("slackserve", "steady", protocol=proto)
        ts = transfer_stats(res)
        out[proto] = (s, ts)
        print(fmt_row(proto, s) +
              f"  xfer_avg={ts['avg_ms']:.1f}ms "
              f"residual={ts['avg_residual_ms']:.1f}ms")
    return out


if __name__ == "__main__":
    main()
