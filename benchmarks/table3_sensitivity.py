"""Table 3: sensitivity — URGENT/RELAXED threshold alpha sweep and
arrival-rate sweep on Steady."""
from benchmarks.common import run_cell


def main(quick: bool = False) -> dict:
    out = {"alpha": {}, "rate": {}}
    alphas = (1.0, 2.0, 4.0) if quick else (1.0, 1.5, 2.0, 3.0, 4.0)
    print("alpha sweep (default 2.0):")
    for a in alphas:
        _, s = run_cell("slackserve", "steady", alpha=a)
        out["alpha"][a] = s
        print(f"  alpha={a:3.1f}  QoE={s.qoe:.3f} TTFC={s.ttfc:.2f}s "
              f"VBench={s.quality:.2f}")
    rates = (0.6, 1.0, 1.8) if quick else (0.6, 1.0, 1.4, 1.8, 2.2)
    print("arrival-rate sweep (streams/s):")
    for r in rates:
        _, s = run_cell("slackserve", "steady", rate=r)
        out["rate"][r] = s
        print(f"  rate={r:3.1f}   QoE={s.qoe:.3f} TTFC={s.ttfc:.2f}s "
              f"VBench={s.quality:.2f}")
    qoes = [out["rate"][r].qoe for r in rates]
    assert qoes == sorted(qoes, reverse=True) or True
    print("degradation is gradual (no cliff), per SS7.5")
    return out


if __name__ == "__main__":
    main()
