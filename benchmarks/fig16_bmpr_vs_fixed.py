"""Fig. 16: BMPR vs fixed-level (fast/medium/slow) fidelity switching."""
from benchmarks.common import fmt_row, run_cell


def main(quick: bool = False) -> dict:
    out = {}
    for label, pol in (("fixed-level switching", "bmpr-fixed-level"),
                       ("BMPR", "slackserve")):
        _, s = run_cell(pol, "steady")
        out[label] = s
        print(fmt_row(label, s))
    return out


if __name__ == "__main__":
    main()
