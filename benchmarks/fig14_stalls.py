"""Fig. 14: stall-event duration distribution on Steady, per system."""
from benchmarks.common import run_cell
from repro.sched_sim.metrics import stall_histogram, summarize


def main(quick: bool = False) -> dict:
    out = {}
    for pol in ("slackserve", "sdv2", "ts", "ts-chunk"):
        res, s = run_cell(pol, "steady")
        hist = stall_histogram(res)
        out[pol] = (s, hist)
        print(f"{pol:12s} stalls/stream={s.stalls_per_stream:5.2f} "
              f"avg={s.avg_stall_ms:5.0f}ms  {hist}")
    return out


if __name__ == "__main__":
    main()
