"""Fig. 10 (SS5.1): quality loss has limited propagation through KV.

Real (tiny) AR-DiT, three runs with identical noise:
    reference   all chunks at the highest-quality config
    low-HISTORY chunks 0..k-1 at a low-cost config, chunk k at highest
    low-CURRENT chunks 0..k-1 at highest, chunk k at the low-cost config
The paper's observation: degraded HISTORY barely moves chunk k, while a
degraded CURRENT chunk moves it a lot -> per-chunk fidelity decisions
are largely independent.  Metric: relative L2 distance to the reference
chunk (VBench proxy on this scale).
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.models import ardit as A

LOW = FidelityConfig(2, 0.9, 1, "fp8")
K = 3          # measure the (K+1)-th chunk


def _run(cfg, params, cond, fids):
    cache = A.init_cache(cfg, params, cond)
    tc = A.chunk_tokens(cfg)
    chunks = []
    for i, fid in enumerate(fids):
        noise = jax.random.normal(jax.random.PRNGKey(100 + i),
                                  (1, tc, A.LATENT_CH))
        chunk, cache = A.serve_chunk(cfg, params, cache, noise, fid)
        chunks.append(chunk)
    return chunks


def main(quick: bool = False) -> dict:
    cfg = get_config("ardit-self-forcing").reduced()
    params = A.init_params(cfg, jax.random.PRNGKey(0))
    cond = 0.02 * jax.random.normal(jax.random.PRNGKey(1),
                                    (1, A.COND_TOKENS, cfg.d_model))
    ref = _run(cfg, params, cond, [HIGHEST_QUALITY] * (K + 1))
    low_hist = _run(cfg, params, cond, [LOW] * K + [HIGHEST_QUALITY])
    low_cur = _run(cfg, params, cond, [HIGHEST_QUALITY] * K + [LOW])

    def rel(a, b):
        return float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-9))

    d_hist = rel(low_hist[K], ref[K])
    d_cur = rel(low_cur[K], ref[K])
    print(f"chunk {K}: rel-L2 vs all-high reference")
    print(f"  low-fidelity HISTORY (KV) : {d_hist:.4f}")
    print(f"  low-fidelity CURRENT chunk: {d_cur:.4f}")
    print(f"  ratio current/history     : {d_cur / max(d_hist, 1e-9):.1f}x "
          f"(paper: history drop is small; current drop is much larger)")
    return {"d_hist": d_hist, "d_cur": d_cur}


if __name__ == "__main__":
    main()
