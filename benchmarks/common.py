"""Shared benchmark helpers: run one simulation cell, cache results."""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sched_sim.metrics import (Summary, stall_histogram, summarize,
                                     transfer_stats)
from repro.sched_sim.policies import SDV2Policy, make_policy
from repro.sched_sim.simulator import SimConfig, Simulator
from repro.sched_sim.workloads import WORKLOADS

# default scale: 300 streams reproduces the paper's dynamics in ~seconds;
# REPRO_FULL_SCALE=1 runs the full 946-prompt workloads
N_STREAMS = 946 if os.environ.get("REPRO_FULL_SCALE") == "1" else 300

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def run_cell(policy: str = "slackserve", workload: str = "steady", *,
             n: int = None, rate: float = 1.0, model: str = "causal-forcing",
             protocol: str = "async-stream", alpha: float = 2.0,
             seed: int = 0):
    n = n or N_STREAMS
    specs = WORKLOADS[workload](n=n, rate=rate, seed=seed)
    kw = {"model": model}
    if policy in ("slackserve",):
        kw["alpha"] = alpha
    pol = make_policy(policy, **kw)
    cfg = (SDV2Policy.sim_config() if policy == "sdv2"
           else SimConfig(model=model, transfer_protocol=protocol))
    sim = Simulator(cfg, specs, pol)
    res = sim.run()
    return res, summarize(res)


def fmt_row(name: str, s: Summary) -> str:
    return (f"{name:34s} QoE={s.qoe:5.3f}  TTFC={s.ttfc:5.2f}s  "
            f"VBench={s.quality:6.2f}  stalls/stream={s.stalls_per_stream:5.2f}"
            f"  avg_stall={s.avg_stall_ms:5.0f}ms")
