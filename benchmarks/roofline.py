"""Roofline report: reads the dry-run artifacts
(benchmarks/artifacts/dryrun/*.json) and prints, per (arch x shape x
mesh): the three roofline terms, the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs (useful ratio), and the roofline fraction
(model-compute-bound time / roofline step time).
"""
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_records(pattern: str = "*"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(ART, pattern + ".json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def roofline_fraction(rec) -> float:
    """Useful model compute / roofline-optimistic step time."""
    step = max(rec["t_compute_s"], rec["t_memory_s"], rec["t_collective_s"])
    if step <= 0:
        return 0.0
    ideal = rec["model_flops"] / (rec["n_chips"] * 197e12)
    return ideal / step


def main(quick: bool = False) -> dict:
    recs = load_records()
    if not recs:
        print("no dry-run artifacts yet: run "
              "PYTHONPATH=src python scripts/run_dryrun_sweep.py")
        return {}
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    failed = [r for r in recs if r.get("status") == "FAILED"]
    print(f"{len(ok)} cells ok, {len(skipped)} skipped (long_500k "
          f"full-attention), {len(failed)} FAILED")
    # multi-pod cells are compile-coherence checks (analyze=False):
    # report pass/fail; the roofline table below is single-pod
    mp = [r for r in ok if "pod2x" in r["cell"]]
    if mp:
        print(f"multi-pod (2x16x16): {len(mp)} cells compiled ok")
    ok = [r for r in ok if "t_compute_s" in r]
    hdr = (f"{'cell':58s} {'comp_ms':>8s} {'mem_ms':>8s} {'coll_ms':>8s} "
           f"{'dom':>10s} {'useful':>7s} {'roofl%':>7s} {'GB/dev':>7s}")
    print(hdr)
    for r in sorted(ok, key=lambda r: r["cell"]):
        frac = roofline_fraction(r)
        per_dev = (r.get("per_device_bytes") or 0) / 1e9
        print(f"{r['cell']:58s} {1000*r['t_compute_s']:8.1f} "
              f"{1000*r['t_memory_s']:8.1f} {1000*r['t_collective_s']:8.1f} "
              f"{r['dominant']:>10s} {r.get('useful_ratio') or 0:7.3f} "
              f"{100*frac:7.2f} {per_dev:7.1f}")
    for r in failed:
        print(f"FAILED {r['cell']}: {r.get('error', '')[:100]}")
    return {"ok": len(ok), "failed": len(failed)}


if __name__ == "__main__":
    main()
