"""App. A: the 90-config fidelity space and its Pareto frontier."""
from repro.core.bmpr import pareto_frontier
from repro.profiler.profiles import get_profile


def main(quick: bool = False) -> dict:
    out = {}
    for model in ("causal-forcing", "self-forcing"):
        prof = get_profile(model)
        f = pareto_frontier(prof)
        print(f"{model}: {len(prof.points)} candidates, "
              f"{len(f.points)} on the frontier, "
              f"Q_floor={f.q_floor:.2f}")
        for p in f.points:
            print(f"    L={1000*p.latency:7.1f}ms  Q={p.quality:6.2f}  "
                  f"{p.fidelity.key}")
        out[model] = f
    return out


if __name__ == "__main__":
    main()
