"""Fig. 12: technique ablation in Control-Plane trigger order:
Credit-only -> +BMPR -> +Re-homing -> +Elastic SP."""
from benchmarks.common import fmt_row, run_cell

LADDER = [("Credit only", "credit-only"),
          ("+ BMPR", "credit+bmpr"),
          ("+ Re-homing", "credit+bmpr+rehome"),
          ("+ Elastic SP (full)", "slackserve")]


def main(quick: bool = False) -> dict:
    out = {}
    for label, pol in LADDER:
        _, s = run_cell(pol, "steady")
        out[label] = s
        print(fmt_row(label, s))
    return out


if __name__ == "__main__":
    main()
