"""Fig. 17: re-homing / elastic-SP trigger counts per workload."""
from benchmarks.common import run_cell


def main(quick: bool = False) -> dict:
    out = {}
    wls = ["burst", "prompt_switch", "pause"] if quick else \
        ["steady", "burst", "prompt_switch", "pause", "trace"]
    for wl in wls:
        res, s = run_cell("slackserve", wl)
        out[wl] = (s.n_rehomings, s.n_sp_events)
        print(f"{wl:14s} re-homings={s.n_rehomings:4d} "
              f"elastic-SP={s.n_sp_events:4d}  QoE={s.qoe:.3f}")
    return out


if __name__ == "__main__":
    main()
