"""Fig. 15: worker-type distribution (URGENT / mixed / RELAXED) over
time for SlackServe vs SDV2 — why aggregate FPS alone is insufficient."""
import statistics

from benchmarks.common import run_cell


def main(quick: bool = False) -> dict:
    out = {}
    for pol in ("slackserve", "sdv2"):
        res, s = run_cell(pol, "steady")
        samples = res.worker_tier_samples
        if not samples:
            continue
        urgent = statistics.mean(x[0] for x in samples)
        mixed = statistics.mean(x[1] for x in samples)
        relaxed = statistics.mean(x[2] for x in samples)
        scale = 4 if pol == "sdv2" else 1     # SDV2 units = 4 GPUs
        out[pol] = (urgent * scale, mixed * scale, relaxed * scale)
        print(f"{pol:12s} avg URGENT={urgent*scale:5.2f} "
              f"mixed={mixed*scale:5.2f} RELAXED={relaxed*scale:5.2f} "
              f"(GPU-equivalents)  QoE={s.qoe:.3f}")
    return out


if __name__ == "__main__":
    main()
