#!/usr/bin/env python
"""Guard the serving-throughput trajectory: compare a freshly measured
``BENCH_batched_executor.json`` against the previous nightly artifact
and fail on a >15% streams/s regression in any tracked scenario.

    python scripts/check_bench.py NEW.json PREV.json [--threshold 0.15]

Tracked scenarios: ``sequential``, ``batched/<backend>``,
``oversubscribed/<backend>`` and ``lanes/<n>`` ``streams_per_s``
entries; any other fields a scenario row carries (migration/SP counts,
QoE, transfer reports, the device-lane ``transfer_measured`` stats and
``lane_transfer_bytes`` in/out attribution, ...) are ignored, so the
compare tolerates new JSON fields without breaking.  Measured transfer
bandwidth is deliberately NOT gated: host-to-host ``jax.device_put``
wall time is too noisy on shared runners for a hard threshold.  Scenarios missing from the previous
artifact (first run, new backend or lane count) are reported and
skipped — the check only compares like with like, so the nightly job
can bootstrap from an empty history.  Exit code 0 = no regression (or
nothing to compare), 1 = regression beyond threshold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _rates(bench: dict) -> dict:
    """Flatten a benchmark JSON into {scenario: streams_per_s}."""
    out = {}
    seq = bench.get("sequential", {})
    if "streams_per_s" in seq:
        out["sequential"] = seq["streams_per_s"]
    for section in ("batched", "oversubscribed", "lanes"):
        for key, row in bench.get(section, {}).items():
            if isinstance(row, dict) and "streams_per_s" in row:
                out[f"{section}/{key}"] = row["streams_per_s"]
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly measured benchmark JSON")
    ap.add_argument("prev", help="previous nightly artifact (may be "
                                 "missing on the first run)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional streams/s drop")
    args = ap.parse_args()

    with open(args.new) as f:
        new = _rates(json.load(f))
    if not os.path.exists(args.prev):
        print(f"no previous artifact at {args.prev}: nothing to compare "
              f"(bootstrapping the bench trajectory)")
        return 0
    with open(args.prev) as f:
        prev = _rates(json.load(f))

    failed = False
    for scenario in sorted(set(new) | set(prev)):
        if scenario not in prev:
            print(f"  {scenario:28s} new scenario "
                  f"({new[scenario]:.3f} streams/s), skipped")
            continue
        if scenario not in new:
            print(f"  {scenario:28s} dropped from benchmark output, "
                  f"skipped")
            continue
        old_r, new_r = prev[scenario], new[scenario]
        if old_r <= 0:
            continue
        delta = (new_r - old_r) / old_r
        flag = "REGRESSION" if delta < -args.threshold else "ok"
        print(f"  {scenario:28s} {old_r:8.3f} -> {new_r:8.3f} streams/s "
              f"({delta:+.1%}) {flag}")
        if delta < -args.threshold:
            failed = True
    if failed:
        print(f"FAIL: streams/s regressed more than "
              f"{args.threshold:.0%} vs the previous nightly run")
        return 1
    print("bench trajectory ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
