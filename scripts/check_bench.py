#!/usr/bin/env python
"""Guard the serving-throughput trajectory: compare a freshly measured
``BENCH_batched_executor.json`` against the previous nightly artifact
and fail on a >15% streams/s regression in any tracked scenario.

    python scripts/check_bench.py NEW.json PREV.json [--threshold 0.15]

``--fleet`` switches to ``BENCH_fleet_sim.json`` gating instead:

    python scripts/check_bench.py --fleet NEW.json PREV.json

Absolute gates (fail even with no history): vectorized-tick speedup
>= --min-speedup (default 5x), scalar-vs-vectorized ``parity_ok``,
front-door ``hard_failures == 0`` (every arrival served or shed, never
lost), and — when the calibration cell ran — sim-vs-real agreement
``ok`` under the pinned tolerances.  Trajectory gate: vectorized
ticks/s vs the previous artifact, same threshold rules as streams/s.

A ``co_serve`` section (``--co-serve`` on the bench) adds another
absolute gate: the co-served aggregate streams/s must come within
``--co-serve-tol`` of the load-weighted composition of the per-model
solo baselines, with zero unserved streams.

Tracked scenarios: ``sequential``, ``batched/<backend>``,
``oversubscribed/<backend>``, ``mixed_fidelity/<mode>``,
``step_cache/<mode>`` and
``lanes/<n>`` ``streams_per_s`` entries; any other fields a scenario row carries (migration/SP counts,
QoE, transfer reports, the device-lane ``transfer_measured`` stats and
``lane_transfer_bytes`` in/out attribution, ...) are ignored, so the
compare tolerates new JSON fields without breaking.  Measured transfer
bandwidth is deliberately NOT gated: host-to-host ``jax.device_put``
wall time is too noisy on shared runners for a hard threshold.  Scenarios missing from the previous
artifact (first run, new backend or lane count) are reported and
skipped — the check only compares like with like, so the nightly job
can bootstrap from an empty history.  Exit code 0 = no regression (or
nothing to compare), 1 = regression beyond threshold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _rates(bench: dict) -> dict:
    """Flatten a benchmark JSON into {scenario: streams_per_s}."""
    out = {}
    seq = bench.get("sequential", {})
    if "streams_per_s" in seq:
        out["sequential"] = seq["streams_per_s"]
    for section in ("batched", "oversubscribed", "mixed_fidelity",
                    "lanes", "step_cache"):
        for key, row in bench.get(section, {}).items():
            if isinstance(row, dict) and "streams_per_s" in row:
                out[f"{section}/{key}"] = row["streams_per_s"]
    return out


def _load_prev(path: str):
    """Previous nightly artifact, or None with a warning: a missing,
    truncated, or corrupt history must never fail the gate — the
    trajectory simply bootstraps from this run's output."""
    if not os.path.exists(path):
        print(f"no previous artifact at {path}: nothing to compare "
              f"(bootstrapping the bench trajectory)")
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"WARNING: previous artifact {path} unreadable ({e}): "
              f"bootstrapping the bench trajectory")
        return None


def check_mixed_fidelity(bench: dict, threshold: float) -> bool:
    """Absolute fused-dispatch gate on the NEW output (no history
    needed): the fused mode must issue strictly fewer jitted launches
    than split AND hold streams/s at least to within the regression
    threshold.  Returns True when the gate FAILS; silently passes when
    the scenario was not run (e.g. --mixed-streams 0)."""
    mf = bench.get("mixed_fidelity") or {}
    split, fused = mf.get("split"), mf.get("fused")
    if not (isinstance(split, dict) and isinstance(fused, dict)):
        return False
    failed = False
    sd, fdp = split.get("dispatch_count"), fused.get("dispatch_count")
    if sd is not None and fdp is not None:
        flag = "ok" if fdp < sd else "FAIL"
        print(f"  mixed_fidelity dispatches    {sd} -> {fdp} "
              f"(gate: fused < split) {flag}")
        failed |= not fdp < sd
    sr, fr = split.get("streams_per_s"), fused.get("streams_per_s")
    if sr and fr:
        floor = sr * (1.0 - threshold)
        flag = "ok" if fr >= floor else "FAIL"
        print(f"  mixed_fidelity streams/s     split={sr:.3f} "
              f"fused={fr:.3f} (gate >= {floor:.3f}) {flag}")
        failed |= fr < floor
    return failed


def check_step_cache(bench: dict) -> bool:
    """Absolute step-cache gate on the NEW output (no history needed):
    whenever the cached run actually hit (hit_rate > 0) it must have
    skipped at least one jitted launch outright AND serve at least as
    many streams/s as the uncached run of the same population.  Returns
    True when the gate FAILS; silently passes when the scenario was not
    run (bootstrap: --step-cache absent) or never hit (nothing to
    gate — the cache fell back to computing every step)."""
    sc = bench.get("step_cache") or {}
    un, ca = sc.get("uncached"), sc.get("cached")
    if not (isinstance(un, dict) and isinstance(ca, dict)):
        return False
    hit_rate = ca.get("hit_rate") or 0.0
    if hit_rate <= 0.0:
        print("  step_cache       hit_rate=0: nothing to gate (skipped)")
        return False
    failed = False
    skipped = ca.get("skipped_launches")
    if skipped is not None:
        flag = "ok" if skipped > 0 else "FAIL"
        print(f"  step_cache skipped_launches  {skipped} "
              f"(gate > 0 at hit_rate={hit_rate:.2f}) {flag}")
        failed |= not skipped > 0
    ur, cr = un.get("streams_per_s"), ca.get("streams_per_s")
    if ur and cr:
        flag = "ok" if cr >= ur else "FAIL"
        print(f"  step_cache streams/s         uncached={ur:.3f} "
              f"cached={cr:.3f} (gate: cached >= uncached at "
              f"hit_rate={hit_rate:.2f}) {flag}")
        failed |= cr < ur
    return failed


def check_co_serve(bench: dict, tol: float = 0.40) -> bool:
    """Absolute co-serving gate on the NEW output (no history needed):
    the co-served aggregate streams/s must come within ``tol`` of the
    load-weighted serial composition of the per-model SOLO rates —
    expected = N_total / sum_m(n_m / solo_rate_m), i.e. the rate of
    serving each model's share back-to-back at its solo speed.  A
    co-serving stack that thrashes between bundles (jit churn, pool
    contention) lands far below that floor.  Also gates n_unserved == 0
    (co-serving must not silently drop streams).  Returns True when the
    gate FAILS; silently passes when the scenario was not run.  The
    default tolerance is generous: shared runners interleave two
    compile caches and the solo baselines re-pay session warm-up."""
    cs = bench.get("co_serve") or {}
    solo, agg = cs.get("solo"), cs.get("aggregate_streams_per_s")
    if not (isinstance(solo, dict) and solo and agg):
        return False
    failed = False
    unserved = cs.get("n_unserved", 0)
    flag = "ok" if unserved == 0 else "FAIL"
    print(f"  co_serve unserved            {unserved} (gate == 0) {flag}")
    failed |= unserved != 0
    serial_s = 0.0
    n_total = 0
    for m, row in solo.items():
        rate = row.get("streams_per_s") or 0.0
        n_m = row.get("streams") or 0
        if rate <= 0.0 or n_m <= 0:
            print(f"  co_serve solo/{m}: no usable baseline, skipped")
            return failed
        serial_s += n_m / rate
        n_total += n_m
    expected = n_total / serial_s if serial_s > 0 else 0.0
    floor = expected * (1.0 - tol)
    flag = "ok" if agg >= floor else "FAIL"
    print(f"  co_serve streams/s           aggregate={agg:.3f} "
          f"load-weighted-solo={expected:.3f} (gate >= {floor:.3f}) "
          f"{flag}")
    failed |= agg < floor
    return failed


def check_fleet(args) -> int:
    """Gate ``BENCH_fleet_sim.json``: absolute acceptance criteria
    first, then the ticks/s trajectory against the previous artifact."""
    with open(args.new) as f:
        new = json.load(f)
    failed = False

    speedup = new.get("speedup") or 0.0
    flag = "ok" if speedup >= args.min_speedup else "FAIL"
    print(f"  speedup          {speedup:.2f}x "
          f"(gate >= {args.min_speedup}x) {flag}")
    failed |= speedup < args.min_speedup

    parity = bool(new.get("parity_ok"))
    print("  parity           " +
          ("ok" if parity else
           "BROKEN: vectorized tick diverged from the scalar baseline"))
    failed |= not parity

    fd = new.get("front_door", {})
    hard = fd.get("hard_failures", None)
    if hard is None:
        print("  front_door       missing from benchmark output FAIL")
        failed = True
    else:
        print(f"  hard_failures    {hard} (gate == 0) "
              f"{'ok' if hard == 0 else 'FAIL'}")
        failed |= hard != 0

    cal = new.get("calibration")
    if cal is not None:
        agr = cal.get("agreement", {})
        ok = bool(cal.get("ok"))
        print(f"  calibration      qoe_delta={agr.get('qoe_delta')} "
              f"(tol {agr.get('qoe_tol')}), "
              f"ttfc_rel={agr.get('ttfc_rel_err')} "
              f"(tol {agr.get('ttfc_rel_tol')}) "
              f"{'ok' if ok else 'DISAGREE'}")
        failed |= not ok

    new_r = (new.get("vectorized") or {}).get("ticks_per_s")
    prev = _load_prev(args.prev)
    if prev is not None:
        prev_r = (prev.get("vectorized") or {}).get("ticks_per_s")
        if new_r and prev_r:
            delta = (new_r - prev_r) / prev_r
            flag = "REGRESSION" if delta < -args.threshold else "ok"
            print(f"  ticks/s          {prev_r:8.1f} -> {new_r:8.1f} "
                  f"({delta:+.1%}) {flag}")
            failed |= delta < -args.threshold

    if failed:
        print("FAIL: fleet benchmark gate")
        return 1
    print("fleet benchmark ok")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly measured benchmark JSON")
    ap.add_argument("prev", help="previous nightly artifact (may be "
                                 "missing on the first run)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional streams/s drop")
    ap.add_argument("--fleet", action="store_true",
                    help="gate BENCH_fleet_sim.json (speedup, parity, "
                         "admission hard-failures, calibration, "
                         "ticks/s trajectory)")
    ap.add_argument("--min-speedup", type=float, default=5.0,
                    help="--fleet: minimum vectorized-over-scalar "
                         "control-tick speedup")
    ap.add_argument("--co-serve-tol", type=float, default=0.40,
                    help="max tolerated fractional shortfall of the "
                         "co-served aggregate streams/s vs the "
                         "load-weighted solo composition")
    args = ap.parse_args()

    if args.fleet:
        return check_fleet(args)

    with open(args.new) as f:
        new_bench = json.load(f)
    new = _rates(new_bench)
    # absolute gate first: fused dispatch must beat split on the NEW
    # output regardless of history
    failed = check_mixed_fidelity(new_bench, args.threshold)
    failed |= check_step_cache(new_bench)
    failed |= check_co_serve(new_bench, args.co_serve_tol)

    prev_bench = _load_prev(args.prev)
    if prev_bench is None:
        if failed:
            print("FAIL: mixed-fidelity, step-cache, or co-serve "
                  "absolute gate")
            return 1
        return 0
    prev = _rates(prev_bench)
    for scenario in sorted(set(new) | set(prev)):
        if scenario not in prev:
            print(f"  {scenario:28s} new scenario "
                  f"({new[scenario]:.3f} streams/s), skipped")
            continue
        if scenario not in new:
            print(f"  {scenario:28s} dropped from benchmark output, "
                  f"skipped")
            continue
        old_r, new_r = prev[scenario], new[scenario]
        if old_r <= 0:
            continue
        delta = (new_r - old_r) / old_r
        flag = "REGRESSION" if delta < -args.threshold else "ok"
        print(f"  {scenario:28s} {old_r:8.3f} -> {new_r:8.3f} streams/s "
              f"({delta:+.1%}) {flag}")
        if delta < -args.threshold:
            failed = True
    if failed:
        print(f"FAIL: fused-dispatch/step-cache/co-serve gate or "
              f"streams/s regression beyond {args.threshold:.0%} vs "
              f"the previous nightly run")
        return 1
    print("bench trajectory ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
