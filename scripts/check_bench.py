#!/usr/bin/env python
"""Guard the serving-throughput trajectory: compare a freshly measured
``BENCH_batched_executor.json`` against the previous nightly artifact
and fail on a >15% streams/s regression in any tracked scenario.

    python scripts/check_bench.py NEW.json PREV.json [--threshold 0.15]

``--fleet`` switches to ``BENCH_fleet_sim.json`` gating instead:

    python scripts/check_bench.py --fleet NEW.json PREV.json

Absolute gates (fail even with no history): vectorized-tick speedup
>= --min-speedup (default 5x), scalar-vs-vectorized ``parity_ok``,
front-door ``hard_failures == 0`` (every arrival served or shed, never
lost), and — when the calibration cell ran — sim-vs-real agreement
``ok`` under the pinned tolerances.  Trajectory gate: vectorized
ticks/s vs the previous artifact, same threshold rules as streams/s.

Tracked scenarios: ``sequential``, ``batched/<backend>``,
``oversubscribed/<backend>`` and ``lanes/<n>`` ``streams_per_s``
entries; any other fields a scenario row carries (migration/SP counts,
QoE, transfer reports, the device-lane ``transfer_measured`` stats and
``lane_transfer_bytes`` in/out attribution, ...) are ignored, so the
compare tolerates new JSON fields without breaking.  Measured transfer
bandwidth is deliberately NOT gated: host-to-host ``jax.device_put``
wall time is too noisy on shared runners for a hard threshold.  Scenarios missing from the previous
artifact (first run, new backend or lane count) are reported and
skipped — the check only compares like with like, so the nightly job
can bootstrap from an empty history.  Exit code 0 = no regression (or
nothing to compare), 1 = regression beyond threshold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _rates(bench: dict) -> dict:
    """Flatten a benchmark JSON into {scenario: streams_per_s}."""
    out = {}
    seq = bench.get("sequential", {})
    if "streams_per_s" in seq:
        out["sequential"] = seq["streams_per_s"]
    for section in ("batched", "oversubscribed", "lanes"):
        for key, row in bench.get(section, {}).items():
            if isinstance(row, dict) and "streams_per_s" in row:
                out[f"{section}/{key}"] = row["streams_per_s"]
    return out


def check_fleet(args) -> int:
    """Gate ``BENCH_fleet_sim.json``: absolute acceptance criteria
    first, then the ticks/s trajectory against the previous artifact."""
    with open(args.new) as f:
        new = json.load(f)
    failed = False

    speedup = new.get("speedup") or 0.0
    flag = "ok" if speedup >= args.min_speedup else "FAIL"
    print(f"  speedup          {speedup:.2f}x "
          f"(gate >= {args.min_speedup}x) {flag}")
    failed |= speedup < args.min_speedup

    parity = bool(new.get("parity_ok"))
    print("  parity           " +
          ("ok" if parity else
           "BROKEN: vectorized tick diverged from the scalar baseline"))
    failed |= not parity

    fd = new.get("front_door", {})
    hard = fd.get("hard_failures", None)
    if hard is None:
        print("  front_door       missing from benchmark output FAIL")
        failed = True
    else:
        print(f"  hard_failures    {hard} (gate == 0) "
              f"{'ok' if hard == 0 else 'FAIL'}")
        failed |= hard != 0

    cal = new.get("calibration")
    if cal is not None:
        agr = cal.get("agreement", {})
        ok = bool(cal.get("ok"))
        print(f"  calibration      qoe_delta={agr.get('qoe_delta')} "
              f"(tol {agr.get('qoe_tol')}), "
              f"ttfc_rel={agr.get('ttfc_rel_err')} "
              f"(tol {agr.get('ttfc_rel_tol')}) "
              f"{'ok' if ok else 'DISAGREE'}")
        failed |= not ok

    new_r = (new.get("vectorized") or {}).get("ticks_per_s")
    if os.path.exists(args.prev):
        with open(args.prev) as f:
            prev_r = (json.load(f).get("vectorized") or {}) \
                .get("ticks_per_s")
        if new_r and prev_r:
            delta = (new_r - prev_r) / prev_r
            flag = "REGRESSION" if delta < -args.threshold else "ok"
            print(f"  ticks/s          {prev_r:8.1f} -> {new_r:8.1f} "
                  f"({delta:+.1%}) {flag}")
            failed |= delta < -args.threshold
    else:
        print(f"  ticks/s          {new_r} (no previous artifact: "
              f"bootstrapping the trajectory)")

    if failed:
        print("FAIL: fleet benchmark gate")
        return 1
    print("fleet benchmark ok")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly measured benchmark JSON")
    ap.add_argument("prev", help="previous nightly artifact (may be "
                                 "missing on the first run)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max tolerated fractional streams/s drop")
    ap.add_argument("--fleet", action="store_true",
                    help="gate BENCH_fleet_sim.json (speedup, parity, "
                         "admission hard-failures, calibration, "
                         "ticks/s trajectory)")
    ap.add_argument("--min-speedup", type=float, default=5.0,
                    help="--fleet: minimum vectorized-over-scalar "
                         "control-tick speedup")
    args = ap.parse_args()

    if args.fleet:
        return check_fleet(args)

    with open(args.new) as f:
        new = _rates(json.load(f))
    if not os.path.exists(args.prev):
        print(f"no previous artifact at {args.prev}: nothing to compare "
              f"(bootstrapping the bench trajectory)")
        return 0
    with open(args.prev) as f:
        prev = _rates(json.load(f))

    failed = False
    for scenario in sorted(set(new) | set(prev)):
        if scenario not in prev:
            print(f"  {scenario:28s} new scenario "
                  f"({new[scenario]:.3f} streams/s), skipped")
            continue
        if scenario not in new:
            print(f"  {scenario:28s} dropped from benchmark output, "
                  f"skipped")
            continue
        old_r, new_r = prev[scenario], new[scenario]
        if old_r <= 0:
            continue
        delta = (new_r - old_r) / old_r
        flag = "REGRESSION" if delta < -args.threshold else "ok"
        print(f"  {scenario:28s} {old_r:8.3f} -> {new_r:8.3f} streams/s "
              f"({delta:+.1%}) {flag}")
        if delta < -args.threshold:
            failed = True
    if failed:
        print(f"FAIL: streams/s regressed more than "
              f"{args.threshold:.0%} vs the previous nightly run")
        return 1
    print("bench trajectory ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
