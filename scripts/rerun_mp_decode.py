import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Re-run the 10 multi-pod decode_32k cells after the PartitionSpec fix."""
import time
from repro.configs.base import get_config, list_archs
from repro.launch.dryrun import run_cell

t0 = time.time()
fails = 0
for arch in [a for a in list_archs() if not a.startswith("ardit")]:
    rec = run_cell(arch, "decode_32k", multi_pod=True, verbose=False,
                   analyze=False)
    print(f"[{time.time()-t0:5.0f}s] {rec['cell']:58s} {rec['status']}",
          flush=True)
    fails += rec["status"] == "FAILED"
print(f"DONE failures={fails}")
