"""Re-run the HLO roofline analysis over saved .hlo.gz artifacts
(no recompiles) and update the dry-run JSON records in place."""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import hlo_cost
from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "artifacts", "dryrun")

for gz in sorted(glob.glob(os.path.join(ART, "*.hlo.gz"))):
    jpath = gz.replace(".hlo.gz", ".json")
    if not os.path.exists(jpath):
        continue
    with open(jpath) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        continue
    cost = hlo_cost.analyze_text(gzip.open(gz, "rt").read())
    n = rec["n_chips"]
    rec.update({
        "flops": cost.flops * n,
        "hbm_bytes": cost.hbm_bytes * n,
        "coll_bytes": cost.coll_bytes * n,
        "t_compute_s": cost.flops / PEAK_FLOPS,
        "t_memory_s": cost.hbm_bytes / HBM_BW,
        "t_collective_s": cost.coll_bytes / ICI_BW,
    })
    terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
             "collective": rec["t_collective_s"]}
    rec["dominant"] = max(terms, key=terms.get)
    rec["useful_ratio"] = (rec["model_flops"] / rec["flops"]
                           if rec["flops"] else None)
    with open(jpath, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"{rec['cell']:58s} mem={1000*rec['t_memory_s']:9.1f}ms "
          f"coll={1000*rec['t_collective_s']:8.1f}ms dom={rec['dominant']}")
print("reanalysis done")
