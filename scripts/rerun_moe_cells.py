import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Re-run MoE-family single-pod cells (dispatch/combine rewrite)."""
import time
from repro.configs.base import SHAPES, get_config
from repro.launch.dryrun import run_cell

t0 = time.time()
for arch in ("granite-moe-1b-a400m", "qwen3-moe-235b-a22b",
             "jamba-v0.1-52b"):
    cfg = get_config(arch)
    for sname in SHAPES:
        rec = run_cell(arch, sname, verbose=False, save_hlo=True)
        print(f"[{time.time()-t0:6.0f}s] {rec['cell']:58s} {rec['status']}"
              + (f" dom={rec.get('dominant')}" if rec['status']=='ok' else ''),
              flush=True)
        if sname == "long_500k" and not cfg.supports_shape(SHAPES[sname]):
            rec = run_cell(arch, sname, windowed_adaptation=True,
                           verbose=False, save_hlo=True)
            print(f"[{time.time()-t0:6.0f}s] {rec['cell']:58s} "
                  f"{rec['status']}", flush=True)
print("DONE")
