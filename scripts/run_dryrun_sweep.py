import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Full dry-run sweep: every (arch x shape) x {single-pod, multi-pod} +
the windowed-KV long_500k adaptations for pure full-attention archs.
Each cell's record lands in benchmarks/artifacts/dryrun/.
"""
import json
import sys
import time

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch.dryrun import run_cell

LM_ARCHS = [a for a in list_archs() if not a.startswith("ardit")]


def main():
    only_multipod = "--multi-pod-only" in sys.argv
    only_singlepod = "--single-pod-only" in sys.argv
    meshes = [True] if only_multipod else ([False] if only_singlepod
                                           else [False, True])
    t0 = time.time()
    n_fail = 0
    for multi_pod in meshes:
        for arch in LM_ARCHS:
            cfg = get_config(arch)
            for sname in SHAPES:
                # multi-pod proves sharding coherence (compile pass/fail +
                # memory fit); the roofline table is single-pod only
                rec = run_cell(arch, sname, multi_pod=multi_pod,
                               verbose=False, analyze=not multi_pod,
                               save_hlo=not multi_pod)
                status = rec["status"]
                print(f"[{time.time()-t0:7.0f}s] {rec['cell']:60s} "
                      f"{status}"
                      + (f" dominant={rec.get('dominant')}"
                         if status == "ok" else
                         f" {rec.get('reason', rec.get('error', ''))[:80]}"),
                      flush=True)
                n_fail += status == "FAILED"
                # windowed adaptation for skipped long_500k cells
                if (sname == "long_500k"
                        and not cfg.supports_shape(SHAPES[sname])):
                    rec = run_cell(arch, sname, multi_pod=multi_pod,
                                   windowed_adaptation=True, verbose=False,
                                   analyze=not multi_pod,
                                   save_hlo=not multi_pod)
                    print(f"[{time.time()-t0:7.0f}s] {rec['cell']:60s} "
                          f"{rec['status']}", flush=True)
                    n_fail += rec["status"] == "FAILED"
    print(f"DONE failures={n_fail} wall={time.time()-t0:.0f}s")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
