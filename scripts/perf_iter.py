import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Perf-iteration harness (EXPERIMENTS.md SSPerf).

Lowers one (arch x shape) cell under a named VARIANT, compiles, and
prints the three roofline terms + deltas vs the baseline artifact — the
measure step of the hypothesis -> change -> measure -> validate loop.

    PYTHONPATH=src python scripts/perf_iter.py internlm2-20b train_4k bf16bwd
"""
import dataclasses
import json
import sys

from repro.configs.base import SHAPES, get_config
from repro.launch import analysis
from repro.launch.lowering import cell_config, lower_cell
from repro.launch.mesh import make_production_mesh

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "artifacts", "dryrun")


def variant_cfg(cfg, name: str):
    """Named beyond-paper variants (each = one hypothesis)."""
    kw = {}
    micro = 1
    if name == "baseline":
        pass
    elif name == "bf16bwd":
        cfg = dataclasses.replace(cfg, bf16_backward=True)
    elif name.startswith("mb"):
        micro = int(name[2:])
    elif name == "bf16bwd+mb4":
        cfg = dataclasses.replace(cfg, bf16_backward=True)
        micro = 4
    elif name == "fp8kv":
        cfg = dataclasses.replace(cfg, kv_dtype="float8_e4m3fn")
    elif name == "ep":
        cfg = dataclasses.replace(cfg, moe_ep=True)
    elif name == "ep+bf16bwd":
        cfg = dataclasses.replace(cfg, moe_ep=True, bf16_backward=True)
    elif name == "zero3":
        cfg = dataclasses.replace(cfg, parallel_layout="zero3")
    elif name == "zero3+mb4":
        cfg = dataclasses.replace(cfg, parallel_layout="zero3")
        micro = 4
    else:
        raise ValueError(name)
    return cfg, micro


def main():
    arch, shape_name, variant = sys.argv[1], sys.argv[2], sys.argv[3]
    multi_pod = "--multi-pod" in sys.argv
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    cfg = cell_config(cfg0, shape)
    cfg, micro = variant_cfg(cfg, variant)
    lowered = lower_cell(cfg, mesh, shape, microbatches=micro)
    compiled = lowered.compile()
    roof = analysis.analyze(lowered, compiled, n_chips)
    mf = analysis.model_flops(cfg, shape)

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    base_path = os.path.join(ART, f"{arch}__{shape_name}__{mesh_name}.json")
    base = None
    if os.path.exists(base_path):
        with open(base_path) as f:
            base = json.load(f)

    def fmt(t):
        return f"{1000*t:9.1f}ms"
    print(f"cell={arch}x{shape_name}x{mesh_name} variant={variant}")
    print(f"  compute    {fmt(roof.t_compute)}"
          + (f"  (base {1000*base['t_compute_s']:9.1f}ms, "
             f"{roof.t_compute/max(base['t_compute_s'],1e-12):5.2f}x)"
             if base else ""))
    print(f"  memory     {fmt(roof.t_memory)}"
          + (f"  (base {1000*base['t_memory_s']:9.1f}ms, "
             f"{roof.t_memory/max(base['t_memory_s'],1e-12):5.2f}x)"
             if base else ""))
    print(f"  collective {fmt(roof.t_collective)}"
          + (f"  (base {1000*base['t_collective_s']:9.1f}ms, "
             f"{roof.t_collective/max(base['t_collective_s'],1e-12):5.2f}x)"
             if base else ""))
    ideal = mf / (n_chips * 197e12)
    print(f"  dominant={roof.dominant}  useful={mf/max(roof.flops,1):.3f}  "
          f"roofline_fraction={ideal/max(roof.step_time,1e-12):.4f}")
    rec = {"cell": f"{arch}__{shape_name}__{mesh_name}",
           "variant": variant, **roof.row(), "model_flops": mf}
    out = os.path.join(ART, "..", "perf",
                       f"{arch}__{shape_name}__{mesh_name}__{variant}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1, default=str)


if __name__ == "__main__":
    main()
