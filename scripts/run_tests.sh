#!/usr/bin/env bash
# Tiered test runner.
#
#   scripts/run_tests.sh            fast tier (-m "not slow"), < 2 min
#   scripts/run_tests.sh --slow     full suite, including JAX-compiling
#                                   model/kernel/sharding tests
#
# Extra arguments are forwarded to pytest, e.g.
#   scripts/run_tests.sh -k batcher -x
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--slow" ]]; then
    shift
    exec python -m pytest -q "$@"
fi
exec python -m pytest -q -m "not slow" "$@"
