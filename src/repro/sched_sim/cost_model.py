"""Modeled hardware constants for the cluster simulator.

Everything the simulator cannot measure on this CPU container is derived
here, with the derivation recorded (DESIGN.md SS8).  Swapping in measured
values is a one-file change.

Testbed (paper SS7.1): 2 nodes x 8 H100-80GB, NVLink 900 GB/s/GPU
intra-node, 400 Gb/s InfiniBand across nodes.

KV page accounting (Self-Forcing-class AR-DiT, 480p):
    tokens/latent-frame = 880; 12 KV heads x 128 head dim; 30 layers
    page = 1 latent frame across all layers (frame-granularity paging,
    SS4.4 footnote: frame-level paging avoids fragmentation)
    page bytes = 880 * 12 * 128 * 2(K,V) * 2(bf16) * 30 = 162.3 MB
    full stream (cond sink + 7-chunk window = 21 frames + sink) ~ 3.5 GB
    pool per worker = kappa * 80 GB = 64 GB ~ 394 pages (~18 streams)

Transfer model (paper App. D.2 reports 31.8 ms avg / 118.4 ms P95 per
KV transfer, 4.4 ms avg residual wait under layer-wise streaming):
    effective intra-node P2P   200 GB/s  (NVLink practical share)
    effective cross-node RDMA   40 GB/s  (400 Gb/s IB, ~80% efficiency)
    fixed submission overhead    4 ms    (page lookup, CUDA events)
A ~2 GB average resident state then costs ~14 ms intra / ~54 ms cross —
the observed 31.8 ms average falls between, and first-layer readiness
(1/30 of the bytes) lands at ~4-6 ms, matching the residual-wait table.

SDV2 batching (SS7.1): batched diffusion steps amortize weight reads;
we model batch-of-b per-step latency as t_step * (0.4 + 0.6 b)
(throughput rises ~1.7x at b=4 while per-chunk latency rises ~2.8x),
consistent with SS7.2's observation that SDV2 "increases per-chunk
latency" while raising aggregate FPS.
"""
from __future__ import annotations

# --- cluster topology (paper testbed) ---------------------------------------
N_WORKERS = 16
WORKERS_PER_NODE = 8

# --- playout (SS7.1) ---------------------------------------------------------
FPS = 16
PIXEL_FRAMES_PER_CHUNK = 12          # 3 latent frames x 4 VAE temporal rate
CHUNK_SECONDS = PIXEL_FRAMES_PER_CHUNK / FPS      # 0.75 s
STREAM_FRAMES = (81, 129, 161, 241)  # ~5-15 s at 16 fps (App. B)

# --- KV paging ---------------------------------------------------------------
PAGE_BYTES = 880 * 12 * 128 * 2 * 2 * 30         # 162.3 MB / latent frame
FRAMES_PER_CHUNK = 3
SINK_PAGES = 1                        # cond tokens ~ one page equivalent
MAX_WINDOW_CHUNKS = 7
POOL_BYTES = int(0.8 * 80e9)          # kappa = 0.8 of 80 GB VRAM (SS4.4)
POOL_PAGES = POOL_BYTES // PAGE_BYTES

# --- transfer engine ----------------------------------------------------------
BW_INTRA = 200e9
BW_INTER = 40e9
TRANSFER_OVERHEAD_S = 0.004
N_LAYERS = 30

# --- baseline modeling --------------------------------------------------------
SDV2_BATCH = 4


SDV2_BATCH_ALPHA = 0.9   # default marginal per-stream step-cost slope


def sdv2_batch_step_factor(b: int, alpha: float = SDV2_BATCH_ALPHA) -> float:
    """Per-step latency multiplier for a lockstep batch of ``b``.

    A 1.3B AR-DiT at 480p is compute-bound at batch 1 (2640-token chunks
    saturate the GPU), so batching amortizes little: ~10% per added
    stream (``alpha = 0.9`` marginal cost).  Throughput gain at b=4 is
    b/factor = 1.08x while every member's chunk latency inflates 3.4x —
    which is exactly SS7.2's observation that SDV2 raises aggregate FPS
    but not per-stream timeliness, leaving multi-stream workers URGENT
    (Fig. 15).  ``alpha`` is a calibration target: the sim-vs-real
    fitting loop (``sched_sim.calibration``) re-estimates it from the
    real batched executor's per-batch-size step EMAs."""
    return 1.0 + alpha * (b - 1)


# --- step cache (AdaCache-style residual reuse, models/stepcache.py) ---------
# The expected-hit-rate latency model lives with the other latency
# surfaces in the profiler; re-exported here so the simulator's cost
# constants stay one import away.
from repro.profiler.profiles import (  # noqa: E402,F401
    STEP_CACHE_HIT_RATE, step_cache_latency_factor,
)


# --- per-model KV footprint (heterogeneous co-serving) -----------------------
# Bytes-per-page multiplier vs the Wan-1.3B AR-DiT reference (12 KV heads
# x 128 head dim x 30 layers).  The paper's two AR-DiT columns share that
# KV geometry (causal-forcing: 16 heads x 96 = same bytes/row).  Other
# registry families carry analytic priors: an SSM holds O(1) state
# instead of a KV window, MoE/dense KV scales with layers x kv_heads x
# head_dim.  Consumed by the simulator's residency/transfer model only.
MODEL_PAGE_FACTOR = {
    "causal-forcing": 1.0,
    "self-forcing": 1.0,
    "mamba2-780m": 0.02,
    "minicpm-2b": 0.5,
    "granite-moe-1b-a400m": 0.4,
    "minitron-8b": 0.8,
    "internlm2-20b": 1.5,
    "jamba-v0.1-52b": 0.3,
    "internvl2-26b": 1.6,
    "qwen1.5-32b": 2.0,
    "qwen3-moe-235b-a22b": 3.0,
    "whisper-medium": 0.6,
}


def model_page_factor(model) -> float:
    return MODEL_PAGE_FACTOR.get(model, 1.0) if model is not None else 1.0


def stream_pages(chunks_resident: int, model=None) -> int:
    """Pages held by a stream with ``chunks_resident`` chunks in window.

    ``model`` scales the count by the bundle's page-footprint factor
    (rounded up: a fractional page still occupies a page); None is the
    exact legacy count."""
    pages = SINK_PAGES + min(chunks_resident,
                             MAX_WINDOW_CHUNKS) * FRAMES_PER_CHUNK
    factor = model_page_factor(model)
    if factor != 1.0:
        import math
        pages = max(1, math.ceil(pages * factor))
    return pages


def stream_bytes(chunks_resident: int, model=None) -> int:
    return stream_pages(chunks_resident, model) * PAGE_BYTES


TS_RECONFIG_S = 0.30     # TridentServe SP/parallelism reconfiguration stall
                         # (SS7.2: "parallelism reconfiguration also delays
                         #  the first chunk, inflating TTFC")
