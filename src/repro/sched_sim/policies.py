"""Serving policies: SlackServe (real control plane) + SS7.1 baselines.

    SlackServePolicy   wraps repro.core.control_plane (the paper system);
                       ablation switches map to Fig. 12's increments
    SDV2Policy         StreamDiffusionV2-style: FIFO + lockstep batching,
                       fixed fidelity, FPS-oriented, slack-blind
    TSPolicy           TridentServe-style: per-STREAM SLO, dynamic
                       parallelism + load-based migration, static fidelity
    TSChunkPolicy      TS + per-CHUNK least-slack-first scheduling (the
                       paper's strongest external baseline)
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core import elastic_sp, rehoming, slack
from repro.core.bmpr import BMPR, FixedLevelSwitcher, StaticFidelity
from repro.core.control_plane import ControlConfig, ControlPlane
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.core.types import Stream, Tier, Worker
from repro.profiler.profiles import get_profile
from repro.sched_sim import cost_model as cm
from repro.sched_sim.simulator import Policy, Simulator


class SlackServePolicy(Policy):
    """The paper's system; ablation flags reproduce Fig. 12's increments."""

    def __init__(self, *, use_bmpr: bool = True, use_rehoming: bool = True,
                 use_elastic_sp: bool = True, fidelity_policy=None,
                 alpha: float = 2.0, model: str = "causal-forcing",
                 profile=None):
        self.name = "slackserve"
        # an injected profile (e.g. a CalibratedProfile from the
        # sim-vs-real fitting loop) replaces the analytic surface for
        # BOTH fidelity selection and latency estimates
        self.profile = profile or get_profile(model)
        if fidelity_policy is None:
            fidelity_policy = (BMPR(self.profile) if use_bmpr
                               else StaticFidelity(profile=self.profile))
        self.control = ControlPlane(
            ControlConfig(alpha=alpha, use_rehoming=use_rehoming,
                          use_elastic_sp=use_elastic_sp),
            fidelity_policy=fidelity_policy)

    def attach(self, sim: Simulator) -> None:
        super().attach(sim)
        # the simulator's vectorization flag drives the control tick's
        # numpy path (bit-identical; benchmarks flip it off to measure
        # the scalar baseline)
        self.control.config.vectorized = sim.cfg.vectorized

    # --- admission ---
    def first_chunk_estimate(self) -> float:
        return self.profile.latency(HIGHEST_QUALITY)

    def initial_slack(self, first_est: float) -> float:
        return self.control.initial_slack(first_est)

    def choose_home(self) -> int:
        return self.control.choose_home(self.sim.view)

    # --- control tick (Algorithm 2) ---
    def on_tick(self, now: float) -> None:
        decisions = self.control.tick(self.sim.view, now)
        if decisions.scale_out:
            self.sim.scale_out(decisions.scale_out)
        if decisions.scale_in:
            self.sim.scale_in(decisions.scale_in)
        for mig in decisions.migrations:
            rehoming.apply_migration(self.sim.view, mig)
            self.sim.migrate(mig.sid, mig.src, mig.dst, mig.cross_node)
        for dec in decisions.sp_decisions:
            if dec.kind == "expand":
                elastic_sp.apply_expand(self.sim.view, dec)
                self.sim.sp_head_partition_transfer(dec.sid, dec.donor)
            else:
                elastic_sp.apply_release(self.sim.view, dec)

    @property
    def n_rehomings(self) -> int:
        return self.control.n_rehomings

    @property
    def n_sp_events(self) -> int:
        return self.control.n_sp_events

    @property
    def tick_times(self):
        return self.control.tick_times

    # --- boundaries ---
    def order(self, worker: Worker) -> None:
        """Credit order with continuation hysteresis: a mid-chunk stream
        keeps the worker unless a queued stream is meaningfully more
        urgent (> half a chunk of credit), avoiding EDF-style mid-chunk
        thrash while preserving step-boundary preemption (SS4.1)."""
        streams = self.sim.view.streams
        if not getattr(self.sim, "_credits_fresh", False):
            # outside a tick's dispatch fan-out the credits are stale;
            # inside it the control tick just refreshed every stream at
            # self.sim.now, so recomputing here would be a no-op scan
            for sid in worker.queue:
                slack.update_stream_credit(streams[sid], self.sim.now,
                                           self.control.config.alpha)
        worker.queue.sort(
            key=lambda sid: streams[sid].credit
            - (0.5 * streams[sid].t_next
               if streams[sid].step_done > 0 else 0.0))

    def select_fidelity(self, s: Stream,
                        now: float) -> Tuple[FidelityConfig, float]:
        """Apply the control decision at the boundary with the freshest
        slack budget (SS3.3: decisions take effect at boundaries)."""
        budget = max(s.playout_slack(now), 0.0)
        dec = self.control.fidelity_policy.select(budget)
        sp = 2 if s.sp_donor is not None else 1
        return dec.fidelity, self.profile.latency(dec.fidelity, sp_degree=sp)


class SDV2Policy(Policy):
    """StreamDiffusionV2-style pipeline+batch serving (SS7.1, Fig. 15).

    The 16 GPUs form 4 pipeline-parallel units of 4 GPUs; each unit
    serves its statically-bound streams FIFO in a lockstep batch at
    fixed fidelity.  Pipelining divides per-step latency by ~2.5
    (bubbles), batching inflates it by ``sdv2_batch_step_factor``:
    aggregate FPS tracks the playout rate while per-stream timeliness
    on crowded units collapses — the paper's imbalance analysis.
    Use ``sim_config()`` for the matching cluster shape.
    """

    batch_size = 8
    pipeline_speedup = 2.2
    gpus_per_unit = 4

    def __init__(self, model: str = "causal-forcing"):
        self.name = "sdv2"
        self.profile = get_profile(model)
        self._rr = 0
        self._static = HIGHEST_QUALITY

    @classmethod
    def sim_config(cls, base: "SimConfig" = None):
        from repro.sched_sim.simulator import SimConfig
        base = base or SimConfig()
        import dataclasses as _dc
        n_units = cm.N_WORKERS // cls.gpus_per_unit
        return _dc.replace(base, n_workers=n_units,
                           workers_per_node=max(1, n_units // 2))

    def first_chunk_estimate(self) -> float:
        return self.profile.latency(self._static)

    def choose_home(self) -> int:
        self._rr = (self._rr + 1) % len(self.sim.view.workers)
        return self._rr

    def order(self, worker: Worker) -> None:
        pass                                    # FIFO

    def select_fidelity(self, s, now):
        return self._static, self.profile.latency(self._static)


class TSPolicy(Policy):
    """TridentServe-style: per-stream SLO control loop (SS7.1/SS7.2).

    Dynamic parallelism reacts to STREAM-level progress (not per-chunk
    slack); every SP reconfiguration costs ``TS_RECONFIG_S`` on the
    stream (SS7.2: reconfiguration inflates TTFC); load-based migration
    balances queue lengths, blind to slack."""

    def __init__(self, model: str = "causal-forcing",
                 chunk_level: bool = False):
        self.name = "ts-chunk" if chunk_level else "ts"
        self.profile = get_profile(model)
        self.chunk_level = chunk_level
        self._static = HIGHEST_QUALITY
        self.n_rehomings = 0
        self.n_sp_events = 0

    def first_chunk_estimate(self) -> float:
        return self.profile.latency(self._static)

    def on_admit(self, s: Stream) -> None:
        # admission-time parallelism planning stalls the first chunk
        self.sim.in_transfer[s.sid] = self.sim.now + cm.TS_RECONFIG_S
        self.sim.push(self.sim.now + cm.TS_RECONFIG_S, "stream_ready",
                      (s.sid, s.home))

    def _behind(self, s: Stream, now: float) -> float:
        """Chunks behind the stream-level SLO trajectory."""
        expected = (now - s.arrival - s.ttfc_slack) / s.chunk_seconds + 1.0
        return expected - s.chunks_done

    def on_tick(self, now: float) -> None:
        view = self.sim.view
        for s in view.active_streams():
            s.t_next = self.profile.latency(
                self._static, sp_degree=2 if s.sp_donor else 1)
            slack.update_stream_credit(s, now)
        # ---- dynamic parallelism ----
        n_donated = sum(1 for w in view.workers if w.donated_to is not None)
        for s in view.active_streams():
            if s.done or s.sid in self.sim.in_transfer:
                continue
            if self.chunk_level:
                expand = s.playout_slack(now) < s.t_next
                release = s.playout_slack(now) > 4.0 * s.t_next
            else:
                expand = self._behind(s, now) > 2.0
                release = self._behind(s, now) < 0.0
            if expand and s.sp_donor is None \
                    and n_donated < len(view.workers) // 4:
                donors = [w for w in view.workers
                          if w.donated_to is None and w.wid != s.home
                          and not self.sim.batch[w.wid]]
                if donors:
                    n_donated += 1
                    donor = min(donors, key=lambda w: w.load())
                    s.sp_donor = donor.wid
                    donor.donated_to = s.sid
                    self.n_sp_events += 1
                    # reconfiguration + KV split cost
                    self.sim.sp_head_partition_transfer(s.sid, donor.wid)
                    self.sim.in_transfer[s.sid] = max(
                        self.sim.in_transfer.get(s.sid, 0.0),
                        now + cm.TS_RECONFIG_S)
            elif release and s.sp_donor is not None:
                view.workers[s.sp_donor].donated_to = None
                s.sp_donor = None
        # ---- load-based migration (slack-blind) ----
        loaded = sorted(view.workers, key=lambda w: w.load())
        if loaded[-1].load() - loaded[0].load() > 2:
            src, dst = loaded[-1], loaded[0]
            movable = [sid for sid in src.queue
                       if view.streams[sid].running_on is None
                       and sid not in self.sim.in_transfer]
            if movable:
                sid = movable[-1]
                s = view.streams[sid]
                src.queue.remove(sid)
                dst.queue.append(sid)
                s.home = dst.wid
                self.n_rehomings += 1
                self.sim.migrate(sid, src.wid, dst.wid,
                                 view.node_of(src.wid) !=
                                 view.node_of(dst.wid))

    def order(self, worker: Worker) -> None:
        if self.chunk_level:
            streams = self.sim.view.streams
            worker.queue.sort(
                key=lambda sid: streams[sid].next_deadline)   # least slack
        # else FIFO

    def select_fidelity(self, s, now):
        sp = 2 if s.sp_donor is not None else 1
        return self._static, self.profile.latency(self._static, sp_degree=sp)


def make_policy(name: str, **kw) -> Policy:
    if name == "slackserve":
        return SlackServePolicy(**kw)
    if name == "sdv2":
        return SDV2Policy(**kw)
    if name == "ts":
        return TSPolicy(**kw)
    if name == "ts-chunk":
        return TSPolicy(chunk_level=True, **kw)
    if name == "credit-only":
        return SlackServePolicy(use_bmpr=False, use_rehoming=False,
                                use_elastic_sp=False, **kw)
    if name == "credit+bmpr":
        return SlackServePolicy(use_rehoming=False, use_elastic_sp=False,
                                **kw)
    if name == "credit+bmpr+rehome":
        return SlackServePolicy(use_elastic_sp=False, **kw)
    if name == "bmpr-fixed-level":
        return SlackServePolicy(
            fidelity_policy=FixedLevelSwitcher(get_profile()), **kw)
    raise ValueError(name)
