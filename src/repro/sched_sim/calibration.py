"""Sim-vs-real calibration loop.

The discrete-event simulator prices every mechanism off analytic
constants (``profiler.profiles`` latency surface, ``cost_model`` batch
slope, ``state_plane`` bandwidths).  A real ``StreamingSession`` run
MEASURES the same quantities on this host: per-fidelity chunk-latency
EMAs in each lane executor, per-step EMAs, and — on device-backed
lanes — real ``jax.device_put`` bandwidth in
``engine.measured_stats()``.  This module closes the loop:

    report = fit_session(session)          # after session.run()
    cfg    = report.sim_config(n_workers=session.lanes.n_lanes)
    sim    = Simulator(cfg, same_specs, make_policy(
                 "slackserve", profile=report.profile()))

and the simulator replays the workload on the CALIBRATED surface — the
latency profile corrected per fidelity, the playout budget and
transfer constants as the session experienced them — so the sim's
QoE/TTFC prediction can be held against the real run's inside a pinned
tolerance (``agreement``; the fleet benchmark + ``check_bench.py
--fleet`` gate it in CI).

The fit is deliberately simple and robust: per-config ratios where the
run produced a measurement, one global host-speed scale everywhere
else.  Calibration corrects compute speed; the SP communication model
stays analytic (see ``profiles.CalibratedProfile``).
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Any, Dict, List, Optional

from repro.core.fidelity import HIGHEST_QUALITY
from repro.profiler.profiles import (CalibratedProfile, ModelProfile,
                                     calibrate_profile, get_profile)
from repro.sched_sim import cost_model as cm

# pinned sim-vs-real agreement tolerances (CI gate; loose enough for
# shared-runner wall-clock noise, tight enough that a unit bug — e.g.
# uncalibrated latencies off by the host-speed factor — fails hard)
QOE_ABS_TOL = 0.25          # |QoE_sim - QoE_real|, QoE in [0, 1]
TTFC_REL_TOL = 1.0          # |TTFC_sim - TTFC_real| / TTFC_real


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """Fitted cost-model constants of one real run."""
    model: str
    ratios: Dict[str, float]        # fidelity key -> measured / analytic
    scale: float                    # global host-speed correction
    chunk_seconds: float            # playout budget the session served
    bw_intra: float                 # B/s (measured-calibrated if moves ran)
    bw_inter: float
    batch_alpha: Optional[float] = None   # sdv2_batch_step_factor slope
    # step-cache level -> measured on/off latency factor (< 1 = speedup)
    cache_speedups: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def profile(self) -> CalibratedProfile:
        # Replay over the 270-point (cache-unlocked) surface only when
        # the real run actually exercised the step cache — otherwise the
        # sim's BMPR would route over cache points the session never
        # had, breaking apples-to-apples agreement.
        used_cache = bool(self.cache_speedups) or any(
            _cache_level_of(k) for k in self.ratios)
        return calibrate_profile(
            get_profile(self.model, step_cache=used_cache),
            self.ratios, self.scale,
            cache_speedups=self.cache_speedups)

    def sim_config(self, base: Any = None, **overrides: Any) -> Any:
        """A ``SimConfig`` replaying on the calibrated surface."""
        from repro.sched_sim.simulator import SimConfig
        return dataclasses.replace(
            base or SimConfig(),
            model=self.model, profile=self.profile(),
            chunk_seconds=self.chunk_seconds,
            bw_intra=self.bw_intra, bw_inter=self.bw_inter,
            batch_alpha=self.batch_alpha, **overrides)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# FidelityConfig.key appends "_c{level[0]}" only for cache-on configs
_CACHE_SUFFIX = {"_cc": "conservative", "_ca": "aggressive"}


def _cache_level_of(key: str) -> Optional[str]:
    """Cache level of a fidelity key, or None for cache=off keys."""
    return _CACHE_SUFFIX.get(key[-3:])


def fit_cache_speedups(measured: Dict[str, float]) -> Dict[str, float]:
    """Measured per-cache-level latency factors (on/off, < 1 = speedup).

    For every cache-on fidelity key whose cache=off SIBLING was also
    measured in the same run, take the on/off chunk-latency ratio and
    average per level — the real-content counterpart of the analytic
    ``step_cache_latency_factor`` prior, which it replaces in
    ``CalibratedProfile`` fallbacks."""
    per_level: Dict[str, List[float]] = {}
    for key, m_on in measured.items():
        level = _cache_level_of(key)
        if level is None or m_on <= 0.0:
            continue
        m_off = measured.get(key[:-3])
        if m_off and m_off > 0.0:
            per_level.setdefault(level, []).append(m_on / m_off)
    return {lvl: statistics.mean(r) for lvl, r in per_level.items()}


def fit_ratios(measured: Dict[str, float],
               profile: ModelProfile) -> Dict[str, float]:
    """Per-config measured/analytic latency ratios (SP1)."""
    by_key = profile.by_key
    return {key: m / by_key[key].latency
            for key, m in measured.items()
            if key in by_key and m > 0.0 and by_key[key].latency > 0.0}


def fit_batch_alpha(batch_step_times: Dict[int, float]) -> Optional[float]:
    """Fit the lockstep-batch slope of ``sdv2_batch_step_factor``
    (t_b = t_1 * (1 + alpha * (b - 1))) from measured per-row step
    times at batch sizes b.  Needs t_1 plus at least one b > 1 point;
    returns None otherwise.  Alpha is clamped to >= 0 (a measured
    superlinear speedup is noise, not a schedulable resource)."""
    t1 = batch_step_times.get(1)
    if not t1 or t1 <= 0.0:
        return None
    pts = [(b, t) for b, t in batch_step_times.items()
           if b > 1 and t > 0.0]
    if not pts:
        return None
    return max(0.0, statistics.mean(
        (t / t1 - 1.0) / (b - 1) for b, t in pts))


def fit_session(session: Any,
                batch_step_times: Optional[Dict[int, float]] = None,
                model: Optional[str] = None) -> CalibrationReport:
    """Fit a ``CalibrationReport`` from a finished ``StreamingSession``.

    Reads the per-fidelity latency EMAs of every lane executor (mean
    across lanes: same host, same device class), the session's playout
    budget, and the transfer engine's measured-calibrated bandwidths
    (device-backed lanes fold real ``device_put`` observations into
    ``engine.bw_intra``; host-only runs keep the analytic constant).

    Co-serving sessions calibrate per bundle: pass ``model`` (a bundle
    name) to fit from THAT bundle's lane executors and profile — each
    co-served model gets its own report, exactly as if it had run
    solo."""
    profile = getattr(session, "_profile", None) or get_profile()
    executors = session.lanes.executors
    if model is not None:
        bundle_profiles = getattr(session, "_bundle_profiles", {})
        if model in bundle_profiles:
            profile = bundle_profiles[model]
        executors = getattr(session.lanes, "bundle_executors",
                            {}).get(model, executors)
    measured: Dict[str, List[float]] = {}
    for ex in executors:
        for key, val in getattr(ex, "latency_ema", {}).items():
            measured.setdefault(key, []).append(val)
    flat = {key: statistics.mean(vals) for key, vals in measured.items()}
    ratios = fit_ratios(flat, profile)
    top = HIGHEST_QUALITY.key
    scale = (ratios.get(top) or
             (statistics.mean(ratios.values()) if ratios else 1.0))
    engine = session.lanes.engine
    return CalibrationReport(
        model=profile.model, ratios=ratios, scale=scale,
        chunk_seconds=session.chunk_seconds,
        bw_intra=getattr(engine, "bw_intra", cm.BW_INTRA),
        bw_inter=getattr(engine, "bw_inter", cm.BW_INTER),
        batch_alpha=fit_batch_alpha(batch_step_times)
        if batch_step_times else None,
        cache_speedups=fit_cache_speedups(flat))


def agreement(real_summary: Any, sim_summary: Any,
              qoe_tol: float = QOE_ABS_TOL,
              ttfc_rel_tol: float = TTFC_REL_TOL) -> Dict[str, Any]:
    """Sim-vs-real QoE/TTFC agreement under the pinned tolerances.

    Returns a dict with the deltas and an overall ``ok`` — the fleet
    benchmark embeds it in ``BENCH_fleet_sim.json`` and
    ``check_bench.py --fleet`` fails CI when ``ok`` is false."""
    qoe_delta = abs(sim_summary.qoe - real_summary.qoe)
    if real_summary.ttfc > 0 and real_summary.ttfc != float("inf"):
        ttfc_rel = (abs(sim_summary.ttfc - real_summary.ttfc)
                    / real_summary.ttfc)
    else:
        ttfc_rel = float("inf")
    return {
        "qoe_real": round(real_summary.qoe, 4),
        "qoe_sim": round(sim_summary.qoe, 4),
        "qoe_delta": round(qoe_delta, 4),
        "qoe_tol": qoe_tol,
        "ttfc_real_s": round(real_summary.ttfc, 4),
        "ttfc_sim_s": round(sim_summary.ttfc, 4),
        "ttfc_rel_err": (round(ttfc_rel, 4)
                         if ttfc_rel != float("inf") else None),
        "ttfc_rel_tol": ttfc_rel_tol,
        "ok": bool(qoe_delta <= qoe_tol and ttfc_rel <= ttfc_rel_tol),
    }
