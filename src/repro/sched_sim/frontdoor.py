"""Fleet front door: SLO-aware admission control + autoscaling.

The control plane (Algorithm 2) decides *placement* among the workers
it has; nothing before this module decided *capacity*.  The front door
sits at arrival time and, per stream, predicts the time-to-first-chunk
(TTFC) the current fleet would deliver, compares it against the TTFC
SLO (the same ``ttfc_factor x first_chunk_estimate`` slack budget that
seeds per-stream playout deadlines), and picks one of four outcomes:

    ADMIT       predicted TTFC slack >= 0: the fleet can serve the
                stream inside its SLO right now.
    SCALE-OUT   slack < 0 but autoscaling has headroom: provision
                ``scale_step`` workers (usable after a cold-start
                delay) and QUEUE the arrival until capacity lands.
    QUEUE       slack < 0, no scale headroom, but the wait is bounded:
                hold the arrival FIFO; its TTFC clock keeps running
                (queueing eats the stream's slack — deliberately).
    REJECT      the queue is full or the stream could no longer meet
                its SLO even if admitted: shed load instead of
                admitting a guaranteed stall.

The TTFC prediction is load-derived, not magic: a stream homed on the
least-loaded worker waits for ~``load`` chunk services before its first
dispatch slot, each costing the observed per-chunk service time (seeded
from the profiled top-fidelity latency, re-estimated online from
completed chunks), plus its own first-chunk generation.

The service estimate is **keyed per (model, fidelity)**: each completed
chunk updates the EMA of its own key, and the fleet-wide expected
service is the observation-weighted mix of the keyed EMAs.  One global
EMA systematically over-predicts on a low-fidelity-heavy fleet — a few
slow high-fidelity completions drag the single estimate far above what
the (mostly cheap) next dispatch slots actually cost, and the door
over-rejects.  The global ``chunk_service_ema`` survives as the
no-observations fallback and stays bit-identical on single-key traffic
(one key's EMA sees exactly the global update sequence).

Deciders emit *decisions*; the driver (discrete-event simulator or the
real ``StreamingSession``) applies them — exactly the control-plane
split used everywhere else in this repo.  ``ControlPlane`` exposes the
hooks: ``attach_front_door`` + ``admission`` per arrival, and the tick
returns the autoscale decision in ``TickDecisions.scale_out``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

SLO_TTFC_FACTOR = 4.0       # SLO = factor x first-chunk estimate (SS3.3)


@dataclasses.dataclass
class FrontDoorConfig:
    """Knobs of the admission/autoscaling layer.

    ``slo_ttfc_factor`` mirrors ``ControlConfig.ttfc_factor``: the TTFC
    SLO is ``factor x first_chunk_estimate``.  ``queue_limit`` bounds
    the FIFO admission queue; ``max_queue_wait`` bounds how long an
    arrival may sit in it before it is shed (timeout reject).
    Autoscaling adds ``scale_step`` workers per decision (cold-start
    ``provision_delay`` seconds before they serve), at most every
    ``scale_cooldown`` seconds, never past ``max_workers``.

    Scale-IN retires ``scale_in_step`` idle workers per decision, at
    most every ``scale_in_cooldown`` seconds, only while the admission
    queue is empty and the surviving fleet's predicted TTFC would stay
    comfortably inside the SLO (``scale_in_slack_factor`` x predicted
    TTFC <= SLO), never below ``min_workers``.  The longer cooldown is
    deliberate hysteresis: provisioning is expensive, so capacity is
    shed far more slowly than it is added."""
    slo_ttfc_factor: float = SLO_TTFC_FACTOR
    queue_limit: int = 512
    max_queue_wait: float = 60.0
    autoscale: bool = True
    max_workers: int = 256
    scale_step: int = 4
    scale_cooldown: float = 9.0
    provision_delay: float = 6.0
    # chunk-service EMA blend (new observation weight)
    ema_decay: float = 0.2
    # scale-in (worker retirement) knobs
    min_workers: int = 1
    scale_in_step: int = 1
    scale_in_cooldown: float = 30.0
    scale_in_slack_factor: float = 2.0


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Per-arrival front-door outcome (the driver applies it)."""
    action: str                 # "admit" | "queue" | "reject"
    predicted_ttfc: float       # load-derived TTFC estimate (seconds)
    slack: float                # SLO - predicted_ttfc at decision time
    scale_workers: int = 0      # workers to provision alongside


class FrontDoor:
    """SLO-aware admission + autoscaling state machine.

    One instance per driver run.  All methods are pure host code — the
    fleet simulator calls them hundreds of thousands of times, so the
    per-arrival path is O(workers) and allocation-free beyond the
    decision record."""

    def __init__(self, config: Optional[FrontDoorConfig] = None,
                 first_chunk_estimate: float = 1.0):
        self.cfg = config or FrontDoorConfig()
        self.first_est = first_chunk_estimate
        self.chunk_service_ema = first_chunk_estimate
        # per-(model, fidelity) service EMAs + observation counts; the
        # expected service is their observation-weighted mix (the
        # traffic the fleet ACTUALLY serves), falling back to the
        # global EMA until the first keyed observation lands
        self._service_emas: Dict[Tuple[Optional[str], Optional[str]],
                                 float] = {}
        self._service_obs: Dict[Tuple[Optional[str], Optional[str]],
                                int] = {}
        # FIFO admission queue: (sid, arrival_time, enqueue_time)
        self.waiting: List[Tuple[int, float, float]] = []
        self._cooldown_until = -1e18
        self._in_cooldown_until = -1e18
        self.outcomes: Dict[int, str] = {}       # sid -> final outcome
        self.n_admitted = 0
        self.n_queued = 0                        # ever queued
        self.n_rejected = 0
        self.n_timeouts = 0                      # rejects from queue wait
        self.n_scale_outs = 0
        self.workers_added = 0
        self.n_scale_ins = 0
        self.workers_retired = 0

    # ------------------------------------------------------------- predict
    def slo_ttfc(self) -> float:
        return self.cfg.slo_ttfc_factor * self.first_est

    def expected_service(self) -> float:
        """Expected per-chunk service of the fleet's CURRENT traffic
        mix: the observation-count-weighted mean of the keyed
        per-(model, fidelity) EMAs.  Falls back to the global
        ``chunk_service_ema`` before any keyed observation exists (and
        equals it exactly under single-key traffic)."""
        if not self._service_obs:
            return self.chunk_service_ema
        total = sum(self._service_obs.values())
        return sum(self._service_emas[k] * n
                   for k, n in self._service_obs.items()) / total

    def predict_ttfc(self, view: Any) -> float:
        """Load-derived TTFC estimate for a stream admitted NOW: homed
        on the least-loaded ACTIVE worker (retired workers take no
        admissions), it waits ~load chunk services for its first
        dispatch slot, then generates its own first chunk."""
        load = min((w.load() for w in view.workers if not w.retired),
                   default=min(w.load() for w in view.workers))
        return load * self.expected_service() + self.first_est

    def observe_chunk(self, service_seconds: float,
                      fidelity: Optional[str] = None,
                      model: Optional[str] = None) -> None:
        """Online re-estimation of the per-chunk service time (dispatch
        wait + generation, as completed chunks actually experienced it).
        Updates the global EMA (the keyless fallback) AND the
        per-(model, fidelity) EMA of the chunk's own key."""
        if service_seconds <= 0.0:
            return
        d = self.cfg.ema_decay
        # a new key seeds from the global EMA's PRE-update value: under
        # single-key traffic the keyed recurrence then reproduces the
        # global one exactly (expected_service == chunk_service_ema,
        # keeping the legacy predictor bit-identical there)
        key = (model, fidelity)
        old = self._service_emas.get(key, self.chunk_service_ema)
        self.chunk_service_ema = ((1.0 - d) * self.chunk_service_ema
                                  + d * service_seconds)
        self._service_emas[key] = (1.0 - d) * old + d * service_seconds
        self._service_obs[key] = self._service_obs.get(key, 0) + 1

    # ------------------------------------------------------------- arrival
    def on_arrival(self, view: Any, now: float, first_est: float,
                   sid: int) -> AdmissionDecision:
        """Admission decision for one arriving stream."""
        self.first_est = first_est
        predicted = self.predict_ttfc(view)
        slack = self.slo_ttfc() - predicted
        if slack >= 0.0 and not self.waiting:
            # FIFO fairness: nobody may jump an existing queue
            self.outcomes[sid] = "admitted"
            self.n_admitted += 1
            return AdmissionDecision("admit", predicted, slack)
        scale = self._maybe_scale(view, now)
        if scale > 0 or len(self.waiting) < self.cfg.queue_limit:
            self.waiting.append((sid, now, now))
            self.outcomes[sid] = "queued"
            self.n_queued += 1
            return AdmissionDecision("queue", predicted, slack,
                                     scale_workers=scale)
        self.outcomes[sid] = "rejected"
        self.n_rejected += 1
        return AdmissionDecision("reject", predicted, slack)

    # ------------------------------------------------------------- queue
    def drain(self, view: Any, now: float) -> Tuple[List[Tuple[int, float]],
                                                    List[int]]:
        """Promote / shed queued arrivals.  Returns
        ``(admit, reject)``: ``admit`` is ``[(sid, original_arrival)]``
        in FIFO order, ``reject`` the sids shed on queue timeout.

        A queued stream's TTFC clock runs from its ORIGINAL arrival —
        queueing consumes its slack — so promotion requires the
        *remaining* budget to cover the predicted TTFC."""
        admits: List[Tuple[int, float]] = []
        rejects: List[int] = []
        while self.waiting:
            sid, t_arr, t_enq = self.waiting[0]
            predicted = self.predict_ttfc(view)
            deadline = t_arr + self.slo_ttfc()
            if now + predicted <= deadline:
                self.waiting.pop(0)
                self.outcomes[sid] = "admitted"
                self.n_admitted += 1
                admits.append((sid, t_arr))
                continue
            if now - t_enq > self.cfg.max_queue_wait:
                self.waiting.pop(0)
                self.outcomes[sid] = "rejected"
                self.n_rejected += 1
                self.n_timeouts += 1
                rejects.append(sid)
                continue
            break                        # FIFO head still waiting
        return admits, rejects

    # ------------------------------------------------------------- scaling
    def _maybe_scale(self, view: Any, now: float) -> int:
        cfg = self.cfg
        if not cfg.autoscale or now < self._cooldown_until:
            return 0
        n = sum(1 for w in view.workers if not w.retired)
        if n >= cfg.max_workers:
            return 0
        k = min(cfg.scale_step, cfg.max_workers - n)
        self._cooldown_until = now + cfg.scale_cooldown
        # hysteresis: fresh capacity must not be shed right back
        self._in_cooldown_until = max(self._in_cooldown_until,
                                      now + cfg.scale_in_cooldown)
        self.n_scale_outs += 1
        self.workers_added += k
        return k

    def autoscale(self, view: Any, now: float) -> int:
        """Tick-cadence scale decision: provision when arrivals are
        waiting (the per-arrival path already scaled for the arrival
        that triggered the pressure; this catches sustained backlogs
        across cooldown windows)."""
        if not self.waiting:
            return 0
        return self._maybe_scale(view, now)

    def maybe_scale_in(self, view: Any, now: float) -> int:
        """Tick-cadence scale-IN decision: retire idle workers when the
        admission queue is empty and the survivors' predicted TTFC
        keeps comfortable SLO slack (``scale_in_slack_factor`` margin).
        Only IDLE workers are candidates — the driver drains a victim's
        queued streams by re-homing before marking it retired, so a
        busy fleet simply yields 0 here.  Cooldown-gated with a much
        longer period than scale-out (hysteresis)."""
        cfg = self.cfg
        if (not cfg.autoscale or self.waiting
                or now < self._in_cooldown_until):
            return 0
        active = [w for w in view.workers if not w.retired]
        idle = [w for w in active
                if w.load() == 0 and w.donated_to is None]
        k = min(cfg.scale_in_step, len(idle),
                len(active) - cfg.min_workers)
        if k <= 0:
            return 0
        # survivors' predicted TTFC must stay comfortably positive:
        # retiring k idle workers leaves min-load = the best survivor
        survivors = active[:]
        for w in idle[:k]:
            survivors.remove(w)
        pred = (min(w.load() for w in survivors) * self.expected_service()
                + self.first_est)
        if pred * cfg.scale_in_slack_factor > self.slo_ttfc():
            return 0
        self._in_cooldown_until = now + cfg.scale_in_cooldown
        self.n_scale_ins += 1
        self.workers_retired += k
        return k

    # ------------------------------------------------------------- report
    def stats(self) -> Dict[str, int]:
        return {
            "admitted": self.n_admitted,
            "queued": self.n_queued,
            "rejected": self.n_rejected,
            "queue_timeouts": self.n_timeouts,
            "scale_outs": self.n_scale_outs,
            "workers_added": self.workers_added,
            "scale_ins": self.n_scale_ins,
            "workers_retired": self.workers_retired,
            "waiting_at_end": len(self.waiting),
        }
