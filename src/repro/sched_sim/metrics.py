"""Evaluation metrics (paper SS7.1) — ONE metrics surface for simulated
and real runs.

    QoE = CPR = mean over streams of (fraction of chunks ready by their
          playout deadlines)
    TTFC = mean time from arrival to first playable chunk
    quality = mean profiled VBench over all delivered chunks
    stalls = per-stream count + duration distribution (Fig. 14)

Every function here is duck-typed over a *result-like* object — the
discrete-event simulator's ``SimResult`` or the real executor's
``serve.session.SessionResult``.  Both expose ``streams`` (sid ->
``core.types.Stream`` record), an ``engine`` transfer log, and the
rehoming / elastic-SP counters, so the same ``StreamSpec`` workload run
through either driver yields ``Summary`` objects with identically
defined fields (apples-to-apples sim-vs-real comparison).
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Any, Dict, List


@dataclasses.dataclass(frozen=True)
class Summary:
    qoe: float
    ttfc: float
    quality: float
    stalls_per_stream: float
    avg_stall_ms: float
    n_streams: int
    n_chunks: int
    n_rehomings: int
    n_sp_events: int
    n_unserved: int = 0           # admitted streams with zero ready chunks
    avg_effective_window: float = 0.0   # mean page-degraded KV window
    # heterogeneous co-serving: per-model rows (model name -> {cpr,
    # ttfc, n_streams, n_chunks, streams_per_s}) so sim-vs-real parity
    # holds per model, not just in aggregate; empty when no stream
    # carries a model tag (single-model runs)
    by_model: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def row(self) -> str:
        return (f"QoE={self.qoe:.3f} TTFC={self.ttfc:.2f}s "
                f"VBench={self.quality:.2f} "
                f"stalls/stream={self.stalls_per_stream:.2f} "
                f"avg_stall={self.avg_stall_ms:.0f}ms")

    def model_rows(self) -> List[str]:
        return [f"  [{m}] CPR={r['cpr']:.3f} TTFC={r['ttfc']:.2f}s "
                f"streams={r['n_streams']:.0f} chunks={r['n_chunks']:.0f} "
                f"streams/s={r['streams_per_s']:.3f}"
                for m, r in sorted(self.by_model.items())]


def summarize(res: Any) -> Summary:
    """CPR / TTFC / quality / stall summary of a result-like object
    (``SimResult`` or ``SessionResult`` — see module docstring).

    An admitted stream with NO ready chunks (overload, ``max_time``
    truncation — exactly the regimes admission control creates) counts
    as CPR 0 and is reported in ``n_unserved``: it received the worst
    possible experience, so skipping it would silently inflate QoE and
    deflate ``n_streams``.  TTFC stays a served-streams mean (an
    unserved stream has no finite first-chunk time to average)."""
    cprs: List[float] = []
    ttfcs: List[float] = []
    quals: List[float] = []
    stall_counts: List[int] = []
    stall_durs: List[float] = []
    n_chunks = 0
    n_unserved = 0
    for s in res.streams.values():
        if not s.ready_times:
            n_unserved += 1
            cprs.append(0.0)               # admitted, never served: CPR 0
            stall_counts.append(0)
            continue
        hits = sum(1 for r, d in zip(s.ready_times, s.deadlines) if r <= d)
        cprs.append(hits / max(len(s.ready_times), 1))
        if s.first_chunk_time is not None:
            ttfcs.append(s.first_chunk_time - s.arrival)
        quals.extend(s.qualities)
        stall_counts.append(len(s.stall_events))
        stall_durs.extend(s.stall_events)
        n_chunks += len(s.ready_times)
    return Summary(
        qoe=statistics.mean(cprs) if cprs else 0.0,
        ttfc=statistics.mean(ttfcs) if ttfcs else float("inf"),
        quality=statistics.mean(quals) if quals else 0.0,
        stalls_per_stream=statistics.mean(stall_counts) if stall_counts
        else 0.0,
        avg_stall_ms=1000.0 * statistics.mean(stall_durs) if stall_durs
        else 0.0,
        n_streams=len(cprs), n_chunks=n_chunks,
        n_rehomings=getattr(res, "n_rehomings", 0),
        n_sp_events=getattr(res, "n_sp_events", 0),
        n_unserved=n_unserved,
        avg_effective_window=_avg_effective_window(res),
        by_model=_by_model(res))


def _by_model(res: Any) -> Dict[str, Dict[str, float]]:
    """Per-model CPR/TTFC/streams-per-s rows (heterogeneous co-serving).
    Empty unless at least one stream record carries a model tag, so
    single-model summaries are unchanged."""
    groups: Dict[str, List[Any]] = {}
    for s in res.streams.values():
        m = getattr(s, "model", None)
        if m is not None:
            groups.setdefault(m, []).append(s)
    rows: Dict[str, Dict[str, float]] = {}
    for m, streams in sorted(groups.items()):
        cprs, ttfcs = [], []
        n_chunks = 0
        served = [s for s in streams if s.ready_times]
        for s in streams:
            if not s.ready_times:
                cprs.append(0.0)
                continue
            hits = sum(1 for r, d in zip(s.ready_times, s.deadlines)
                       if r <= d)
            cprs.append(hits / max(len(s.ready_times), 1))
            if s.first_chunk_time is not None:
                ttfcs.append(s.first_chunk_time - s.arrival)
            n_chunks += len(s.ready_times)
        span = (max(s.ready_times[-1] for s in served)
                - min(s.arrival for s in streams)) if served else 0.0
        rows[m] = {
            "cpr": statistics.mean(cprs) if cprs else 0.0,
            "ttfc": statistics.mean(ttfcs) if ttfcs else float("inf"),
            "n_streams": float(len(streams)),
            "n_chunks": float(n_chunks),
            "streams_per_s": (len(served) / span if span > 0 else 0.0),
        }
    return rows


def _avg_effective_window(res: Any) -> float:
    """Mean of per-stream mean effective (page-degraded) KV windows.
    Real runs attach ``effective_window`` (sid -> per-launch window
    history); simulated results lack it and report 0."""
    logs = getattr(res, "effective_window", None) or {}
    per_stream = [statistics.mean(log) for log in logs.values() if log]
    return statistics.mean(per_stream) if per_stream else 0.0


def stall_histogram(res: Any,
                    edges=(0.1, 0.25, 0.5, 1.0, 2.0, 5.0)) -> Dict[str, int]:
    durs = [d for s in res.streams.values() for d in s.stall_events]
    hist: Dict[str, int] = {}
    lo = 0.0
    for e in edges:
        hist[f"{lo:.2f}-{e:.2f}s"] = sum(1 for d in durs if lo <= d < e)
        lo = e
    hist[f">{edges[-1]:.2f}s"] = sum(1 for d in durs if d >= edges[-1])
    return hist


def transfer_stats(res: Any) -> Dict[str, float]:
    log = res.engine.log
    if not log:
        return {"n": 0, "avg_ms": 0.0, "p95_ms": 0.0,
                "avg_residual_ms": 0.0, "p95_residual_ms": 0.0}
    totals = sorted(t.total for t in log)
    waits = sorted(t.residual_wait for t in log)

    def p95(xs):
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]
    return {"n": len(log),
            "avg_ms": 1000 * statistics.mean(totals),
            "p95_ms": 1000 * p95(totals),
            "avg_residual_ms": 1000 * statistics.mean(waits),
            "p95_residual_ms": 1000 * p95(waits)}
