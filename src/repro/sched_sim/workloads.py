"""Workload generators (paper SS7.1 + App. B).

All five workloads share per-stream settings: 946 VBench prompts, target
lengths sampled from {81, 129, 161, 241} pixel frames (~5-15 s at 16 fps),
480p, 3 latent frames per chunk (12 pixel frames -> 0.75 s of playout).

    Steady         Poisson arrivals, lambda = 1 stream/s
    Burst          Steady + 3 burst points (20/50/80% progress), each
                   pulling 10% of all streams to arrive simultaneously
    Prompt-switch  Steady + per-stream condition switches (1-3 by length)
                   that reset playout slack to the initial TTFC
    Pause          Steady + client pauses (1-3 by length, each 20% of the
                   stream duration) during which slack accumulates
    Trace          enterprise-trace-shaped arrivals: interleaved steady
                   segments, bursts, and idle gaps
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import List, Optional, Tuple

from repro.sched_sim import cost_model as cm

N_PROMPTS = 946          # VBench prompt count


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    sid: int
    arrival: float
    frames: int                       # target pixel frames
    switches: Tuple[float, ...] = ()  # prompt-switch times (relative, s)
    pauses: Tuple[Tuple[float, float], ...] = ()   # (rel start, duration)
    model: Optional[str] = None       # co-serving: profile/model name

    @property
    def chunks(self) -> int:
        return math.ceil(self.frames / cm.PIXEL_FRAMES_PER_CHUNK)

    @property
    def duration(self) -> float:
        return self.frames / cm.FPS


def _poisson_arrivals(n: int, rate: float, rng: random.Random) -> List[float]:
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate)
        out.append(t)
    return out


def _lengths(n: int, rng: random.Random) -> List[int]:
    return [rng.choice(cm.STREAM_FRAMES) for _ in range(n)]


def steady(n: int = N_PROMPTS, rate: float = 1.0,
           seed: int = 0) -> List[StreamSpec]:
    rng = random.Random(seed)
    arr = _poisson_arrivals(n, rate, rng)
    return [StreamSpec(i, arr[i], f)
            for i, f in enumerate(_lengths(n, rng))]


def burst(n: int = N_PROMPTS, rate: float = 1.0,
          seed: int = 0) -> List[StreamSpec]:
    """10% of streams reassigned to each of 3 synchronized burst points."""
    rng = random.Random(seed)
    base = steady(n, rate, seed)
    arrivals = sorted(s.arrival for s in base)
    idx = list(range(n))
    rng.shuffle(idx)
    n_b = n // 10
    out = [dataclasses.replace(s) for s in base]
    cursor = 0
    for frac in (0.2, 0.5, 0.8):
        t_burst = arrivals[int(frac * (n - 1))]
        for j in idx[cursor:cursor + n_b]:
            out[j] = dataclasses.replace(out[j], arrival=t_burst)
        cursor += n_b
    return out


def _n_events(frames: int) -> int:
    return {81: 1, 129: 2, 161: 2, 241: 3}[frames]


def prompt_switch(n: int = N_PROMPTS, rate: float = 1.0,
                  seed: int = 0) -> List[StreamSpec]:
    rng = random.Random(seed)
    out = []
    for s in steady(n, rate, seed):
        ks = sorted(rng.uniform(0.1, 0.9) * s.duration
                    for _ in range(_n_events(s.frames)))
        out.append(dataclasses.replace(s, switches=tuple(ks)))
    return out


def pause(n: int = N_PROMPTS, rate: float = 1.0,
          seed: int = 0) -> List[StreamSpec]:
    rng = random.Random(seed)
    out = []
    for s in steady(n, rate, seed):
        dur = 0.2 * s.duration
        ps = tuple(sorted((rng.uniform(0.1, 0.9) * s.duration, dur)
                          for _ in range(_n_events(s.frames))))
        out.append(dataclasses.replace(s, pauses=ps))
    return out


def trace(n: int = N_PROMPTS, rate: float = 1.0,
          seed: int = 0) -> List[StreamSpec]:
    """Enterprise-trace-shaped arrivals: alternating steady segments
    (rates 0.6-1.6/s), flash bursts, and idle gaps (App. B).

    ``rate`` scales the whole trace's arrival intensity: segment rates
    are multiplied and idle gaps divided by it, so ``rate=2`` compresses
    the trace ~2x in time without changing its shape (at ``rate=1`` the
    rng consumption is unchanged, so pre-existing seeds reproduce)."""
    if rate <= 0.0:
        raise ValueError(f"trace rate must be positive, got {rate}")
    rng = random.Random(seed)
    arrivals: List[float] = []
    t = 0.0
    while len(arrivals) < n:
        kind = rng.random()
        if kind < 0.6:                       # steady segment
            seg_rate = rng.uniform(0.6, 1.6) * rate
            for _ in range(min(rng.randint(30, 120), n - len(arrivals))):
                t += rng.expovariate(seg_rate)
                arrivals.append(t)
        elif kind < 0.8:                     # flash burst
            k = min(rng.randint(5, 25), n - len(arrivals))
            arrivals.extend([t] * k)
        else:                                # idle gap
            t += rng.uniform(10.0, 40.0) / rate
    arrivals = arrivals[:n]
    rng2 = random.Random(seed + 1)
    return [StreamSpec(i, arrivals[i], rng2.choice(cm.STREAM_FRAMES))
            for i in range(n)]


def diurnal(n: int = N_PROMPTS, rate: float = 1.0, seed: int = 0,
            period: float = 1200.0,
            trough: float = 0.2) -> List[StreamSpec]:
    """Diurnal arrivals: a nonhomogeneous Poisson process whose rate
    follows one sinusoidal day-cycle, peak ``rate`` at mid-period and
    ``trough * rate`` at the edges (the fleet-scale sizing workload:
    autoscaling must track the swell, admission must absorb the crest).

    Sampled by thinning against the peak rate, so per-seed streams are
    deterministic and the instantaneous rate never exceeds ``rate``."""
    rng = random.Random(seed)
    arrivals: List[float] = []
    t = 0.0
    while len(arrivals) < n:
        t += rng.expovariate(rate)
        # lambda(t)/rate in [trough, 1]: sin half-wave over the period
        phase = (t % period) / period
        lam = trough + (1.0 - trough) * math.sin(math.pi * phase) ** 2
        if rng.random() < lam:
            arrivals.append(t)
    rng2 = random.Random(seed + 1)
    return [StreamSpec(i, arrivals[i], rng2.choice(cm.STREAM_FRAMES))
            for i in range(n)]


def flash_crowd(n: int = N_PROMPTS, rate: float = 1.0, seed: int = 0,
                spike_frac: float = 0.3,
                spike_width: float = 2.0) -> List[StreamSpec]:
    """Flash-crowd arrivals: a steady Poisson baseline carrying
    ``1 - spike_frac`` of the streams, with the remaining ``spike_frac``
    slammed into a ``spike_width``-second window at mid-trace (a viral
    event: the admission-control stress test — the spike exceeds any
    statically provisioned capacity, so the front door must queue,
    shed, or scale out)."""
    rng = random.Random(seed)
    n_spike = int(spike_frac * n)
    base = _poisson_arrivals(n - n_spike, rate, rng)
    t_spike = base[len(base) // 2] if base else 0.0
    spike = sorted(t_spike + rng.uniform(0.0, spike_width)
                   for _ in range(n_spike))
    arrivals = sorted(base + spike)
    rng2 = random.Random(seed + 1)
    return [StreamSpec(i, arrivals[i], rng2.choice(cm.STREAM_FRAMES))
            for i in range(n)]


def mixed_models(n: int = N_PROMPTS, rate: float = 1.0, seed: int = 0,
                 models: Tuple[str, ...] = ("causal-forcing",
                                            "self-forcing"),
                 weights: Optional[Tuple[float, ...]] = None
                 ) -> List[StreamSpec]:
    """Heterogeneous co-serving arrivals: ``steady`` with each stream
    tagged with a model drawn from ``models`` (uniform unless
    ``weights`` given).  A separate rng (``seed + 2``) does the model
    draws so arrivals and lengths match ``steady`` at the same seed —
    per-model sub-workloads are then directly comparable to the
    single-model run they were carved out of."""
    if not models:
        raise ValueError("mixed_models needs at least one model name")
    rng = random.Random(seed + 2)
    base = steady(n, rate, seed)
    picks = (rng.choices(list(models), weights=list(weights), k=n)
             if weights is not None else
             [rng.choice(list(models)) for _ in range(n)])
    return [dataclasses.replace(s, model=m) for s, m in zip(base, picks)]


WORKLOADS = {
    "steady": steady,
    "burst": burst,
    "prompt_switch": prompt_switch,
    "pause": pause,
    "trace": trace,
    "diurnal": diurnal,
    "flash_crowd": flash_crowd,
    "mixed_models": mixed_models,
}
