"""Discrete-event cluster simulator reproducing the paper's evaluation.

The simulator drives the REAL control-plane code (repro.core) on a
virtual clock over a modeled 16-worker / 2-node cluster (the paper's
16xH100 testbed), or any other topology.  Workloads follow App. B;
baselines (SDV2 / TS / TS-chunk) follow SS7.1; metrics follow SS7.1
(QoE = CPR, TTFC, quality, stalls).
"""
from repro.sched_sim.simulator import Simulator, SimConfig  # noqa: F401
