"""Discrete-event cluster simulator (paper SS7 testbed on a virtual clock).

The simulator owns the event loop, playout bookkeeping, worker execution
and the paged-KV pools; ALL control decisions come from policy objects —
SlackServe's policy calls the real ``repro.core`` control plane, baselines
implement SS7.1's SDV2 / TS / TS-chunk behaviors.  Execution is modeled at
*denoise-step* granularity, so step-boundary preemption (SS3.1) is exact.

Event kinds: arrival, tick, step_done, stream_ready (transfer finished /
atomic-safety reinsertion), prompt_switch, pause_end, worker_unblock.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time as _time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import queues as q_mod
from repro.core import slack as slack_mod
from repro.core.control_plane import ControlPlane, ControlConfig
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.core.state_plane import AsyncTransferEngine, PagedKVPool
from repro.core.types import ClusterView, Stream, Tier, Worker
from repro.profiler.profiles import MODEL_COST, ModelProfile, get_profile
from repro.sched_sim import cost_model as cm
from repro.sched_sim.frontdoor import FrontDoor, FrontDoorConfig
from repro.sched_sim.workloads import StreamSpec


@dataclasses.dataclass
class SimConfig:
    n_workers: int = cm.N_WORKERS
    workers_per_node: int = cm.WORKERS_PER_NODE
    model: str = "causal-forcing"
    transfer_protocol: str = "async-stream"
    tick_interval: float = 3.0
    pool_pages: int = cm.POOL_PAGES
    max_time: float = 3.0e4
    # --- calibration overrides (sched_sim.calibration fits these to a
    # real --lanes run; defaults are the analytic cost-model constants) ---
    chunk_seconds: float = cm.CHUNK_SECONDS
    bw_intra: float = cm.BW_INTRA
    bw_inter: float = cm.BW_INTER
    batch_alpha: Optional[float] = None   # sdv2_batch_step_factor slope
    profile: Optional[ModelProfile] = None   # calibrated latency surface
    # --- fleet front door (None = legacy unconditional admission) ---
    front_door: Optional[FrontDoorConfig] = None
    # numpy-batched control tick + fresh-credit dispatch ordering
    # (bit-identical to the scalar path; the fleet benchmark flips this
    # off to measure the pre-vectorization baseline)
    vectorized: bool = True


@dataclasses.dataclass
class SimResult:
    streams: Dict[int, Stream]
    engine: AsyncTransferEngine
    n_rehomings: int
    n_sp_events: int
    worker_tier_samples: List[Tuple[int, int, int]]   # (urgent, mixed, relaxed)
    fidelity_counts: Dict[str, int]
    control_tick_times: List[float]
    admission: Dict[str, int] = dataclasses.field(default_factory=dict)
    tick_wall: List[float] = dataclasses.field(default_factory=list)
    n_workers_final: int = 0


class Simulator:
    def __init__(self, config: SimConfig, specs: Sequence[StreamSpec],
                 policy: "Policy"):
        self.cfg = config
        self.specs = {s.sid: s for s in specs}
        self.policy = policy
        self.profile: ModelProfile = (config.profile
                                      or get_profile(config.model))
        self.engine = AsyncTransferEngine(
            protocol=config.transfer_protocol, bw_intra=config.bw_intra,
            bw_inter=config.bw_inter, overhead=cm.TRANSFER_OVERHEAD_S,
            n_layers=cm.N_LAYERS)
        workers = [Worker(w, node=w // config.workers_per_node)
                   for w in range(config.n_workers)]
        self.view = ClusterView({}, workers, config.workers_per_node)
        self.pools = [PagedKVPool(config.pool_pages)
                      for _ in range(config.n_workers)]
        self.blocked_until = [0.0] * config.n_workers
        self.in_transfer: Dict[int, float] = {}       # sid -> ready time
        self.now = 0.0
        self._heap: List[Tuple[float, int, str, Any]] = []
        self._seq = itertools.count()
        self.worker_tier_samples: List[Tuple[int, int, int]] = []
        self.fidelity_counts: Dict[str, int] = {}
        # per-worker execution context: list of (sid) running in lockstep
        self.batch: List[List[int]] = [[] for _ in range(config.n_workers)]
        # batch generation counter per worker: a step_done event carries
        # the epoch it was scheduled under, so an aborted-then-restarted
        # batch with the SAME sid list cannot be credited a stale step
        self.batch_epoch: List[int] = [0] * config.n_workers
        # O(1) completion tracking (_all_done was an O(streams) scan per
        # event — the top cost in fleet-scale profiles)
        self._n_done = 0
        self._n_rejected = 0
        # fleet front door (admission + autoscaling)
        self.front_door: Optional[FrontDoor] = None
        if config.front_door is not None:
            self.front_door = FrontDoor(
                config.front_door,
                first_chunk_estimate=policy.first_chunk_estimate()
                if hasattr(policy, "profile") else 1.0)
        # wall-clock of each _on_tick handler (ticks/s benchmark metric)
        self.tick_wall: List[float] = []
        # True only inside a tick's dispatch fan-out, right after the
        # control tick refreshed every credit at self.now: policies may
        # then skip per-dispatch credit recomputation (exact: nothing
        # that feeds Eq. 1 for QUEUED streams mutates inside the loop)
        self._credits_fresh = False
        policy.attach(self)
        if self.front_door is not None and hasattr(policy, "control"):
            policy.control.attach_front_door(self.front_door)

    # ------------------------------------------------------------------ events
    def push(self, t: float, kind: str, payload: Any = None) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    def run(self) -> SimResult:
        for spec in self.specs.values():
            self.push(spec.arrival, "arrival", spec.sid)
            for st in spec.switches:
                self.push(spec.arrival + st, "prompt_switch", spec.sid)
            for (ps, dur) in spec.pauses:
                self.push(spec.arrival + ps, "pause", (spec.sid, dur))
        self.push(self.cfg.tick_interval, "tick", None)

        while self._heap:
            t, _, kind, payload = heapq.heappop(self._heap)
            if t > self.cfg.max_time:
                break
            self.now = t
            if kind == "tick":
                w0 = _time.perf_counter()
                self._on_tick(payload)
                self.tick_wall.append(_time.perf_counter() - w0)
            else:
                getattr(self, f"_on_{kind}")(payload)
                if self._all_done():
                    break
        return SimResult(self.view.streams, self.engine,
                         getattr(self.policy, "n_rehomings", 0),
                         getattr(self.policy, "n_sp_events", 0),
                         self.worker_tier_samples, self.fidelity_counts,
                         getattr(self.policy, "tick_times", []),
                         admission=(self.front_door.stats()
                                    if self.front_door else {}),
                         tick_wall=self.tick_wall,
                         n_workers_final=sum(1 for w in self.view.workers
                                             if not w.retired))

    def _all_done(self) -> bool:
        # O(1): every spec either finished serving or was shed by the
        # front door (equivalent to the old all(s.done) scan — a stream
        # waiting in the admission queue counts as neither)
        return self._n_done + self._n_rejected == len(self.specs)

    # ------------------------------------------------------------------ admission
    def _on_arrival(self, sid: int) -> None:
        first_est = self.policy.first_chunk_estimate()
        if self.front_door is not None:
            dec = self.front_door.on_arrival(self.view, self.now,
                                             first_est, sid)
            if dec.scale_workers:
                self.scale_out(dec.scale_workers)
            if dec.action == "reject":
                self._n_rejected += 1
                return
            if dec.action == "queue":
                return                 # drained at ticks / completions
        self._admit(sid, self.now, first_est)

    def _admit(self, sid: int, arrival: float, first_est: float) -> None:
        """Place an admitted stream (``arrival`` is the ORIGINAL arrival
        time: a front-door queue wait consumes the stream's TTFC slack,
        so its playout clock starts when the user asked, not when
        capacity appeared)."""
        spec = self.specs[sid]
        ttfc_slack = self.policy.initial_slack(first_est)
        home = self.policy.choose_home()
        s = Stream(sid=sid, arrival=arrival, target_chunks=spec.chunks,
                   chunk_seconds=self.cfg.chunk_seconds, home=home,
                   ttfc_slack=ttfc_slack,
                   next_deadline=arrival + ttfc_slack)
        s.t_next = first_est
        s.model = spec.model          # co-serving: None on legacy workloads
        self.view.streams[sid] = s
        self.policy.on_admit(s)
        self.view.workers[home].queue.append(sid)
        self.pools[home].alloc(sid, cm.SINK_PAGES)
        s.resident_on.add(home)
        self._try_dispatch(home)

    def _drain_front_door(self) -> None:
        fd = self.front_door
        admits, rejects = fd.drain(self.view, self.now)
        self._n_rejected += len(rejects)
        for sid, t_arr in admits:
            self._admit(sid, t_arr, self.policy.first_chunk_estimate())

    def scale_out(self, k: int) -> int:
        """Provision ``k`` workers (front-door autoscale).  Each lands
        after the cold-start ``provision_delay`` — modeled as a blocked
        dispatcher with a ``worker_unblock`` at readiness — and extends
        every per-worker array the event loop owns."""
        cfg = self.cfg
        delay = (self.front_door.cfg.provision_delay
                 if self.front_door else 0.0)
        for _ in range(k):
            # revive a retired worker before growing the arrays: its
            # slot (pool, batch lane, dispatcher) is already there, but
            # it still pays the same cold-start delay
            revive = next((w for w in self.view.workers if w.retired),
                          None)
            if revive is not None:
                revive.retired = False
                self.blocked_until[revive.wid] = self.now + delay
                self.push(self.now + delay, "worker_unblock", revive.wid)
                continue
            wid = len(self.view.workers)
            self.view.workers.append(
                Worker(wid, node=wid // cfg.workers_per_node))
            self.pools.append(PagedKVPool(cfg.pool_pages))
            self.blocked_until.append(self.now + delay)
            self.batch.append([])
            self.batch_epoch.append(0)
            self.push(self.now + delay, "worker_unblock", wid)
        return k

    def scale_in(self, k: int) -> int:
        """Retire up to ``k`` workers (front-door scale-in).  A retired
        worker keeps its wid slot — per-worker arrays are indexed by
        wid everywhere — but takes no dispatches, admissions,
        re-homings, or SP donations until revived.  Victims are drained
        first: queued streams re-home to the least-loaded surviving
        worker through the normal migration path (page-pool and
        transfer conservation intact); a worker actually RUNNING a
        chunk is never a victim.  Most-recently-provisioned workers
        retire first (LIFO, mirroring ``scale_out``)."""
        from repro.core import rehoming
        retired = 0
        for w in sorted(self.view.workers, key=lambda x: -x.wid):
            if retired >= k:
                break
            if (w.retired or w.donated_to is not None
                    or w.running is not None or self.batch[w.wid]):
                continue
            survivors = [x for x in self.view.workers
                         if not x.retired and x.wid != w.wid
                         and x.donated_to is None]
            if not survivors:
                break
            for sid in list(w.queue):
                if not self._runnable(sid):
                    break
                dst = min(survivors, key=lambda x: x.load())
                mig = rehoming.Migration(
                    sid, w.wid, dst.wid,
                    self.view.node_of(w.wid) != self.view.node_of(dst.wid))
                rehoming.apply_migration(self.view, mig)
                self.migrate(sid, w.wid, dst.wid, mig.cross_node)
            if w.queue:
                continue                 # undrainable: keep it serving
            w.retired = True
            retired += 1
        return retired

    # ------------------------------------------------------------------ control
    def _on_tick(self, _: None) -> None:
        if self.front_door is not None:
            self._drain_front_door()
        self.policy.on_tick(self.now)
        # the control tick refreshed every credit at self.now; nothing
        # in the dispatch fan-out mutates a QUEUED stream's Eq. 1 inputs
        # (batch starts only remove streams from their own queue), so
        # order() may reuse them verbatim
        if self.cfg.vectorized:
            self._credits_fresh = True
        try:
            # sample worker classes (Fig. 15)
            if self.cfg.vectorized:
                self.worker_tier_samples.append(
                    q_mod.worker_class_triple(self.view))
            else:
                counts = q_mod.tier_counts(self.view)
                cls = [q_mod.worker_class(counts[w.wid])
                       for w in self.view.workers]
                self.worker_tier_samples.append(
                    (cls.count("urgent"), cls.count("mixed"),
                     cls.count("relaxed")))
            for w in self.view.workers:
                self._try_dispatch(w.wid)
        finally:
            self._credits_fresh = False
        if not self._all_done():
            self.push(self.now + self.cfg.tick_interval, "tick", None)

    # ------------------------------------------------------------------ playout
    def _on_prompt_switch(self, sid: int) -> None:
        s = self.view.streams.get(sid)
        if s is None or s.done:
            return
        # chunks buffered under the old condition are useless: slack resets
        s.next_deadline = self.now + s.ttfc_slack
        s.step_done = 0                        # abort in-flight chunk work
        s.remaining = 0.0
        s.chunk_started = None                 # fresh chunk, fresh fidelity
        # cancel the in-flight batch: without this the pending step_done
        # event still matches batch[wid] and credits the ABORTED chunk a
        # step, resuming the stale-condition chunk instead of restarting
        # it (the real executor's reset_condition drops the flight) —
        # batchmates lose only their current partial step and requeue at
        # the front with their progress intact
        run_wids = [w for w in range(len(self.batch))
                    if sid in self.batch[w]]
        if run_wids:
            members = list(self.batch[run_wids[0]])
            freed = set()
            for w2 in range(len(self.batch)):
                if self.batch[w2] and self.batch[w2][0] in members:
                    self.batch[w2] = []
                    freed.add(w2)
            for member in members:
                m = self.view.streams[member]
                back = (m.running_on[0] if m.running_on
                        else run_wids[0])
                m.running_on = None
                wq = self.view.workers[back].queue
                if member not in wq and not m.done:
                    wq.insert(0, member)
                freed.add(back)
            for w2 in freed:
                self._try_dispatch(w2)

    def _on_pause(self, payload: Tuple[int, float]) -> None:
        sid, dur = payload
        s = self.view.streams.get(sid)
        if s is None or s.done:
            return
        s.next_deadline += dur                 # playout halts; slack grows

    # ------------------------------------------------------------------ execution
    def _runnable(self, sid: int) -> bool:
        s = self.view.streams[sid]
        return (not s.done and not s.finished and sid not in self.in_transfer)

    def _try_dispatch(self, wid: int) -> None:
        w = self.view.workers[wid]
        if w.retired or self.batch[wid] or self.now < self.blocked_until[wid]:
            return
        if w.donated_to is not None:
            sid = w.donated_to
            s = self.view.streams[sid]
            home_w = self.view.workers[s.home]
            if (self._runnable(sid) and not self.batch[s.home]
                    and sid in home_w.queue
                    and self.now >= self.blocked_until[s.home]):
                self._start_batch(s.home, [sid], sp=2)
                return
            # donated stream not dispatchable right now: serve own queue
            # (the donor re-joins at the stream's next boundary)
        self.policy.order(w)
        cand: List[int] = []
        for sid in list(w.queue):
            if self._runnable(sid):
                s = self.view.streams[sid]
                if wid not in s.resident_on:
                    self._restore(sid, wid)      # non-resident: stream back
                    continue
                cand.append(sid)
                if len(cand) >= self.policy.batch_size:
                    break
        if cand:
            sp = 1
            s0 = self.view.streams[cand[0]]
            if (len(cand) == 1 and s0.sp_donor is not None):
                donor = self.view.workers[s0.sp_donor]
                if not self.batch[donor.wid] and \
                        self.now >= self.blocked_until[donor.wid]:
                    sp = 2
            self._start_batch(wid, cand[:1] if sp == 2 else cand, sp=sp)

    def _start_batch(self, wid: int, sids: List[int], sp: int = 1) -> None:
        w = self.view.workers[wid]
        b = len(sids)
        for sid in sids:
            s = self.view.streams[sid]
            if sid in w.queue:
                w.queue.remove(sid)
            if s.chunk_started is None or s.step_done == 0:
                fid, lat = self.policy.select_fidelity(s, self.now)
                s.next_fidelity = fid
                s.t_next = lat
                s.chunk_started = self.now
                s.step_done = 0
            s.running_on = ((wid, s.sp_donor) if sp == 2 and s.sp_donor
                            is not None else (wid,))
            step_t = self._step_time(s, b, sp)
            s.remaining = (s.next_fidelity.steps - s.step_done) * step_t
        self.batch[wid] = list(sids)
        self.batch_epoch[wid] += 1
        if sp == 2 and self.view.streams[sids[0]].sp_donor is not None:
            self.batch[self.view.streams[sids[0]].sp_donor] = list(sids)
        step_t = self._step_time(self.view.streams[sids[0]], b, sp)
        self.push(self.now + step_t, "step_done",
                  (wid, list(sids), self.batch_epoch[wid]))

    def _step_time(self, s: Stream, batch: int, sp: int) -> float:
        """Per-step wall time.  A lockstep batch of b shares the unit, so
        every member sees t_step * batch_factor(b); pipeline-parallel
        units (SDV2) divide the step time by their pipeline speedup.
        Co-served streams scale by their model's relative step cost
        (``MODEL_COST``, 1.0 for the primary — untagged streams are
        untouched)."""
        lat = self.profile.latency(s.next_fidelity, sp_degree=sp)
        if s.model is not None:
            cost = MODEL_COST.get(s.model, 1.0)
            if cost != 1.0:
                lat *= cost
        step = lat / s.next_fidelity.steps
        step /= getattr(self.policy, "pipeline_speedup", 1.0)
        if batch > 1:
            alpha = self.cfg.batch_alpha
            step *= (cm.sdv2_batch_step_factor(batch) if alpha is None
                     else cm.sdv2_batch_step_factor(batch, alpha))
        return step

    def _on_step_done(self, payload: Tuple[int, List[int], int]) -> None:
        wid, sids, epoch = payload
        # stale-event guard: the batch was preempted or aborted since
        # this event was scheduled.  The epoch check catches an aborted
        # batch RESTARTED with the same sid list (prompt switch -> fresh
        # chunk), which list equality alone would mistake for in-flight.
        if self.batch[wid] != sids or self.batch_epoch[wid] != epoch:
            return
        done_chunk: List[int] = []
        for sid in sids:
            s = self.view.streams[sid]
            s.step_done += 1
            sp = len(s.running_on or (wid,))
            step_t = self._step_time(s, len(sids), sp)
            s.remaining = (s.next_fidelity.steps - s.step_done) * step_t
            if s.step_done >= s.next_fidelity.steps:
                done_chunk.append(sid)
        if done_chunk:
            for sid in done_chunk:
                self._complete_chunk(sid, wid)
        # release batch and redispatch (step/chunk boundary = safe point)
        for sid in sids:
            s = self.view.streams[sid]
            if sid in done_chunk:
                continue
            # chunk unfinished: requeue at the FRONT with partial progress
            # (run-to-completion unless a lower-credit stream preempts at
            #  this safe boundary; FIFO policies simply continue it)
            s.running_on = None
            if sid not in self.view.workers[wid].queue and not s.done:
                self.view.workers[wid].queue.insert(0, sid)
        # free every worker that ran this batch (home + any SP2 mirror —
        # scan all mirrors so a mid-step donor release cannot leak one)
        freed = []
        for w2 in range(len(self.batch)):
            if self.batch[w2] == sids:
                self.batch[w2] = []
                freed.append(w2)
        for f in freed:
            self._try_dispatch(f)

    def _complete_chunk(self, sid: int, wid: int) -> None:
        s = self.view.streams[sid]
        ready = self.now
        ddl = s.next_deadline
        if self.front_door is not None and s.chunk_started is not None:
            self.front_door.observe_chunk(ready - s.chunk_started,
                                          fidelity=s.next_fidelity.key,
                                          model=s.model)
        s.ready_times.append(ready)
        s.deadlines.append(ddl)
        if s.first_chunk_time is None:
            s.first_chunk_time = ready
        if ready > ddl:
            s.stall_time += ready - ddl
            s.stall_events.append(ready - ddl)
        s.next_deadline = max(ddl, ready) + s.chunk_seconds
        s.chunks_done += 1
        s.step_done = 0
        s.chunk_started = None
        s.running_on = None
        s.remaining = 0.0
        fid = s.next_fidelity
        s.qualities.append(self.profile.quality(fid))
        s.fidelity_log.append(fid.key)
        self.fidelity_counts[fid.key] = self.fidelity_counts.get(
            fid.key, 0) + 1
        # KV growth: allocate this chunk's pages (evict if needed, SS4.1)
        self._grow_kv(sid, wid)
        if s.finished:
            s.done = True
            self._n_done += 1
            for w_res in list(s.resident_on):
                self.pools[w_res].release(sid)
            s.resident_on.clear()
            if s.sp_donor is not None:
                self.view.workers[s.sp_donor].donated_to = None
                s.sp_donor = None
            # freed capacity: promote front-door queued arrivals now
            # instead of waiting out the tick interval
            if self.front_door is not None and self.front_door.waiting:
                self._drain_front_door()
        else:
            self.view.workers[wid].queue.append(sid)

    # ------------------------------------------------------------------ state
    def _grow_kv(self, sid: int, wid: int) -> None:
        s = self.view.streams[sid]
        pool = self.pools[wid]
        want = cm.stream_pages(s.chunks_done, model=s.model)
        delta = want - pool.pages_of(sid)
        if delta <= 0:
            return
        while not pool.can_alloc(delta):
            victim = q_mod.pick_eviction(
                [x for x in pool.resident_sids()
                 if self.view.streams[x].running_on is None],
                self.view.streams, protect=sid)
            if victim is None:
                return                          # nothing evictable
            pool.release(victim)
            self.view.streams[victim].resident_on.discard(wid)
        pool.alloc(sid, delta)
        s.resident_on.add(wid)

    def _restore(self, sid: int, wid: int) -> None:
        """Evicted stream selected for dispatch: stream state back in
        (host->device modeled at intra-node bandwidth)."""
        s = self.view.streams[sid]
        w = self.view.workers[wid]
        if sid in w.queue:
            w.queue.remove(sid)
        n_bytes = cm.stream_bytes(s.chunks_done, model=s.model)
        timing = self.engine.transfer(self.now, n_bytes, cross_node=False)
        self.in_transfer[sid] = timing.first_layer_ready
        pool = self.pools[wid]
        want = cm.stream_pages(s.chunks_done, model=s.model)
        while not pool.can_alloc(want):
            victim = q_mod.pick_eviction(
                [x for x in pool.resident_sids()
                 if self.view.streams[x].running_on is None],
                self.view.streams, protect=sid)
            if victim is None:
                break
            pool.release(victim)
            self.view.streams[victim].resident_on.discard(wid)
        pool.alloc(sid, min(want, pool.free))
        s.resident_on.add(wid)
        self.push(timing.first_layer_ready, "stream_ready", (sid, wid))
        if self.engine.blocks_dispatcher():
            self.blocked_until[wid] = timing.complete
            # wake the dispatcher when the blocking restore finishes
            # (mirrors migrate(): without the event the worker idled
            # until the next 3 s control tick)
            self.push(timing.complete, "worker_unblock", wid)

    def _on_stream_ready(self, payload: Tuple[int, int]) -> None:
        sid, wid = payload
        self.in_transfer.pop(sid, None)
        s = self.view.streams.get(sid)
        if s is None or s.done:
            return
        w = self.view.workers[wid]
        if sid not in w.queue and s.running_on is None:
            w.queue.append(sid)
        self._try_dispatch(wid)

    def _on_worker_unblock(self, wid: int) -> None:
        self._try_dispatch(wid)

    # ------------------------------------------------------------------ used by policies
    def migrate(self, sid: int, src: int, dst: int,
                cross_node: bool) -> None:
        """Re-homing state movement through the State Plane (SS4.4)."""
        s = self.view.streams[sid]
        n_bytes = cm.stream_bytes(s.chunks_done, model=s.model)
        timing = self.engine.transfer(self.now, n_bytes,
                                      cross_node=cross_node)
        self.pools[src].release(sid)
        s.resident_on.discard(src)
        pool = self.pools[dst]
        want = cm.stream_pages(s.chunks_done, model=s.model)
        while not pool.can_alloc(want):
            victim = q_mod.pick_eviction(
                [x for x in pool.resident_sids()
                 if self.view.streams[x].running_on is None],
                self.view.streams, protect=sid)
            if victim is None:
                break
            pool.release(victim)
            self.view.streams[victim].resident_on.discard(dst)
        pool.alloc(sid, min(want, pool.free))
        s.resident_on.add(dst)
        # atomic safety: out of every queue until first layer lands
        for w in self.view.workers:
            if sid in w.queue:
                w.queue.remove(sid)
        self.in_transfer[sid] = timing.first_layer_ready
        self.push(timing.first_layer_ready, "stream_ready", (sid, dst))
        if self.engine.blocks_dispatcher():
            self.blocked_until[dst] = timing.complete
            self.push(timing.complete, "worker_unblock", dst)

    def sp_head_partition_transfer(self, sid: int, donor: int) -> None:
        """Ulysses head-partition KV to the donor (App. C.4): half bytes."""
        s = self.view.streams[sid]
        n_bytes = cm.stream_bytes(s.chunks_done, model=s.model) // 2
        timing = self.engine.transfer(self.now, n_bytes, cross_node=False)
        self.in_transfer[sid] = timing.first_layer_ready
        for w in self.view.workers:
            if sid in w.queue:
                w.queue.remove(sid)
        self.push(timing.first_layer_ready, "stream_ready", (sid, s.home))


# ---------------------------------------------------------------------------
# policy interface
# ---------------------------------------------------------------------------

class Policy:
    name = "base"
    batch_size = 1

    def attach(self, sim: Simulator) -> None:
        self.sim = sim

    # admission
    def first_chunk_estimate(self) -> float:
        raise NotImplementedError

    def initial_slack(self, first_est: float) -> float:
        return 4.0 * first_est

    def choose_home(self) -> int:
        return min(self.sim.view.workers, key=lambda w: w.load()).wid

    def on_admit(self, s: Stream) -> None:
        pass

    # control
    def on_tick(self, now: float) -> None:
        pass

    def order(self, worker: Worker) -> None:
        pass

    def select_fidelity(self, s: Stream,
                        now: float) -> Tuple[FidelityConfig, float]:
        raise NotImplementedError
