"""Uniform model API: family -> (init, loss, prefill, decode, cache) plus
``input_specs`` / ``cache_specs`` ShapeDtypeStruct stand-ins for the
multi-pod dry-run (weak-type-correct, shardable, no device allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

Sds = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable                    # (cfg, key) -> params
    loss: Callable                    # (cfg, params, batch) -> scalar
    prefill: Optional[Callable]       # (cfg, params, tokens, **kw)
    decode_step: Optional[Callable]   # (cfg, params, cache, token, pos)
    init_cache: Optional[Callable]    # (cfg, batch, max_len) -> cache


def get_api(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer as M
        return ModelAPI(M.init_params, M.train_loss, M.prefill,
                        M.decode_step, M.init_cache)
    if fam == "ssm":
        from repro.models import ssm as M
        return ModelAPI(M.init_params, M.train_loss, M.prefill,
                        M.decode_step,
                        lambda cfg, b, _ml: M.init_state(cfg, b))
    if fam == "hybrid":
        from repro.models import hybrid as M
        return ModelAPI(M.init_params, M.train_loss, M.prefill,
                        M.decode_step, M.init_cache)
    if fam == "encdec":
        from repro.models import encdec as M
        return ModelAPI(M.init_params, M.train_loss, M.prefill,
                        M.decode_step, M.init_cache)
    if fam == "ardit":
        from repro.models import ardit as M
        return ModelAPI(M.init_params, M.train_loss, None, None, None)
    raise ValueError(f"unknown family {fam!r}")


def init_fn(cfg: ModelConfig) -> Callable:
    api = get_api(cfg)
    return lambda key: api.init(cfg, key)


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------

def _embed_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch stand-ins for the given shape cell.

    train:   the full train batch (tokens/targets or latents for AR-DiT).
    prefill: {tokens [B,S]} (+ frontend stubs).
    decode:  {token [B,1], pos [B]} — the cache comes from ``cache_specs``.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.family == "ardit":
        from repro.models import ardit as A
        tc = A.chunk_tokens(cfg)
        n_chunks = max(1, s // tc)
        return {
            "latents": Sds((b, n_chunks, tc, A.LATENT_CH), _embed_dtype(cfg)),
            "cond": Sds((b, A.COND_TOKENS, cfg.d_model), _embed_dtype(cfg)),
            "t": Sds((b, n_chunks), jnp.float32),
            "noise": Sds((b, n_chunks, tc, A.LATENT_CH), _embed_dtype(cfg)),
        }
    if shape.kind == "train":
        batch: Dict[str, Any] = {"tokens": None, "targets": None}
        if cfg.family == "vlm":
            s_text = s - cfg.n_frontend_tokens
            batch = {"tokens": Sds((b, s_text), i32),
                     "targets": Sds((b, s_text), i32),
                     "img_embeds": Sds((b, cfg.n_frontend_tokens,
                                        cfg.d_model), _embed_dtype(cfg))}
        elif cfg.family == "encdec":
            batch = {"tokens": Sds((b, s), i32),
                     "targets": Sds((b, s), i32),
                     "audio_embeds": Sds((b, cfg.n_frontend_tokens,
                                          cfg.d_model), _embed_dtype(cfg))}
        else:
            batch = {"tokens": Sds((b, s), i32), "targets": Sds((b, s), i32)}
        return batch
    if shape.kind == "prefill":
        if cfg.family == "vlm":
            s_text = s - cfg.n_frontend_tokens
            return {"tokens": Sds((b, s_text), i32),
                    "img_embeds": Sds((b, cfg.n_frontend_tokens,
                                       cfg.d_model), _embed_dtype(cfg))}
        if cfg.family == "encdec":
            return {"tokens": Sds((b, s), i32),
                    "audio_embeds": Sds((b, cfg.n_frontend_tokens,
                                         cfg.d_model), _embed_dtype(cfg))}
        return {"tokens": Sds((b, s), i32)}
    # decode: one new token against a cache of seq_len
    return {"token": Sds((b, 1), i32), "pos": Sds((b,), i32)}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """ShapeDtypeStruct pytree of the decode cache for the shape cell."""
    api = get_api(cfg)
    assert api.init_cache is not None, cfg.name
    return jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))


def param_specs(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(lambda: get_api(cfg).init(
        cfg, jax.random.PRNGKey(0)))
