"""AR-DiT: chunk-wise autoregressive video diffusion transformer.

The paper's model family (Self-Forcing / Causal-Forcing style): video is
generated one *chunk* (``chunk_frames`` latent frames = ``chunk_tokens``
tokens) at a time.  Each chunk is denoised over ``S`` steps; within-chunk
attention is bidirectional, and every token also attends to the rolling
KV cache of previous chunks (sink + local window, SS2.1).  Conditioning
embeddings occupy the sink slot, so the sink doubles as the prompt context.

All four fidelity knobs are live here (SS5 / App. A):
    S    denoise steps       -> fewer model evaluations
    rho  attention sparsity  -> static strided drop of cached KV blocks
    W    KV window (chunks)  -> shorter visible cache slice
    Q    quantization        -> fp8 KV cache
``serve_chunk`` is the unit of work the serving system schedules.  Cache
bookkeeping (len/chunks) is host-side Python — the serving executor jits
only ``chunk_forward``; shapes are static per (fill, fidelity) state, of
which there are at most ``window_chunks + 1``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.logical import shard
from repro.models import kvcache
from repro.models import layers as L
from repro.models.attention import (merge_head_shards, mha, paged_mha,
                                    shard_heads, sparse_keep_list)

Params = Dict[str, Any]

LATENT_CH = 16          # latent channels out of the (stubbed) video VAE
COND_TOKENS = 77        # text-conditioning tokens (stub encoder output)


class FidelityConfig(NamedTuple):
    """A concrete assignment of the paper's four fidelity knobs (SS5),
    plus the repo's fifth knob: the AdaCache-style step cache
    (``models/stepcache.py``), reusing a cached velocity when the
    inter-step residual delta is stable."""
    steps: int = 4              # S in {2,3,4}
    sparsity: float = 0.0       # rho in {0,.6,.7,.8,.9}
    window: int = 7             # W in {1,3,7} chunks
    quant: str = "bf16"         # Q in {bf16,fp8}
    cache: str = "off"          # step cache in {off,conservative,aggressive}

    @property
    def key(self) -> str:
        # cache=off keys are unchanged from the 4-knob era so existing
        # EMAs, calibration ratios, and parity baselines stay valid
        base = f"S{self.steps}_r{self.sparsity}_W{self.window}_{self.quant}"
        return base if self.cache == "off" else f"{base}_c{self.cache[0]}"


HIGHEST_QUALITY = FidelityConfig(4, 0.0, 7, "bf16")


def chunk_tokens(cfg: ModelConfig) -> int:
    return cfg.ardit_chunk_frames * cfg.ardit_frame_tokens


def cache_capacity(cfg: ModelConfig) -> int:
    """KV capacity in tokens: cond sink + window chunks."""
    return COND_TOKENS + cfg.ardit_window_chunks * chunk_tokens(cfg)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = L.split_keys(key, 3)
    d = cfg.d_model
    return {
        "attn": L.init_attn(cfg, ks[0], dtype),
        "mlp": L.init_mlp(cfg, ks[1], dtype),
        # adaLN-zero: 6 modulation vectors per layer
        "mod": jnp.zeros((d, 6 * d), dtype),
        "mod_b": jnp.zeros((6 * d,), dtype),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = L.split_keys(key, 6)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    d = cfg.d_model
    return {
        "in_proj": L.dense_init(ks[1], (LATENT_CH, d), dtype),
        "cond_proj": L.dense_init(ks[2], (d, d), dtype),
        "t_mlp1": L.dense_init(ks[3], (256, d), dtype),
        "t_mlp2": L.dense_init(ks[4], (d, d), dtype),
        "layers": jax.vmap(lambda k: _init_layer(cfg, k, dtype))(layer_keys),
        "final_norm": jnp.ones((d,), dtype),
        "final_mod": jnp.zeros((d, 2 * d), dtype),
        "out_proj": L.dense_init(ks[5], (d, LATENT_CH), dtype, scale=0.02),
    }


def _time_embed(p: Params, t: jax.Array, d: int) -> jax.Array:
    """t [B] in [0,1] -> [B, D] conditioning vector."""
    half = 128
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t.astype(jnp.float32)[:, None] * freqs[None, :] * 1000.0
    emb = jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)  # [B,256]
    h = jax.nn.silu(emb.astype(p["t_mlp1"].dtype) @ p["t_mlp1"])
    return h @ p["t_mlp2"]


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None, :]) + shift[:, None, :]


def cache_sparse_index(cfg: ModelConfig, ctx_len: int,
                       sparsity: float) -> Optional[np.ndarray]:
    """Static token indices of the cached context kept under knob rho.

    Sink (cond) tokens and the most recent chunk are always kept; a strided
    ~(1-rho) fraction of the middle blocks survives (SS5, Light-Forcing
    style block sparsity, 128-aligned for the TPU kernel).
    """
    if sparsity <= 0.0 or ctx_len <= COND_TOKENS:
        return None
    blk = 128
    body = ctx_len - COND_TOKENS
    n_blocks = max(1, body // blk)
    keep = sparse_keep_list(1, [n_blocks], sparsity, sink_blocks=1)[0]
    idx = [np.arange(COND_TOKENS)]
    for j in keep:
        lo = COND_TOKENS + j * blk
        hi = min(COND_TOKENS + (j + 1) * blk, ctx_len)
        idx.append(np.arange(lo, hi))
    tail = COND_TOKENS + n_blocks * blk
    if tail < ctx_len:
        idx.append(np.arange(tail, ctx_len))
    return np.unique(np.concatenate(idx))


# ---------------------------------------------------------------------------
# core forward: one chunk conditioned on visible context KV
# ---------------------------------------------------------------------------

def chunk_forward(cfg: ModelConfig, p: Params, x_chunk: jax.Array,
                  t: jax.Array, ctx_k: Optional[jax.Array],
                  ctx_v: Optional[jax.Array], *, q_offset,
                  sparsity: float = 0.0,
                  ctx_mask: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One DiT pass over a chunk.

    x_chunk [B, T_c, LATENT_CH]; t [B] denoise time; ctx_k/v
    [L, B, ctx_len, Hkv, Dh] visible context (or None).  Returns
    (prediction [B, T_c, LATENT_CH], {"k","v"} per-layer chunk KV).

    ``q_offset`` is either a host int (all streams at the same absolute
    position) or a per-stream [B] array (the batched executor's stacked
    streams sit at different chunk indices).  ``ctx_mask`` [B, ctx_len]
    marks the context tokens each stream may attend to (ring-cache
    residency + fidelity window + sparsity baked in by the caller);
    when given, the static ``sparsity`` gather is skipped.
    """
    b, tc, _ = x_chunk.shape
    d = cfg.d_model
    h = shard(x_chunk.astype(p["in_proj"].dtype) @ p["in_proj"],
              "batch", None, "embed")
    temb = _time_embed(p, t, d)                                   # [B,D]
    q_off = jnp.asarray(q_offset)
    if q_off.ndim:                                  # per-stream offsets
        positions = q_off[:, None] + jnp.arange(tc)[None, :]      # [B,Tc]
    else:
        positions = q_off + jnp.arange(tc)                        # [Tc]
    ones = jnp.ones((d,), h.dtype)

    keep_idx = None
    kv_mask = None
    if ctx_k is not None:
        if ctx_mask is not None:
            kv_mask = jnp.concatenate(
                [ctx_mask, jnp.ones((b, tc), bool)], axis=1)
        else:
            keep_idx = cache_sparse_index(cfg, ctx_k.shape[2], sparsity)

    def body(hh, xs):
        lp = xs["layer"]
        mod = jax.nn.silu(temb) @ lp["mod"] + lp["mod_b"]         # [B,6D]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        a_in = _modulate(L.rmsnorm(hh, ones, cfg.norm_eps), sh1, sc1)
        q, k, v = L.attn_qkv(cfg, lp["attn"], a_in, positions)
        if ctx_k is not None:
            kc, vc = xs["ck"], xs["cv"]
            if keep_idx is not None:
                kc, vc = kc[:, keep_idx], vc[:, keep_idx]
            k_all = jnp.concatenate([kc.astype(k.dtype), k], axis=1)
            v_all = jnp.concatenate([vc.astype(v.dtype), v], axis=1)
        else:
            k_all, v_all = k, v
        o = mha(q, k_all, v_all, n_kv_heads=cfg.n_kv_heads, causal=False,
                kv_mask=kv_mask)
        o = o.reshape(b, tc, cfg.n_heads * cfg.head_dim)
        hh = hh + g1[:, None, :] * shard(o @ lp["attn"]["wo"],
                                         "batch", None, "embed")
        f_in = _modulate(L.rmsnorm(hh, ones, cfg.norm_eps), sh2, sc2)
        hh = hh + g2[:, None, :] * L.mlp_block(cfg, lp["mlp"], f_in)
        return hh, {"k": k, "v": v}

    xs = {"layer": p["layers"]}
    if ctx_k is not None:
        xs["ck"] = ctx_k
        xs["cv"] = ctx_v
    h, new_kv = jax.lax.scan(body, h, xs)

    mod = jax.nn.silu(temb) @ p["final_mod"]
    sh, sc = jnp.split(mod, 2, axis=-1)
    h = _modulate(L.rmsnorm(h, p["final_norm"], cfg.norm_eps), sh, sc)
    return h @ p["out_proj"], new_kv


# ---------------------------------------------------------------------------
# serving: host-side cache bookkeeping + chunk generation
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, p: Params, cond: jax.Array,
               kv_dtype: Optional[str] = None) -> Dict[str, Any]:
    """Cache whose sink slot is the conditioning tokens.

    cond: [B, COND_TOKENS, d_model] (stub text-encoder output).
    ``len``/``chunks`` are host-side Python ints (static shapes per state).
    """
    dt = jnp.dtype(kv_dtype or cfg.kv_dtype)
    cond = cond.astype(p["cond_proj"].dtype) @ p["cond_proj"]
    positions = jnp.arange(COND_TOKENS)

    def kv_of(lp):
        _, k, v = L.attn_qkv(cfg, lp, cond, positions)
        return k, v

    ks, vs = jax.vmap(kv_of)(p["layers"]["attn"])   # [L,B,T,H,Dh]
    return {"k": ks.astype(dt), "v": vs.astype(dt),
            "len": COND_TOKENS, "chunks": 0}


def visible_context(cfg: ModelConfig, cache: Dict[str, Any],
                    window: int) -> Tuple[jax.Array, jax.Array]:
    """Sink + last ``window`` chunks of the cache (knob W)."""
    tc = chunk_tokens(cfg)
    resident = (cache["len"] - COND_TOKENS) // tc
    w = min(window, resident)
    k, v = cache["k"], cache["v"]
    if w == resident:
        return k[:, :, :cache["len"]], v[:, :, :cache["len"]]
    lo = cache["len"] - w * tc
    return (jnp.concatenate([k[:, :, :COND_TOKENS], k[:, :, lo:cache["len"]]],
                            axis=2),
            jnp.concatenate([v[:, :, :COND_TOKENS], v[:, :, lo:cache["len"]]],
                            axis=2))


def append_chunk_kv(cfg: ModelConfig, cache: Dict[str, Any],
                    new_kv: Dict[str, jax.Array]) -> Dict[str, Any]:
    """Append a chunk's KV; evict the oldest non-sink chunk when full."""
    tc = chunk_tokens(cfg)
    cap = cache_capacity(cfg)
    k, v = cache["k"], cache["v"]
    ln, nch = cache["len"], cache["chunks"]
    nk = new_kv["k"].astype(k.dtype)    # [L,B,tc,H,Dh]
    nv = new_kv["v"].astype(v.dtype)
    if ln + tc <= cap:
        k = jnp.concatenate([k[:, :, :ln], nk], axis=2)
        v = jnp.concatenate([v[:, :, :ln], nv], axis=2)
        return {"k": k, "v": v, "len": ln + tc, "chunks": nch + 1}
    sink = COND_TOKENS
    k = jnp.concatenate([k[:, :, :sink], k[:, :, sink + tc:ln], nk], axis=2)
    v = jnp.concatenate([v[:, :, :sink], v[:, :, sink + tc:ln], nv], axis=2)
    return {"k": k, "v": v, "len": ln, "chunks": nch + 1}


def sigma_schedule(steps: int) -> np.ndarray:
    """Rectified-flow time grid 1 -> 0 (noise -> data)."""
    return np.linspace(1.0, 0.0, steps + 1)


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("sparsity",))
def chunk_step(cfg: ModelConfig, p: Params, x: jax.Array, t: jax.Array,
               ctx_k: Optional[jax.Array], ctx_v: Optional[jax.Array],
               q_offset, ctx_mask: Optional[jax.Array],
               sparsity: float = 0.0):
    """Jitted one-denoise-step entry for the batched serving path (the
    sequential ``serve_chunk`` stays eager, as originally shipped).
    Shapes are static per (ctx extent, batch, sparsity), so a batched
    session compiles once per (sub-batch size, fill extent)."""
    return chunk_forward(cfg, p, x, t, ctx_k, ctx_v, q_offset=q_offset,
                         sparsity=sparsity, ctx_mask=ctx_mask)


@functools.partial(jax.jit, static_argnums=(0,))
def denoise_step(cfg: ModelConfig, p: Params, x: jax.Array, t: jax.Array,
                 dt: jax.Array, ctx_k: jax.Array, ctx_v: jax.Array,
                 q_offset: jax.Array, dn_mask: Optional[jax.Array],
                 cl_mask: Optional[jax.Array], is_denoise: jax.Array):
    """Fused batched executor step: forward + Euler update in ONE jitted
    call.  Rows in their denoise phase use the sparsified mask and a
    nonzero ``dt``; rows in their clean-context phase use the full-window
    mask and dt=0 (their ``new_kv`` is what matters).  Phase is data, so
    one executable serves every phase mix of a sub-batch.  Masks are
    None when the whole (extent-sliced) context is visible to every
    stream — the fill-homogeneous, unsparsified common case — which
    skips the per-score mask selects entirely."""
    if dn_mask is None and cl_mask is None:
        mask = None
    else:
        ones = jnp.ones(ctx_k.shape[1:3], bool)
        mask = jnp.where(is_denoise[:, None],
                         ones if dn_mask is None else dn_mask,
                         ones if cl_mask is None else cl_mask)
    v_pred, new_kv = chunk_forward(cfg, p, x, t, ctx_k, ctx_v,
                                   q_offset=q_offset, ctx_mask=mask)
    x_new = x - dt[:, None, None] * v_pred.astype(x.dtype)
    return x_new, new_kv


def _chunk_forward_pages(cfg: ModelConfig, p: Params, x_chunk: jax.Array,
                         t: jax.Array, pools,
                         page_mask: Optional[jax.Array], *, q_offset,
                         ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Shared DiT body of the page-table-native forwards.

    ``pools`` is a tuple of ``(k_pages, v_pages, block_table, head_lo,
    head_hi)`` KV-head shards covering ``[0, n_kv_heads)``: one shard
    is the plain paged forward (no head slicing at all — identical to
    the pre-SP code path); two shards is elastic SP2, each shard's
    attention reading its own pool/table (Ulysses head partition —
    per-head attention never mixes heads, so the sharded result is
    bit-identical to the single-shard one whenever the shards mirror
    the same KV).
    """
    b, tc, _ = x_chunk.shape
    d = cfg.d_model
    hkv = cfg.n_kv_heads
    single = len(pools) == 1
    h = shard(x_chunk.astype(p["in_proj"].dtype) @ p["in_proj"],
              "batch", None, "embed")
    temb = _time_embed(p, t, d)                                   # [B,D]
    q_off = jnp.asarray(q_offset)
    if q_off.ndim:                                  # per-stream offsets
        positions = q_off[:, None] + jnp.arange(tc)[None, :]      # [B,Tc]
    else:
        positions = q_off + jnp.arange(tc)                        # [Tc]
    ones = jnp.ones((d,), h.dtype)

    def body(hh, xs):
        lp = xs["layer"]
        mod = jax.nn.silu(temb) @ lp["mod"] + lp["mod_b"]         # [B,6D]
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        a_in = _modulate(L.rmsnorm(hh, ones, cfg.norm_eps), sh1, sc1)
        q, k, v = L.attn_qkv(cfg, lp["attn"], a_in, positions)
        outs = []
        for i, (_, _, tbl, lo, hi) in enumerate(pools):
            kp, vp = xs[f"kp{i}"], xs[f"vp{i}"]
            if single:
                o_s = paged_mha(q, kp, vp, tbl, page_mask, k, v,
                                n_kv_heads=hkv, sink=COND_TOKENS,
                                chunk_tokens=tc)
            else:
                o_s = paged_mha(shard_heads(q, hkv, lo, hi),
                                kp[..., lo:hi, :], vp[..., lo:hi, :],
                                tbl, page_mask,
                                shard_heads(k, hkv, lo, hi),
                                shard_heads(v, hkv, lo, hi),
                                n_kv_heads=hi - lo, sink=COND_TOKENS,
                                chunk_tokens=tc)
            outs.append(o_s)
        o = outs[0] if single else merge_head_shards(
            outs, [hi - lo for (_, _, _, lo, hi) in pools])
        o = o.reshape(b, tc, cfg.n_heads * cfg.head_dim)
        hh = hh + g1[:, None, :] * shard(o @ lp["attn"]["wo"],
                                         "batch", None, "embed")
        f_in = _modulate(L.rmsnorm(hh, ones, cfg.norm_eps), sh2, sc2)
        hh = hh + g2[:, None, :] * L.mlp_block(cfg, lp["mlp"], f_in)
        return hh, {"k": k, "v": v}

    xs = {"layer": p["layers"]}
    for i, (kp, vp, _, _, _) in enumerate(pools):
        xs[f"kp{i}"], xs[f"vp{i}"] = kp, vp
    h, new_kv = jax.lax.scan(body, h, xs)

    mod = jax.nn.silu(temb) @ p["final_mod"]
    sh, sc = jnp.split(mod, 2, axis=-1)
    h = _modulate(L.rmsnorm(h, p["final_norm"], cfg.norm_eps), sh, sc)
    return h @ p["out_proj"], new_kv


def chunk_forward_paged(cfg: ModelConfig, p: Params, x_chunk: jax.Array,
                        t: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_table: jax.Array,
                        page_mask: Optional[jax.Array], *, q_offset,
                        ) -> Tuple[jax.Array, Dict[str, Any]]:
    """``chunk_forward`` with the cached context consumed IN PLACE from
    the paged KV pool instead of a gathered [L, B, ctx_len, ...] copy.

    k_pages/v_pages [L, n_pages, page, Hkv, Dh] — the whole device
    pool; block_table [B, n] per-stream page tables (entry 0 = sink
    page, entry 1+r = ring slot r); page_mask [B, n*page] visible
    context tokens in table order, or None when every valid token is
    visible (homogeneous fill, full window, no sparsity — per-score
    masking is skipped entirely, like the gathered path's dropped
    masks).  Attention is
    ``attention.paged_mha``: paged-context online-softmax partials
    merged with the chunk's own fresh KV, so the only per-step KV
    traffic is the pages the tables actually reference.  Returns the
    same (prediction, {"k","v"}) as ``chunk_forward``; numerics agree
    with the gathered path up to fp32 online-softmax merge order.
    """
    return _chunk_forward_pages(
        cfg, p, x_chunk, t,
        ((k_pages, v_pages, block_table, 0, cfg.n_kv_heads),),
        page_mask, q_offset=q_offset)


@functools.partial(jax.jit, static_argnums=(0,))
def denoise_step_paged(cfg: ModelConfig, p: Params, x: jax.Array,
                       t: jax.Array, dt: jax.Array, k_pages: jax.Array,
                       v_pages: jax.Array, block_table: jax.Array,
                       dn_mask: Optional[jax.Array],
                       cl_mask: Optional[jax.Array],
                       q_offset: jax.Array, is_denoise: jax.Array):
    """Page-table-native sibling of ``denoise_step``: the sub-batch's
    context stays IN the pool and per-stream visibility rides in the
    page-coordinate masks.  Batch-axis elastic SP rides this same step:
    a stream borrowed onto another device becomes an ordinary extra
    batch row over the donor's pool (full-head mirror pages in the
    donor's block table), so co-serving it with the donor's own streams
    is the one fused call — no SP-specific kernel and no solo dispatch.
    ``dn_mask=None`` is the all-visible fast
    path (homogeneous fill, full window, no sparsity: each page's
    static valid prefix is visible, no per-score select — the paged
    analogue of the gathered path's dropped masks; note dn all-visible
    implies cl all-visible, since the clean window is a superset);
    ``cl_mask=None`` marks the common case where the clean pass sees
    exactly the denoise mask, skipping the per-row select."""
    mask = dn_mask if cl_mask is None else \
        jnp.where(is_denoise[:, None], dn_mask, cl_mask)
    v_pred, new_kv = chunk_forward_paged(cfg, p, x, t, k_pages, v_pages,
                                         block_table, mask,
                                         q_offset=q_offset)
    x_new = x - dt[:, None, None] * v_pred.astype(x.dtype)
    return x_new, new_kv


def chunk_forward_paged_sp(cfg: ModelConfig, p: Params, x_chunk: jax.Array,
                           t: jax.Array, k_home: jax.Array,
                           v_home: jax.Array, k_donor: jax.Array,
                           v_donor: jax.Array, table_home: jax.Array,
                           table_donor: jax.Array,
                           page_mask: Optional[jax.Array], *, q_offset,
                           ) -> Tuple[jax.Array, Dict[str, Any]]:
    """SP2 sibling of ``chunk_forward_paged``: the stream's KV heads are
    Ulysses-partitioned across two lanes (paper SS4.3 / App. C.4).

    The home lane's pool ``k_home``/``v_home`` is the system of record
    (full heads); the donor lane's pool ``k_donor``/``v_donor`` carries
    the stream's UPPER half heads in its own page set (``table_donor``).
    Each shard runs paged attention over its own half — the home shard
    reads heads [0, H/2) from the home pool, the donor shard reads
    heads [H/2, H) from the donor pool — and the outputs concatenate
    back into full-head order.  Per-head attention never mixes heads,
    so the result is bit-identical to the SP1 ``chunk_forward_paged``
    whenever the donor's half mirrors the home pool's upper half.  On a
    multi-device mesh the two shards map onto the two lanes' devices;
    on CPU they model the donor's borrowed compute slot.
    """
    hkv = cfg.n_kv_heads
    h2 = hkv // 2
    assert hkv % 2 == 0, f"SP2 head split needs even n_kv_heads ({hkv})"
    return _chunk_forward_pages(
        cfg, p, x_chunk, t,
        ((k_home, v_home, table_home, 0, h2),
         (k_donor, v_donor, table_donor, h2, hkv)),
        page_mask, q_offset=q_offset)


@functools.partial(jax.jit, static_argnums=(0,))
def denoise_step_paged_sp(cfg: ModelConfig, p: Params, x: jax.Array,
                          t: jax.Array, dt: jax.Array, k_home: jax.Array,
                          v_home: jax.Array, k_donor: jax.Array,
                          v_donor: jax.Array, table_home: jax.Array,
                          table_donor: jax.Array,
                          dn_mask: Optional[jax.Array],
                          cl_mask: Optional[jax.Array],
                          q_offset: jax.Array, is_denoise: jax.Array):
    """Elastic-SP2 sibling of ``denoise_step_paged``: one stream's
    denoise step with its KV heads split across the home and donor
    lanes' pools.  Mask semantics match ``denoise_step_paged``.  The
    serving executor pre-jits this per SP group (`LanePool.prejit_sp`)
    so triggering elastic SP never compiles on the critical path."""
    mask = dn_mask if cl_mask is None else \
        jnp.where(is_denoise[:, None], dn_mask, cl_mask)
    v_pred, new_kv = chunk_forward_paged_sp(
        cfg, p, x, t, k_home, v_home, k_donor, v_donor, table_home,
        table_donor, mask, q_offset=q_offset)
    x_new = x - dt[:, None, None] * v_pred.astype(x.dtype)
    return x_new, new_kv


def serve_chunk(cfg: ModelConfig, p: Params, cache: Dict[str, Any],
                noise: jax.Array, fidelity: FidelityConfig = HIGHEST_QUALITY,
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Generate one chunk under a fidelity configuration.

    noise: [B, T_c, LATENT_CH].  Returns (clean chunk latents, new cache).
    """
    tc = chunk_tokens(cfg)
    ctx_k, ctx_v = visible_context(cfg, cache, fidelity.window)
    q_offset = COND_TOKENS + cache["chunks"] * tc

    grid = sigma_schedule(fidelity.steps)
    x = noise
    for i in range(fidelity.steps):
        t = jnp.full((noise.shape[0],), float(grid[i]), jnp.float32)
        v_pred, _ = chunk_forward(cfg, p, x, t, ctx_k, ctx_v,
                                  q_offset=q_offset,
                                  sparsity=fidelity.sparsity)
        dt = float(grid[i] - grid[i + 1])
        x = x - dt * v_pred.astype(x.dtype)     # Euler step toward data

    # context KV for future chunks comes from a clean-context pass
    t0 = jnp.zeros((noise.shape[0],), jnp.float32)
    _, clean_kv = chunk_forward(cfg, p, x, t0, ctx_k, ctx_v,
                                q_offset=q_offset)
    if fidelity.quant == "fp8":
        clean_kv = {k_: v_.astype(jnp.float8_e4m3fn)
                    for k_, v_ in clean_kv.items()}
    cache = append_chunk_kv(cfg, cache, clean_kv)
    return x, cache


# ---------------------------------------------------------------------------
# batched serving: leading stream-batch axis over per-stream ring caches
# ---------------------------------------------------------------------------
# The batched executor stacks streams along the cache batch axis.  Unlike
# the sequential cache (host-side len/chunks, shapes grow with fill), the
# batched cache is a fixed-capacity chunk-granular ring per stream: the
# sink (cond) tokens sit in slots [0, COND_TOKENS) and chunk c lands in
# the ring slot ``kvcache.chunk_slot(c, window_chunks, ...)``.  Streams at
# different chunk indices coexist in one batch; per-stream positions come
# from ``chunks`` and per-stream visibility (residency + fidelity window
# + sparsity) is a boolean mask, so every denoise step is one jitted call
# at full-capacity static shapes regardless of fill.


def init_batched_cache(cfg: ModelConfig, p: Params, cond: jax.Array,
                       kv_dtype: Optional[str] = None) -> Dict[str, Any]:
    """Fixed-capacity ring cache for a batch of streams.

    cond: [B, COND_TOKENS, d_model] per-stream conditioning.  Returns
    {"k","v"} of [L, B, cap, Hkv, Dh] plus host-side per-stream chunk
    counts ``chunks`` [B].
    """
    dt = jnp.dtype(kv_dtype or cfg.kv_dtype)
    cond = cond.astype(p["cond_proj"].dtype) @ p["cond_proj"]
    positions = jnp.arange(COND_TOKENS)

    def kv_of(lp):
        _, k, v = L.attn_qkv(cfg, lp, cond, positions)
        return k, v

    ks, vs = jax.vmap(kv_of)(p["layers"]["attn"])   # [L,B,COND,H,Dh]
    pad = ((0, 0), (0, 0), (0, cache_capacity(cfg) - COND_TOKENS),
           (0, 0), (0, 0))
    return {"k": jnp.pad(ks.astype(dt), pad),
            "v": jnp.pad(vs.astype(dt), pad),
            "chunks": np.zeros(cond.shape[0], np.int64)}


def batched_context_mask(cfg: ModelConfig, chunks: np.ndarray, window: int,
                         sparsity: float = 0.0) -> np.ndarray:
    """Per-stream context-visibility mask [B, cap] over the ring cache.

    Marks, for each stream, the sink tokens plus the tokens of its last
    ``min(window, resident)`` chunks that survive the rho sparsity drop —
    the exact token set ``visible_context`` + ``cache_sparse_index`` give
    the sequential path, mapped through the ring permutation.
    """
    n = len(np.asarray(chunks, np.int64))
    return batched_context_mask_multi(
        cfg, chunks, np.full(n, window, np.int64),
        np.full(n, sparsity, np.float64))


def batched_context_mask_multi(cfg: ModelConfig, chunks: np.ndarray,
                               windows: np.ndarray,
                               sparsities: np.ndarray) -> np.ndarray:
    """``batched_context_mask`` with PER-ROW window/sparsity knobs.

    The fused heterogeneous-fidelity dispatch stacks streams of
    different fidelities into one sub-batch; since window and sparsity
    only ever enter the step as mask *data*, each row simply gets the
    mask its own fidelity would have produced — row i here is
    bit-identical to row i of a per-fidelity ``batched_context_mask``
    call (the uniform builder above delegates to this one).
    """
    tc = chunk_tokens(cfg)
    w_max = cfg.ardit_window_chunks
    mask = np.zeros((len(chunks), cache_capacity(cfg)), bool)
    windows = np.asarray(windows, np.int64)
    sparsities = np.asarray(sparsities, np.float64)
    for i, n in enumerate(np.asarray(chunks, np.int64)):
        w = min(int(windows[i]), int(n), w_max)
        ctx_len = COND_TOKENS + w * tc
        keep = cache_sparse_index(cfg, ctx_len, float(sparsities[i]))
        idx = np.arange(ctx_len) if keep is None else keep
        mask[i, idx[idx < COND_TOKENS]] = True
        body = idx[idx >= COND_TOKENS] - COND_TOKENS
        if w and body.size:
            c_abs = (int(n) - w) + body // tc       # absolute chunk index
            slot = COND_TOKENS + (c_abs % w_max) * tc + body % tc
            mask[i, slot] = True
    return mask


def append_chunk_kv_batched(cfg: ModelConfig, cache: Dict[str, Any],
                            new_kv: Dict[str, jax.Array]) -> Dict[str, Any]:
    """Ring-write one new chunk of KV per stream at its own slot."""
    tc = chunk_tokens(cfg)
    chunks = np.asarray(cache["chunks"], np.int64)
    dest = kvcache.chunk_slot(jnp.asarray(chunks), cfg.ardit_window_chunks,
                              COND_TOKENS, tc)
    return {"k": kvcache.write_block_layers(cache["k"], new_kv["k"], dest),
            "v": kvcache.write_block_layers(cache["v"], new_kv["v"], dest),
            "chunks": chunks + 1}


def serve_chunk_batched(cfg: ModelConfig, p: Params, cache: Dict[str, Any],
                        noise: jax.Array,
                        fidelity: FidelityConfig = HIGHEST_QUALITY,
                        ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One chunk for every stream of a batched cache under one shared
    fidelity configuration (= one same-fidelity sub-batch).

    noise: [B, T_c, LATENT_CH]; streams may sit at different chunk
    indices.  Per stream, numerically equivalent to ``serve_chunk``.
    """
    tc = chunk_tokens(cfg)
    chunks = np.asarray(cache["chunks"], np.int64)
    q_offset = jnp.asarray(COND_TOKENS + chunks * tc, jnp.int32)
    dn_mask = jnp.asarray(batched_context_mask(
        cfg, chunks, fidelity.window, fidelity.sparsity))

    grid = sigma_schedule(fidelity.steps)
    x = noise
    for i in range(fidelity.steps):
        t = jnp.full((noise.shape[0],), float(grid[i]), jnp.float32)
        v_pred, _ = chunk_step(cfg, p, x, t, cache["k"], cache["v"],
                               q_offset, dn_mask)
        dt = float(grid[i] - grid[i + 1])
        x = x - dt * v_pred.astype(x.dtype)

    # clean-context pass sees the full (unsparsified) window
    clean_mask = jnp.asarray(batched_context_mask(
        cfg, chunks, fidelity.window))
    t0 = jnp.zeros((noise.shape[0],), jnp.float32)
    _, clean_kv = chunk_step(cfg, p, x, t0, cache["k"], cache["v"],
                             q_offset, clean_mask)
    if fidelity.quant == "fp8":
        clean_kv = {k_: v_.astype(jnp.float8_e4m3fn)
                    for k_, v_ in clean_kv.items()}
    return x, append_chunk_kv_batched(cfg, cache, clean_kv)


# ---------------------------------------------------------------------------
# training: causal-forcing style denoising over a chunk sequence
# ---------------------------------------------------------------------------

def train_loss(cfg: ModelConfig, p: Params,
               batch: Dict[str, jax.Array]) -> jax.Array:
    """Flow-matching loss over a sequence of chunks with causal context.

    batch: latents [B, n_chunks, T_c, LATENT_CH], cond [B, 77, d_model],
           t [B, n_chunks] denoise times, noise (same shape as latents).
    Chunks are processed in a Python loop (static, growing context), the
    exact teacher-forced analogue of ``serve_chunk``'s rolling window.
    """
    lat, cond = batch["latents"], batch["cond"]
    t_all, noise = batch["t"], batch["noise"]
    b, n_chunks, tc, _ = lat.shape
    cache = init_cache(cfg, p, cond)
    total = jnp.zeros((), jnp.float32)
    for c in range(n_chunks):
        x0, eps, t = lat[:, c], noise[:, c], t_all[:, c]
        x_t = (1.0 - t[:, None, None]) * x0 + t[:, None, None] * eps
        target = eps - x0                       # rectified-flow velocity
        ctx_k, ctx_v = visible_context(cfg, cache, cfg.ardit_window_chunks)
        q_offset = COND_TOKENS + c * chunk_tokens(cfg)
        pred, _ = chunk_forward(cfg, p, x_t, t, ctx_k, ctx_v,
                                q_offset=q_offset)
        total = total + jnp.mean((pred.astype(jnp.float32)
                                  - target.astype(jnp.float32)) ** 2)
        # clean pass provides the causal context for the next chunk
        _, clean_kv = chunk_forward(cfg, p, x0, jnp.zeros_like(t),
                                    ctx_k, ctx_v, q_offset=q_offset)
        cache = append_chunk_kv(cfg, cache, clean_kv)
    return total / n_chunks
