"""Sink + ring-buffer KV cache helpers (paper SS2.1 "sink+local").

Layout: slots [0, sink) hold the attention sink; slots [sink, cap) are a
ring over the sliding window.  When ``cap >= seq_len`` the ring degenerates
to a plain linear cache (dest == pos), so the same code serves both the
full-cache and the windowed-adaptation paths.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def capacity(seq_len: int, window: int, sink: int) -> int:
    """Cache capacity in tokens for a stream of ``seq_len``."""
    if window:
        return min(seq_len, sink + window)
    return seq_len


def ring_dest(pos: jax.Array, cap: int, sink: int) -> jax.Array:
    """Write slot for absolute position ``pos`` (per-batch array ok)."""
    ring = cap - sink
    wrapped = sink + (pos - sink) % jnp.maximum(ring, 1)
    return jnp.where(pos < cap, jnp.minimum(pos, cap - 1),
                     wrapped).astype(jnp.int32)


def write_token(cache: jax.Array, new: jax.Array,
                dest: jax.Array) -> jax.Array:
    """cache [B,cap,H,D]; new [B,1,H,D]; dest [B] -> updated cache."""
    return jax.vmap(lambda cb, nb, db: jax.lax.dynamic_update_slice(
        cb, nb.astype(cb.dtype), (db, 0, 0)))(cache, new, dest)


def n_valid(pos: jax.Array, cap: int) -> jax.Array:
    """Number of resident (valid) cache entries after writing ``pos``."""
    return jnp.minimum(pos + 1, cap)


def chunk_slot(chunk_idx: jax.Array, window_chunks: int, sink: int,
               chunk_tokens: int) -> jax.Array:
    """First-token slot of absolute chunk ``chunk_idx`` in the
    chunk-granular ring: slots [0, sink) hold the attention sink and the
    ring holds ``window_chunks`` chunks of ``chunk_tokens`` each.
    ``chunk_idx`` may be a per-stream batch array."""
    return (sink + (chunk_idx % window_chunks) * chunk_tokens).astype(
        jnp.int32)


def write_block(cache: jax.Array, new: jax.Array,
                dest: jax.Array) -> jax.Array:
    """cache [B,cap,...]; new [B,T,...]; dest [B] first-token slot.

    Block-granular sibling of ``write_token``: writes a contiguous
    T-token block per batch row at a per-row slot (the batched serving
    executor appends one chunk's KV per stream this way)."""
    return jax.vmap(lambda cb, nb, db: jax.lax.dynamic_update_slice(
        cb, nb.astype(cb.dtype),
        (db,) + (0,) * (cb.ndim - 1)))(cache, new, dest)


@jax.jit
def write_block_layers(cache: jax.Array, new: jax.Array,
                       dest: jax.Array) -> jax.Array:
    """``write_block`` lifted over a leading layer axis, jitted (eager
    vmap re-traces per call, which dominates append cost on CPU).

    cache [L,B,cap,...]; new [L,B,T,...]; dest [B]."""
    return jax.vmap(write_block, in_axes=(0, 0, None))(cache, new, dest)


@functools.partial(jax.jit, donate_argnums=(0,))
def pool_write_chunk(pool: jax.Array, new: jax.Array, rows: jax.Array,
                     dest: jax.Array) -> jax.Array:
    """Scatter one chunk of KV per stream straight into a stacked pool.

    pool [L,Bmax,cap,...]; new [L,b,T,...]; rows [b] pool rows; dest [b]
    first-token slots.  The pool buffer is donated so the update can be
    performed in place where the backend supports it (avoids the
    gather-modify-scatter round trip of updating via a sub-batch view).
    """
    for i in range(new.shape[1]):
        pool = jax.lax.dynamic_update_slice(
            pool, new[:, i:i + 1].astype(pool.dtype),
            (0, rows[i], dest[i]) + (0,) * (pool.ndim - 3))
    return pool


@functools.partial(jax.jit, static_argnums=(2,))
def gather_rows(pool: jax.Array, rows: jax.Array, extent: int) -> jax.Array:
    """pool [L,Bmax,cap,...] -> [L,b,extent,...] for the given rows
    (jitted: one fused gather instead of eager fancy-indexing)."""
    return pool[:, rows, :extent]


def place_prefill(k: jax.Array, cap: int, sink: int,
                  window: int) -> jax.Array:
    """[B,S,H,D] -> [B,cap,H,D]: full copy if it fits, else sink+ring gather.

    Ring slot r holds the LAST token t < S with (t - sink) % ring == r.
    Gather (not scatter) so duplicate ring slots resolve deterministically.
    """
    b, s = k.shape[:2]
    if cap >= s:
        return jnp.pad(k, ((0, 0), (0, cap - s)) + ((0, 0),) * (k.ndim - 2))
    assert window > 0, (
        f"cache capacity {cap} < sequence {s} without a sliding window "
        f"— caller must size max_len to the full prefill length")
    ring = cap - sink
    slots = jnp.arange(cap)
    r = slots - sink
    ring_tok = sink + r + ((s - 1 - sink - r) // ring) * ring
    tok_idx = jnp.where(slots < sink, slots, ring_tok)
    valid = (tok_idx >= 0) & (tok_idx < s)
    tok_idx = jnp.clip(tok_idx, 0, s - 1)
    out = k[:, tok_idx]
    shape = (1, cap) + (1,) * (k.ndim - 2)
    return out * valid.reshape(shape).astype(k.dtype)
