"""Sink + ring-buffer KV cache helpers (paper SS2.1 "sink+local").

Layout: slots [0, sink) hold the attention sink; slots [sink, cap) are a
ring over the sliding window.  When ``cap >= seq_len`` the ring degenerates
to a plain linear cache (dest == pos), so the same code serves both the
full-cache and the windowed-adaptation paths.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def capacity(seq_len: int, window: int, sink: int) -> int:
    """Cache capacity in tokens for a stream of ``seq_len``."""
    if window:
        return min(seq_len, sink + window)
    return seq_len


def ring_dest(pos: jax.Array, cap: int, sink: int) -> jax.Array:
    """Write slot for absolute position ``pos`` (per-batch array ok)."""
    ring = cap - sink
    wrapped = sink + (pos - sink) % jnp.maximum(ring, 1)
    return jnp.where(pos < cap, jnp.minimum(pos, cap - 1),
                     wrapped).astype(jnp.int32)


def write_token(cache: jax.Array, new: jax.Array,
                dest: jax.Array) -> jax.Array:
    """cache [B,cap,H,D]; new [B,1,H,D]; dest [B] -> updated cache."""
    return jax.vmap(lambda cb, nb, db: jax.lax.dynamic_update_slice(
        cb, nb.astype(cb.dtype), (db, 0, 0)))(cache, new, dest)


def n_valid(pos: jax.Array, cap: int) -> jax.Array:
    """Number of resident (valid) cache entries after writing ``pos``."""
    return jnp.minimum(pos + 1, cap)


def chunk_slot(chunk_idx: jax.Array, window_chunks: int, sink: int,
               chunk_tokens: int) -> jax.Array:
    """First-token slot of absolute chunk ``chunk_idx`` in the
    chunk-granular ring: slots [0, sink) hold the attention sink and the
    ring holds ``window_chunks`` chunks of ``chunk_tokens`` each.
    ``chunk_idx`` may be a per-stream batch array."""
    return (sink + (chunk_idx % window_chunks) * chunk_tokens).astype(
        jnp.int32)


def write_block(cache: jax.Array, new: jax.Array,
                dest: jax.Array) -> jax.Array:
    """cache [B,cap,...]; new [B,T,...]; dest [B] first-token slot.

    Block-granular sibling of ``write_token``: writes a contiguous
    T-token block per batch row at a per-row slot (the batched serving
    executor appends one chunk's KV per stream this way)."""
    return jax.vmap(lambda cb, nb, db: jax.lax.dynamic_update_slice(
        cb, nb.astype(cb.dtype),
        (db,) + (0,) * (cb.ndim - 1)))(cache, new, dest)


@jax.jit
def write_block_layers(cache: jax.Array, new: jax.Array,
                       dest: jax.Array) -> jax.Array:
    """``write_block`` lifted over a leading layer axis, jitted (eager
    vmap re-traces per call, which dominates append cost on CPU).

    cache [L,B,cap,...]; new [L,B,T,...]; dest [B]."""
    return jax.vmap(write_block, in_axes=(0, 0, None))(cache, new, dest)


# ---------------------------------------------------------------------------
# page-granular pool (serve/batcher.py KVPool): KV lives as
# [L, n_pages, page_tokens, ...] and each stream owns a page *table*
# (entry 0 = cond sink page, entry 1+r = ring slot r, chunk c in entry
# 1 + c % window_chunks).  The helpers below are pure permutations of
# pool rows, so a page-table cache is bitwise-identical to the stacked
# per-stream chunk-ring layout it replaces.
# ---------------------------------------------------------------------------


def pages_per_stream(window_chunks: int) -> int:
    """Pages a resident stream owns: one cond sink page + the ring."""
    return 1 + window_chunks


def page_of_chunk(chunk_idx: int, window_chunks: int) -> int:
    """Page-table entry holding absolute chunk ``chunk_idx`` (the ring
    slot of ``chunk_slot`` shifted past the sink entry)."""
    return 1 + chunk_idx % window_chunks


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def gather_pages(pool: jax.Array, tables: jax.Array, sink: int,
                 chunk_tokens: int, n_ring: int) -> jax.Array:
    """pool [L,n_pages,P,...]; tables [b, 1+W] page ids ->
    [L, b, sink + n_ring*chunk_tokens, ...].

    Reassembles, per stream, the contiguous sink+ring context the
    stacked chunk-ring layout kept per row: tokens [0, sink) from the
    sink page (table entry 0), ring slot r at
    [sink + r*chunk_tokens, sink + (r+1)*chunk_tokens) from table entry
    1+r, sliced to the first ``n_ring`` ring slots (the sub-batch's
    resident extent).  A pure gather: bitwise-exact."""
    sink_part = pool[:, tables[:, 0], :sink]
    if n_ring == 0:
        return sink_part
    ring = pool[:, tables[:, 1:1 + n_ring], :chunk_tokens]
    l, b = ring.shape[:2]
    ring = ring.reshape((l, b, n_ring * chunk_tokens) + ring.shape[4:])
    return jnp.concatenate([sink_part, ring], axis=2)


def mask_to_pages(mask: np.ndarray, n_ring: int, sink: int,
                  chunk_tokens: int, page_tokens: int) -> np.ndarray:
    """Contiguous sink+ring visibility mask [B, >= sink + n_ring*tc] ->
    page-coordinate mask [B, (1+n_ring)*page_tokens] in TABLE order
    (entry 0 = sink page, entry 1+r = ring slot r) for the paged
    attention path.  Pages are ``page_tokens`` wide but only partially
    valid — ``sink`` tokens on the sink page, ``chunk_tokens`` on ring
    pages — so page tails come out False regardless of the input mask.
    """
    b = mask.shape[0]
    out = np.zeros((b, (1 + n_ring) * page_tokens), bool)
    out[:, :sink] = mask[:, :sink]
    for r in range(n_ring):
        lo = (1 + r) * page_tokens
        out[:, lo:lo + chunk_tokens] = \
            mask[:, sink + r * chunk_tokens:sink + (r + 1) * chunk_tokens]
    return out


@functools.partial(jax.jit, donate_argnums=(0,))
def pool_write_pages(pool: jax.Array, new: jax.Array,
                     pages: jax.Array) -> jax.Array:
    """pool [L,n_pages,P,...]; new [L,b,T,...] (T <= P); pages [b].

    Writes one T-token block per stream at token 0 of its destination
    page — the page-granular sibling of ``write_block``.  The pool
    buffer is donated so the update happens in place where the backend
    supports it.  Device-backed pools rely on this donation staying
    device-local: ``new`` blocks arriving from another lane (migration
    landings, SP shipbacks) are ``device_put`` onto the pool's device by
    the caller BEFORE this jit, so the write never silently pins the
    donated pool to a foreign device."""
    for i in range(new.shape[1]):
        pool = jax.lax.dynamic_update_slice(
            pool, new[:, i:i + 1].astype(pool.dtype),
            (0, pages[i], 0) + (0,) * (pool.ndim - 3))
    return pool


@functools.partial(jax.jit, donate_argnums=(0,), static_argnums=(3,))
def pool_write_pages_heads(pool: jax.Array, new: jax.Array,
                           pages: jax.Array, head_offset: int) -> jax.Array:
    """pool [L,n_pages,P,Hkv,D]; new [L,b,T,h_sub,D] (T <= P,
    h_sub <= Hkv - head_offset); pages [b].

    Head-sliced sibling of ``pool_write_pages``: writes each block at
    token 0 of its destination page, KV-head offset ``head_offset`` —
    the elastic-SP donor pool holds only its half of a stream's KV
    heads (Ulysses head partition, paper App. C.4), so appends touch
    only that half."""
    for i in range(new.shape[1]):
        pool = jax.lax.dynamic_update_slice(
            pool, new[:, i:i + 1].astype(pool.dtype),
            (0, pages[i], 0, head_offset, 0))
    return pool


def place_prefill(k: jax.Array, cap: int, sink: int,
                  window: int) -> jax.Array:
    """[B,S,H,D] -> [B,cap,H,D]: full copy if it fits, else sink+ring gather.

    Ring slot r holds the LAST token t < S with (t - sink) % ring == r.
    Gather (not scatter) so duplicate ring slots resolve deterministically.
    """
    b, s = k.shape[:2]
    if cap >= s:
        return jnp.pad(k, ((0, 0), (0, cap - s)) + ((0, 0),) * (k.ndim - 2))
    assert window > 0, (
        f"cache capacity {cap} < sequence {s} without a sliding window "
        f"— caller must size max_len to the full prefill length")
    ring = cap - sink
    slots = jnp.arange(cap)
    r = slots - sink
    ring_tok = sink + r + ((s - 1 - sink - r) // ring) * ring
    tok_idx = jnp.where(slots < sink, slots, ring_tok)
    valid = (tok_idx >= 0) & (tok_idx < s)
    tok_idx = jnp.clip(tok_idx, 0, s - 1)
    out = k[:, tok_idx]
    shape = (1, cap) + (1,) * (k.ndim - 2)
    return out * valid.reshape(shape).astype(k.dtype)
