"""Attention substrate with the paper's fidelity knobs.

All variants are pure JAX (jnp + lax) with *static* block schedules so that
compiled FLOPs actually scale with the knobs:

  * causal        — block-triangular schedule, no masked-out waste blocks
  * windowed      — sink + sliding window (paper SS2.1 "sink+local"; knob W):
                    per-q-block static KV slices
  * block-sparse  — knob rho: deterministic strided block keep-list
  * decode        — single-query direct attention over a (possibly sharded)
                    KV cache

The Pallas TPU kernels in ``repro/kernels`` implement the same math with
explicit VMEM tiling; ``repro/kernels/*/ops.py`` dispatches between the two.
Numerics: fp32 online-softmax accumulation regardless of input dtype.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,Hq,D] -> [B,S,Hkv,G,D] without materializing repeated KV."""
    b, s, hq, d = q.shape
    assert hq % n_kv == 0, (hq, n_kv)
    return q.reshape(b, s, n_kv, hq // n_kv, d)


def _segment_attn(q, k, v, mask, scale):
    """One (q-block, kv-segment) flash step.

    q: [B,bq,Hkv,G,D]; k/v: [B,skv,Hkv,D]; mask: [bq,skv] bool or None.
    Returns unnormalized partials (s_max, p_sum, p_v) in fp32.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if mask is not None:
        # [bq,skv] shared across batch, or [B,bq,skv] per-batch (the
        # batched serving executor's per-stream KV-validity masks)
        mask = mask[None, None, None] if mask.ndim == 2 \
            else mask[:, None, None]
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                   # [B,H,G,bq]
    # Guard fully-masked rows (all -inf).
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    if mask is not None:
        p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)                                   # [B,H,G,bq]
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return m_safe, l, pv


def _merge(acc, new):
    """Merge two online-softmax partials."""
    m0, l0, o0 = acc
    m1, l1, o1 = new
    m = jnp.maximum(m0, m1)
    c0 = jnp.exp(m0 - m)
    c1 = jnp.exp(m1 - m)
    return m, l0 * c0 + l1 * c1, o0 * c0[..., None] + o1 * c1[..., None]


def _finalize(acc, dtype):
    _, l, o = acc
    l = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows -> 0
    out = o / l[..., None]                          # [B,H,G,bq,D]
    return out.astype(dtype)


def _init_acc(b, h, g, bq, d):
    z = jnp.zeros((b, h, g, bq), jnp.float32)
    return (jnp.full((b, h, g, bq), -jnp.inf, jnp.float32), z,
            jnp.zeros((b, h, g, bq, d), jnp.float32))


def _causal_mask(q_pos: jax.Array, k_pos: jax.Array) -> jax.Array:
    return q_pos[:, None] >= k_pos[None, :]


def sparse_keep_list(n_q_blocks: int, n_kv_blocks_per_q: Sequence[int],
                     sparsity: float, sink_blocks: int = 1) -> List[List[int]]:
    """Deterministic strided block keep-list for the rho fidelity knob.

    For q block i with causal KV blocks [0..i], always keep the sink block(s)
    and the diagonal block; keep a strided ~(1-rho) fraction of the rest.
    """
    keep: List[List[int]] = []
    frac = max(1e-6, 1.0 - sparsity)
    for i in range(n_q_blocks):
        n_kv = n_kv_blocks_per_q[i]
        forced = set(range(min(sink_blocks, n_kv))) | {n_kv - 1}
        middle = [j for j in range(n_kv) if j not in forced]
        n_keep = int(round(len(middle) * frac))
        if n_keep >= len(middle):
            chosen = middle
        elif n_keep <= 0:
            chosen = []
        else:
            idx = np.linspace(0, len(middle) - 1, n_keep).round().astype(int)
            chosen = [middle[j] for j in sorted(set(idx.tolist()))]
        keep.append(sorted(forced | set(chosen)))
    return keep


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
        n_kv_heads: int,
        causal: bool = True,
        q_offset: int = 0,
        window: int = 0,
        sink: int = 0,
        sparsity: float = 0.0,
        kv_mask: Optional[jax.Array] = None,
        block_q: int = 512,
        block_kv: int = 512) -> jax.Array:
    """Multi-head attention with GQA + fidelity knobs.

    q: [B,Sq,Hq,D]; k,v: [B,Skv,Hkv,D].  Returns [B,Sq,Hq,D].
    ``q_offset``: absolute position of q[0] relative to k[0] (for chunk-wise
    generation and decode, where Skv > Sq).
    ``kv_mask``: optional [B,Skv] per-batch KV validity (non-causal/direct
    path only) — the batched serving executor masks ring-cache slots that
    are unfilled, outside a stream's fidelity window, or sparsity-dropped.
    Because the mask is per-ROW data, one launch can serve rows with
    DIFFERENT fidelity windows/sparsities (fused heterogeneous-fidelity
    dispatch) and rows whose ring pages were partially evicted — the
    caller zeroes the dropped chunks' token slices and this function
    never reads them.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    dtype = q.dtype
    scale = 1.0 / math.sqrt(d)
    qg = _group(q, n_kv_heads)

    # ---- direct path: decode / tiny shapes / cross attention --------------
    # (rho block sparsity is defined on the blocked causal schedule, so any
    #  sparsity>0 request takes the blocked path at the given block sizes)
    if ((sq * skv <= block_q * block_kv and sparsity == 0.0)
            or sq == 1 or not causal):
        mask = None
        if causal:
            q_pos = q_offset + jnp.arange(sq)
            k_pos = jnp.arange(skv)
            mask = _causal_mask(q_pos, k_pos)
            if window:
                mask &= (k_pos[None, :] > q_pos[:, None] - window) | \
                        (k_pos[None, :] < sink)
        if kv_mask is not None:
            km = kv_mask[:, None, :]                     # [B,1,Skv]
            mask = km if mask is None else mask[None] & km
        m, l, pv = _segment_attn(qg, k, v, mask, scale)
        out = _finalize((m, l, pv), dtype)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    assert kv_mask is None, "kv_mask is only supported on the direct path"

    # ---- blocked paths -----------------------------------------------------
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0, (sq, block_q)
    n_q = sq // block_q
    g = hq // n_kv_heads

    def kv_seg(lo: int, hi: int):
        return k[:, lo:hi], v[:, lo:hi]

    outs = []
    for i in range(n_q):
        q_blk = qg[:, i * block_q:(i + 1) * block_q]
        q_lo = q_offset + i * block_q
        q_hi = q_lo + block_q
        q_pos = q_lo + jnp.arange(block_q)
        acc = _init_acc(b, n_kv_heads, g, block_q, d)

        if window:
            # sink prefix + sliding window (static slices; exact FLOPs)
            segs: List[Tuple[int, int]] = []
            if sink:
                segs.append((0, min(sink, skv)))
            w_lo = max(sink, q_lo - window + 1)
            # round down for block alignment, but never below the sink
            # prefix (it has its own segment; overlap would double-count)
            w_lo = max((w_lo // block_kv) * block_kv, sink)
            segs.append((w_lo, min(q_hi, skv)))
            for lo, hi in segs:
                if lo >= hi:
                    continue
                ks, vs = kv_seg(lo, hi)
                k_pos = lo + jnp.arange(hi - lo)
                msk = _causal_mask(q_pos, k_pos)
                msk &= (k_pos[None, :] > q_pos[:, None] - window) | \
                       (k_pos[None, :] < sink)
                acc = _merge(acc, _segment_attn(q_blk, ks, vs, msk, scale))
        else:
            # causal block-triangular schedule; optional rho block sparsity
            n_kv_for_q = (q_hi + block_kv - 1) // block_kv
            if sparsity > 0.0:
                keep = sparse_keep_list(1, [n_kv_for_q], sparsity)[0]
            else:
                keep = list(range(n_kv_for_q))
            for j in keep:
                lo, hi = j * block_kv, min((j + 1) * block_kv, skv)
                ks, vs = kv_seg(lo, hi)
                if hi > q_lo:  # diagonal/edge segment: needs elementwise mask
                    k_pos = lo + jnp.arange(hi - lo)
                    msk = _causal_mask(q_pos, k_pos)
                else:
                    msk = None
                acc = _merge(acc, _segment_attn(q_blk, ks, vs, msk, scale))

        outs.append(_finalize(acc, dtype))

    out = jnp.concatenate([o.transpose(0, 3, 1, 2, 4).reshape(
        b, block_q, hq, d) for o in outs], axis=1)
    return out


def shard_heads(x: jax.Array, n_kv_heads: int, lo: int,
                hi: int) -> jax.Array:
    """Slice a [B,S,H,D] tensor to the heads grouped under KV heads
    [lo, hi) — the Ulysses-style head partition of elastic SP (SS4.3).

    For a query tensor H = n_heads = G * n_kv_heads and the slice keeps
    the G query heads of every KV head in [lo, hi); for a KV tensor
    H = n_kv_heads and the slice is direct.  Head order is preserved, so
    concatenating the shards' attention outputs with
    ``merge_head_shards`` is bit-identical to the unsharded call —
    per-head attention never mixes heads.
    """
    b, s, h, d = x.shape
    g = h // n_kv_heads
    return x.reshape(b, s, n_kv_heads, g, d)[:, :, lo:hi] \
        .reshape(b, s, (hi - lo) * g, d)


def merge_head_shards(outs: Sequence[jax.Array],
                      n_kv_heads_per_shard: Sequence[int]) -> jax.Array:
    """Concatenate per-shard attention outputs back into full-head
    order (inverse of ``shard_heads`` over a covering partition)."""
    b, s = outs[0].shape[:2]
    d = outs[0].shape[-1]
    parts = [o.reshape(b, s, h, -1, d)
             for o, h in zip(outs, n_kv_heads_per_shard)]
    merged = jnp.concatenate(parts, axis=2)
    return merged.reshape(b, s, -1, d)


def paged_mha(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
              block_table: jax.Array, page_mask: jax.Array,
              chunk_k: jax.Array, chunk_v: jax.Array, *,
              n_kv_heads: int, sink: int = 0,
              chunk_tokens: int = 0) -> jax.Array:
    """Page-table-native attention for chunk-wise generation.

    q [B,Sq,Hq,D] attends to (a) the visible cached context, read IN
    PLACE from the physical page pool ``k_pages``/``v_pages``
    [n_pages, page, Hkv, D] through per-stream ``block_table`` [B, n]
    with ``page_mask`` [B, n*page] marking the visible context tokens in
    table order (ring residency + fidelity window + sparsity + page-tail
    validity + partial-window page drops baked in by the caller — all
    per-row, so one fused launch serves heterogeneous fidelities, and a
    degraded stream's dropped ring page (hole remapped to its sink row,
    mask slice false) is simply never attended), and (b) the chunk's own fresh KV
    ``chunk_k``/``chunk_v`` [B,Sq,Hkv,D] (bidirectional, fully visible).

    The paged segment contributes online-softmax partials — the
    ``kernels/paged_attention`` chunk-query kernel on TPU, its pure-jnp
    oracle elsewhere — which are merged with a dense in-chunk segment
    before the softmax divide.  No contiguous [B, ctx_len, ...] context
    is ever materialized.  ``sink``/``chunk_tokens`` (optional) declare
    the valid prefixes of the sink/ring pages so the oracle can skip
    always-masked page tails.
    """
    # late import: the kernel package's ref oracle imports this module
    from repro.kernels.paged_attention.ops import paged_chunk_attention
    b, sq, hq, d = q.shape
    scale = 1.0 / math.sqrt(d)
    ctx = paged_chunk_attention(q, k_pages, v_pages, block_table,
                                page_mask, sink=sink,
                                chunk_tokens=chunk_tokens)
    own = _segment_attn(_group(q, n_kv_heads), chunk_k, chunk_v, None,
                        scale)
    out = _finalize(_merge(ctx, own), q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     n_kv_heads: int, cache_len: jax.Array,
                     window: int = 0, sink: int = 0) -> jax.Array:
    """Single-token decode over a KV cache.

    q: [B,1,Hq,D]; caches: [B,Smax,Hkv,D]; ``cache_len``: [B] or scalar int32
    count of valid cache entries (the new token's KV must already be written).
    """
    b, sq, hq, d = q.shape
    smax = k_cache.shape[1]
    scale = 1.0 / math.sqrt(d)
    qg = _group(q, n_kv_heads)
    k_pos = jnp.arange(smax)
    valid = k_pos[None, :] < jnp.reshape(cache_len, (-1, 1))     # [B,S]
    if window:
        last = jnp.reshape(cache_len, (-1, 1)) - 1
        valid &= (k_pos[None, :] > last - window) | (k_pos[None, :] < sink)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_cache.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d).astype(q.dtype)
