"""Mamba-2 (SSD) model substrate: block init/apply, full model with
train / prefill / decode paths.  Attention-free; per-token decode is O(1)
state update, so ``long_500k`` runs natively (DESIGN.md SS4).

Block layout follows Mamba-2 (arXiv:2405.21060): separate projections per
component (z, x, B, C, dt) so tensor-parallel sharding never splits a
projection across semantic boundaries; depthwise causal conv over (x,B,C);
SSD scan; gated RMSNorm; out projection.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.logical import shard
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.models import layers as L

Params = Dict[str, Any]

N_GROUPS = 1


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(d_inner, n_ssm_heads, head_dim, state)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    hd = cfg.ssm_head_dim
    assert d_inner % hd == 0, (d_inner, hd)
    return d_inner, d_inner // hd, hd, cfg.ssm_state


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def init_mamba(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    di, h, hd, n = dims(cfg)
    g = N_GROUPS
    ks = L.split_keys(key, 7)
    # dt bias: init so softplus(dt_bias) spans [1e-3, 1e-1] (Mamba-2 default)
    u = jax.random.uniform(ks[5], (h,), jnp.float32)
    dt_init = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))       # inv softplus
    conv_ch = di + 2 * g * n
    return {
        "wz": L.dense_init(ks[0], (d, di), dtype),
        "wx": L.dense_init(ks[1], (d, di), dtype),
        "wB": L.dense_init(ks[2], (d, g * n), dtype),
        "wC": L.dense_init(ks[3], (d, g * n), dtype),
        "wdt": L.dense_init(ks[4], (d, h), dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "conv_w": L.dense_init(ks[6], (conv_ch, cfg.ssm_conv), dtype,
                               scale=1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm_w": jnp.ones((di,), dtype),
        "out": L.dense_init(ks[6], (di, d), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x [B,S,C], w [C,K], prev [B,K-1,C] or None."""
    k = w.shape[1]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[None, None, :, i].astype(jnp.float32)
              for i in range(k))
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _gated_norm(y: jax.Array, z: jax.Array, w: jax.Array,
                eps: float) -> jax.Array:
    return L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                     w, eps)


def mamba_block(cfg: ModelConfig, p: Params, x: jax.Array, *,
                conv_state: Optional[jax.Array] = None,
                ssm_state: Optional[jax.Array] = None,
                return_state: bool = False):
    """Full-sequence Mamba-2 block.  x [B,S,D] -> [B,S,D].

    With ``return_state``, also returns (conv_state [B,K-1,C],
    ssm_state [B,H,P,N]) after the last position.
    """
    b, s, _ = x.shape
    di, h, hd, n = dims(cfg)
    g = N_GROUPS
    z = shard(x @ p["wz"], "batch", None, "inner")
    xi = shard(x @ p["wx"], "batch", None, "inner")
    Bp = x @ p["wB"]
    Cp = x @ p["wC"]
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])    # [B,S,H]

    xbc = jnp.concatenate([xi, Bp, Cp], axis=-1)
    new_conv_state = xbc[:, -(cfg.ssm_conv - 1):, :] if return_state else None
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xi, Bp, Cp = jnp.split(xbc, [di, di + g * n], axis=-1)

    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(b, s, h, hd)
    y, final_state = ssd_ops.ssd(
        xh, dt, A, Bp.reshape(b, s, g, n), Cp.reshape(b, s, g, n),
        chunk=cfg.ssm_chunk, init_state=ssm_state)
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = _gated_norm(y.reshape(b, s, di), z, p["norm_w"], cfg.norm_eps)
    out = shard(y @ p["out"], "batch", None, "embed")
    if return_state:
        return out, (new_conv_state, final_state)
    return out


def mamba_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                 conv_state: jax.Array, ssm_state: jax.Array):
    """One-token step.  x [B,1,D]; states as produced by mamba_block.

    Returns (out [B,1,D], (conv_state, ssm_state)).
    """
    b = x.shape[0]
    di, h, hd, n = dims(cfg)
    g = N_GROUPS
    z = x @ p["wz"]
    xi = x @ p["wx"]
    Bp = x @ p["wB"]
    Cp = x @ p["wC"]
    dt = jax.nn.softplus(
        (x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,H]

    xbc = jnp.concatenate([xi, Bp, Cp], axis=-1)                  # [B,1,C]
    window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    new_conv_state = window[:, 1:]
    out = jnp.einsum("bkc,ck->bc", window.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(out + p["conv_b"].astype(jnp.float32))
    xbc = xbc.astype(x.dtype)[:, None, :]
    xi, Bp, Cp = jnp.split(xbc, [di, di + g * n], axis=-1)

    A = -jnp.exp(p["A_log"])
    y, new_ssm = ssd_ops.ssd_decode(
        xi.reshape(b, h, hd), dt, A,
        Bp.reshape(b, g, n), Cp.reshape(b, g, n), ssm_state)
    y = y + xi.reshape(b, h, hd) * p["D"].astype(y.dtype)[None, :, None]
    y = _gated_norm(y.reshape(b, 1, di), z, p["norm_w"], cfg.norm_eps)
    return y @ p["out"], (new_conv_state, new_ssm)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def init_layer(cfg: ModelConfig, key, dtype) -> Params:
    return {
        "norm": jnp.ones((cfg.d_model,), dtype),
        "mamba": init_mamba(cfg, key, dtype),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers = jax.random.split(key)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(cfg, k, dtype))(layer_keys)
    return {
        "embed": L.dense_init(k_embed, (cfg.padded_vocab, cfg.d_model), dtype,
                              scale=0.02),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def forward(cfg: ModelConfig, p: Params, tokens: jax.Array, *,
            remat: bool = False) -> jax.Array:
    h = shard(jnp.take(p["embed"], tokens, axis=0), "batch", None, "embed")

    def body(hh, lp):
        x = L.rmsnorm(hh, lp["norm"], cfg.norm_eps)
        return hh + mamba_block(cfg, lp["mamba"], x), None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, p["layers"])
    return h


def _unembed(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    h = L.rmsnorm(h, p["final_norm"], cfg.norm_eps)
    return shard(h @ p["embed"].T, "batch", None, "vocab")


def train_loss(cfg: ModelConfig, p: Params,
               batch: Dict[str, jax.Array]) -> jax.Array:
    from repro.models.transformer import chunked_ce
    h = forward(cfg, p, batch["tokens"], remat=True)
    return chunked_ce(
        lambda hb: L.rmsnorm(hb, p["final_norm"], cfg.norm_eps) @ p["embed"].T,
        h, batch["targets"], batch.get("loss_mask"))


def init_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    di, h, hd, n = dims(cfg)
    conv_ch = di + 2 * N_GROUPS * cfg.ssm_state
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch),
                          jnp.dtype(cfg.param_dtype)),
        "ssm": jnp.zeros((cfg.n_layers, batch, h, hd, n), jnp.float32),
    }


def prefill(cfg: ModelConfig, p: Params, tokens: jax.Array, **_):
    """Returns (last-position logits [B,V], state, cache_len [B])."""
    b, s = tokens.shape
    h = jnp.take(p["embed"], tokens, axis=0)

    def body(hh, lp):
        x = L.rmsnorm(hh, lp["norm"], cfg.norm_eps)
        out, (conv_s, ssm_s) = mamba_block(cfg, lp["mamba"], x,
                                           return_state=True)
        return hh + out, {"conv": conv_s, "ssm": ssm_s}

    h, state = jax.lax.scan(body, h, p["layers"])
    logits = _unembed(cfg, p, h[:, -1:])[:, 0]
    return logits, state, jnp.full((b,), s, jnp.int32)


def decode_step(cfg: ModelConfig, p: Params, state: Dict[str, jax.Array],
                token: jax.Array, pos: jax.Array, **_):
    """One decode step.  token [B,1].  Returns (logits [B,V], state)."""
    h = jnp.take(p["embed"], token, axis=0)

    def body(hh, xs):
        lp, conv_s, ssm_s = xs
        x = L.rmsnorm(hh, lp["norm"], cfg.norm_eps)
        out, (c2, s2) = mamba_decode(cfg, lp["mamba"], x, conv_s, ssm_s)
        return hh + out, {"conv": c2, "ssm": s2}

    h, state = jax.lax.scan(body, h, (p["layers"], state["conv"],
                                      state["ssm"]))
    return _unembed(cfg, p, h)[:, 0], state
