"""Jamba-style hybrid model: Mamba + attention interleave with MoE.

Layer l is an attention layer iff ``l % attn_every == attn_every // 2``;
the FFN is MoE iff ``l % moe_every == moe_every - 1`` (Jamba places MoE on
every other layer).  Layers are grouped into *periods* of
``lcm(attn_every, moe_every)`` sublayers; per-period params are stacked and
consumed by ``lax.scan`` so HLO size is O(one period), not O(n_layers).

Attention layers keep a bounded sink+window KV cache (ring buffer at decode
time), so ``long_500k`` decode is sub-quadratic: the Mamba state carries
long-range context, windowed attention covers local structure.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.logical import shard
from repro.models import kvcache
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.attention import decode_attention

Params = Dict[str, Any]


def period_len(cfg: ModelConfig) -> int:
    return math.lcm(cfg.attn_every, cfg.moe_every or 1)


def sublayer_kinds(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """[(mixer, ffn)] per sublayer within one period."""
    out = []
    for j in range(period_len(cfg)):
        mixer = "attn" if j % cfg.attn_every == cfg.attn_every // 2 else "mamba"
        ffn = ("moe" if cfg.n_experts and
               j % cfg.moe_every == cfg.moe_every - 1 else "mlp")
        out.append((mixer, ffn))
    return out


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_period(cfg: ModelConfig, key, dtype) -> Params:
    kinds = sublayer_kinds(cfg)
    ks = L.split_keys(key, 2 * len(kinds))
    p: Params = {}
    for j, (mixer, ffn) in enumerate(kinds):
        sub: Params = {
            "mixer_norm": jnp.ones((cfg.d_model,), dtype),
            "ffn_norm": jnp.ones((cfg.d_model,), dtype),
        }
        if mixer == "attn":
            sub["attn"] = L.init_attn(cfg, ks[2 * j], dtype)
        else:
            sub["mamba"] = S.init_mamba(cfg, ks[2 * j], dtype)
        if ffn == "moe":
            sub["moe"] = L.init_moe(cfg, ks[2 * j + 1], dtype)
        else:
            sub["mlp"] = L.init_mlp(cfg, ks[2 * j + 1], dtype)
        p[f"sub{j}"] = sub
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    pl = period_len(cfg)
    assert cfg.n_layers % pl == 0, (cfg.n_layers, pl)
    n_periods = cfg.n_layers // pl
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    pkeys = jax.random.split(k_layers, n_periods)
    stacked = jax.vmap(lambda k: init_period(cfg, k, dtype))(pkeys)
    return {
        "embed": L.dense_init(k_embed, (cfg.padded_vocab, cfg.d_model), dtype,
                              scale=0.02),
        "periods": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype),
    }


# ---------------------------------------------------------------------------
# forward / train
# ---------------------------------------------------------------------------

def _period_fwd(cfg: ModelConfig, pp: Params, h: jax.Array, *,
                positions: jax.Array, sparsity: float = 0.0):
    aux = jnp.zeros((), jnp.float32)
    for j, (mixer, ffn) in enumerate(sublayer_kinds(cfg)):
        sub = pp[f"sub{j}"]
        x = L.rmsnorm(h, sub["mixer_norm"], cfg.norm_eps)
        if mixer == "attn":
            h = h + L.attn_block(cfg, sub["attn"], x, positions=positions,
                                 window=cfg.attn_window, sink=cfg.attn_sink,
                                 sparsity=sparsity)
        else:
            h = h + S.mamba_block(cfg, sub["mamba"], x)
        f = L.rmsnorm(h, sub["ffn_norm"], cfg.norm_eps)
        if ffn == "moe":
            h = h + L.moe_block(cfg, sub["moe"], f)
            aux = aux + L.moe_block.last_aux
        else:
            h = h + L.mlp_block(cfg, sub["mlp"], f)
    return h, aux


def forward(cfg: ModelConfig, p: Params, tokens: jax.Array, *,
            sparsity: float = 0.0, remat: bool = False):
    h = shard(jnp.take(p["embed"], tokens, axis=0), "batch", None, "embed")
    positions = jnp.arange(h.shape[1])

    def body(carry, pp):
        hh, aux = carry
        hh, a = _period_fwd(cfg, pp, hh, positions=positions,
                            sparsity=sparsity)
        return (hh, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                               p["periods"])
    return h, aux


def _unembed(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    h = L.rmsnorm(h, p["final_norm"], cfg.norm_eps)
    return shard(h @ p["lm_head"], "batch", None, "vocab")


def train_loss(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
               aux_weight: float = 0.01) -> jax.Array:
    from repro.models.transformer import chunked_ce
    h, aux = forward(cfg, p, batch["tokens"], remat=True)
    loss = chunked_ce(
        lambda hb: L.rmsnorm(hb, p["final_norm"], cfg.norm_eps) @ p["lm_head"],
        h, batch["targets"], batch.get("loss_mask"))
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: windowed ring-buffer KV for attention sublayers + mamba states
# ---------------------------------------------------------------------------

def cache_capacity(cfg: ModelConfig, seq_len: int) -> int:
    return kvcache.capacity(seq_len, cfg.attn_window, cfg.attn_sink)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    pl = period_len(cfg)
    n_periods = cfg.n_layers // pl
    kinds = sublayer_kinds(cfg)
    cap = cache_capacity(cfg, max_len)
    kv_dtype = jnp.dtype(cfg.kv_dtype)
    di, h, hd, n = S.dims(cfg)
    conv_ch = di + 2 * S.N_GROUPS * cfg.ssm_state
    cache: Dict[str, Any] = {}
    for j, (mixer, _) in enumerate(kinds):
        if mixer == "attn":
            shp = (n_periods, batch, cap, cfg.n_kv_heads, cfg.head_dim)
            cache[f"sub{j}"] = {"k": jnp.zeros(shp, kv_dtype),
                                "v": jnp.zeros(shp, kv_dtype)}
        else:
            cache[f"sub{j}"] = {
                "conv": jnp.zeros((n_periods, batch, cfg.ssm_conv - 1,
                                   conv_ch), jnp.dtype(cfg.param_dtype)),
                "ssm": jnp.zeros((n_periods, batch, h, hd, n), jnp.float32),
            }
    return cache


def prefill(cfg: ModelConfig, p: Params, tokens: jax.Array, *,
            max_len: Optional[int] = None, sparsity: float = 0.0, **_):
    """Returns (last-position logits, cache, cache_len [B])."""
    b, s = tokens.shape
    max_len = max_len or s
    cap = cache_capacity(cfg, max_len)
    sink, window = cfg.attn_sink, cfg.attn_window
    positions = jnp.arange(s)
    kv_dtype = jnp.dtype(cfg.kv_dtype)
    h = jnp.take(p["embed"], tokens, axis=0)

    def place_kv(k):                       # [B,S,H,D] -> [B,cap,H,D]
        return kvcache.place_prefill(k, cap, sink, window)

    def body(hh, pp):
        sub_cache = {}
        for j, (mixer, ffn) in enumerate(sublayer_kinds(cfg)):
            sub = pp[f"sub{j}"]
            x = L.rmsnorm(hh, sub["mixer_norm"], cfg.norm_eps)
            if mixer == "attn":
                q, k, v = L.attn_qkv(cfg, sub["attn"], x, positions)
                from repro.models.attention import mha
                o = mha(q, k, v, n_kv_heads=cfg.n_kv_heads, causal=True,
                        window=window, sink=sink, sparsity=sparsity)
                o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
                hh = hh + o @ sub["attn"]["wo"]
                sub_cache[f"sub{j}"] = {
                    "k": place_kv(k).astype(kv_dtype),
                    "v": place_kv(v).astype(kv_dtype)}
            else:
                out, (conv_s, ssm_s) = S.mamba_block(
                    cfg, sub["mamba"], x, return_state=True)
                hh = hh + out
                sub_cache[f"sub{j}"] = {"conv": conv_s, "ssm": ssm_s}
            f = L.rmsnorm(hh, sub["ffn_norm"], cfg.norm_eps)
            if ffn == "moe":
                hh = hh + L.moe_block(cfg, sub["moe"], f)
            else:
                hh = hh + L.mlp_block(cfg, sub["mlp"], f)
        return hh, sub_cache

    h, cache = jax.lax.scan(body, h, p["periods"])
    logits = _unembed(cfg, p, h[:, -1:])[:, 0]
    return logits, cache, jnp.full((b,), s, jnp.int32)


def decode_step(cfg: ModelConfig, p: Params, cache: Dict[str, Any],
                token: jax.Array, pos: jax.Array, **_):
    """One decode step with ring-buffer windowed attention caches."""
    b = token.shape[0]
    sink, window = cfg.attn_sink, cfg.attn_window
    h = jnp.take(p["embed"], token, axis=0)
    positions = pos[:, None]

    def body(hh, xs):
        pp, pc = xs
        new_cache = {}
        for j, (mixer, ffn) in enumerate(sublayer_kinds(cfg)):
            sub, subc = pp[f"sub{j}"], pc[f"sub{j}"]
            x = L.rmsnorm(hh, sub["mixer_norm"], cfg.norm_eps)
            if mixer == "attn":
                q, k, v = L.attn_qkv(cfg, sub["attn"], x, positions)
                cap = subc["k"].shape[1]
                ring_mode = bool(window) and cap == sink + window
                dest = kvcache.ring_dest(pos, cap, sink) if ring_mode else pos
                kc = kvcache.write_token(subc["k"], k, dest)
                vc = kvcache.write_token(subc["v"], v, dest)
                o = decode_attention(q, kc, vc, n_kv_heads=cfg.n_kv_heads,
                                     cache_len=kvcache.n_valid(pos, cap),
                                     window=0 if ring_mode else window,
                                     sink=sink)
                o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
                hh = hh + o @ sub["attn"]["wo"]
                new_cache[f"sub{j}"] = {"k": kc, "v": vc}
            else:
                out, (c2, s2) = S.mamba_decode(cfg, sub["mamba"], x,
                                               subc["conv"], subc["ssm"])
                hh = hh + out
                new_cache[f"sub{j}"] = {"conv": c2, "ssm": s2}
            f = L.rmsnorm(hh, sub["ffn_norm"], cfg.norm_eps)
            if ffn == "moe":
                hh = hh + L.moe_block(cfg, sub["moe"], f)
            else:
                hh = hh + L.mlp_block(cfg, sub["mlp"], f)
        return hh, new_cache

    h, cache = jax.lax.scan(body, h, (p["periods"], cache))
    return _unembed(cfg, p, h)[:, 0], cache
