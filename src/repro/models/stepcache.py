"""Content-adaptive step cache (AdaCache-style residual reuse).

Video-DiT compute is content-dependent: the residual (velocity) a
denoise step predicts changes little between adjacent steps on stable
content, so a step whose *measured* inter-step residual delta fell
under a threshold can reuse the cached velocity instead of recomputing
the whole attention+MLP stack — the Euler update collapses to an
O(tokens) AXPY ``x - dt * v_cached``.  This module is the fifth
fidelity knob (``FidelityConfig.cache in {off, conservative,
aggressive}``): BMPR routes over it like steps/sparsity/window/quant,
so slack-poor streams take cached steps before degrading window or
resolution.

Three pieces:

* ``ResidualPool`` — a device-resident buffer keyed like the KV pool
  (slot table + LIFO free list): per slot the cached velocity
  ``v [tc, C]`` of the last computed step and a per-layer feature
  signature ``feats [L]`` (mean |k_l| of that step's fresh chunk KV).
  Both live on the executor's device; per-row updates ride ONE fused
  donated-buffer dispatch (``_record``) issued asynchronously with the
  step.
* ``StepCacheManager`` — host-side per-stream tracker.  After every
  COMPUTED denoise step it issues (device-side, no sync) the combined
  residual delta

      delta = max( mean|v - v_prev| / (mean|v_prev| + eps),
                   mean|f - f_prev| / (mean|f_prev| + eps) )

  read back LAZILY at the next step's hit decision, so the executor's
  no-mid-chunk-sync pipelining survives (the read blocks only until
  the previous launch — already enqueued — retires).
* Motion regularizer — AdaCache's MoReg at chunk granularity: the
  chunk-to-chunk latent delta of the stream's last two completed
  chunks scales the threshold down (``base / (1 + MOREG_WEIGHT *
  motion)``) so high-motion chunks stay conservative.

Hit eligibility (per denoise step): cache level != off, at least two
computed velocities this chunk (a delta exists), consecutive reuses
under the level's cap, and delta under the motion-scaled threshold.
The clean (context) pass NEVER hits — it writes the chunk's KV pages.
Cache state is per-chunk transient: spill/restore/migration and
prompt switches drop it safely (the next chunk re-tracks from its
first computed steps; motion recomputes from the chunk history that
already travels with the stream).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

EPS = 1e-6

# Base residual-delta thresholds per cache level.  Conservative only
# reuses when the velocity field is nearly frozen (5% relative change);
# aggressive reuses up to a full 100% relative change.  Both scale DOWN
# with measured motion.
THRESHOLDS = {"conservative": 0.05, "aggressive": 1.0}
# Consecutive-reuse caps: how many steps in a row may ride one cached
# velocity before a recompute is forced.
MAX_CONSECUTIVE = {"conservative": 1, "aggressive": 2}
MOREG_WEIGHT = 4.0


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _record(v_pool: jax.Array, f_pool: jax.Array, slot: jax.Array,
            x_old: jax.Array, x_new: jax.Array, dt: float,
            k_row: jax.Array):
    """ONE fused dispatch per computed step: recover the velocity
    ``v = (x_old - x_new) / dt``, build the per-layer KV signature
    ``[L, tc, H, Dh] -> [L]`` mean |k|, compute the combined relative
    residual delta against the slot's previous entry, and write both
    pool rows in place (buffers donated — no copy).  Returns the
    updated pools and the device-scalar delta."""
    v_new = (x_old - x_new) / dt
    f_new = jnp.mean(jnp.abs(k_row), axis=(1, 2, 3))
    v_prev = v_pool[slot]
    f_prev = f_pool[slot]
    dv = jnp.mean(jnp.abs(v_new - v_prev)) \
        / (jnp.mean(jnp.abs(v_prev)) + EPS)
    df = jnp.mean(jnp.abs(f_new - f_prev)) \
        / (jnp.mean(jnp.abs(f_prev)) + EPS)
    delta = jnp.maximum(dv, df)
    return (v_pool.at[slot].set(v_new), f_pool.at[slot].set(f_new),
            delta)


@jax.jit
def _apply_cached(x: jax.Array, v_pool: jax.Array, slot: jax.Array,
                  dt: float) -> jax.Array:
    """The cache-hit Euler step: ``x - dt * v_cached`` (an AXPY — the
    whole point: no attention, no MLP), slot-sliced in the same
    dispatch."""
    return x - dt * v_pool[slot]


@dataclasses.dataclass
class StreamCacheState:
    """Host-side per-stream, per-chunk tracker state."""
    slot: int
    n_computed: int = 0            # computed velocities this chunk
    consecutive: int = 0           # reuses riding the current velocity
    motion: float = 0.0            # chunk-to-chunk latent delta
    delta: Optional[jax.Array] = None   # device scalar, read lazily


class ResidualPool:
    """Device-resident cached-velocity buffer, keyed like the KV pool:
    a slot per tracked stream, host free list, ``.at[slot]`` writes."""

    def __init__(self, n_slots: int, chunk_tokens: int, latent_ch: int,
                 n_layers: int, device=None):
        self.n_slots = n_slots
        v = jnp.zeros((n_slots, 1, chunk_tokens, latent_ch), jnp.float32)
        f = jnp.zeros((n_slots, n_layers), jnp.float32)
        if device is not None:
            v = jax.device_put(v, device)
            f = jax.device_put(f, device)
        self.v = v
        self.feats = f
        self._free: List[int] = list(range(n_slots - 1, -1, -1))

    def alloc(self) -> Optional[int]:
        return self._free.pop() if self._free else None

    def free(self, slot: int) -> None:
        self._free.append(slot)


class StepCacheManager:
    """Per-executor step-cache bookkeeping: slot lifecycle, hit
    decisions, residual tracking, hit/miss accounting."""

    def __init__(self, n_slots: int, chunk_tokens: int, latent_ch: int,
                 n_layers: int, device=None):
        self.pool = ResidualPool(n_slots, chunk_tokens, latent_ch,
                                 n_layers, device=device)
        self.states: Dict[int, StreamCacheState] = {}
        self.hits = 0
        self.misses = 0

    # ---- lifecycle ---------------------------------------------------------
    def begin_chunk(self, sid: int,
                    history: Optional[Sequence[jax.Array]]) -> None:
        """Reset the per-chunk tracker and measure motion from the last
        two COMPLETED chunks (host read — they were synced when their
        chunk finished).  Chunks 0 and 1 get neutral motion 0."""
        st = self.states.get(sid)
        if st is None:
            slot = self.pool.alloc()
            if slot is None:            # slots exhausted: never hits
                return
            st = StreamCacheState(slot=slot)
            self.states[sid] = st
        st.n_computed = 0
        st.consecutive = 0
        st.delta = None
        st.motion = 0.0
        if history is not None and len(history) >= 2:
            prev = np.asarray(history[-1], np.float32)
            prev2 = np.asarray(history[-2], np.float32)
            st.motion = float(np.mean(np.abs(prev - prev2))
                              / (np.mean(np.abs(prev2)) + EPS))

    def drop(self, sid: int) -> None:
        """Free the stream's slot and forget its tracker (retire,
        migration export, spill): cache state is per-chunk transient
        and is deliberately NOT carried — the next chunk re-tracks."""
        st = self.states.pop(sid, None)
        if st is not None:
            self.pool.free(st.slot)

    def reset_chunk(self, sid: int) -> None:
        """Invalidate mid-chunk state (abort / prompt switch) but keep
        the slot for the stream's next chunk."""
        st = self.states.get(sid)
        if st is not None:
            st.n_computed = 0
            st.consecutive = 0
            st.delta = None

    # ---- the decision ------------------------------------------------------
    def effective_threshold(self, level: str, motion: float) -> float:
        return THRESHOLDS[level] / (1.0 + MOREG_WEIGHT * motion)

    def should_hit(self, sid: int, level: str) -> bool:
        """Hit decision for the NEXT denoise step of ``sid``.  Reads
        the lazily issued delta (blocks at most until the previous
        launch retires).  Counts the decision (hit or miss)."""
        st = self.states.get(sid)
        hit = False
        if (st is not None and level != "off"
                and st.n_computed >= 2
                and st.consecutive < MAX_CONSECUTIVE[level]
                and st.delta is not None):
            hit = (float(st.delta)
                   < self.effective_threshold(level, st.motion))
        if hit:
            self.hits += 1
        else:
            self.misses += 1
        return hit

    # ---- state updates -----------------------------------------------------
    def apply_hit(self, sid: int, x: jax.Array, dt: float) -> jax.Array:
        """The reused Euler step: ``x - dt * v_cached``."""
        st = self.states[sid]
        st.consecutive += 1
        return _apply_cached(x, self.pool.v, st.slot, dt)

    def record_step(self, sid: int, x_old: jax.Array, x_new: jax.Array,
                    dt: float, k_row: jax.Array) -> None:
        """After a COMPUTED denoise step: recover the velocity, build
        the per-layer KV signature, and (from the second computed step
        on) issue the residual delta — one fused device dispatch, no
        sync (the delta is read lazily at the next hit decision)."""
        st = self.states.get(sid)
        if st is None or dt == 0.0:
            return
        self.pool.v, self.pool.feats, delta = _record(
            self.pool.v, self.pool.feats, st.slot, x_old, x_new, dt,
            k_row)
        if st.n_computed >= 1:      # first step has no previous entry
            st.delta = delta
        st.n_computed += 1
        st.consecutive = 0

    # ---- reporting ---------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0}
