"""Shared layer substrate: norms, RoPE, MLP, MoE, attention blocks.

Pure-functional: params are nested dicts of arrays; every apply fn takes the
config + params explicitly.  Stacked-layer params (leading L axis) are
consumed via ``lax.scan`` by the model drivers for O(1-layer) compile time.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.logical import shard
from repro.models import attention as attn_lib

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B,S,H,D]; positions: [S] or [B,S] (absolute)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[None, :, None] * freqs  # [1,S,half]
    else:
        ang = positions.astype(jnp.float32)[:, :, None] * freqs     # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., :half], x32[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def init_attn(cfg: ModelConfig, key, dtype) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh), dtype),
        "wk": dense_init(ks[1], (d, hkv * dh), dtype),
        "wv": dense_init(ks[2], (d, hkv * dh), dtype),
        "wo": dense_init(ks[3], (hq * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def attn_qkv(cfg: ModelConfig, p: Params, x: jax.Array, positions: jax.Array):
    """Project + RoPE.  Returns q [B,S,Hq,Dh], k,v [B,S,Hkv,Dh]."""
    b, s, _ = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = shard(rope(q, positions, cfg.rope_theta), "batch", None, "heads", None)
    k = shard(rope(k, positions, cfg.rope_theta), "batch", None, "kv_heads", None)
    return q, k, v


def attn_block(cfg: ModelConfig, p: Params, x: jax.Array, *,
               positions: jax.Array, q_offset: int = 0,
               window: int = 0, sink: int = 0, sparsity: float = 0.0,
               kv_override=None, causal: bool = True,
               block_q: int = 512, block_kv: int = 512) -> jax.Array:
    """Self-attention (or cross-attention via kv_override=(k,v))."""
    b, s, _ = x.shape
    q, k, v = attn_qkv(cfg, p, x, positions)
    if kv_override is not None:
        k, v = kv_override
    o = attn_lib.mha(q, k, v, n_kv_heads=cfg.n_kv_heads, causal=causal,
                     q_offset=q_offset, window=window, sink=sink,
                     sparsity=sparsity, block_q=block_q, block_kv=block_kv)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return shard(o @ p["wo"], "batch", "seq_sp", "embed")


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    if cfg.act == "swiglu":
        return {"w_gate": dense_init(ks[0], (d, f), dtype),
                "w_up": dense_init(ks[1], (d, f), dtype),
                "w_down": dense_init(ks[2], (f, d), dtype)}
    return {"wi": dense_init(ks[0], (d, f), dtype),
            "wo": dense_init(ks[1], (f, d), dtype)}


def mlp_block(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = shard(h, "batch", None, "ff")
        return shard(h @ p["w_down"], "batch", "seq_sp", "embed")
    h = jax.nn.gelu(x @ p["wi"])
    h = shard(h, "batch", None, "ff")
    return shard(h @ p["wo"], "batch", "seq_sp", "embed")


# ---------------------------------------------------------------------------
# MoE (capacity-based grouped dispatch; expert-TP sharding by default)
# ---------------------------------------------------------------------------

def _slot_maps(e: int, cap: int, s: int, a_e, slot, a_t, w):
    """Per-slot inverse maps: token index and weight of each (e, c) slot
    (out-of-capacity assignments land on row ``s`` -> dropped)."""
    def one(eg, sg, tg, wg):
        tok_of = jnp.full((e, cap), s, jnp.int32)
        tok_of = tok_of.at[eg, sg].set(tg.astype(jnp.int32), mode="drop")
        w_of = jnp.zeros((e, cap), jnp.float32)
        w_of = w_of.at[eg, sg].set(wg.astype(jnp.float32), mode="drop")
        return tok_of, w_of
    return jax.vmap(one)(a_e, slot, a_t, w)


def _slot_scatter_to_tokens(s: int, buf, tok_of, w_of):
    """Scatter-add expert-slot values back to token space: [B,E,C,D] ->
    [B,S,D].  Under EP (buf expert-sharded) GSPMD reduces a per-TOKEN
    partial — k-times less traffic than gathering per assignment."""
    e, cap, d = buf.shape[1], buf.shape[2], buf.shape[3]

    def one(ob, tokb, wb):
        vals = ob.reshape(e * cap, d) * wb.reshape(-1, 1).astype(ob.dtype)
        y = jnp.zeros((s, d), ob.dtype)
        return y.at[tokb.reshape(-1)].add(vals, mode="drop")
    return jax.vmap(one)(buf, tok_of, w_of)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _moe_dispatch(e: int, cap: int, s: int, x, a_e, slot, a_t, keep):
    """Token->slot dispatch [B,S,D] -> [B,E,C,D] with a TOKEN-granular
    backward: autodiff of the forward scatter would gather the buffer
    cotangent per ASSIGNMENT ([B,S*k,D] through the EP all-reduce); the
    custom bwd scatter-adds per SLOT instead ([B,S,D]).  Capacity masks
    and routing indices are stop-gradient (standard for top-k MoE)."""
    def one(xg, eg, sg, tg, kg):
        buf = jnp.zeros((e, cap) + xg.shape[-1:], xg.dtype)
        vals = xg[tg] * kg[:, None]
        return buf.at[eg, jnp.clip(sg, 0, cap - 1)].add(vals, mode="drop")
    return jax.vmap(one)(x, a_e, slot, a_t, keep)


def _moe_dispatch_fwd(e, cap, s, x, a_e, slot, a_t, keep):
    buf = _moe_dispatch(e, cap, s, x, a_e, slot, a_t, keep)
    tok_of, keep_of = _slot_maps(e, cap, s, a_e, slot, a_t, keep)
    return buf, (tok_of, keep_of, a_e, keep)


def _moe_dispatch_bwd(e, cap, s, res, g):
    import numpy as _np
    tok_of, keep_of, a_e, keep = res
    dx = _slot_scatter_to_tokens(s, g, tok_of, keep_of).astype(keep.dtype)
    zint = _np.zeros(a_e.shape, jax.dtypes.float0)
    return (dx, zint, zint, zint, jnp.zeros(keep.shape, keep.dtype))


_moe_dispatch.defvjp(_moe_dispatch_fwd, _moe_dispatch_bwd)

def init_moe(cfg: ModelConfig, key, dtype) -> Params:
    d, fe, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "we_gate": dense_init(ks[1], (e, d, fe), dtype),
        "we_up": dense_init(ks[2], (e, d, fe), dtype),
        "we_down": dense_init(ks[3], (e, fe, d), dtype),
    }


def moe_capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    return max(1, int(math.ceil(
        tokens_per_group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)))


def moe_block(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Token-choice top-k MoE with per-sequence grouping.

    x: [B,S,D].  Dispatch is a within-group scatter (local under batch=data
    sharding); expert FFN hidden dim is sharded over "model" (expert-TP).
    Returns [B,S,D] plus stores aux loss in ``moe_block.last_aux``.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = moe_capacity(s, cfg)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                   # [B,S,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                          # [E]
    ce = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1, 2))
    moe_block.last_aux = e * jnp.sum(me * ce)

    # ---- dispatch ---------------------------------------------------------
    a_e = idx.reshape(b, s * k)                                # expert of asgn
    a_g = gate_vals.reshape(b, s * k)
    a_t = jnp.broadcast_to(jnp.repeat(jnp.arange(s), k)[None, :],
                           (b, s * k))                         # token of asgn
    oh = jax.nn.one_hot(a_e, e, dtype=jnp.int32)               # [B,S*k,E]
    slot = jnp.take_along_axis(jnp.cumsum(oh, axis=1) - 1,
                               a_e[..., None], axis=-1)[..., 0]
    keep = (slot < cap).astype(x.dtype)                        # capacity drop

    buf = _moe_dispatch(e, cap, s, x, a_e, slot, a_t, keep)    # [B,E,C,D]
    tok_of, gate_of = _slot_maps(e, cap, s, a_e, slot, a_t,
                                 a_g * keep.astype(a_g.dtype))
    # expert-TP (default): dispatch buffer replicated over "model", the
    # expert hidden dim sharded.  EP (cfg.moe_ep): the EXPERT dim sharded
    # over "model" — GSPMD emits the all-to-all dispatch/return.
    buf = shard(buf, "batch", "experts", None, None)

    # ---- expert FFN ---------------------------------------------------------
    hg = jnp.einsum("becd,edf->becf", buf, p["we_gate"])
    hu = jnp.einsum("becd,edf->becf", buf, p["we_up"])
    h = shard(jax.nn.silu(hg) * hu, "batch", "experts", None, "expert_ff")
    out_buf = jnp.einsum("becf,efd->becd", h, p["we_down"])
    out_buf = shard(out_buf, "batch", "experts", None, "embed")

    # ---- combine: scatter-add from expert slots back to tokens -------------
    # (gathering per-ASSIGNMENT would move [B, S*k, D] through the EP
    #  all-reduce; scattering per-SLOT moves only [B, S, D] — the return
    #  path is per-token, k-times smaller)
    y = _slot_scatter_to_tokens(s, out_buf, tok_of, gate_of)
    return shard(y, "batch", "seq_sp", "embed")


moe_block.last_aux = 0.0


def ffn_block(cfg: ModelConfig, p: Params, x: jax.Array,
              use_moe: bool) -> jax.Array:
    return moe_block(cfg, p, x) if use_moe else mlp_block(cfg, p, x)
