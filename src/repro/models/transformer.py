"""Dense / MoE / VLM decoder-only transformer with train, prefill and
decode paths.  Layers are scanned (stacked params, O(1-layer) HLO).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.logical import shard
from repro.models import kvcache
from repro.models import layers as L
from repro.models.attention import decode_attention

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = L.split_keys(key, 4)
    p = {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attn(cfg, ks[0], dtype),
    }
    if cfg.n_experts:
        p["moe"] = L.init_moe(cfg, ks[1], dtype)
    else:
        p["mlp"] = L.init_mlp(cfg, ks[2], dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(cfg, k, dtype))(layer_keys)
    p: Params = {
        "embed": L.dense_init(k_embed, (cfg.padded_vocab, cfg.d_model), dtype,
                              scale=0.02),
        "layers": stacked,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab),
                                    dtype)
    return p


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, p: Params, tokens: jax.Array,
           img_embeds: Optional[jax.Array]) -> jax.Array:
    h = jnp.take(p["embed"], tokens, axis=0)
    if img_embeds is not None:                       # VLM: prepend patch stub
        h = jnp.concatenate([img_embeds.astype(h.dtype), h], axis=1)
    return shard(h, "batch", "seq_sp", "embed")


def _unembed(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    h = L.rmsnorm(h, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return shard(h @ w, "batch", None, "vocab")


def _layer_fwd(cfg: ModelConfig, lp: Params, h: jax.Array, *,
               positions: jax.Array, q_offset: int = 0,
               window: int = 0, sink: int = 0, sparsity: float = 0.0,
               block_q: int = 512) -> Tuple[jax.Array, jax.Array]:
    a_in = L.rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
    h = h + L.attn_block(cfg, lp["attn"], a_in, positions=positions,
                         q_offset=q_offset, window=window, sink=sink,
                         sparsity=sparsity, block_q=block_q)
    f_in = L.rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
    if cfg.n_experts:
        h = h + L.moe_block(cfg, lp["moe"], f_in)
        aux = L.moe_block.last_aux
    else:
        h = h + L.mlp_block(cfg, lp["mlp"], f_in)
        aux = jnp.zeros((), jnp.float32)
    return h, aux


def forward(cfg: ModelConfig, p: Params, tokens: jax.Array, *,
            img_embeds: Optional[jax.Array] = None,
            window: int = 0, sink: int = 0, sparsity: float = 0.0,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (hidden [B,S,D], moe_aux scalar)."""
    h = _embed(cfg, p, tokens, img_embeds)
    positions = jnp.arange(h.shape[1])
    window = window or cfg.attn_window
    sink = sink or cfg.attn_sink

    def body(carry, lp):
        hh, aux = carry
        hh, a = _layer_fwd(cfg, lp, hh, positions=positions, window=window,
                           sink=sink, sparsity=sparsity)
        if cfg.bf16_backward:
            from repro.distributed.precision import bf16_cotangent
            hh = bf16_cotangent(hh)
        return (hh, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (h, aux), _ = jax.lax.scan(body_fn, (h, jnp.zeros((), jnp.float32)),
                               p["layers"])
    return h, aux


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def chunked_ce(logits_fn, h: jax.Array, targets: jax.Array,
               mask: Optional[jax.Array], block: int = 1024) -> jax.Array:
    """Cross-entropy computed in S-blocks to bound the logits working set."""
    b, s, _ = h.shape
    block = min(block, s)
    n = s // block
    rem = s - n * block

    def ce_block(h_blk, t_blk, m_blk):
        logits = logits_fn(h_blk).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_blk[..., None], axis=-1)[..., 0]
        losses = (lse - gold) * m_blk
        return jnp.sum(losses), jnp.sum(m_blk)

    def body(carry, xs):
        tot, cnt = carry
        s_, c_ = ce_block(*xs)
        return (tot + s_, cnt + c_), None

    hb = h[:, :n * block].reshape(b, n, block, -1).swapaxes(0, 1)
    tb = targets[:, :n * block].reshape(b, n, block).swapaxes(0, 1)
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    mb = mask[:, :n * block].reshape(b, n, block).swapaxes(0, 1)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (hb, tb, mb))
    if rem:
        s_, c_ = ce_block(h[:, n * block:], targets[:, n * block:],
                          mask[:, n * block:])
        tot, cnt = tot + s_, cnt + c_
    return tot / jnp.maximum(cnt, 1.0)


def train_loss(cfg: ModelConfig, p: Params, batch: Dict[str, jax.Array],
               aux_weight: float = 0.01) -> jax.Array:
    img = batch.get("img_embeds")
    h, aux = forward(cfg, p, batch["tokens"], img_embeds=img, remat=True)
    if img is not None:                 # loss only on text positions
        h = h[:, img.shape[1]:]
    w = (lambda x: x @ (p["embed"].T if cfg.tie_embeddings else p["lm_head"]))
    loss = chunked_ce(lambda hb: w(L.rmsnorm(hb, p["final_norm"],
                                             cfg.norm_eps)),
                      h, batch["targets"], batch.get("loss_mask"))
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    cap = kvcache.capacity(max_len, cfg.attn_window, cfg.attn_sink)
    kv_dtype = jnp.dtype(cfg.kv_dtype)
    shp = (cfg.n_layers, batch, cap, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shp, kv_dtype), "v": jnp.zeros(shp, kv_dtype)}


def prefill(cfg: ModelConfig, p: Params, tokens: jax.Array, *,
            img_embeds: Optional[jax.Array] = None,
            max_len: Optional[int] = None,
            window: int = 0, sink: int = 0, sparsity: float = 0.0):
    """Returns (last-position logits [B,V], cache, cache_len [B])."""
    h = _embed(cfg, p, tokens, img_embeds)
    b, s, _ = h.shape
    max_len = max_len or s
    window = window or cfg.attn_window
    sink = sink or cfg.attn_sink
    cap = kvcache.capacity(max_len, window, sink)
    positions = jnp.arange(s)
    kv_dtype = jnp.dtype(cfg.kv_dtype)

    def body(h, lp):
        a_in = L.rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.attn_qkv(cfg, lp["attn"], a_in, positions)
        from repro.models.attention import mha
        o = mha(q, k, v, n_kv_heads=cfg.n_kv_heads, causal=True,
                window=window, sink=sink, sparsity=sparsity)
        o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
        h = h + shard(o @ lp["attn"]["wo"], "batch", "seq_sp", "embed")
        f_in = L.rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.n_experts:
            h = h + L.moe_block(cfg, lp["moe"], f_in)
        else:
            h = h + L.mlp_block(cfg, lp["mlp"], f_in)
        k_c = kvcache.place_prefill(k, cap, sink, window).astype(kv_dtype)
        v_c = kvcache.place_prefill(v, cap, sink, window).astype(kv_dtype)
        k_c = shard(k_c, "batch", "seq_kv", "kv_heads", None)
        v_c = shard(v_c, "batch", "seq_kv", "kv_heads", None)
        return h, {"k": k_c, "v": v_c}

    h, cache = jax.lax.scan(body, h, p["layers"])
    logits = _unembed(cfg, p, h[:, -1:])[:, 0]
    cache_len = jnp.full((b,), s, jnp.int32)
    return logits, cache, cache_len


def decode_step(cfg: ModelConfig, p: Params, cache: Dict[str, Any],
                token: jax.Array, pos: jax.Array, *,
                window: int = 0, sink: int = 0):
    """One decode step.  token [B,1], pos [B] (write position = current len).

    With a ring cache (cap == sink + window < seq_len) eviction replaces
    masking; with a full-length cache the window mask applies.
    Returns (logits [B,V], new cache).
    """
    h = _embed(cfg, p, token, None)
    b = token.shape[0]
    positions = pos[:, None]
    window = window or cfg.attn_window
    sink = sink or cfg.attn_sink
    cap = cache["k"].shape[2]
    ring_mode = bool(window) and cap == sink + window
    dest = kvcache.ring_dest(pos, cap, sink) if ring_mode else pos

    def body(h, xs):
        lp, kc, vc = xs
        a_in = L.rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.attn_qkv(cfg, lp["attn"], a_in, positions)
        kc = kvcache.write_token(kc, k, dest)
        vc = kvcache.write_token(vc, v, dest)
        o = decode_attention(q, kc, vc, n_kv_heads=cfg.n_kv_heads,
                             cache_len=kvcache.n_valid(pos, cap),
                             window=0 if ring_mode else window,
                             sink=sink)
        o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
        h = h + o @ lp["attn"]["wo"]
        f_in = L.rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.n_experts:
            h = h + L.moe_block(cfg, lp["moe"], f_in)
        else:
            h = h + L.mlp_block(cfg, lp["mlp"], f_in)
        return h, {"k": kc, "v": vc}

    h, cache = jax.lax.scan(body, h, (p["layers"], cache["k"], cache["v"]))
    logits = _unembed(cfg, p, h)[:, 0]
    return logits, cache
