"""Whisper-style encoder-decoder.

Per the assignment the conv/mel frontend is a STUB: the encoder consumes
precomputed frame embeddings [B, n_frontend_tokens, d_model].  The decoder
is a causal transformer with cross-attention over encoder states; decode
shapes exercise the decoder (self-attn KV cache of seq_len + precomputed
cross-attn KV).  RoPE replaces Whisper's learned positional tables (noted
in DESIGN.md SS3) so arbitrary assigned sequence lengths need no tables.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.logical import shard
from repro.models import layers as L
from repro.models.attention import decode_attention, mha

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_enc_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = L.split_keys(key, 2)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attn(cfg, ks[0], dtype),
        "mlp": L.init_mlp(cfg, ks[1], dtype),
    }


def _init_dec_layer(cfg: ModelConfig, key, dtype) -> Params:
    ks = L.split_keys(key, 3)
    return {
        "attn_norm": jnp.ones((cfg.d_model,), dtype),
        "cross_norm": jnp.ones((cfg.d_model,), dtype),
        "mlp_norm": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attn(cfg, ks[0], dtype),
        "cross": L.init_attn(cfg, ks[1], dtype),
        "mlp": L.init_mlp(cfg, ks[2], dtype),
    }


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_enc, k_dec, k_head = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_dec_layers)
    return {
        "embed": L.dense_init(k_embed, (cfg.padded_vocab, cfg.d_model), dtype,
                              scale=0.02),
        "enc_layers": jax.vmap(
            lambda k: _init_enc_layer(cfg, k, dtype))(enc_keys),
        "dec_layers": jax.vmap(
            lambda k: _init_dec_layer(cfg, k, dtype))(dec_keys),
        "enc_final_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": L.dense_init(k_head, (cfg.d_model, cfg.padded_vocab), dtype),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, p: Params, audio_embeds: jax.Array) -> jax.Array:
    """audio_embeds [B, T_a, D] (stub frontend output) -> encoder states."""
    h = shard(audio_embeds.astype(jnp.dtype(cfg.param_dtype)),
              "batch", None, "embed")
    positions = jnp.arange(h.shape[1])

    def body(hh, lp):
        a_in = L.rmsnorm(hh, lp["attn_norm"], cfg.norm_eps)
        hh = hh + L.attn_block(cfg, lp["attn"], a_in, positions=positions,
                               causal=False)
        f_in = L.rmsnorm(hh, lp["mlp_norm"], cfg.norm_eps)
        hh = hh + L.mlp_block(cfg, lp["mlp"], f_in)
        return hh, None

    h, _ = jax.lax.scan(body, h, p["enc_layers"])
    return L.rmsnorm(h, p["enc_final_norm"], cfg.norm_eps)


def _cross_kv(cfg: ModelConfig, lp: Params, enc: jax.Array):
    """Precompute cross-attention K/V from encoder states (no RoPE)."""
    b, t, _ = enc.shape
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc @ lp["wk"]).reshape(b, t, hkv, dh)
    v = (enc @ lp["wv"]).reshape(b, t, hkv, dh)
    if cfg.qkv_bias:
        k = k + lp["bk"].reshape(hkv, dh)
        v = v + lp["bv"].reshape(hkv, dh)
    return k, v


def _cross_attn(cfg: ModelConfig, lp: Params, x: jax.Array,
                k: jax.Array, v: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    hq, dh = cfg.n_heads, cfg.head_dim
    q = (x @ lp["wq"]).reshape(b, s, hq, dh)
    if cfg.qkv_bias:
        q = q + lp["bq"].reshape(hq, dh)
    o = mha(q, k, v, n_kv_heads=cfg.n_kv_heads, causal=False)
    return o.reshape(b, s, hq * dh) @ lp["wo"]


# ---------------------------------------------------------------------------
# decoder: train / prefill / decode
# ---------------------------------------------------------------------------

def _dec_layer(cfg: ModelConfig, lp: Params, h: jax.Array, *,
               positions: jax.Array, cross_k, cross_v,
               sparsity: float = 0.0, window: int = 0, sink: int = 0):
    a_in = L.rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
    h = h + L.attn_block(cfg, lp["attn"], a_in, positions=positions,
                         window=window, sink=sink, sparsity=sparsity)
    c_in = L.rmsnorm(h, lp["cross_norm"], cfg.norm_eps)
    h = h + _cross_attn(cfg, lp["cross"], c_in, cross_k, cross_v)
    f_in = L.rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
    return h + L.mlp_block(cfg, lp["mlp"], f_in)


def forward(cfg: ModelConfig, p: Params, tokens: jax.Array,
            audio_embeds: jax.Array, *, sparsity: float = 0.0,
            remat: bool = False) -> jax.Array:
    enc = encode(cfg, p, audio_embeds)
    h = shard(jnp.take(p["embed"], tokens, axis=0), "batch", None, "embed")
    positions = jnp.arange(h.shape[1])

    def body(hh, lp):
        ck, cv = _cross_kv(cfg, lp["cross"], enc)
        return _dec_layer(cfg, lp, hh, positions=positions,
                          cross_k=ck, cross_v=cv, sparsity=sparsity), None

    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, h, p["dec_layers"])
    return h


def _unembed(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    h = L.rmsnorm(h, p["final_norm"], cfg.norm_eps)
    return shard(h @ p["lm_head"], "batch", None, "vocab")


def train_loss(cfg: ModelConfig, p: Params,
               batch: Dict[str, jax.Array]) -> jax.Array:
    from repro.models.transformer import chunked_ce
    h = forward(cfg, p, batch["tokens"], batch["audio_embeds"], remat=True)
    return chunked_ce(
        lambda hb: L.rmsnorm(hb, p["final_norm"], cfg.norm_eps) @ p["lm_head"],
        h, batch["targets"], batch.get("loss_mask"))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: Optional[int] = None) -> Dict[str, Any]:
    enc_len = enc_len or cfg.n_frontend_tokens
    kv_dtype = jnp.dtype(cfg.kv_dtype)
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((cfg.n_dec_layers, batch, max_len, hkv, dh), kv_dtype),
        "v": jnp.zeros((cfg.n_dec_layers, batch, max_len, hkv, dh), kv_dtype),
        "ck": jnp.zeros((cfg.n_dec_layers, batch, enc_len, hkv, dh), kv_dtype),
        "cv": jnp.zeros((cfg.n_dec_layers, batch, enc_len, hkv, dh), kv_dtype),
    }


def prefill(cfg: ModelConfig, p: Params, tokens: jax.Array, *,
            audio_embeds: jax.Array, max_len: Optional[int] = None,
            sparsity: float = 0.0, **_):
    """Returns (last logits [B,V], cache {self k/v, cross k/v}, len [B])."""
    b, s = tokens.shape
    max_len = max_len or s
    enc = encode(cfg, p, audio_embeds)
    h = jnp.take(p["embed"], tokens, axis=0)
    positions = jnp.arange(s)
    kv_dtype = jnp.dtype(cfg.kv_dtype)

    def body(hh, lp):
        ck, cv = _cross_kv(cfg, lp["cross"], enc)
        a_in = L.rmsnorm(hh, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.attn_qkv(cfg, lp["attn"], a_in, positions)
        o = mha(q, k, v, n_kv_heads=cfg.n_kv_heads, causal=True,
                sparsity=sparsity)
        hh = hh + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        c_in = L.rmsnorm(hh, lp["cross_norm"], cfg.norm_eps)
        hh = hh + _cross_attn(cfg, lp["cross"], c_in, ck, cv)
        f_in = L.rmsnorm(hh, lp["mlp_norm"], cfg.norm_eps)
        hh = hh + L.mlp_block(cfg, lp["mlp"], f_in)
        pad = max_len - s
        k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kv_dtype)
        v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(kv_dtype)
        return hh, {"k": shard(k_c, "batch", "seq_kv", "kv_heads", None),
                    "v": shard(v_c, "batch", "seq_kv", "kv_heads", None),
                    "ck": ck.astype(kv_dtype), "cv": cv.astype(kv_dtype)}

    h, cache = jax.lax.scan(body, h, p["dec_layers"])
    logits = _unembed(cfg, p, h[:, -1:])[:, 0]
    return logits, cache, jnp.full((b,), s, jnp.int32)


def decode_step(cfg: ModelConfig, p: Params, cache: Dict[str, Any],
                token: jax.Array, pos: jax.Array, **_):
    """One decoder step.  token [B,1], pos [B].  Returns (logits, cache)."""
    b = token.shape[0]
    h = jnp.take(p["embed"], token, axis=0)
    positions = pos[:, None]

    def write(c, new):
        return jax.vmap(lambda cb, nb, pb: jax.lax.dynamic_update_slice(
            cb, nb.astype(cb.dtype), (pb, 0, 0)))(c, new, pos)

    def body(hh, xs):
        lp, pc = xs
        a_in = L.rmsnorm(hh, lp["attn_norm"], cfg.norm_eps)
        q, k, v = L.attn_qkv(cfg, lp["attn"], a_in, positions)
        kc, vc = write(pc["k"], k), write(pc["v"], v)
        o = decode_attention(q, kc, vc, n_kv_heads=cfg.n_kv_heads,
                             cache_len=pos + 1)
        hh = hh + o.reshape(b, 1, -1) @ lp["attn"]["wo"]
        c_in = L.rmsnorm(hh, lp["cross_norm"], cfg.norm_eps)
        hh = hh + _cross_attn(cfg, lp["cross"], c_in, pc["ck"], pc["cv"])
        f_in = L.rmsnorm(hh, lp["mlp_norm"], cfg.norm_eps)
        hh = hh + L.mlp_block(cfg, lp["mlp"], f_in)
        return hh, {"k": kc, "v": vc, "ck": pc["ck"], "cv": pc["cv"]}

    h, cache = jax.lax.scan(body, h, (p["dec_layers"], cache))
    return _unembed(cfg, p, h)[:, 0], cache
