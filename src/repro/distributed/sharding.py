"""Parameter / activation sharding rules for the production meshes.

Meshes (launch/mesh.py): single-pod ``(data=16, model=16)`` = 256 chips;
multi-pod ``(pod=2, data=16, model=16)`` = 512 chips.

Training layout (DESIGN.md SS5):
    TP   ("model"): attention heads / FFN hidden / vocab sharded
    FSDP ("data"):  the non-TP dim of every large weight sharded; XLA
                    all-gathers per layer inside the scan (prefetchable)
    DP   ("pod" x "data"): batch; cross-pod traffic is gradient-only
Optimizer state follows the parameter layout (ZeRO: sharded over both
mesh axes; nothing is replicated but small vectors).

Serving layout: weights replicated over "data" (gathers would sit on the
decode critical path), TP over "model"; the KV cache shards batch over
"data" and KV heads over "model"; ``long_500k`` (batch=1) shards the
cache SEQUENCE over "data" instead — GSPMD then emits the
flash-decoding-style partial-softmax combine.

Rules are name-based (t5x-style): the LAST path component of each param
selects a spec for its trailing dims; stacked-layer leading dims (scan)
get None.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "data"
TP = "model"

# trailing-dims spec per parameter name; leading (stacked/scan) dims -> None
_TRAIN_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # transformer attention
    "wq": (FSDP, TP), "wk": (FSDP, TP), "wv": (FSDP, TP), "wo": (TP, FSDP),
    "bq": (TP,), "bk": (TP,), "bv": (TP,),
    # dense MLP
    "w_gate": (FSDP, TP), "w_up": (FSDP, TP), "w_down": (TP, FSDP),
    "wi": (FSDP, TP),
    # MoE (expert-TP: expert hidden over TP, expert dim unsharded)
    "router": (FSDP, None),
    "we_gate": (None, FSDP, TP), "we_up": (None, FSDP, TP),
    "we_down": (None, TP, FSDP),
    # embeddings / heads
    "embed": (TP, FSDP), "lm_head": (FSDP, TP),
    # mamba
    "wz": (FSDP, TP), "wx": (FSDP, TP),
    "wB": (FSDP, None), "wC": (FSDP, None), "wdt": (FSDP, None),
    "out": (TP, FSDP), "conv_w": (TP, None), "conv_b": (TP,),
    "A_log": (None,), "D": (None,), "dt_bias": (None,), "norm_w": (TP,),
    # AR-DiT
    "in_proj": (None, TP), "cond_proj": (FSDP, TP),
    "t_mlp1": (None, TP), "t_mlp2": (TP, FSDP),
    "mod": (FSDP, TP), "mod_b": (None,),
    "final_mod": (FSDP, TP), "out_proj": (TP, None),
}

_SERVE_OVERRIDES = {k: tuple(None if a == FSDP else a for a in v)
                    for k, v in _TRAIN_PARAM_RULES.items()}

# EP variant: expert dim over "model", expert hidden unsharded
_EP_RULES = {
    "we_gate": (TP, FSDP, None), "we_up": (TP, FSDP, None),
    "we_down": (TP, None, FSDP),
}
_EP_SERVE_RULES = {k: tuple(None if a == FSDP else a for a in v)
                   for k, v in _EP_RULES.items()}


ALL = ("data", "model")        # combined 256-way axis for the zero3 layout


def param_pspec(path: Sequence[str], ndim: int, *,
                serve: bool = False, ep: bool = False,
                layout: str = "tp_fsdp") -> P:
    rules = _SERVE_OVERRIDES if serve else _TRAIN_PARAM_RULES
    name = path[-1]
    spec = rules.get(name)
    if ep and name in _EP_RULES:
        spec = (_EP_SERVE_RULES if serve else _EP_RULES)[name]
    if spec is None:
        spec = (None,) * ndim                  # norms & misc: replicated
    if layout == "zero3" and not serve:
        # ZeRO-3: no tensor parallelism — shard the first previously-
        # sharded dim of each weight over BOTH axes (256-way), rest
        # replicated; XLA all-gathers each layer's weights on use.
        first = next((i for i, a in enumerate(spec) if a is not None),
                     None)
        spec = tuple(ALL if i == first else None
                     for i in range(len(spec)))
    assert len(spec) <= ndim, (path, ndim, spec)
    return P(*((None,) * (ndim - len(spec)) + tuple(spec)))


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif hasattr(k, "key"):
            out.append(str(k.key))
        else:
            out.append(str(k))
    return tuple(out)


def param_shardings(param_tree: Any, mesh: Mesh, *,
                    serve: bool = False, ep: bool = False,
                    layout: str = "tp_fsdp") -> Any:
    """NamedSharding pytree matching ``param_tree`` (specs or arrays)."""
    def spec_of(path, leaf):
        return NamedSharding(mesh, param_pspec(
            _path_names(path), np.ndim(leaf) or len(leaf.shape),
            serve=serve, ep=ep, layout=layout))
    return jax.tree_util.tree_map_with_path(spec_of, param_tree)


# ---------------------------------------------------------------------------
# activation logical-axis rules (consumed by distributed.logical.shard)
# ---------------------------------------------------------------------------

def train_rules(mesh: Mesh, *, ep: bool = False,
                layout: str = "tp_fsdp") -> Dict[str, Any]:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if layout == "zero3":
        # batch over EVERY mesh axis; no tensor-parallel activation axes
        batch_axes = tuple(a for a in ("pod", "data", "model")
                           if a in mesh.axis_names)
        return {"batch": batch_axes, "heads": None, "kv_heads": None,
                "ff": None, "inner": None, "experts": None,
                "expert_ff": None, "vocab": None, "embed": None,
                "seq_sp": None, "seq_kv": None}
    return {
        "batch": batch_axes,
        "heads": TP, "kv_heads": TP,
        "ff": TP, "inner": TP,
        "experts": TP if ep else None,
        "expert_ff": None if ep else TP,
        "vocab": TP,
        "embed": None, "seq_sp": None, "seq_kv": None,
    }


def serve_rules(mesh: Mesh, *, shard_seq: bool = False,
                ep: bool = False) -> Dict[str, Any]:
    """``shard_seq``: long-context decode (batch=1) — KV sequence over
    "data" gives the flash-decoding partial-softmax combine."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {
        "batch": None if shard_seq else batch_axes,
        "heads": TP, "kv_heads": TP,
        "ff": TP, "inner": TP,
        "experts": TP if ep else None,
        "expert_ff": None if ep else TP,
        "vocab": TP,
        "embed": None, "seq_sp": None,
        "seq_kv": batch_axes if shard_seq else None,
    }


def batch_pspec(mesh: Mesh, layout: str = "tp_fsdp") -> P:
    names = ("pod", "data", "model") if layout == "zero3" else \
        ("pod", "data")
    batch_axes = tuple(a for a in names if a in mesh.axis_names)
    return P(batch_axes)


def cache_pspec(mesh: Mesh, cache_leaf_ndim: int, *,
                shard_seq: bool = False) -> P:
    """Decode-cache sharding: [L, B, S, H, D]-shaped leaves (or SSM/conv
    state shapes).  Batch over data (or seq over data for long-context),
    KV heads over model where the rank allows."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if cache_leaf_ndim == 5:     # [L,B,S,H,D] attention KV
        if shard_seq:
            return P(None, None, batch_axes, TP, None)
        return P(None, batch_axes, None, TP, None)
    if cache_leaf_ndim == 4:     # [L,B,*,*] ssm conv state etc.
        return P(None, batch_axes, None, None)
    if cache_leaf_ndim == 3:
        return P(None, batch_axes, None)
    return P(*([None] * cache_leaf_ndim))
