"""Logical-axis sharding: models annotate tensors with *logical* axis names;
a rules table maps logical names to mesh axes.  Outside a mesh context the
annotations are no-ops, so the same model code runs on 1 CPU device and on a
512-chip mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


def _current() -> Optional[Tuple[Mesh, Dict[str, MeshAxis]]]:
    return getattr(_STATE, "env", None)


@contextlib.contextmanager
def logical_axis_rules(mesh: Mesh, rules: Dict[str, MeshAxis]):
    """Activate a mesh + logical->mesh axis mapping for model tracing."""
    prev = _current()
    _STATE.env = (mesh, dict(rules))
    try:
        yield
    finally:
        _STATE.env = prev


def resolve_spec(axes: Sequence[Optional[str]],
                 rules: Dict[str, MeshAxis]) -> P:
    """Map logical axis names to a PartitionSpec, dropping duplicate mesh
    axes (a mesh axis may shard at most one tensor dimension)."""
    used = set()
    out = []
    for name in axes:
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        kept = tuple(a for a in mesh_axes if a not in used)
        used.update(kept)
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(kept)
    return P(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with logical axes (no-op without an active mesh)."""
    env = _current()
    if env is None:
        return x
    mesh, rules = env
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} array")
    spec = resolve_spec(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_for(axes: Sequence[Optional[str]],
             mesh: Mesh, rules: Dict[str, MeshAxis]) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(axes, rules))
