"""Precision levers for the perf hillclimb (beyond-paper optimizations).

``bf16_cotangent``: identity in the forward pass; rounds the cotangent
to bf16 (and back to its original dtype) in the backward pass.  Inserted
at layer boundaries it forces backward activation-gradients — and the
tensor-parallel all-reduces that carry them — down to bf16, halving the
dominant collective and memory-traffic terms of the training roofline.
The fp32 master math inside the optimizer is unaffected; this mirrors
the bf16-gradient configurations of Megatron/MaxText-class systems.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def bf16_cotangent(x: jax.Array) -> jax.Array:
    return x


def _fwd(x):
    return x, None


def _bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


bf16_cotangent.defvjp(_fwd, _bwd)
