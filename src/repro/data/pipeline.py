"""Sharding-aware synthetic data pipeline.

Deterministic per (seed, step, shard): every data-parallel host generates
exactly its own slice of the global batch with no coordination, and the
SAME global batch is produced for any DP layout — so elastic rescale or
restart-from-checkpoint replays identical data (bitwise), which is what
makes the fault-tolerance story testable.  Token streams are Zipf-ish
synthetic text; AR-DiT batches are unit-Gaussian latents + cond stubs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2


def _rng_for(seed: int, step: int, row: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, row]))


def _tokens_row(cfg: ModelConfig, dcfg: DataConfig, step: int, row: int,
                seq_len: int) -> np.ndarray:
    rng = _rng_for(dcfg.seed, step, row)
    v = max(cfg.vocab_size, 4)
    toks = rng.zipf(dcfg.zipf_a, size=seq_len + 1).astype(np.int64)
    return np.clip(toks, 1, v - 1).astype(np.int32)


def global_batch(cfg: ModelConfig, shape: ShapeConfig, step: int, *,
                 dcfg: DataConfig = DataConfig(),
                 rows: Optional[range] = None) -> Dict[str, Any]:
    """Build (a slice of) the global train batch for ``step``.

    ``rows``: which global-batch rows to materialize (a DP shard asks for
    its own range); defaults to all rows.
    """
    rows = rows if rows is not None else range(shape.global_batch)
    if cfg.family == "ardit":
        from repro.models import ardit as A
        tc = A.chunk_tokens(cfg)
        n_chunks = max(1, shape.seq_len // tc)
        rng = _rng_for(dcfg.seed, step, 10**6)
        b = len(rows)
        return {
            "latents": rng.standard_normal(
                (b, n_chunks, tc, A.LATENT_CH)).astype(np.float32),
            "cond": rng.standard_normal(
                (b, A.COND_TOKENS, cfg.d_model)).astype(np.float32),
            "t": rng.uniform(0.05, 0.95, (b, n_chunks)).astype(np.float32),
            "noise": rng.standard_normal(
                (b, n_chunks, tc, A.LATENT_CH)).astype(np.float32),
        }
    s_text = shape.seq_len
    if cfg.family == "vlm":
        s_text = shape.seq_len - cfg.n_frontend_tokens
    toks = np.stack([_tokens_row(cfg, dcfg, step, r, s_text) for r in rows])
    batch: Dict[str, Any] = {"tokens": toks[:, :-1],
                             "targets": toks[:, 1:]}
    if cfg.family == "vlm":
        rng = _rng_for(dcfg.seed, step, 10**6 + 1)
        batch["img_embeds"] = rng.standard_normal(
            (len(rows), cfg.n_frontend_tokens, cfg.d_model)).astype(
                np.float32) * 0.02
    if cfg.family == "encdec":
        rng = _rng_for(dcfg.seed, step, 10**6 + 2)
        batch["audio_embeds"] = rng.standard_normal(
            (len(rows), cfg.n_frontend_tokens, cfg.d_model)).astype(
                np.float32) * 0.02
    return batch


def shard_rows(global_batch_size: int, dp_rank: int,
               dp_size: int) -> range:
    per = global_batch_size // dp_size
    return range(dp_rank * per, (dp_rank + 1) * per)


def batches(cfg: ModelConfig, shape: ShapeConfig, *,
            start_step: int = 0, dcfg: DataConfig = DataConfig(),
            dp_rank: int = 0, dp_size: int = 1) -> Iterator[Dict[str, Any]]:
    step = start_step
    while True:
        rows = shard_rows(shape.global_batch, dp_rank, dp_size)
        yield global_batch(cfg, shape, step, dcfg=dcfg, rows=rows)
        step += 1
