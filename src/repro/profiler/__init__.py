from repro.profiler.profiles import (  # noqa: F401
    ChunkProfile, ModelProfile, get_profile,
)
