"""Offline profiler: latency / quality surfaces per fidelity configuration.

The paper profiles every candidate configuration offline (App. A): average
per-chunk latency L (ms) and VBench quality Q per config.  On real
hardware this is a measurement pass; in this repo the latency surface is
an analytic cost model calibrated to the paper's operating points (a
Self-Forcing-class 1.3B AR-DiT at 480p generates a 3-latent-frame chunk
in ~0.72 s at the highest-quality config on one H100 — just inside the
16 fps real-time budget of 0.75 s/chunk), and the quality surface is a
deterministic response model reproducing App. A's frontier shape:

    latency(cfg) = S * (t_fixed + t_mlp*q(Q) + t_attn*vis(W)*(1-rho)*q(Q))
    quality(cfg) = q_max - a_S(4-S)^1.6 - a_r*rho^2.5*vis(W)^0.5
                   - a_W*(1 - vis(W))^1.4 - a_Q*[fp8] - interactions

Both surfaces are exposed through ``ModelProfile`` so BMPR (SS5.2), the
service-credit estimator (Eq. 1), and the cluster simulator read one
consistent timing prior — exactly the role the paper's offline profiler
plays.  Constants live here, with their derivations, so swapping in real
measurements is a one-file change.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

from repro.core.fidelity import FidelityConfig, candidate_space

# -- timing constants (seconds), per H100-class worker, 480p, 3-frame chunk --
# Derivation: the highest-quality reference (S=4, rho=0, W=7, bf16)
# lands at 0.72 s/chunk — JUST inside the 0.75 s playout budget, matching
# Self-Forcing's ~17 fps single-GPU rate.  A solo stream is sustainable
# at top fidelity; pressure comes from worker SHARING (two streams on a
# worker run at an effective 1.44 s cadence and bleed ~0.7 s of slack per
# chunk), which is what slack-driven reallocation + BMPR absorb and
# slack-blind baselines do not (Fig. 15's URGENT/RELAXED imbalance).
# Per-step split: fixed overhead 40 ms, MLP+projections 90 ms,
# full-window attention 50 ms; fp8 keeps tensor-core paths ~1.6x faster
# on the quantizable share (SageAttention2 reports 1.6-2.1x).
T_FIXED = 0.040
T_MLP = 0.090
T_ATTN = 0.050
FP8_FACTOR = 0.625
W_MAX = 7

# -- quality constants (VBench points, 0-100) --------------------------------
# q_max matches the paper's reported ~81.1 VBench for Causal-Forcing; knob
# penalties are shaped so the 90-config surface spans ~6 VBench points and
# the median (the paper's global quality floor) sits ~1.2 under q_max.
Q_MAX = {"causal-forcing": 81.3, "self-forcing": 80.9}
A_S = 0.55
A_RHO = 2.6
A_W = 1.1
A_Q = 0.35
A_INT = 0.8          # rho x low-S interaction (fewer steps amplify sparsity)

# -- step cache (AdaCache-style residual reuse, models/stepcache.py) ----------
# Expected fraction of *cacheable* denoise steps (steps 1..S-1 of a
# chunk; step 0 and the clean pass always compute) that reuse the cached
# velocity on generic content.  Conservative allows at most one
# consecutive reuse under a tight residual threshold; aggressive allows
# two under a loose one.  Calibration (``fit_cache_speedups``) replaces
# the analytic factor with measured on/off latency ratios once a real
# session has observed both.  Quality penalties (VBench points) follow
# AdaCache's report that residual-gated reuse costs little on stable
# content; aggressive pays visibly more.
STEP_CACHE_HIT_RATE = {"off": 0.0, "conservative": 0.25, "aggressive": 0.5}
A_CACHE = {"off": 0.0, "conservative": 0.18, "aggressive": 0.5}

# -- per-model step-cost multipliers (heterogeneous co-serving) ---------------
# Relative per-chunk compute vs the Wan-1.3B AR-DiT reference.  The two
# paper columns share that backbone (1.0 — multiplying by 1.0 is skipped,
# keeping single-model latencies bit-identical).  The other registry
# families carry analytic priors from their arithmetic intensity — a
# Mamba-2 scan is cheap per token, a top-k MoE activates a parameter
# slice far larger than a dense 1.3B — consumed by the simulator's
# per-stream step cost and by placement weighting (``Worker.load``),
# never by the live jitted path (which measures its own EMAs).
MODEL_COST: Dict[str, float] = {
    "causal-forcing": 1.0,
    "self-forcing": 1.0,
    "mamba2-780m": 0.35,
    "minicpm-2b": 0.8,
    "granite-moe-1b-a400m": 0.6,
    "minitron-8b": 2.2,
    "internlm2-20b": 4.5,
    "jamba-v0.1-52b": 3.0,
    "internvl2-26b": 5.5,
    "qwen1.5-32b": 6.5,
    "qwen3-moe-235b-a22b": 7.5,
    "whisper-medium": 0.5,
}


def step_cache_latency_factor(level: str, steps: int) -> float:
    """Expected chunk-latency multiplier of a cache level.

    A chunk runs ``steps`` denoise forwards plus one clean forward;
    a hit replaces a whole forward with an O(tokens) AXPY (modeled
    free next to the transformer stack)."""
    h = STEP_CACHE_HIT_RATE[level]
    total = steps + 1
    cacheable = max(steps - 1, 0)
    return (total - h * cacheable) / total


@dataclasses.dataclass(frozen=True)
class ChunkProfile:
    fidelity: FidelityConfig
    latency: float           # seconds per chunk on one worker (SP1)
    quality: float           # VBench points


@functools.lru_cache(maxsize=None)
def chunk_latency(cfg: FidelityConfig, *, sp_degree: int = 1,
                  model: str = "causal-forcing") -> float:
    """Profiled per-chunk generation time (SS2.1: highly profileable).

    Cached: the fleet simulator evaluates this for every denoise-step
    event (hundreds of thousands of calls over a 90-point config space),
    and the surface is pure in (cfg, sp_degree, model)."""
    vis = min(cfg.window, W_MAX) / W_MAX
    qf = FP8_FACTOR if cfg.quant == "fp8" else 1.0
    step = T_FIXED + T_MLP * qf + T_ATTN * vis * (1.0 - cfg.sparsity) * qf
    lat = cfg.steps * step
    if sp_degree > 1:
        # Ulysses SP2: compute halves, all-to-all adds ~12% of the split
        # compute (intra-node NVLink / ICI); fixed overhead not split.
        compute = lat - cfg.steps * T_FIXED
        lat = cfg.steps * T_FIXED + compute / sp_degree * 1.12
    cache = getattr(cfg, "cache", "off")
    if cache != "off":
        lat *= step_cache_latency_factor(cache, cfg.steps)
    cost = MODEL_COST.get(model, 1.0)
    if cost != 1.0:
        lat *= cost
    return lat


def chunk_quality(cfg: FidelityConfig, *,
                  model: str = "causal-forcing") -> float:
    vis = min(cfg.window, W_MAX) / W_MAX
    q = Q_MAX.get(model, 81.0)
    q -= A_S * (4 - cfg.steps) ** 1.6
    q -= A_RHO * (cfg.sparsity ** 2.5) * (vis ** 0.5)
    q -= A_W * (1.0 - vis) ** 1.4
    q -= A_Q * (1.0 if cfg.quant == "fp8" else 0.0)
    q -= A_INT * cfg.sparsity * (4 - cfg.steps) / 2.0
    q -= A_CACHE[getattr(cfg, "cache", "off")]
    return q


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """All 90 profiled points for one AR-DiT model (App. A)."""
    model: str
    points: Tuple[ChunkProfile, ...]

    def latency(self, cfg: FidelityConfig, sp_degree: int = 1) -> float:
        return chunk_latency(cfg, sp_degree=sp_degree, model=self.model)

    def quality(self, cfg: FidelityConfig) -> float:
        return chunk_quality(cfg, model=self.model)

    @property
    def by_key(self) -> Dict[str, ChunkProfile]:
        return {p.fidelity.key: p for p in self.points}


@functools.lru_cache(maxsize=None)
def get_profile(model: str = "causal-forcing",
                step_cache: bool = False) -> ModelProfile:
    """The App. A profile: 90 points, or 270 with the step-cache knob
    unlocked (``step_cache=True`` — BMPR then routes over cache levels
    like any other fidelity axis)."""
    pts = tuple(ChunkProfile(c, chunk_latency(c, model=model),
                             chunk_quality(c, model=model))
                for c in candidate_space(step_cache=step_cache))
    return ModelProfile(model, pts)


@dataclasses.dataclass(frozen=True)
class CalibratedProfile(ModelProfile):
    """Analytic latency surface corrected by MEASURED per-config chunk
    latencies (sim-vs-real calibration, DESIGN.md SS8: swapping in real
    measurements is a one-file change — this is that change, done
    online).

    ``ratios[key]`` multiplies the analytic latency of the fidelity
    config with that key (measured / analytic at SP1); configs the real
    run never executed fall back to the uniform ``scale`` (the
    measured-over-analytic ratio of the top-fidelity config — one global
    host-speed correction).  SP degrees inherit the same ratio: the
    calibration measures host compute speed, and the SP communication
    model stays analytic.

    Step-cache fallback chain: a cache-on key the run never executed
    first tries its cache=off sibling's measured ratio times the fitted
    per-level speedup (``cache_speedups``, from
    ``calibration.fit_cache_speedups``) — or, with no fitted speedup,
    the analytic ``step_cache_latency_factor`` — before the global
    ``scale``."""
    ratios: Dict[str, float] = dataclasses.field(default_factory=dict)
    scale: float = 1.0
    cache_speedups: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def latency(self, cfg: FidelityConfig, sp_degree: int = 1) -> float:
        if cfg.key in self.ratios:
            return chunk_latency(cfg, sp_degree=sp_degree,
                                 model=self.model) * self.ratios[cfg.key]
        cache = getattr(cfg, "cache", "off")
        if cache != "off":
            off = cfg._replace(cache="off")
            if off.key in self.ratios:
                lat_off = chunk_latency(off, sp_degree=sp_degree,
                                        model=self.model) \
                    * self.ratios[off.key]
                factor = self.cache_speedups.get(
                    cache, step_cache_latency_factor(cache, cfg.steps))
                return lat_off * factor
        base = chunk_latency(cfg, sp_degree=sp_degree, model=self.model)
        return base * self.scale


def calibrate_profile(base: ModelProfile, ratios: Dict[str, float],
                      scale: float = 1.0,
                      cache_speedups: Optional[Dict[str, float]] = None,
                      ) -> CalibratedProfile:
    """Build a ``CalibratedProfile`` whose ``points`` (the BMPR frontier
    input) carry the corrected latencies, so fidelity selection and the
    simulator's cost model read ONE calibrated surface."""
    prof = CalibratedProfile(base.model, (), ratios=dict(ratios),
                             scale=scale,
                             cache_speedups=dict(cache_speedups or {}))
    pts = tuple(ChunkProfile(p.fidelity, prof.latency(p.fidelity),
                             p.quality) for p in base.points)
    return dataclasses.replace(prof, points=pts)
