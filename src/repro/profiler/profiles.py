"""Offline profiler: latency / quality surfaces per fidelity configuration.

The paper profiles every candidate configuration offline (App. A): average
per-chunk latency L (ms) and VBench quality Q per config.  On real
hardware this is a measurement pass; in this repo the latency surface is
an analytic cost model calibrated to the paper's operating points (a
Self-Forcing-class 1.3B AR-DiT at 480p generates a 3-latent-frame chunk
in ~0.72 s at the highest-quality config on one H100 — just inside the
16 fps real-time budget of 0.75 s/chunk), and the quality surface is a
deterministic response model reproducing App. A's frontier shape:

    latency(cfg) = S * (t_fixed + t_mlp*q(Q) + t_attn*vis(W)*(1-rho)*q(Q))
    quality(cfg) = q_max - a_S(4-S)^1.6 - a_r*rho^2.5*vis(W)^0.5
                   - a_W*(1 - vis(W))^1.4 - a_Q*[fp8] - interactions

Both surfaces are exposed through ``ModelProfile`` so BMPR (SS5.2), the
service-credit estimator (Eq. 1), and the cluster simulator read one
consistent timing prior — exactly the role the paper's offline profiler
plays.  Constants live here, with their derivations, so swapping in real
measurements is a one-file change.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

from repro.core.fidelity import FidelityConfig, candidate_space

# -- timing constants (seconds), per H100-class worker, 480p, 3-frame chunk --
# Derivation: the highest-quality reference (S=4, rho=0, W=7, bf16)
# lands at 0.72 s/chunk — JUST inside the 0.75 s playout budget, matching
# Self-Forcing's ~17 fps single-GPU rate.  A solo stream is sustainable
# at top fidelity; pressure comes from worker SHARING (two streams on a
# worker run at an effective 1.44 s cadence and bleed ~0.7 s of slack per
# chunk), which is what slack-driven reallocation + BMPR absorb and
# slack-blind baselines do not (Fig. 15's URGENT/RELAXED imbalance).
# Per-step split: fixed overhead 40 ms, MLP+projections 90 ms,
# full-window attention 50 ms; fp8 keeps tensor-core paths ~1.6x faster
# on the quantizable share (SageAttention2 reports 1.6-2.1x).
T_FIXED = 0.040
T_MLP = 0.090
T_ATTN = 0.050
FP8_FACTOR = 0.625
W_MAX = 7

# -- quality constants (VBench points, 0-100) --------------------------------
# q_max matches the paper's reported ~81.1 VBench for Causal-Forcing; knob
# penalties are shaped so the 90-config surface spans ~6 VBench points and
# the median (the paper's global quality floor) sits ~1.2 under q_max.
Q_MAX = {"causal-forcing": 81.3, "self-forcing": 80.9}
A_S = 0.55
A_RHO = 2.6
A_W = 1.1
A_Q = 0.35
A_INT = 0.8          # rho x low-S interaction (fewer steps amplify sparsity)


@dataclasses.dataclass(frozen=True)
class ChunkProfile:
    fidelity: FidelityConfig
    latency: float           # seconds per chunk on one worker (SP1)
    quality: float           # VBench points


@functools.lru_cache(maxsize=None)
def chunk_latency(cfg: FidelityConfig, *, sp_degree: int = 1,
                  model: str = "causal-forcing") -> float:
    """Profiled per-chunk generation time (SS2.1: highly profileable).

    Cached: the fleet simulator evaluates this for every denoise-step
    event (hundreds of thousands of calls over a 90-point config space),
    and the surface is pure in (cfg, sp_degree, model)."""
    vis = min(cfg.window, W_MAX) / W_MAX
    qf = FP8_FACTOR if cfg.quant == "fp8" else 1.0
    step = T_FIXED + T_MLP * qf + T_ATTN * vis * (1.0 - cfg.sparsity) * qf
    lat = cfg.steps * step
    if sp_degree > 1:
        # Ulysses SP2: compute halves, all-to-all adds ~12% of the split
        # compute (intra-node NVLink / ICI); fixed overhead not split.
        compute = lat - cfg.steps * T_FIXED
        lat = cfg.steps * T_FIXED + compute / sp_degree * 1.12
    return lat


def chunk_quality(cfg: FidelityConfig, *,
                  model: str = "causal-forcing") -> float:
    vis = min(cfg.window, W_MAX) / W_MAX
    q = Q_MAX.get(model, 81.0)
    q -= A_S * (4 - cfg.steps) ** 1.6
    q -= A_RHO * (cfg.sparsity ** 2.5) * (vis ** 0.5)
    q -= A_W * (1.0 - vis) ** 1.4
    q -= A_Q * (1.0 if cfg.quant == "fp8" else 0.0)
    q -= A_INT * cfg.sparsity * (4 - cfg.steps) / 2.0
    return q


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """All 90 profiled points for one AR-DiT model (App. A)."""
    model: str
    points: Tuple[ChunkProfile, ...]

    def latency(self, cfg: FidelityConfig, sp_degree: int = 1) -> float:
        return chunk_latency(cfg, sp_degree=sp_degree, model=self.model)

    def quality(self, cfg: FidelityConfig) -> float:
        return chunk_quality(cfg, model=self.model)

    @property
    def by_key(self) -> Dict[str, ChunkProfile]:
        return {p.fidelity.key: p for p in self.points}


@functools.lru_cache(maxsize=None)
def get_profile(model: str = "causal-forcing") -> ModelProfile:
    pts = tuple(ChunkProfile(c, chunk_latency(c, model=model),
                             chunk_quality(c, model=model))
                for c in candidate_space())
    return ModelProfile(model, pts)


@dataclasses.dataclass(frozen=True)
class CalibratedProfile(ModelProfile):
    """Analytic latency surface corrected by MEASURED per-config chunk
    latencies (sim-vs-real calibration, DESIGN.md SS8: swapping in real
    measurements is a one-file change — this is that change, done
    online).

    ``ratios[key]`` multiplies the analytic latency of the fidelity
    config with that key (measured / analytic at SP1); configs the real
    run never executed fall back to the uniform ``scale`` (the
    measured-over-analytic ratio of the top-fidelity config — one global
    host-speed correction).  SP degrees inherit the same ratio: the
    calibration measures host compute speed, and the SP communication
    model stays analytic."""
    ratios: Dict[str, float] = dataclasses.field(default_factory=dict)
    scale: float = 1.0

    def latency(self, cfg: FidelityConfig, sp_degree: int = 1) -> float:
        base = chunk_latency(cfg, sp_degree=sp_degree, model=self.model)
        return base * self.ratios.get(cfg.key, self.scale)


def calibrate_profile(base: ModelProfile, ratios: Dict[str, float],
                      scale: float = 1.0) -> CalibratedProfile:
    """Build a ``CalibratedProfile`` whose ``points`` (the BMPR frontier
    input) carry the corrected latencies, so fidelity selection and the
    simulator's cost model read ONE calibrated surface."""
    pts = tuple(ChunkProfile(
        p.fidelity,
        chunk_latency(p.fidelity, model=base.model)
        * ratios.get(p.fidelity.key, scale),
        p.quality) for p in base.points)
    return CalibratedProfile(base.model, pts, ratios=dict(ratios),
                             scale=scale)
