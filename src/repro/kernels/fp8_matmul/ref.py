"""Pure-jnp oracle for the scaled fp8 matmul (fidelity knob Q, SS2.1/SS6).

SageAttention2-style online quantization: activations are dynamically
scaled per row / per column into float8_e4m3fn with no weight reloading;
the matmul accumulates in fp32 and folds the scales back at the end.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

FP8_MAX = 448.0         # float8_e4m3fn dynamic range


def quantize_fp8_ref(x: jax.Array, axis: int) -> Tuple[jax.Array, jax.Array]:
    """Per-slice dynamic quantization along ``axis`` (the contracted dim).

    Returns (x_fp8, scale) with x ~= x_fp8 * scale (scale broadcastable).
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / FP8_MAX
    q = (x.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    return q, scale


def fp8_matmul_ref(x_q: jax.Array, w_q: jax.Array,
                   sx: jax.Array, sw: jax.Array) -> jax.Array:
    """x_q [M,K] fp8, w_q [K,N] fp8, sx [M,1], sw [1,N] -> [M,N] fp32."""
    acc = jnp.dot(x_q.astype(jnp.float32), w_q.astype(jnp.float32))
    return acc * sx * sw
