from repro.kernels.fp8_matmul.ops import fp8_matmul, quantize_fp8  # noqa: F401
