"""Dispatching wrapper for the scaled fp8 matmul."""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fp8_matmul import ref as _ref


def _mode():
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return "interpret"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def quantize_fp8(x: jax.Array, axis: int) -> Tuple[jax.Array, jax.Array]:
    return _ref.quantize_fp8_ref(x, axis)


def fp8_matmul(x: jax.Array, w: jax.Array, *,
               out_dtype=jnp.float32) -> jax.Array:
    """Online-quantized matmul: x [M,K] any float, w [K,N] any float."""
    x_q, sx = quantize_fp8(x, axis=1)
    w_q, sw = quantize_fp8(w, axis=0)
    mode = _mode()
    if mode == "ref":
        return _ref.fp8_matmul_ref(x_q, w_q, sx, sw).astype(out_dtype)
    from repro.kernels.fp8_matmul import kernel as _k
    return _k.fp8_matmul_pallas(x_q, w_q, sx, sw, out_dtype=out_dtype,
                                interpret=(mode == "interpret"))
