"""Pallas TPU scaled fp8 matmul.

MXU-aligned (128x128x128 default) blocked matmul over float8_e4m3fn
operands with fp32 accumulation in VMEM scratch; per-row (x) and
per-column (w) dequant scales are folded in once, at the final K step.
On TPU the fp8->MXU path is native; interpret mode upcasts in the body,
which is numerically identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams



def _kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_scr):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] * sx_ref[...] * sw_ref[...]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def fp8_matmul_pallas(x_q: jax.Array, w_q: jax.Array,
                      sx: jax.Array, sw: jax.Array, *,
                      block_m: int = 128, block_n: int = 128,
                      block_k: int = 128, out_dtype=jnp.float32,
                      interpret: bool = False) -> jax.Array:
    """x_q [M,K] fp8, w_q [K,N] fp8, sx [M,1], sw [1,N] -> [M,N]."""
    m, k = x_q.shape
    _, n = w_q.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0

    return pl.pallas_call(
        _kernel,
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((block_k, block_n), lambda i, j, ki: (ki, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, ki: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, ki: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, sx, sw)
