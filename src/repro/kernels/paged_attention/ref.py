"""Pure-jnp oracle for paged decode attention.

Gathers the logical KV sequence out of the physical page pool through the
block table, then runs the dense decode-attention reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention


def gather_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """pages [P_total, page, Hkv, D]; block_table [B, n] -> [B, n*page, Hkv, D]."""
    b, n = block_table.shape
    _, page, hkv, d = pages.shape
    out = pages[block_table.reshape(-1)]            # [B*n, page, Hkv, D]
    return out.reshape(b, n * page, hkv, d)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_table: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """q [B,Hq,D] -> [B,Hq,D]; lengths [B] = valid tokens per sequence."""
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    k = gather_pages(k_pages, block_table)
    v = gather_pages(v_pages, block_table)
    out = decode_attention(q[:, None], k, v, n_kv_heads=hkv,
                           cache_len=lengths)
    return out[:, 0]
