"""Pure-jnp oracles for paged attention.

``paged_decode_attention_ref`` (single-token decode) gathers the logical
KV sequence out of the physical page pool through the block table, then
runs the dense decode-attention reference.  ``paged_chunk_attention_ref``
is the chunk-query generalization used by the batched serving executor's
``paged`` context backend: it returns ONLINE-SOFTMAX PARTIALS over the
visible page set so the caller can merge them with the chunk's own fresh
KV segment (``models.attention.paged_mha``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention

NEG_INF = -1e30


def gather_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """pages [P_total, page, Hkv, D]; block_table [B, n] -> [B, n*page, Hkv, D]."""
    b, n = block_table.shape
    _, page, hkv, d = pages.shape
    out = pages[block_table.reshape(-1)]            # [B*n, page, Hkv, D]
    return out.reshape(b, n * page, hkv, d)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_table: jax.Array,
                               lengths: jax.Array) -> jax.Array:
    """q [B,Hq,D] -> [B,Hq,D]; lengths [B] = valid tokens per sequence."""
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    k = gather_pages(k_pages, block_table)
    v = gather_pages(v_pages, block_table)
    out = decode_attention(q[:, None], k, v, n_kv_heads=hkv,
                           cache_len=lengths)
    return out[:, 0]


def paged_chunk_attention_ref(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_table: jax.Array,
                              page_mask: jax.Array, *,
                              sink: int = 0, chunk_tokens: int = 0):
    """Chunk-query paged attention partials over the visible page set.

    q [B,Sq,Hq,D]; pages [P_total, page, Hkv, D]; block_table [B, n];
    page_mask [B, n*page] bool — visible context tokens in TABLE order
    (entry 0's tokens first, then entry 1's, ...), with page tails past
    each page's valid extent already masked off by the caller.
    ``page_mask=None`` (layout hint required) means "every valid token
    visible" — the homogeneous-fill, full-window, unsparsified common
    case — and skips per-score masking entirely.

    ``sink``/``chunk_tokens`` are an optional layout hint: when given,
    table entry 0 is known to hold at most ``sink`` valid tokens and
    every later entry at most ``chunk_tokens``, so the oracle skips the
    always-masked page tails entirely (the TPU kernel keeps page-aligned
    compute — pages are its DMA granule — but the CPU serving path
    should not pay FLOPs for provably-dead padding).  The partials are
    identical either way: masked tokens contribute m=NEG_INF, p=0.

    Returns unfinalized fp32 partials in the ``attention._merge`` layout:
    m, l [B, Hkv, G, Sq] and acc [B, Hkv, G, Sq, D] (acc unnormalized),
    with m == NEG_INF where a query row saw no visible token.
    """
    b, sq, hq, d = q.shape
    page = k_pages.shape[1]
    hkv = k_pages.shape[2]
    g = hq // hkv
    n = block_table.shape[1]
    scale = 1.0 / math.sqrt(d)
    s0, tc = min(sink, page), min(chunk_tokens, page)
    if page_mask is None:
        assert sink and chunk_tokens, \
            "page_mask=None needs the sink/chunk_tokens layout hint"
    if sink and chunk_tokens and (s0 < page or (n > 1 and tc < page)):
        # compact layout: valid prefixes only
        ks = k_pages[block_table[:, 0], :s0]        # [B, s0, Hkv, D]
        vs = v_pages[block_table[:, 0], :s0]
        k, v = ks, vs
        if n > 1:
            kr = k_pages[block_table[:, 1:].reshape(-1), :tc].reshape(
                b, (n - 1) * tc, hkv, d)
            vr = v_pages[block_table[:, 1:].reshape(-1), :tc].reshape(
                b, (n - 1) * tc, hkv, d)
            k = jnp.concatenate([ks, kr], axis=1)
            v = jnp.concatenate([vs, vr], axis=1)
        if page_mask is not None:
            cols = [jnp.arange(s0)] + [(1 + r) * page + jnp.arange(tc)
                                       for r in range(n - 1)]
            page_mask = page_mask[:, jnp.concatenate(cols)]
    else:
        k = gather_pages(k_pages, block_table)      # [B, n*page, Hkv, D]
        v = gather_pages(v_pages, block_table)
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if page_mask is None:       # every compact token visible: no select
        m = jnp.max(s, axis=-1)                     # [B,Hkv,G,Sq]
        p = jnp.exp(s - m[..., None])
    else:
        vis = page_mask[:, None, None, None, :]
        s = jnp.where(vis, s, NEG_INF)
        m = jnp.max(s, axis=-1)
        p = jnp.where(vis, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return m, l, acc
