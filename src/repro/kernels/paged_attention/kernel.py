"""Pallas TPU paged attention (State-Plane paged KV, SS4.4).

The State Plane stores KV at latent-frame granularity in a physical page
pool; attention must cover a logically-contiguous sequence scattered
across pages.  The block table is scalar-prefetched so the page index_map
performs the indirection *before* the DMA — the TPU analogue of gather-
from-page-table on GPU.  Grid: (batch, kv_head, page); online-softmax
state rides in VMEM scratch across the page dimension.

Two entry points:

* ``paged_decode_attention_pallas`` — single-token decode
  (q [B,Hq,D], per-stream valid ``lengths``), finalized output.
* ``paged_chunk_attention_pallas`` — chunk-query generalization for the
  batched serving executor's ``paged`` context backend
  (q [B,Sq,Hq,D], per-stream token-granular visibility ``page_mask``).
  Returns ONLINE-SOFTMAX PARTIALS (m, l, unnormalized acc) so the
  caller can merge the paged-context segment with the chunk's own
  fresh KV (``models.attention.paged_mha``) — the pool is never
  gathered into a contiguous context.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


NEG_INF = -1e30


def _kernel(bt_ref, len_ref,                  # scalar prefetch
            q_ref, k_ref, v_ref,              # VMEM
            o_ref,
            m_scr, l_scr, acc_scr,
            *, scale: float, page_size: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(i * page_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)         # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array,
                                  block_table: jax.Array,
                                  lengths: jax.Array, *,
                                  interpret: bool = False) -> jax.Array:
    """q [B,Hq,D]; pages [P_total, page, Hkv, D]; block_table [B, n];
    lengths [B].  Returns [B,Hq,D]."""
    b, hq, d = q.shape
    _, page, hkv, _ = k_pages.shape
    n_pages = block_table.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, group, d)

    kernel = functools.partial(_kernel, scale=scale, page_size=page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h, i, bt, ln: (b_, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, i, bt, ln: (bt[b_, i], 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, i, bt, ln: (bt[b_, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda b_, h, i, bt, ln: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, lengths, qg, k_pages, v_pages)
    return out.reshape(b, hq, d)


def _chunk_kernel(bt_ref, pa_ref,             # scalar prefetch
                  q_ref, k_ref, v_ref, mask_ref,   # VMEM
                  m_out, l_out, acc_out,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, sink: int, chunk_tokens: int):
    """``mask_ref`` is None in the all-visible fast path: visibility is
    then just each page's static valid prefix (``sink`` tokens on table
    entry 0, ``chunk_tokens`` on ring entries)."""
    b = pl.program_id(0)
    i = pl.program_id(2)
    page = k_ref.shape[1]

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # pages with no visible token are skipped entirely (a skipped page
    # contributes m=NEG_INF, l+=0, acc+=0 — identical to computing it)
    @pl.when(pa_ref[b, i] > 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # [R, D], R = Sq*G
        k = k_ref[0, :, 0].astype(jnp.float32)     # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        if mask_ref is not None:
            vis = mask_ref[0, 0] > 0               # [page]
        else:
            limit = jax.lax.select(i == 0, sink, chunk_tokens)
            vis = jax.lax.broadcasted_iota(
                jnp.int32, (1, page), 1)[0] < limit
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(vis[None, :], s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # exp(NEG_INF - NEG_INF) == 1 on an all-masked row: zero those
        # probabilities explicitly so l is not polluted
        p = jnp.where(vis[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _finalize():
        # partials, NOT a finalized output: the caller still merges the
        # in-chunk KV segment before the softmax divide
        m_out[0, 0] = m_scr[...]
        l_out[0, 0] = l_scr[...]
        acc_out[0, 0] = acc_scr[...]


def _chunk_kernel_nomask(bt_ref, pa_ref, q_ref, k_ref, v_ref,
                         m_out, l_out, acc_out, m_scr, l_scr, acc_scr,
                         *, scale: float, sink: int, chunk_tokens: int):
    _chunk_kernel(bt_ref, pa_ref, q_ref, k_ref, v_ref, None,
                  m_out, l_out, acc_out, m_scr, l_scr, acc_scr,
                  scale=scale, sink=sink, chunk_tokens=chunk_tokens)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "sink", "chunk_tokens"))
def paged_chunk_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                 v_pages: jax.Array,
                                 block_table: jax.Array,
                                 page_mask, *,
                                 sink: int = 0, chunk_tokens: int = 0,
                                 interpret: bool = False):
    """q [B,Sq,Hq,D]; pages [P_total, page, Hkv, D]; block_table [B, n];
    page_mask [B, n*page] bool (visible tokens in table order), or None
    for the all-visible fast path (``sink``/``chunk_tokens`` then give
    each page's static valid prefix).

    Returns fp32 online-softmax partials in the ``attention._merge``
    layout: m, l [B, Hkv, G, Sq]; acc [B, Hkv, G, Sq, D] unnormalized.
    """
    b, sq, hq, d = q.shape
    _, page, hkv, _ = k_pages.shape
    n = block_table.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    r = sq * group                      # query rows per (batch, kv head)
    scale = 1.0 / math.sqrt(d)
    qr = q.reshape(b, sq, hkv, group, d).transpose(0, 2, 1, 3, 4) \
          .reshape(b, hkv, r, d)

    in_specs = [
        pl.BlockSpec((1, 1, r, d),
                     lambda b_, h, i, bt, pa: (b_, h, 0, 0)),
        pl.BlockSpec((1, page, 1, d),
                     lambda b_, h, i, bt, pa: (bt[b_, i], 0, h, 0)),
        pl.BlockSpec((1, page, 1, d),
                     lambda b_, h, i, bt, pa: (bt[b_, i], 0, h, 0)),
    ]
    if page_mask is None:
        assert sink and chunk_tokens, \
            "page_mask=None needs the sink/chunk_tokens layout hint"
        kernel = functools.partial(_chunk_kernel_nomask, scale=scale,
                                   sink=sink, chunk_tokens=chunk_tokens)
        page_any = jnp.ones((b, n), jnp.int32)
        inputs = (block_table, page_any, qr, k_pages, v_pages)
    else:
        kernel = functools.partial(_chunk_kernel, scale=scale,
                                   sink=sink, chunk_tokens=chunk_tokens)
        mask_i = page_mask.reshape(b, n, page).astype(jnp.int32)
        page_any = (jnp.sum(mask_i, axis=-1) > 0).astype(jnp.int32)
        in_specs.append(pl.BlockSpec(
            (1, 1, page), lambda b_, h, i, bt, pa: (b_, i, 0)))
        inputs = (block_table, page_any, qr, k_pages, v_pages, mask_i)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, r), lambda b_, h, i, bt, pa: (b_, h, 0)),
            pl.BlockSpec((1, 1, r), lambda b_, h, i, bt, pa: (b_, h, 0)),
            pl.BlockSpec((1, 1, r, d),
                         lambda b_, h, i, bt, pa: (b_, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((r,), jnp.float32),
            pltpu.VMEM((r,), jnp.float32),
            pltpu.VMEM((r, d), jnp.float32),
        ],
    )
    m, l, acc = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hkv, r), jnp.float32),
                   jax.ShapeDtypeStruct((b, hkv, r), jnp.float32),
                   jax.ShapeDtypeStruct((b, hkv, r, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    m = m.reshape(b, hkv, sq, group).transpose(0, 1, 3, 2)
    l = l.reshape(b, hkv, sq, group).transpose(0, 1, 3, 2)
    acc = acc.reshape(b, hkv, sq, group, d).transpose(0, 1, 3, 2, 4)
    return m, l, acc
