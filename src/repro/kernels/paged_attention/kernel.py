"""Pallas TPU paged decode attention (State-Plane paged KV, SS4.4).

The State Plane stores KV at latent-frame granularity in a physical page
pool; decode must attend over a logically-contiguous sequence scattered
across pages.  The block table is scalar-prefetched so the page index_map
performs the indirection *before* the DMA — the TPU analogue of gather-
from-page-table on GPU.  Grid: (batch, kv_head, page); online-softmax
state for the head group rides in VMEM scratch across the page dimension.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


NEG_INF = -1e30


def _kernel(bt_ref, len_ref,                  # scalar prefetch
            q_ref, k_ref, v_ref,              # VMEM
            o_ref,
            m_scr, l_scr, acc_scr,
            *, scale: float, page_size: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[b]

    @pl.when(i * page_size < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
        k = k_ref[0, :, 0].astype(jnp.float32)         # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = i * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array,
                                  block_table: jax.Array,
                                  lengths: jax.Array, *,
                                  interpret: bool = False) -> jax.Array:
    """q [B,Hq,D]; pages [P_total, page, Hkv, D]; block_table [B, n];
    lengths [B].  Returns [B,Hq,D]."""
    b, hq, d = q.shape
    _, page, hkv, _ = k_pages.shape
    n_pages = block_table.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, hkv, group, d)

    kernel = functools.partial(_kernel, scale=scale, page_size=page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda b_, h, i, bt, ln: (b_, h, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, i, bt, ln: (bt[b_, i], 0, h, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h, i, bt, ln: (bt[b_, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda b_, h, i, bt, ln: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_table, lengths, qg, k_pages, v_pages)
    return out.reshape(b, hq, d)
