"""Dispatching wrapper for paged decode attention."""
from __future__ import annotations

import os

import jax

from repro.kernels.paged_attention import ref as _ref


def _mode():
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return "interpret"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths):
    """q [B,Hq,D]; pages [P_total,page,Hkv,D]; block_table [B,n];
    lengths [B] -> [B,Hq,D]."""
    mode = _mode()
    if mode == "ref":
        return _ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                               block_table, lengths)
    from repro.kernels.paged_attention import kernel as _k
    return _k.paged_decode_attention_pallas(
        q, k_pages, v_pages, block_table, lengths,
        interpret=(mode == "interpret"))
