"""Dispatching wrapper for paged decode attention."""
from __future__ import annotations

import os

import jax

from repro.kernels.paged_attention import ref as _ref


def _mode():
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return "interpret"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths):
    """q [B,Hq,D]; pages [P_total,page,Hkv,D]; block_table [B,n];
    lengths [B] -> [B,Hq,D]."""
    mode = _mode()
    if mode == "ref":
        return _ref.paged_decode_attention_ref(q, k_pages, v_pages,
                                               block_table, lengths)
    from repro.kernels.paged_attention import kernel as _k
    return _k.paged_decode_attention_pallas(
        q, k_pages, v_pages, block_table, lengths,
        interpret=(mode == "interpret"))


def paged_chunk_attention(q, k_pages, v_pages, block_table, page_mask,
                          *, sink: int = 0, chunk_tokens: int = 0):
    """Chunk-query paged attention partials (the serving executor's
    ``paged`` context backend).  q [B,Sq,Hq,D]; pages
    [P_total,page,Hkv,D]; block_table [B,n]; page_mask [B,n*page] bool.
    ``sink``/``chunk_tokens`` optionally declare the valid prefix of the
    sink page / ring pages so the jnp oracle can skip always-masked page
    tails (the Pallas kernel stays page-aligned — pages are its DMA
    granule); ``page_mask=None`` (hint required) is the all-visible fast
    path that skips per-score masking.  ``page_mask`` is per-ROW, so a
    single launch serves rows with different fidelity windows and
    sparsities (fused heterogeneous-fidelity dispatch) as well as rows
    degraded by partial-window page eviction: the caller maps a dropped
    ring page's hole entry to some valid page row (the stream's own
    sink) with its whole mask slice false, so whatever K/V the hole
    stand-in holds contributes only -inf scores and never reaches the
    softmax.  Returns fp32 online-softmax
    partials (m, l [B,Hkv,G,Sq]; acc [B,Hkv,G,Sq,D] unnormalized) for
    ``attention.paged_mha`` to merge with the chunk's own fresh KV
    segment."""
    mode = _mode()
    if mode == "ref":
        return _ref.paged_chunk_attention_ref(
            q, k_pages, v_pages, block_table, page_mask,
            sink=sink, chunk_tokens=chunk_tokens)
    from repro.kernels.paged_attention import kernel as _k
    return _k.paged_chunk_attention_pallas(
        q, k_pages, v_pages, block_table, page_mask,
        sink=sink, chunk_tokens=chunk_tokens,
        interpret=(mode == "interpret"))
