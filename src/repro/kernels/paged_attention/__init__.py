from repro.kernels.paged_attention.ops import (  # noqa: F401
    paged_chunk_attention, paged_decode_attention)
