"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

Each subpackage is <name>/{kernel.py (pl.pallas_call + BlockSpec),
ops.py (dispatching wrapper), ref.py (pure-jnp oracle)}:

    flash_attention   causal / sink+window (knob W) / block-sparse (knob
                      rho) / bidirectional AR-DiT attention
    paged_attention   decode over the State Plane's paged KV pool (SS4.4)
    fp8_matmul        online-quantized scaled matmul (knob Q, SS6)
    ssd_scan          Mamba-2 SSD chunked scan (mamba2/jamba archs)

Kernels target TPU (MXU-aligned BlockSpecs, VMEM scratch carries) and are
validated on CPU in interpret mode against the oracles
(REPRO_FORCE_PALLAS_INTERPRET=1).
"""
