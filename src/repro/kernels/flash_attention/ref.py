"""Pure-jnp oracle for the flash-attention kernel.

The model-side attention substrate (``repro.models.attention.mha``) *is*
the reference implementation: fp32 online-softmax over block schedules.
This module re-exports it under the kernel-oracle naming convention so
every kernel package has a ``ref.py`` with matching call signature.
"""
from __future__ import annotations

import jax

from repro.models.attention import mha as _mha


def flash_mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  n_kv_heads: int, causal: bool = True, q_offset: int = 0,
                  window: int = 0, sink: int = 0, sparsity: float = 0.0,
                  block_q: int = 512, block_kv: int = 512) -> jax.Array:
    """q [B,Sq,Hq,D]; k,v [B,Skv,Hkv,D] -> [B,Sq,Hq,D]."""
    return _mha(q, k, v, n_kv_heads=n_kv_heads, causal=causal,
                q_offset=q_offset, window=window, sink=sink,
                sparsity=sparsity, block_q=block_q, block_kv=block_kv)
