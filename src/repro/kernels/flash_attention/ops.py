"""Dispatching wrapper for flash attention.

Model layout in/out: [B, S, H, D].  TPU -> Pallas kernel; CPU -> jnp ref;
``REPRO_FORCE_PALLAS_INTERPRET=1`` -> Pallas interpret mode (kernel tests).
"""
from __future__ import annotations

import os

import jax

from repro.kernels.flash_attention import ref as _ref


def _mode():
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return "interpret"
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              n_kv_heads: int, causal: bool = True, q_offset: int = 0,
              window: int = 0, sink: int = 0, sparsity: float = 0.0,
              block_q: int = 128, block_kv: int = 128) -> jax.Array:
    """q [B,Sq,Hq,D]; k,v [B,Skv,Hkv,D] -> [B,Sq,Hq,D]."""
    mode = _mode()
    if mode == "ref":
        return _ref.flash_mha_ref(q, k, v, n_kv_heads=n_kv_heads,
                                  causal=causal, q_offset=q_offset,
                                  window=window, sink=sink,
                                  sparsity=sparsity)
    from repro.kernels.flash_attention import kernel as _k
    out = _k.flash_mha_pallas(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal=causal, q_offset=q_offset, window=window, sink=sink,
        sparsity=sparsity, block_q=block_q, block_kv=block_kv,
        interpret=(mode == "interpret"))
    return out.swapaxes(1, 2)
