from repro.kernels.flash_attention.ops import flash_mha  # noqa: F401
