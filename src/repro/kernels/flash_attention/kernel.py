"""Pallas TPU flash attention with the paper's fidelity knobs.

One kernel serves four attention modes (SS2.1, SS5):
    causal                 block-triangular schedule
    sink + sliding window  knob W: off-window KV blocks skipped
    block-sparse           knob rho: static keep-list, skipped blocks do
                           not run (pl.when predication on the MXU)
    non-causal             chunk-bidirectional AR-DiT attention

TPU adaptation (DESIGN.md SS3): blocks are 128-aligned for the MXU; the
online-softmax running state (m, l, acc) lives in VMEM scratch and is
carried across the innermost (arbitrary-semantics) KV grid dimension;
whole-block skips are grid predicates rather than warp-level masks.

Layout: q [B, Hq, Sq, D]; k,v [B, Hkv, Skv, D] (ops.py transposes from the
model's [B, S, H, D]).  GQA: kv head index = q head // group.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


NEG_INF = -1e30


def _kernel(keep_ref,                       # scalar-prefetch [nq*nk] i32
            q_ref, k_ref, v_ref,            # VMEM blocks
            o_ref,                          # output block
            m_scr, l_scr, acc_scr,          # VMEM scratch
            *, scale: float, causal: bool, q_offset: int,
            window: int, sink: int, block_q: int, block_kv: int,
            n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = q_offset + qi * block_q
    k_lo = ki * block_kv

    # ---- whole-block schedule predicate (grid-level skip) -----------------
    run = keep_ref[qi * n_kv + ki] != 0
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + block_q - 1)
        if window:
            # block overlaps [q_lo-window+1, q_hi] or the sink prefix
            in_win = k_lo + block_kv - 1 >= q_lo - window + 1
            in_sink = k_lo < sink
            run = jnp.logical_and(run, jnp.logical_or(in_win, in_sink))

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
            k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 1)
            mask = q_pos >= k_pos
            if window:
                mask = jnp.logical_and(
                    mask, jnp.logical_or(k_pos > q_pos - window,
                                         k_pos < sink))
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def keep_matrix(n_q: int, n_kv: int, *, causal: bool, q_offset: int,
                window: int, sink: int, sparsity: float,
                block_q: int, block_kv: int) -> np.ndarray:
    """Static [n_q, n_kv] 0/1 schedule for the rho knob (strided keep)."""
    keep = np.ones((n_q, n_kv), np.int32)
    if sparsity <= 0.0:
        return keep
    from repro.models.attention import sparse_keep_list
    sink_blocks = max(1, sink // block_kv) if sink else 1
    for i in range(n_q):
        if causal:
            q_hi = q_offset + (i + 1) * block_q
            n_vis = min(n_kv, (q_hi + block_kv - 1) // block_kv)
        else:
            n_vis = n_kv
        kept = sparse_keep_list(1, [n_vis], sparsity,
                                sink_blocks=sink_blocks)[0]
        row = np.zeros((n_kv,), np.int32)
        row[list(kept)] = 1
        row[n_vis:] = 1          # blocks beyond visibility: causal pred cuts
        keep[i] = row
    return keep


@functools.partial(
    jax.jit, static_argnames=("causal", "q_offset", "window", "sink",
                              "sparsity", "block_q", "block_kv", "interpret"))
def flash_mha_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, q_offset: int = 0,
                     window: int = 0, sink: int = 0, sparsity: float = 0.0,
                     block_q: int = 128, block_kv: int = 128,
                     interpret: bool = False) -> jax.Array:
    """q [B,Hq,Sq,D]; k,v [B,Hkv,Skv,D] -> [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv)
    n_q, n_kv = sq // block_q, skv // block_kv
    scale = 1.0 / math.sqrt(d)

    keep = jnp.asarray(keep_matrix(
        n_q, n_kv, causal=causal, q_offset=q_offset, window=window,
        sink=sink, sparsity=sparsity, block_q=block_q,
        block_kv=block_kv).reshape(-1))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, q_offset=q_offset,
        window=window, sink=sink, block_q=block_q, block_kv=block_kv,
        n_kv=n_kv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, qi, ki, keep: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, qi, ki, keep: (b_, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h, qi, ki, keep: (b_, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki, keep: (b_, h, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(keep, q, k, v)
