"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the quadratic
intra-chunk part is three MXU matmuls over a [Q, Q] segment-sum mask; the
inter-chunk recurrence is carried in VMEM scratch ([N, P] per (batch,
head)) across the innermost (arbitrary-semantics) chunk grid dimension —
the kernel-level analogue of ``lax.scan`` over chunk states.

Wrapper layout: x [B, H, NC, Q, P]; dt [B, H, NC, Q]; Bm/Cm [B, NC, Q, N]
(n_groups folded to 1; shared across heads); A [H].
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams



def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref,
            y_ref, sf_ref,
            state_scr,
            *, q: int, use_init: bool):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        if use_init:
            state_scr[...] = s0_ref[0, 0].astype(jnp.float32)
        else:
            state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)           # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)         # [Q]
    a = a_ref[0]                                     # scalar
    bm = b_ref[0, 0].astype(jnp.float32)             # [Q, N]
    cm = c_ref[0, 0].astype(jnp.float32)             # [Q, N]

    dA = dt * a                                      # [Q] (<= 0)
    cs = jnp.cumsum(dA)                              # [Q]

    # intra-chunk: Y = ((C B^T) * L * dt_j) X
    seg = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # [Q,Q]
    w = cb * L * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # [Q,P]

    # inter-chunk: Y += (C * exp(cs)) @ state   (state [N, P])
    state = state_scr[...]
    c_scaled = cm * jnp.exp(cs)[:, None]
    y += jax.lax.dot_general(c_scaled, state, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: state' = exp(cs_last) * state + (B * dt * decay)^T X
    decay_to_end = jnp.exp(cs[-1] - cs)              # [Q]
    b_scaled = bm * (dt * decay_to_end)[:, None]     # [Q,N]
    chunk_state = jax.lax.dot_general(
        b_scaled, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [N,P]
    state_scr[...] = jnp.exp(cs[-1]) * state + chunk_state

    @pl.when(ci == pl.num_programs(2) - 1)
    def _final():
        sf_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
               Bm: jax.Array, Cm: jax.Array, *, chunk: int = 128,
               init_state: Optional[jax.Array] = None,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Same contract as ``ref.ssd_ref`` (model layout [B,S,H,P] etc.)."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert g == 1, "kernel folds n_groups to 1 (models use G=1)"
    out_dtype = x.dtype

    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // q

    xk = x.reshape(b, nc, q, h, p).transpose(0, 3, 1, 2, 4)   # [B,H,NC,Q,P]
    dtk = dt.reshape(b, nc, q, h).transpose(0, 3, 1, 2)       # [B,H,NC,Q]
    bk = Bm.reshape(b, nc, q, n)                              # [B,NC,Q,N]
    ck = Cm.reshape(b, nc, q, n)
    use_init = init_state is not None
    if use_init:
        s0 = init_state.transpose(0, 1, 3, 2).astype(jnp.float32)  # [B,H,N,P]
    else:
        s0 = jnp.zeros((b, h, n, p), jnp.float32)

    kernel = functools.partial(_kernel, q=q, use_init=use_init)

    y, sf = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda b_, h_, c: (b_, h_, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda b_, h_, c: (b_, h_, c, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c: (h_,)),
            pl.BlockSpec((1, 1, q, n), lambda b_, h_, c: (b_, c, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda b_, h_, c: (b_, c, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda b_, h_, c: (b_, h_, c, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, nc, q, p), out_dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xk, dtk, A.astype(jnp.float32), bk, ck, s0)

    y = y.transpose(0, 2, 3, 1, 4).reshape(b, sp, h, p)[:, :s]
    return y.astype(out_dtype), sf.transpose(0, 1, 3, 2)      # [B,H,P,N]
