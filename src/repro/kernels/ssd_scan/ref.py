"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) chunked scan.

Semantics (Mamba-2, arXiv:2405.21060 SS6): the selective SSM
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . h_t
is evaluated in chunks of length ``Q``: quadratic attention-like math inside
a chunk (tensor-core friendly), linear recurrence across chunk boundaries.

Shapes (G = n_groups divides H = n_heads):
    x  [B, S, H, P]     dt [B, S, H] (post-softplus, >= 0)
    A  [H] (negative)   Bm [B, S, G, N]   Cm [B, S, G, N]
    init_state [B, H, P, N] or None
Returns  (y [B, S, H, P], final_state [B, H, P, N]), all fp32 accumulation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
            Bm: jax.Array, Cm: jax.Array, *,
            chunk: int = 128,
            init_state: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, jax.Array]:
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    assert h % g == 0, (h, g)
    out_dtype = x.dtype

    # pad sequence to a multiple of the chunk length
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // q

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Bf = Bm.astype(jnp.float32).reshape(b, nc, q, g, n)
    Cf = Cm.astype(jnp.float32).reshape(b, nc, q, g, n)
    rep = h // g
    Bh = jnp.repeat(Bf, rep, axis=3)                     # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cf, rep, axis=3)

    dA = dtf * A.astype(jnp.float32)                     # [B,nc,Q,H] (<= 0)
    cs = jnp.cumsum(dA, axis=2)                          # inclusive cumsum

    # ---- intra-chunk (quadratic, masked) -----------------------------------
    # L[i,j] = exp(cs_i - cs_j) for i >= j else 0
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]    # [B,nc,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)
    W = CB * L * dtf[:, :, None, :, :]                   # weight on x_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xf)

    # ---- per-chunk state contribution --------------------------------------
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)        # [B,nc,Q,H]
    chunk_states = jnp.einsum("bcjhn,bcjhp->bchpn",
                              Bh * (dtf * decay_to_end)[..., None], xf)
    chunk_decay = jnp.exp(cs[:, :, -1, :])               # [B,nc,H]

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    if init_state is None:
        s0 = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        s0 = init_state.astype(jnp.float32)

    def step(state, inputs):
        c_state, c_decay = inputs                        # [B,H,P,N], [B,H]
        entering = state                                 # state before chunk
        new = state * c_decay[:, :, None, None] + c_state
        return new, entering

    (final_state, entering_states) = jax.lax.scan(
        step, s0, (chunk_states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    entering_states = entering_states.swapaxes(0, 1)     # [B,nc,H,P,N]

    # ---- inter-chunk output -------------------------------------------------
    c_weight = Ch * jnp.exp(cs)[..., None]               # [B,nc,Q,H,N]
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", c_weight, entering_states)

    y = (y_intra + y_inter).reshape(b, sp, h, p)[:, :s]
    return y.astype(out_dtype), final_state


def ssd_decode_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                   Bm: jax.Array, Cm: jax.Array,
                   state: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update.

    x [B,H,P], dt [B,H], Bm/Cm [B,G,N], state [B,H,P,N].
    Returns (y [B,H,P], new_state).
    """
    b, h, p = x.shape
    g, n = Bm.shape[1], Bm.shape[2]
    rep = h // g
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)   # [B,H,N]
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))              # [B,H]
    xdt = x.astype(jnp.float32) * dtf[..., None]           # [B,H,P]
    new_state = (state.astype(jnp.float32) * dA[:, :, None, None]
                 + xdt[..., None] * Bh[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state
