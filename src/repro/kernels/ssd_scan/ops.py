"""Dispatching wrapper for the SSD chunked scan.

On TPU the Pallas kernel (``kernel.py``) is used; on CPU the pure-jnp
oracle (``ref.py``) runs.  ``REPRO_FORCE_PALLAS_INTERPRET=1`` forces the
Pallas kernel in interpret mode (used by the kernel tests on CPU).
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax

from repro.kernels.ssd_scan import ref as _ref


def _use_pallas() -> Optional[bool]:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return None          # pallas, interpret mode
    return jax.default_backend() == "tpu"


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128,
        init_state=None) -> Tuple[jax.Array, jax.Array]:
    mode = _use_pallas()
    if mode is False:
        return _ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk,
                            init_state=init_state)
    from repro.kernels.ssd_scan import kernel as _k
    return _k.ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                         init_state=init_state,
                         interpret=(mode is None))


def ssd_decode(x, dt, A, Bm, Cm, state):
    return _ref.ssd_decode_ref(x, dt, A, Bm, Cm, state)
