"""AR-DiT (Causal-Forcing).  [arXiv:2602.02214]

Same Wan-1.3B backbone family as Self-Forcing with a deeper head count;
the two AR-DiT configs let the end-to-end benchmarks reproduce both model
columns of the paper's Figure 11.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="ardit-causal-forcing",
    family="ardit",
    n_layers=30,
    d_model=1536,
    n_heads=16,
    n_kv_heads=16,
    d_head=96,
    d_ff=8960,
    vocab_size=0,
    act="gelu",
    ardit_frame_tokens=880,
    ardit_chunk_frames=3,
    ardit_sink_chunks=1,
    ardit_window_chunks=7,
    denoise_steps=4,
))
