"""AR-DiT (Self-Forcing): the paper's own model family.  [arXiv Self-Forcing]

Wan2.1-T2V-1.3B-derived causal video DiT: 30 layers, d=1536, 12 heads,
ff 8960.  480p latents -> 3 latent frames per chunk, 880 tokens per latent
frame (60x44 patch grid / 4x temporal VAE), sink+local rolling KV.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="ardit-self-forcing",
    family="ardit",
    n_layers=30,
    d_model=1536,
    n_heads=12,
    n_kv_heads=12,
    d_head=128,
    d_ff=8960,
    vocab_size=0,           # latent-space model: no token embedding
    act="gelu",
    ardit_frame_tokens=880,
    ardit_chunk_frames=3,
    ardit_sink_chunks=1,
    ardit_window_chunks=7,
    denoise_steps=4,
))
