"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

Layer l is an attention layer iff (l % attn_every) == attn_every // 2
(1 attention : 7 Mamba).  MoE FFN on every other layer (moe_every=2).
Attention layers keep a bounded sink+window KV cache so long_500k decode is
sub-quadratic (Mamba state carries long-range context).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,
    attn_every=8,           # 1 attention layer per 8 (1:7 interleave)
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_window=8192,       # bounded attention cache for long-context decode
    attn_sink=128,
))
