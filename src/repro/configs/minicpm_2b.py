"""minicpm-2b [dense] — WSD schedule (arch=llama-like).  [arXiv:2404.06395; hf]

The WSD (warmup-stable-decay) schedule this model was trained with is
implemented in ``repro/train/optimizer.py`` and selected by this config.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
))
