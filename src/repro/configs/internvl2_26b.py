"""internvl2-26b [vlm] — InternViT + InternLM2 backbone.  [arXiv:2404.16821; hf]

Per the assignment, only the transformer BACKBONE is modeled; the InternViT
vision frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings (n_frontend_tokens x d_model) that are prepended to the text
sequence.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=92553,
    frontend="patch",
    n_frontend_tokens=256,
))
