"""qwen1.5-32b [dense] — QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,          # GQA kv=40 (full MHA-width KV)
    d_head=128,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
))
