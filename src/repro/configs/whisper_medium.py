"""whisper-medium [audio] — enc-dec, conv frontend (stub).  [arXiv:2212.04356]

Per the assignment the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (batch, seq, d_model) to the encoder.
24 encoder + 24 decoder layers.  Decode shapes exercise the DECODER
(self-attn KV cache of seq_len + cross-attn over encoder states).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    n_enc_layers=24,
    n_dec_layers=24,
    frontend="audio",
    n_frontend_tokens=1500,
))
