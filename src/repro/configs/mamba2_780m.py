"""mamba2-780m [ssm] — SSD (state-space duality).  [arXiv:2405.21060]

Attention-free; the paper's attention-specific fidelity knobs (rho, W) are
inapplicable (DESIGN.md SSArch-applicability) — the fidelity space for this
family degenerates to {Q, chunk size}.  Decode is O(1)/token, so long_500k
runs natively.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,              # attention-free
    n_kv_heads=0,
    d_ff=0,                 # no MLP; Mamba-2 blocks only
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
))
