from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K,
    LONG_500K, get_config, list_archs, register,
)
