"""Model / shape configuration system.

Every assigned architecture is a frozen ``ModelConfig``; the registry maps
``--arch <id>`` to its config.  ``reduced()`` derives a tiny same-family
config for CPU smoke tests.  Shapes are the four assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes (assigned: every arch is paired with these four cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str               # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524288, 1)

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str             # dense | moe | vlm | hybrid | ssm | encdec | ardit
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    act: str = "swiglu"             # swiglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden size
    moe_every: int = 1              # MoE FFN applied every k-th layer
    capacity_factor: float = 1.25
    # --- hybrid / ssm (Mamba-2) ---
    attn_every: int = 0             # hybrid: 1 attention layer per `attn_every`
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128            # SSD chunk length
    # --- encoder-decoder ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- multimodal frontend stub ---
    frontend: str = "none"          # none | patch | audio
    n_frontend_tokens: int = 0      # tokens contributed by the stub frontend
    # --- AR-DiT (the paper's model family) ---
    ardit_frame_tokens: int = 0     # tokens per latent frame (h/p * w/p)
    ardit_chunk_frames: int = 3     # latent frames per chunk (paper default)
    ardit_sink_chunks: int = 1      # attention-sink chunks kept forever
    ardit_window_chunks: int = 7    # local KV window (fidelity knob W max)
    denoise_steps: int = 4          # fidelity knob S default (highest quality)
    # --- serving ---
    attn_window: int = 0            # >0: sliding-window attention (tokens)
    attn_sink: int = 0              # sink tokens kept with windowed attention
    # --- numerics ---
    param_dtype: str = "bfloat16"
    kv_dtype: str = "bfloat16"      # fidelity knob Q can lower this to fp8
    # beyond-paper perf lever (EXPERIMENTS.md SSPerf): round backward
    # cotangents to bf16 at layer boundaries (halves backward-activation
    # collectives + HBM traffic; fp32 optimizer math unaffected)
    bf16_backward: bool = False
    # beyond-paper perf lever: expert parallelism — shard the EXPERT dim
    # over "model" (all-to-all dispatch) instead of expert-TP (hidden dim
    # over "model"); wins when per-expert hidden is small (granite: 512)
    moe_ep: bool = False
    # beyond-paper perf lever: parallel layout for training.
    #   "tp_fsdp" (default): TP over "model", FSDP over "data"
    #   "zero3": batch + params sharded over BOTH axes (256-way ZeRO-3,
    #            no tensor parallelism) — trades activation psums for
    #            per-layer parameter all-gathers
    parallel_layout: str = "tp_fsdp"

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding to a multiple of 256 so the
        vocab-parallel embedding/head shard evenly on any TP degree up
        to 256.  Token ids stay < vocab_size; padded rows are ordinary
        learnable rows that are never targets."""
        return ((self.vocab_size + 255) // 256) * 256 if self.vocab_size \
            else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def supports_shape(self, shape: ShapeConfig) -> bool:
        """Whether the (arch x shape) cell is runnable per the assignment.

        long_500k needs sub-quadratic attention: run for SSM / hybrid archs;
        pure full-attention archs are skipped (a windowed-KV adaptation is
        lowered separately, see DESIGN.md SS4).
        """
        if shape.name == "long_500k":
            return self.family in ("ssm", "hybrid") or self.attn_window > 0
        return True

    def with_window(self, window: int, sink: int = 4096) -> "ModelConfig":
        """Paper-technique adaptation: sink+local KV (SS2.1) for long contexts."""
        return replace(self, attn_window=window, attn_sink=sink)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_head=16,
            d_ff=128,
            vocab_size=256,
            param_dtype="float32",
            kv_dtype="float32",
        )
        if self.n_experts:
            kw.update(n_experts=4, top_k=2, moe_d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
        if self.n_enc_layers:
            kw.update(n_enc_layers=2, n_dec_layers=2)
        if self.n_frontend_tokens:
            kw.update(n_frontend_tokens=8)
        if self.ardit_frame_tokens:
            kw.update(ardit_frame_tokens=16)
        if self.attn_every:
            kw.update(attn_every=min(self.attn_every, 4), n_layers=4)
        return replace(self, name=self.name + "-reduced", **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    # Import every per-arch module for its registration side effect.
    from repro.configs import (  # noqa: F401
        qwen1_5_32b, minitron_8b, minicpm_2b, internlm2_20b,
        granite_moe_1b_a400m, qwen3_moe_235b_a22b, internvl2_26b,
        jamba_v0_1_52b, mamba2_780m, whisper_medium,
        ardit_self_forcing, ardit_causal_forcing,
    )
    _LOADED = True


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (matches init exactly; asserted in tests)."""
    from repro.models import registry as model_registry
    import jax

    params = jax.eval_shape(lambda: model_registry.init_fn(cfg)(jax_key()))
    return sum(int(_size(x)) for x in jax.tree_util.tree_leaves(params))


def _size(x):
    import numpy as np
    return np.prod(x.shape) if x.shape else 1


def jax_key():
    import jax
    return jax.random.PRNGKey(0)
