"""Int8 gradient compression with error feedback for cross-pod traffic.

At 1000+ node scale the pod-to-pod (DCN) all-reduce dominates training
communication.  We compress per-block to int8 with fp32 scales before the
cross-pod reduction and keep the quantization residual in an error-
feedback buffer (added back next step), which preserves convergence
(1-bit Adam / EF-SGD lineage).  Within a pod the all-reduce stays exact
bf16/fp32 — only the "pod" axis sees compressed bytes.

Usage inside a shard_map'd train step:
    g_q, scales, err = compress(g + err)
    g_sync = psum(decompress(g_q, scales), axis_name="pod") / n_pods
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """g (any shape, float) -> (int8 blocks, fp32 scales, residual)."""
    blocks, pad = _pad_to_block(g.astype(jnp.float32))
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    resid = (blocks - deq).reshape(-1)
    if pad:
        resid = resid[:-pad]
    return q, scale[:, 0], resid.reshape(g.shape).astype(g.dtype)


def decompress(q: jax.Array, scale: jax.Array, shape,
               dtype=jnp.float32) -> jax.Array:
    deq = q.astype(jnp.float32) * scale[:, None]
    flat = deq.reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(g: jax.Array, err: jax.Array,
                    axis_name: str) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over ``axis_name`` (inside
    shard_map).  Returns (averaged gradient, new error buffer)."""
    q, scale, new_err = compress(g + err.astype(g.dtype))
    deq = decompress(q, scale, g.shape, g.dtype)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    summed = jax.lax.psum(deq, axis_name)
    return summed / n, new_err


def init_error_buffers(grads_like: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, g.dtype), grads_like)
