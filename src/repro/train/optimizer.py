"""AdamW + LR schedules (cosine, and the WSD schedule minicpm-2b was
trained with — arXiv:2404.06395).  Pure-JAX (no optax in the container);
moments are fp32 and follow the parameter sharding (ZeRO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"          # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1           # WSD: final fraction spent decaying


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    if cfg.schedule == "wsd":
        # warmup -> stable -> decay (1-sqrt decay over the final fraction)
        decay_start = cfg.total_steps * (1.0 - cfg.decay_frac)
        frac = jnp.clip((s - decay_start)
                        / jnp.maximum(cfg.total_steps - decay_start, 1.0),
                        0.0, 1.0)
        return cfg.lr * warm * (1.0 - (1.0 - 0.1) * jnp.sqrt(frac))
    # cosine to 10% of peak
    t = jnp.clip(s / cfg.total_steps, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def _decay_mask(path) -> bool:
    """Weight decay on matrices only (no norms / biases / scalars)."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return name not in ("attn_norm", "mlp_norm", "final_norm", "norm",
                        "norm_w", "mixer_norm", "ffn_norm", "cross_norm",
                        "enc_final_norm", "bq", "bk", "bv", "conv_b",
                        "dt_bias", "A_log", "D", "mod_b")


def adamw_update(cfg: OptConfig, params: Any, grads: Any,
                 state: OptState) -> Tuple[Any, OptState, Dict[str, Any]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * g32 * g32
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * u
        return p2.astype(p.dtype), m2, v2

    flat = jax.tree_util.tree_map_with_path(upd, params, grads,
                                            state.m, state.v)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda t: isinstance(t,
                                                                     tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_m, new_v), metrics
