"""Sharded checkpoint/restart with elastic resharding.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
JSON index (tree structure, shapes, dtypes, step).  Saves run on a
background thread (async — the train loop donates nothing and keeps
stepping).  ``restore`` rebuilds the state under ANY mesh: leaves are
loaded on host and ``jax.device_put`` against the new NamedShardings, so
a job checkpointed on a (16,16) mesh restarts on (2,16,16), (8,8), or a
single CPU device — the fault-tolerance path for node failures and
elastic rescale at 1000+ node scale.

Crash safety: writes go to ``step_<N>.tmp`` and are atomically renamed;
``latest_step`` only ever sees complete checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = []
    for path, leaf in leaves:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path)
        named.append((name.replace("/", "__") or "leaf", leaf))
    return named, treedef


def save(ckpt_dir: str, step: int, state: Any, *,
         blocking: bool = True) -> threading.Thread:
    """Write ``state`` under ``ckpt_dir/step_<step>``.  With
    ``blocking=False`` the device->host copy happens now but file IO runs
    on a daemon thread (async checkpointing)."""
    named, _ = _flatten_with_names(state)
    host = [(n, np.asarray(x)) for n, x in named]

    def _write():
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        index: Dict[str, Any] = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(host):
            fn = f"{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            index["leaves"].append({"name": name, "file": fn,
                                    "shape": list(arr.shape),
                                    "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if blocking:
        t.join()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_", 1)[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "index.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, state_like: Any,
            shardings: Optional[Any] = None) -> Any:
    """Rebuild ``state_like``-shaped state from disk.

    ``shardings``: optional NamedSharding pytree for the CURRENT mesh —
    elastic resharding happens here (host load + device_put per leaf).
    """
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    named_like, treedef = _flatten_with_names(state_like)
    by_name = {e["name"]: e for e in index["leaves"]}
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(named_like))
    out = []
    for (name, like), shard in zip(named_like, shard_leaves):
        entry = by_name[name]
        arr = np.load(os.path.join(path, entry["file"]))
        want_shape = tuple(like.shape)
        assert tuple(arr.shape) == want_shape, (name, arr.shape, want_shape)
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
