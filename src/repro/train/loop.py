"""Distributed train step + loop.

``make_train_step`` builds the jit-able step for any registered model
family: loss (model-specific) -> grads -> AdamW.  Under a mesh the step
is jit'd with NamedSharding in/out specs from ``distributed.sharding``
(TP x FSDP x DP; ZeRO optimizer state).  Microbatching (gradient
accumulation) runs as a ``lax.scan`` over microbatch slices so the
compiled HLO is O(1) in the accumulation factor.  Remat is inside each
model's ``forward`` (checkpointed scan over layers).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.distributed.logical import logical_axis_rules
from repro.models import registry
from repro.train import optimizer as opt


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: opt.OptState


def loss_for(cfg: ModelConfig) -> Callable:
    api = registry.get_api(cfg)
    return lambda params, batch: api.loss(cfg, params, batch)


def make_train_step(cfg: ModelConfig, opt_cfg: opt.OptConfig,
                    *, microbatches: int = 1) -> Callable:
    """(state, batch) -> (state, metrics).  Pure; jit outside."""
    loss_fn = loss_for(cfg)

    def step(state: TrainState, batch: Dict[str, Any]):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            def slice_mb(x, i):
                mb = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

            def mb_body(acc, i):
                mb = jax.tree_util.tree_map(
                    functools.partial(slice_mb, i=i), batch)
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree_util.tree_map(jnp.add, acc_g, g)), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                mb_body, (jnp.zeros(()), zero_g),
                jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches,
                                           grads)
        params, opt_state, metrics = opt.adamw_update(
            opt_cfg, state.params, grads, state.opt_state)
        metrics["loss"] = loss
        return TrainState(params, opt_state), metrics

    return step


# ---------------------------------------------------------------------------
# sharded initialization / jit wiring
# ---------------------------------------------------------------------------

def state_shardings(cfg: ModelConfig, mesh: Mesh) -> TrainState:
    """NamedSharding pytree for TrainState (ZeRO: moments follow params)."""
    p_specs = registry.param_specs(cfg)
    p_shard = shd.param_shardings(p_specs, mesh, ep=cfg.moe_ep,
                                  layout=cfg.parallel_layout)
    scalar = NamedSharding(mesh, P())
    return TrainState(
        params=p_shard,
        opt_state=opt.OptState(
            step=scalar,
            m=jax.tree_util.tree_map(lambda s: s, p_shard),
            v=jax.tree_util.tree_map(lambda s: s, p_shard)))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_spec: Any) -> Any:
    bp = shd.batch_pspec(mesh, layout=cfg.parallel_layout)

    def shard_leaf(leaf):
        nd = len(leaf.shape)
        return NamedSharding(mesh, P(*(tuple(bp) + (None,) * (nd - 1))))
    return jax.tree_util.tree_map(shard_leaf, batch_spec)


def lower_train_step(cfg: ModelConfig, mesh: Mesh, shape,
                     opt_cfg: Optional[opt.OptConfig] = None,
                     microbatches: int = 1):
    """Lower (not run) the sharded train step for the dry-run."""
    opt_cfg = opt_cfg or opt.OptConfig(
        schedule="wsd" if cfg.name.startswith("minicpm") else "cosine")
    step = make_train_step(cfg, opt_cfg, microbatches=microbatches)
    st_shard = state_shardings(cfg, mesh)
    batch_spec = registry.input_specs(cfg, shape)
    b_shard = batch_shardings(cfg, mesh, batch_spec)

    p_specs = registry.param_specs(cfg)
    state_spec = TrainState(
        params=p_specs,
        opt_state=opt.OptState(
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                p_specs),
            jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                p_specs)))

    def wrapped(state, batch):
        with logical_axis_rules(mesh, shd.train_rules(
                mesh, ep=cfg.moe_ep, layout=cfg.parallel_layout)):
            return step(state, batch)

    scalar = NamedSharding(mesh, P())
    metrics_shard = {"grad_norm": scalar, "lr": scalar, "loss": scalar}
    jitted = jax.jit(wrapped,
                     in_shardings=(jax.tree_util.tree_map(
                         lambda s: s, st_shard), b_shard),
                     out_shardings=(st_shard, metrics_shard))
    return jitted.lower(state_spec, batch_spec)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt_state), None),
    lambda _, c: TrainState(*c))
