"""Three-tier priority queues and credit-aware eviction (paper SS4.1).

At each control tick the Control Plane orders every worker's queue by
service credit ascending (lower credit dispatches first), giving local
preemption at step/chunk boundaries.  Credit-aware eviction frees KV-pool
residency by evicting the *highest*-credit resident stream — the one
least likely to stall (Fig. 8).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Union

from repro.core.types import ClusterView, Stream, Tier, Worker


def order_queue(worker: Worker, streams: Dict[int, Stream]) -> None:
    """Sort the worker's queue by service credit (ascending)."""
    worker.queue.sort(key=lambda sid: streams[sid].credit)


def order_all(view: ClusterView) -> None:
    for w in view.workers:
        order_queue(w, view.streams)


def next_dispatch_set(worker: Worker, streams: Dict[int, Stream],
                      now: float,
                      max_batch: Optional[int] = None) -> List[int]:
    """Credit-ordered runnable streams on this worker, lowest credit
    first, up to ``max_batch`` (paused/migrating streams are skipped;
    atomic safety keeps mid-transfer streams out of the queue entirely,
    SS4.4).  The batched executor composes its denoise-step micro-batch
    from this set; ``next_dispatch`` is the sequential special case."""
    out: List[int] = []
    for sid in worker.queue:
        s = streams[sid]
        if s.done or s.finished:
            continue
        if s.paused_until > now:
            continue
        out.append(sid)
        if max_batch is not None and len(out) >= max_batch:
            break
    return out


def next_dispatch(worker: Worker, streams: Dict[int, Stream],
                  now: float) -> Optional[int]:
    """Lowest-credit runnable stream on this worker (or None)."""
    sids = next_dispatch_set(worker, streams, now, max_batch=1)
    return sids[0] if sids else None


def pick_eviction(resident_sids: List[int], streams: Dict[int, Stream],
                  protect: Union[int, Iterable[int], None] = None,
                  ) -> Optional[int]:
    """Credit-aware eviction: evict the highest-credit resident stream
    (the one least likely to stall, Fig. 8).

    ``protect`` is a sid — or an iterable of sids — that must not be
    chosen: the stream being admitted plus any in-flight streams whose
    gathered context still references pool pages.  Credit ties break
    deterministically toward the LOWEST sid, so a replayed schedule
    evicts identically."""
    if protect is None:
        shield = frozenset()
    elif isinstance(protect, Iterable):
        shield = frozenset(protect)
    else:
        shield = frozenset((protect,))
    candidates = [sid for sid in resident_sids if sid not in shield]
    if not candidates:
        return None
    return max(candidates, key=lambda sid: (streams[sid].credit, -sid))


def pick_page_eviction(resident_sids: List[int], streams: Dict[int, Stream],
                       protect: Union[int, Iterable[int], None] = None,
                       has_evictable=None) -> Optional[int]:
    """Page-granular eviction victim: the highest-credit resident that
    still has an evictable ring page (``has_evictable(sid)``, supplied
    by the pool — a stream degraded down to its floor drops out of the
    candidate set).  Same protections and deterministic tie-break as
    ``pick_eviction``; this is the FIRST rung of the degradation ladder
    (trade one stream's window W down by a page) before whole-stream
    spill."""
    if protect is None:
        shield = frozenset()
    elif isinstance(protect, Iterable):
        shield = frozenset(protect)
    else:
        shield = frozenset((protect,))
    candidates = [sid for sid in resident_sids if sid not in shield
                  and (has_evictable is None or has_evictable(sid))]
    if not candidates:
        return None
    return max(candidates, key=lambda sid: (streams[sid].credit, -sid))


def tier_counts(view: ClusterView) -> Dict[int, Dict[Tier, int]]:
    """Per-worker tier histogram over queued + running streams."""
    out: Dict[int, Dict[Tier, int]] = {}
    streams = view.streams
    for w in view.workers:
        u = nrm = r = 0
        for sid in w.queue:
            t = streams[sid].tier
            if t is Tier.URGENT:
                u += 1
            elif t is Tier.NORMAL:
                nrm += 1
            else:
                r += 1
        if w.running is not None:
            t = streams[w.running].tier
            if t is Tier.URGENT:
                u += 1
            elif t is Tier.NORMAL:
                nrm += 1
            else:
                r += 1
        out[w.wid] = {Tier.URGENT: u, Tier.NORMAL: nrm, Tier.RELAXED: r}
    return out


def worker_class(counts: Dict[Tier, int]) -> str:
    """URGENT-heavy / RELAXED-only / mixed (SS4.2 terminology)."""
    if counts[Tier.URGENT] > 0:
        return "urgent"
    if counts[Tier.NORMAL] == 0:
        return "relaxed"
    return "mixed"


def worker_class_triple(view: ClusterView) -> tuple:
    """(n_urgent, n_mixed, n_relaxed) worker counts in ONE pass —
    exactly ``worker_class(tier_counts(view)[wid])`` tallied over all
    workers, without materializing the per-worker histograms (the fleet
    tick samples this every 3 simulated seconds)."""
    n_urgent = n_mixed = n_relaxed = 0
    streams = view.streams
    for w in view.workers:
        urgent = False
        normal = False
        for sid in w.queue:
            t = streams[sid].tier
            if t == Tier.URGENT:
                urgent = True
                break
            if t == Tier.NORMAL:
                normal = True
        else:
            if w.running is not None:
                t = streams[w.running].tier
                if t == Tier.URGENT:
                    urgent = True
                elif t == Tier.NORMAL:
                    normal = True
        if urgent:
            n_urgent += 1
        elif normal:
            n_mixed += 1
        else:
            n_relaxed += 1
    return (n_urgent, n_mixed, n_relaxed)


def min_credits(view: ClusterView) -> Dict[int, float]:
    """Per-worker minimum credit over queued + running streams (inf for
    an idle worker) — the elastic-SP donor-quality signal, hoisted to
    one pass per tick."""
    out: Dict[int, float] = {}
    streams = view.streams
    for w in view.workers:
        best = float("inf")
        for sid in w.queue:
            c = streams[sid].credit
            if c < best:
                best = c
        if w.running is not None:
            c = streams[w.running].credit
            if c < best:
                best = c
        out[w.wid] = best
    return out
