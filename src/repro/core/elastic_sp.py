"""Elastic sequence parallelism (paper SS4.3 + App. C.3).

Last-resort recovery: when a stream's service credit is negative (it is
projected to miss its playout window even after priority scheduling and
re-homing), borrow ONE donor worker — the highest-credit RELAXED worker
in the same node — and switch the stream to the pre-initialized intra-node
SP2 group.  The donor is released at the next safe boundary once the
stream recovers to NORMAL (C_u >= 2 T_u).  All SP2 groups are
pre-initialized before serving (pre-compiled executables in the JAX
executor), so triggering elastic SP never creates communication groups on
the critical path; the head-partition KV transfer (App. C.4) goes through
the State Plane.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import queues
from repro.core.types import ClusterView, Stream, Tier, Worker

RELEASE_FACTOR = 2.0          # release when C_u >= 2 * T_u (NORMAL tier)
MAX_SP = 2                    # intra-node SP2 only (App. C.3)


@dataclasses.dataclass(frozen=True)
class SPDecision:
    sid: int
    donor: int                # worker borrowed
    kind: str                 # "expand" | "release"


def plan_elastic_sp(view: ClusterView, now: float,
                    exclude: Optional[set] = None,
                    counts: Optional[Dict[int, Dict[Tier, int]]] = None,
                    donor_credits: Optional[Dict[int, float]] = None,
                    ) -> List[SPDecision]:
    """``exclude``: streams already helped this tick (e.g. just re-homed)
    — elastic SP is the NEXT line of defense, not a parallel one (SS4).
    ``counts``: the tick's tier histogram, passed by ``ControlPlane.tick``
    so both planners share one counting pass.  ``donor_credits``: per-
    worker min resident credit, precomputed in ONE pass by the vectorized
    control tick — queue contents don't change while planning, so the
    hoist is exact (the fallback recomputes per candidate donor)."""
    exclude = exclude or set()
    if counts is None:
        counts = queues.tier_counts(view)
    decisions: List[SPDecision] = []

    if donor_credits is not None:
        # vectorized tick: in overload almost every stream is C_u < 0
        # while almost no worker is RELAXED, so the scan order flips —
        # ONE pass over the streams collects releases + the borrowed
        # donor set + the C_u < 0 candidates, then the (few) donor-
        # eligible workers are bucketed per node.  Exact: releases
        # don't depend on other streams, each donor serves at most one
        # stream, the stable sort over the filtered subsequence visits
        # streams in the same order the full sort would, and the
        # per-node buckets preserve ``view.workers`` iteration order,
        # so each stream sees the identical donor list.
        borrowed: set = set()
        released: set = set()
        cands: List[Stream] = []
        for s in view.streams.values():
            d = s.sp_donor
            if d is not None:
                if (not s.done and s.t_next > 0.0
                        and s.credit >= RELEASE_FACTOR * s.t_next):
                    decisions.append(SPDecision(s.sid, d, "release"))
                    released.add(d)
                else:
                    borrowed.add(d)
            elif (not s.done and s.credit < 0.0
                    and s.sid not in exclude):
                cands.append(s)
        relaxed_by_node: Dict[int, List[Worker]] = {}
        for w in view.workers:
            if (not w.retired
                    and (w.donated_to is None or w.wid in released)
                    and queues.worker_class(counts[w.wid]) == "relaxed"):
                relaxed_by_node.setdefault(view.node_of(w.wid),
                                           []).append(w)
        if not relaxed_by_node:
            return decisions              # no donor anywhere this tick
        for s in sorted(cands, key=lambda s: s.credit):
            donors = [w for w in relaxed_by_node.get(view.node_of(s.home),
                                                     ())
                      if w.wid != s.home and w.wid not in borrowed]
            if not donors:
                continue
            donor = max(donors,
                        key=lambda w: donor_credits.get(w.wid,
                                                        float("inf")))
            borrowed.add(donor.wid)
            decisions.append(SPDecision(s.sid, donor.wid, "expand"))
        return decisions

    borrowed = {s.sp_donor for s in view.streams.values()
                if s.sp_donor is not None}

    # ---- releases first (free donors at safe boundaries) ------------------
    # t_next == 0.0 is the "no latency estimate yet" default (e.g.
    # use_fidelity=False, or before the first selection); comparing
    # credit against RELEASE_FACTOR * 0 would release every donor on
    # the very tick it was borrowed, so the check requires a real
    # estimate.  A donor released here rejoins the donor set below —
    # it is free again this tick, not stranded until the next one.
    released = set()
    for s in view.active_streams():
        if (s.sp_donor is not None and s.t_next > 0.0
                and s.credit >= RELEASE_FACTOR * s.t_next):
            decisions.append(SPDecision(s.sid, s.sp_donor, "release"))
            borrowed.discard(s.sp_donor)
            released.add(s.sp_donor)

    # ---- expansions: C_u < 0 streams, one donor each -----------------------
    for s in sorted(view.active_streams(), key=lambda s: s.credit):
        if (s.credit >= 0.0 or s.sp_donor is not None or s.done
                or s.sid in exclude):
            continue
        node = view.node_of(s.home)
        donors = [w for w in view.workers
                  if view.node_of(w.wid) == node and w.wid != s.home
                  and not w.retired
                  and (w.donated_to is None or w.wid in released)
                  and w.wid not in borrowed
                  and queues.worker_class(counts[w.wid]) == "relaxed"]
        if not donors:
            continue          # no same-node RELAXED donor: SP not triggered
        # credit-aware donor selection: highest-credit RELAXED worker
        def donor_credit(w: Worker) -> float:
            sids = list(w.queue) + ([w.running] if w.running
                                    is not None else [])
            if not sids:
                return float("inf")
            return min(view.streams[x].credit for x in sids)
        donor = max(donors, key=donor_credit)
        borrowed.add(donor.wid)
        decisions.append(SPDecision(s.sid, donor.wid, "expand"))
    return decisions


def apply_expand(view: ClusterView, dec: SPDecision) -> None:
    s = view.streams[dec.sid]
    s.sp_donor = dec.donor
    view.workers[dec.donor].donated_to = dec.sid


def apply_release(view: ClusterView, dec: SPDecision) -> None:
    s = view.streams[dec.sid]
    if s.sp_donor is not None:
        view.workers[s.sp_donor].donated_to = None
    s.sp_donor = None
