"""Fidelity-configuration space (paper SS2.1, SS5, App. A).

Four knobs: denoising steps S in {2,3,4}, attention sparsity rho in
{0,.6,.7,.8,.9}, KV-window W in {1,3,7} chunks, quantization Q in
{FP16, FP8} -> 3*5*3*2 = 90 candidate configurations; (4, 0, 7, FP16)
is the highest-quality reference.
"""
from __future__ import annotations

import itertools
from typing import List

from repro.models.ardit import FidelityConfig, HIGHEST_QUALITY  # noqa: F401

STEPS = (2, 3, 4)
SPARSITIES = (0.0, 0.6, 0.7, 0.8, 0.9)
WINDOWS = (1, 3, 7)
QUANTS = ("bf16", "fp8")


def candidate_space() -> List[FidelityConfig]:
    """All 90 candidate fidelity configurations (App. A)."""
    return [FidelityConfig(s, r, w, q)
            for s, r, w, q in itertools.product(STEPS, SPARSITIES,
                                                WINDOWS, QUANTS)]


assert len(candidate_space()) == 90
