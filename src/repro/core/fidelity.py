"""Fidelity-configuration space (paper SS2.1, SS5, App. A).

Four knobs: denoising steps S in {2,3,4}, attention sparsity rho in
{0,.6,.7,.8,.9}, KV-window W in {1,3,7} chunks, quantization Q in
{FP16, FP8} -> 3*5*3*2 = 90 candidate configurations; (4, 0, 7, FP16)
is the highest-quality reference.

The repo adds a fifth knob the paper doesn't have: the AdaCache-style
step cache (``models/stepcache.py``), ``cache in {off, conservative,
aggressive}``.  ``candidate_space(step_cache=True)`` triples the space
to 270; the default keeps the paper's 90 cache=off points so existing
profiles, frontiers, and calibration baselines are unchanged.
"""
from __future__ import annotations

import itertools
from typing import List

from repro.models.ardit import FidelityConfig, HIGHEST_QUALITY  # noqa: F401

STEPS = (2, 3, 4)
SPARSITIES = (0.0, 0.6, 0.7, 0.8, 0.9)
WINDOWS = (1, 3, 7)
QUANTS = ("bf16", "fp8")
CACHE_LEVELS = ("off", "conservative", "aggressive")


def candidate_space(step_cache: bool = False) -> List[FidelityConfig]:
    """All candidate fidelity configurations: the paper's 90 (App. A),
    or 270 with the step-cache knob unlocked."""
    caches = CACHE_LEVELS if step_cache else ("off",)
    return [FidelityConfig(s, r, w, q, c)
            for s, r, w, q, c in itertools.product(STEPS, SPARSITIES,
                                                   WINDOWS, QUANTS,
                                                   caches)]


assert len(candidate_space()) == 90
assert len(candidate_space(step_cache=True)) == 270
