"""State Plane (paper SS4.4, Fig. 9, App. D.2).

Unified KV management: each worker owns a paged pool (kappa = 0.8 of
VRAM), pages at latent-frame granularity, logical page table per stream.
Credit-aware eviction (SS4.1), re-homing (SS4.2) and elastic SP (SS4.3)
all move state through ONE interface:

    transfer(stream, src, dst, page_range)

executed by an async transfer engine with three protocols (Fig. 13):

    sync             dispatcher blocked until the full transfer completes
    async-nostream   submitted asynchronously; destination compute starts
                     only after the full state arrives
    async-stream     layer-wise streaming: the stream is re-queued once
                     its FIRST layer is resident (atomic safety), later
                     layers overlap with computation

Timing model (CPU container; constants mirror the paper's testbed — see
``repro.sched_sim.cost_model`` for derivations): NVLink-class intra-node
effective bandwidth, IB-class cross-node, fixed submission overhead.  In
the JAX executor the same engine issues device-to-device copies.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

# ---------------------------------------------------------------------------
# paged pool
# ---------------------------------------------------------------------------


class PagedKVPool:
    """Physical page pool of one worker; frame-granularity pages."""

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.free: int = n_pages
        self.tables: Dict[int, int] = {}      # sid -> pages held

    def resident(self, sid: int) -> bool:
        return sid in self.tables

    def pages_of(self, sid: int) -> int:
        return self.tables.get(sid, 0)

    def can_alloc(self, n: int) -> bool:
        return self.free >= n

    def alloc(self, sid: int, n: int) -> bool:
        if self.free < n:
            return False
        self.free -= n
        self.tables[sid] = self.tables.get(sid, 0) + n
        return True

    def release(self, sid: int) -> int:
        n = self.tables.pop(sid, 0)
        self.free += n
        return n

    def release_pages(self, sid: int, n: int) -> None:
        """Give back ``n`` of ``sid``'s pages without releasing the
        stream (page-granular partial-window eviction: the stream stays
        resident with a smaller effective window)."""
        held = self.tables.get(sid, 0)
        assert held >= n, \
            f"stream {sid} holds {held} pages, cannot release {n}"
        self.tables[sid] = held - n
        self.free += n

    def resident_sids(self) -> List[int]:
        return list(self.tables)

    @property
    def used(self) -> int:
        return self.n_pages - self.free

    def check(self) -> None:
        """Page-conservation invariant: every page is either free or in
        exactly one table.  Raises AssertionError on accounting drift
        (the device-side pool mirrors into this class, so the property
        suite leans on it).  Zero-page tables are legal: the simulator
        admits a restored stream with ``alloc(sid, min(want, free))``,
        which is 0 under full pressure."""
        assert self.free >= 0, "negative free-page count"
        assert all(n >= 0 for n in self.tables.values()), \
            "resident stream holding negative pages"
        assert self.free + sum(self.tables.values()) == self.n_pages, \
            "page leak: used + free != n_pages"


# ---------------------------------------------------------------------------
# transfer engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeasuredTransfer:
    """One REAL cross-device move observed by the executor (wall time
    around a ``jax.device_put`` + ``block_until_ready``), recorded next
    to the modeled ``TransferTiming`` log so measured and modeled
    transfer costs share one surface."""
    n_bytes: int
    seconds: float
    cross_node: bool
    kind: str                     # "migration" | "sp-expand" | "move"

    @property
    def bytes_per_s(self) -> float:
        return self.n_bytes / max(self.seconds, 1e-9)


@dataclasses.dataclass(frozen=True)
class TransferTiming:
    submitted: float
    first_layer_ready: float      # stream may re-enter the queue here
    complete: float               # all pages resident
    cross_node: bool
    bytes: int

    @property
    def total(self) -> float:
        return self.complete - self.submitted

    @property
    def residual_wait(self) -> float:
        """Time the dispatcher actually waited (protocol-dependent)."""
        return self.first_layer_ready - self.submitted


class AsyncTransferEngine:
    """Models SS4.4's NIXL/NCCL engine; one protocol for eviction,
    re-homing and elastic SP."""

    # blend of prior vs newest observed bandwidth when calibrating
    BW_EMA_DECAY = 0.5

    def __init__(self, *, protocol: str = "async-stream",
                 bw_intra: float = 200e9, bw_inter: float = 40e9,
                 overhead: float = 0.004, n_layers: int = 30,
                 calibrate: bool = True):
        assert protocol in ("sync", "async-nostream", "async-stream")
        self.protocol = protocol
        self.bw_intra = bw_intra
        self.bw_inter = bw_inter
        # the offline constants, kept for reporting once measurement
        # starts calibrating the live values
        self.bw_intra_model = bw_intra
        self.bw_inter_model = bw_inter
        self.overhead = overhead
        self.n_layers = n_layers
        self.calibrate = calibrate
        self.log: List[TransferTiming] = []
        self.measured: List[MeasuredTransfer] = []

    def record_measured(self, n_bytes: int, seconds: float, *,
                        cross_node: bool = False,
                        kind: str = "move") -> MeasuredTransfer:
        """Record one REAL device-to-device move (measured wall time)
        and, when ``calibrate``, fold its observed bytes/sec into the
        matching bandwidth constant (EMA) — so the *modeled* timelines
        of future ``transfer`` calls track this host's interconnect
        instead of the offline testbed constant."""
        m = MeasuredTransfer(n_bytes, seconds, cross_node, kind)
        self.measured.append(m)
        if self.calibrate and n_bytes > 0:
            obs = m.bytes_per_s
            if cross_node:
                self.bw_inter = (self.BW_EMA_DECAY * self.bw_inter
                                 + (1.0 - self.BW_EMA_DECAY) * obs) \
                    if len([x for x in self.measured
                            if x.cross_node]) > 1 else obs
            else:
                self.bw_intra = (self.BW_EMA_DECAY * self.bw_intra
                                 + (1.0 - self.BW_EMA_DECAY) * obs) \
                    if len([x for x in self.measured
                            if not x.cross_node]) > 1 else obs
        return m

    def measured_stats(self) -> Dict[str, float]:
        """Aggregate view of the measured-move log (the benchmark's
        ``transfer_measured`` block)."""
        n_bytes = sum(m.n_bytes for m in self.measured)
        seconds = sum(m.seconds for m in self.measured)
        return {
            "count": len(self.measured),
            "bytes": n_bytes,
            "seconds": round(seconds, 6),
            "bytes_per_s": round(n_bytes / seconds, 2) if seconds else 0.0,
            "bw_intra_calibrated": round(self.bw_intra, 2),
            "bw_intra_model": self.bw_intra_model,
        }

    def transfer(self, now: float, n_bytes: int, *,
                 cross_node: bool) -> TransferTiming:
        """Unified interface: returns the readiness timeline."""
        bw = self.bw_inter if cross_node else self.bw_intra
        total = self.overhead + n_bytes / bw
        per_layer = (n_bytes / self.n_layers) / bw
        if self.protocol == "async-stream":
            ready = now + self.overhead + per_layer
        else:
            ready = now + total          # sync / async-nostream wait fully
        t = TransferTiming(now, ready, now + total, cross_node, n_bytes)
        self.log.append(t)
        return t

    def blocks_dispatcher(self) -> bool:
        return self.protocol == "sync"
