"""Shared control-plane state types (paper SS3.1, Table 1).

These are the *control-plane views*: plain dataclasses mutated by the
event loop (simulator or real executor).  All times are absolute seconds
on the driving clock.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY


class Tier(enum.IntEnum):
    URGENT = 0
    NORMAL = 1
    RELAXED = 2


@dataclasses.dataclass
class Stream:
    """One real-time video generation session (Table 1)."""
    sid: int
    arrival: float
    target_chunks: int
    chunk_seconds: float              # playout seconds per chunk
    home: int                         # home worker id
    ttfc_slack: float                 # initial playout slack (SS3.3 step 1)

    # --- playout timeline ---
    next_deadline: float = 0.0        # ddl of the next (chunks_done+1) chunk
    chunks_done: int = 0
    first_chunk_time: Optional[float] = None
    ready_times: List[float] = dataclasses.field(default_factory=list)
    deadlines: List[float] = dataclasses.field(default_factory=list)
    stall_time: float = 0.0
    stall_events: List[float] = dataclasses.field(default_factory=list)
    qualities: List[float] = dataclasses.field(default_factory=list)
    fidelity_log: List[str] = dataclasses.field(default_factory=list)

    # --- execution state ---
    running_on: Optional[Tuple[int, ...]] = None   # worker ids (SP group)
    step_done: int = 0                # denoise steps finished in cur chunk
    chunk_started: Optional[float] = None
    next_fidelity: FidelityConfig = HIGHEST_QUALITY
    _t_next: float = dataclasses.field(default=0.0, repr=False)
    remaining: float = 0.0            # R_u estimate for running chunk

    # --- control state ---
    credit: float = 0.0
    tier: Tier = Tier.NORMAL
    cooldown_until: float = -1e9      # re-homing cooldown (App. C.2)
    sp_donor: Optional[int] = None    # borrowed worker (SS4.3)
    resident_on: Set[int] = dataclasses.field(default_factory=set)
    paused_until: float = -1.0
    done: bool = False
    # heterogeneous co-serving: which model bundle backs this stream
    # (None on single-model paths — every consumer treats None as the
    # session's one model, so legacy behavior is untouched)
    model: Optional[str] = None

    @property
    def t_next(self) -> float:
        """T_u (Eq. 1): profiled *latency* of the next chunk — a
        DURATION in driving-clock seconds, never an absolute completion
        time.  Both writers (the simulator's cost model and the real
        session's ``_begin_if_needed``) must store the same unit; the
        elastic-SP release guard compares it against ``credit`` (also a
        duration), so an absolute timestamp here silently disables
        release.  The setter rejects values that cannot be a latency."""
        return self._t_next

    @t_next.setter
    def t_next(self, latency: float) -> None:
        if not (isinstance(latency, (int, float))
                and math.isfinite(latency) and latency >= 0.0):
            raise ValueError(
                f"t_next must be a finite non-negative duration (T_u), "
                f"got {latency!r} — absolute timestamps are a unit bug")
        self._t_next = float(latency)

    @property
    def finished(self) -> bool:
        return self.chunks_done >= self.target_chunks

    def playout_slack(self, now: float) -> float:
        """P_u: remaining playable buffer ahead of the playout cursor."""
        return self.next_deadline - now


@dataclasses.dataclass
class Worker:
    """One GPU / one model replica (SS3.1 footnote 3)."""
    wid: int
    node: int
    queue: List[int] = dataclasses.field(default_factory=list)  # stream ids
    running: Optional[int] = None          # stream currently executing
    donated_to: Optional[int] = None       # stream borrowing this worker
    sent_this_tick: int = 0
    recv_this_tick: int = 0
    # front-door scale-in: a retired worker keeps its wid slot (wids
    # index per-worker arrays everywhere) but receives no dispatches,
    # re-homings, SP donations, or admissions until revived
    retired: bool = False

    def load(self, weight: Optional[Callable[[int], float]] = None):
        """Queued + running + donated: a worker lending itself as an
        SP2 half (SS4.3) is occupied even though the borrowed stream
        never appears in its own queue.

        With ``weight`` (sid -> per-model placement weight, heterogeneous
        co-serving) each occupant counts its weight instead of 1 — a
        cheap SSM stream occupies less of a worker than a heavy MoE
        stream.  Without it the exact integer count is returned, so
        single-model argmins are unchanged."""
        if weight is None:
            return (len(self.queue) + (1 if self.running is not None else 0)
                    + (1 if self.donated_to is not None else 0))
        load = sum(weight(sid) for sid in self.queue)
        if self.running is not None:
            load += weight(self.running)
        if self.donated_to is not None:
            load += weight(self.donated_to)
        return load


@dataclasses.dataclass
class ClusterView:
    """Everything the Control Plane sees at a tick."""
    streams: Dict[int, Stream]
    workers: List[Worker]
    workers_per_node: int = 8
    # heterogeneous co-serving: sid -> placement weight of the stream's
    # model bundle; None keeps placement on the integer queue-depth path
    stream_weight: Optional[Callable[[int], float]] = None

    def node_of(self, wid: int) -> int:
        return self.workers[wid].node

    def active_streams(self) -> List[Stream]:
        return [s for s in self.streams.values() if not s.done]
