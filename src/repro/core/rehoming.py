"""Bipartite Re-homing Planning (paper Algorithm 1 + App. C.2).

Senders: URGENT-heavy workers.  Receivers: workers with no URGENT or
NORMAL streams (slack headroom only).  Safeguards: per-stream 60 s
cooldown, per-tick caps (send <= 2, recv <= 1), intra-node receivers
preferred before cross-node ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import queues
from repro.core.types import ClusterView, Stream, Tier, Worker

COOLDOWN_S = 60.0
CAP_SEND = 2
CAP_RECV = 1


@dataclasses.dataclass(frozen=True)
class Migration:
    sid: int
    src: int
    dst: int
    cross_node: bool


def plan_rehoming(view: ClusterView, now: float,
                  cooldown_s: float = COOLDOWN_S,
                  cap_send: int = CAP_SEND,
                  cap_recv: int = CAP_RECV,
                  counts: Optional[Dict[int, Dict[Tier, int]]] = None,
                  ) -> List[Migration]:
    # the caller (ControlPlane.tick) may pass the tick's tier histogram
    # so the two planners share ONE O(streams) counting pass
    if counts is None:
        counts = queues.tier_counts(view)
    # a worker serving someone else's SP2 half is NOT slack headroom:
    # its donated compute is invisible to its own tier counts (the
    # borrowed stream is homed elsewhere), so without this filter a
    # migration could land on a lane that is already busy donating
    receivers = [w for w in view.workers
                 if w.donated_to is None and not w.retired
                 and queues.worker_class(counts[w.wid]) == "relaxed"]
    if not receivers:
        # fleet-overload fast exit: with nowhere to re-home to, the
        # sender scan below is a dead O(streams) pass (no migration —
        # and no cooldown burn — can happen without a receiver)
        return []
    # senders are URGENT-HEAVY workers (congested URGENT queues, Alg. 1
    # line 1): at least one urgent stream is WAITING (queued, not being
    # served) — an urgent stream already on the GPU is not congestion
    def queued_urgent(w: Worker) -> int:
        return sum(1 for sid in w.queue
                   if view.streams[sid].tier == Tier.URGENT
                   and view.streams[sid].running_on is None)
    senders = [w for w in view.workers if queued_urgent(w) >= 1]
    # most-pressured senders first
    senders.sort(key=lambda w: -counts[w.wid][Tier.URGENT])

    sent: Dict[int, int] = {w.wid: 0 for w in view.workers}
    recv: Dict[int, int] = {w.wid: 0 for w in view.workers}
    plan: List[Migration] = []

    for src in senders:
        # movable: queued URGENT streams not in cooldown, not running,
        # and not mid-SP2 — a stream borrowing a donor is already being
        # helped (SS4's escalation order), and its head-partition state
        # spans two workers, so re-homing it is not a clean page move.
        # (Planning it anyway would also burn its cooldown on a
        # migration the apply layer refuses.)
        movable = [view.streams[sid] for sid in src.queue
                   if view.streams[sid].tier == Tier.URGENT
                   and view.streams[sid].cooldown_until <= now
                   and view.streams[sid].running_on is None
                   and view.streams[sid].sp_donor is None]
        movable.sort(key=lambda s: s.credit)          # lowest credit first
        for s in movable:
            if sent[src.wid] >= cap_send:
                break
            # intra-node-first receiver order (line 5)
            cands = sorted(
                (r for r in receivers if recv[r.wid] < cap_recv
                 and r.wid != src.wid),
                key=lambda r: (view.node_of(r.wid) != view.node_of(src.wid),
                               r.load()))
            if not cands:
                break
            dst = cands[0]
            plan.append(Migration(
                s.sid, src.wid, dst.wid,
                cross_node=view.node_of(dst.wid) != view.node_of(src.wid)))
            sent[src.wid] += 1
            recv[dst.wid] += 1
            s.cooldown_until = now + cooldown_s
    return plan


def apply_migration(view: ClusterView, mig: Migration) -> None:
    """Move the stream's home + queue entry (KV moves via the State
    Plane; the caller couples this with a transfer request)."""
    s = view.streams[mig.sid]
    src, dst = view.workers[mig.src], view.workers[mig.dst]
    if mig.sid in src.queue:
        src.queue.remove(mig.sid)
    dst.queue.append(mig.sid)
    s.home = mig.dst
