"""SlackServe core: the paper's contribution.

    fidelity.py       the 90-config knob space (SS2.1, App. A)
    bmpr.py           Bi-Modal Pareto Routing (SS5)
    slack.py          service credit Eq. 1 + urgency tiers (SS4.1)
    queues.py         three-tier queues, credit-aware eviction (SS4.1)
    rehoming.py       bipartite re-homing planning (SS4.2, Alg. 1)
    elastic_sp.py     intra-node SP2 borrow/release (SS4.3)
    state_plane.py    paged KV pool + async transfer engine (SS4.4)
    control_plane.py  the 3 s control tick composing all of it (Alg. 2)

Pure control logic: the same code drives the discrete-event cluster
simulator (repro.sched_sim) and the JAX chunk executor (repro.serve).
"""
