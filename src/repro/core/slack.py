"""Service credit (paper Eq. 1) and urgency tiers (SS4.1, SS7.5).

    C_u = P_u - (R_u + T_u)

P_u: playout slack (remaining playable buffer), R_u: estimated remaining
time of the running chunk (0 if not running), T_u: profiled generation
time of the next chunk under its selected fidelity configuration.

Tier thresholds (sensitivity-swept in Table 3, default alpha = 2):
    URGENT   C_u <  alpha * T_u
    RELAXED  C_u > 2*alpha * T_u
    NORMAL   otherwise
"""
from __future__ import annotations

from repro.core.types import Stream, Tier

DEFAULT_ALPHA = 2.0


def service_credit(stream: Stream, now: float) -> float:
    p_u = stream.playout_slack(now)
    r_u = stream.remaining if stream.running_on else 0.0
    return p_u - (r_u + stream.t_next)


def classify(credit: float, t_next: float,
             alpha: float = DEFAULT_ALPHA) -> Tier:
    if credit < alpha * t_next:
        return Tier.URGENT
    if credit > 2.0 * alpha * t_next:
        return Tier.RELAXED
    return Tier.NORMAL


def update_stream_credit(stream: Stream, now: float,
                         alpha: float = DEFAULT_ALPHA) -> None:
    stream.credit = service_credit(stream, now)
    stream.tier = classify(stream.credit, stream.t_next, alpha)
