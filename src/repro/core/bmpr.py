"""Bi-Modal Pareto Routing (BMPR, paper SS5.2).

Offline: build the empirical latency-quality Pareto frontier over the 90
candidate fidelity configurations and set the global quality floor to the
median quality of all candidates.  Online: given a playout-slack budget B,

    quality mode        argmax quality among {L <= B, Q >= floor}
    speed-recovery mode argmin latency among {Q >= floor}  (may exceed B;
                        resource reallocation (SS4) is the next defense)
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import List, Optional, Sequence, Tuple

from repro.core.fidelity import FidelityConfig
from repro.profiler.profiles import ChunkProfile, ModelProfile, get_profile


@dataclasses.dataclass(frozen=True)
class ParetoFrontier:
    points: Tuple[ChunkProfile, ...]      # sorted by latency ascending
    q_floor: float

    def __post_init__(self):
        assert all(self.points[i].latency <= self.points[i + 1].latency
                   for i in range(len(self.points) - 1))


def pareto_frontier(profile: ModelProfile) -> ParetoFrontier:
    """Non-dominated (L, Q) points + median quality floor (SS5.2).

    The sort key is a TOTAL order: equal-(latency, quality) points tie
    toward the lexicographically smallest fidelity key, so the frontier
    is deterministic under any permutation of ``profile.points``
    (a plain ``(latency, -quality)`` sort is stable in input order and
    would let the input permutation pick which of two tied configs
    represents the frontier point)."""
    pts = sorted(profile.points,
                 key=lambda p: (p.latency, -p.quality, p.fidelity.key))
    frontier: List[ChunkProfile] = []
    best_q = float("-inf")
    for p in pts:
        if p.quality > best_q:
            frontier.append(p)
            best_q = p.quality
    q_floor = statistics.median(p.quality for p in profile.points)
    return ParetoFrontier(tuple(frontier), q_floor)


@dataclasses.dataclass(frozen=True)
class BMPRDecision:
    fidelity: FidelityConfig
    latency: float
    quality: float
    mode: str                 # "quality" | "speed-recovery"


class BMPR:
    """Per-chunk fidelity selector with a quality floor."""

    def __init__(self, profile: Optional[ModelProfile] = None,
                 frontier: Optional[ParetoFrontier] = None):
        self.profile = profile or get_profile()
        self.frontier = frontier or pareto_frontier(self.profile)
        # floor-eligible frontier suffix, cached for select_bulk: the
        # frontier is latency-ascending with STRICTLY increasing quality
        # (pareto_frontier appends only on quality improvement), so the
        # Q >= floor points form a suffix and "argmax quality with
        # L <= B" is simply the LAST suffix point with latency <= B.
        self._eligible = tuple(p for p in self.frontier.points
                               if p.quality >= self.frontier.q_floor)
        self._eligible_lats: Optional[object] = None   # lazy np array

    def eligible_points(self) -> Tuple[ChunkProfile, ...]:
        """Floor-eligible frontier points, latency ascending."""
        return self._eligible

    def select_bulk(self, budgets) -> "object":
        """Vectorized ``select`` over an array of budgets: returns the
        index into ``eligible_points()`` per budget.  Exactly equivalent
        to calling ``select`` per budget: ``searchsorted(side='right')-1``
        is the last eligible point with ``latency <= budget`` (quality
        mode); a negative index means no point fits, which ``select``
        resolves as speed-recovery = the min-latency eligible point =
        index 0."""
        import numpy as np
        if self._eligible_lats is None:
            self._eligible_lats = np.array(
                [p.latency for p in self._eligible], dtype=np.float64)
        idx = np.searchsorted(self._eligible_lats, budgets,
                              side="right") - 1
        return np.maximum(idx, 0)

    def select(self, budget: float) -> BMPRDecision:
        floor = self.frontier.q_floor
        eligible = [p for p in self.frontier.points
                    if p.latency <= budget and p.quality >= floor]
        if eligible:
            best = max(eligible, key=lambda p: (p.quality, -p.latency))
            return BMPRDecision(best.fidelity, best.latency, best.quality,
                                "quality")
        # speed-recovery: min-latency point that still meets the floor
        above = [p for p in self.frontier.points if p.quality >= floor]
        best = min(above, key=lambda p: p.latency)
        return BMPRDecision(best.fidelity, best.latency, best.quality,
                            "speed-recovery")


class FixedLevelSwitcher:
    """Ablation baseline (Fig. 16): three frontier configs (fast/medium/
    slow) switched on slack thresholds, no quality floor."""

    def __init__(self, profile: Optional[ModelProfile] = None):
        profile = profile or get_profile()
        f = pareto_frontier(profile).points
        self.fast = f[0]
        self.medium = f[len(f) // 2]
        self.slow = f[-1]

    def select(self, budget: float) -> BMPRDecision:
        for p, name in ((self.slow, "slow"), (self.medium, "medium")):
            if p.latency <= budget:
                return BMPRDecision(p.fidelity, p.latency, p.quality, name)
        p = self.fast
        return BMPRDecision(p.fidelity, p.latency, p.quality, "fast")


class StaticFidelity:
    """Baseline: one config for the whole stream (SDV2/TS-style)."""

    def __init__(self, fidelity: Optional[FidelityConfig] = None,
                 profile: Optional[ModelProfile] = None):
        self.profile = profile or get_profile()
        self.fidelity = fidelity or FidelityConfig()
        self._lat = self.profile.latency(self.fidelity)
        self._q = self.profile.quality(self.fidelity)

    def select(self, budget: float) -> BMPRDecision:
        return BMPRDecision(self.fidelity, self._lat, self._q, "static")
