"""Control Plane (paper SS3.2-3.3, Algorithm 2, App. C.1).

Wakes at each control tick (3 s default) and, in trigger order:

    1. BMPR fidelity selection per active stream (SS5)
    2. service-credit + tier update under the selected fidelity (Eq. 1)
    3. three-tier queue (re)ordering -> local preemption (SS4.1)
    4. bipartite re-homing plan -> cross-worker preemption (SS4.2)
    5. elastic-SP plan -> compute expansion for C_u < 0 (SS4.3)

Every mechanism is individually switchable (technique ablation, Fig. 12).
The Control Plane emits *decisions*; the driver (discrete-event simulator
or JAX executor) applies them and routes state movement through the State
Plane (SS4.4).
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import elastic_sp, queues, rehoming, slack
from repro.core.bmpr import BMPR, BMPRDecision
from repro.core.types import ClusterView, Stream, Tier, Worker

DEFAULT_TICK_S = 3.0
TTFC_FACTOR = 4.0          # initial playout slack = 4x first-chunk estimate


@dataclasses.dataclass
class ControlConfig:
    tick_interval: float = DEFAULT_TICK_S
    alpha: float = slack.DEFAULT_ALPHA
    use_fidelity: bool = True          # BMPR (or injected policy)
    use_rehoming: bool = True
    use_elastic_sp: bool = True
    ttfc_factor: float = TTFC_FACTOR


@dataclasses.dataclass
class TickDecisions:
    migrations: List[rehoming.Migration]
    sp_decisions: List[elastic_sp.SPDecision]
    control_time_s: float              # wall-clock cost of this tick


class ControlPlane:
    def __init__(self, config: Optional[ControlConfig] = None,
                 fidelity_policy=None):
        self.config = config or ControlConfig()
        self.fidelity_policy = fidelity_policy or BMPR()
        self.n_rehomings = 0
        self.n_sp_events = 0
        self.tick_times: List[float] = []

    # ---- admission (SS3.3 steps 1-2) --------------------------------------
    def choose_home(self, view: ClusterView) -> int:
        """Least-loaded worker, excluding SP donors: a worker serving
        someone else's SP2 half has no headroom its own queue shows
        (``Worker.load`` also counts the donation, but an admitted
        stream would still contend with the borrowed one, so donors are
        skipped outright while any non-donating worker exists)."""
        free = [w for w in view.workers if w.donated_to is None]
        return min(free or view.workers, key=lambda w: w.load()).wid

    def initial_slack(self, first_chunk_estimate: float) -> float:
        return self.config.ttfc_factor * first_chunk_estimate

    # ---- the control tick (Algorithm 2 lines 7-15) ------------------------
    def tick(self, view: ClusterView, now: float) -> TickDecisions:
        t0 = _time.perf_counter()
        cfg = self.config

        for s in view.active_streams():
            # (3) fidelity selection under the current slack budget
            if cfg.use_fidelity and not s.finished:
                budget = max(s.playout_slack(now)
                             - (s.remaining if s.running_on else 0.0), 0.0)
                dec: BMPRDecision = self.fidelity_policy.select(budget)
                s.next_fidelity = dec.fidelity
                sp = 2 if s.sp_donor is not None else 1
                s.t_next = self.fidelity_policy.profile.latency(
                    dec.fidelity, sp_degree=sp) \
                    if hasattr(self.fidelity_policy, "profile") else dec.latency
            # (4) service credit + tier under the selected fidelity
            slack.update_stream_credit(s, now, cfg.alpha)

        queues.order_all(view)

        migrations: List[rehoming.Migration] = []
        if cfg.use_rehoming:
            migrations = rehoming.plan_rehoming(view, now)
            self.n_rehomings += len(migrations)

        sp_decisions: List[elastic_sp.SPDecision] = []
        if cfg.use_elastic_sp:
            just_migrated = {m.sid for m in migrations}
            sp_decisions = elastic_sp.plan_elastic_sp(
                view, now, exclude=just_migrated)
            self.n_sp_events += sum(1 for d in sp_decisions
                                    if d.kind == "expand")

        dt = _time.perf_counter() - t0
        self.tick_times.append(dt)
        return TickDecisions(migrations, sp_decisions, dt)
