"""Control Plane (paper SS3.2-3.3, Algorithm 2, App. C.1).

Wakes at each control tick (3 s default) and, in trigger order:

    1. BMPR fidelity selection per active stream (SS5)
    2. service-credit + tier update under the selected fidelity (Eq. 1)
    3. three-tier queue (re)ordering -> local preemption (SS4.1)
    4. bipartite re-homing plan -> cross-worker preemption (SS4.2)
    5. elastic-SP plan -> compute expansion for C_u < 0 (SS4.3)

Every mechanism is individually switchable (technique ablation, Fig. 12).
The Control Plane emits *decisions*; the driver (discrete-event simulator
or JAX executor) applies them and routes state movement through the State
Plane (SS4.4).
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import elastic_sp, queues, rehoming, slack
from repro.core.bmpr import BMPR, BMPRDecision
from repro.core.types import ClusterView, Stream, Tier, Worker

DEFAULT_TICK_S = 3.0
TTFC_FACTOR = 4.0          # initial playout slack = 4x first-chunk estimate


@dataclasses.dataclass
class ControlConfig:
    tick_interval: float = DEFAULT_TICK_S
    alpha: float = slack.DEFAULT_ALPHA
    use_fidelity: bool = True          # BMPR (or injected policy)
    use_rehoming: bool = True
    use_elastic_sp: bool = True
    ttfc_factor: float = TTFC_FACTOR
    # batch the per-stream fidelity/credit/tier updates through numpy
    # (bit-identical to the scalar loop; requires a fidelity policy with
    # ``select_bulk``, else the tick falls back to the scalar loop)
    vectorized: bool = False


@dataclasses.dataclass
class TickDecisions:
    migrations: List[rehoming.Migration]
    sp_decisions: List[elastic_sp.SPDecision]
    control_time_s: float              # wall-clock cost of this tick
    scale_out: int = 0                 # front-door autoscale: workers to add
    scale_in: int = 0                  # front-door scale-in: workers to retire


class ControlPlane:
    def __init__(self, config: Optional[ControlConfig] = None,
                 fidelity_policy=None):
        self.config = config or ControlConfig()
        self.fidelity_policy = fidelity_policy or BMPR()
        self.front_door = None         # optional admission/autoscale layer
        self.n_rehomings = 0
        self.n_sp_events = 0
        self.tick_times: List[float] = []

    # ---- front door (admission + autoscaling, sched_sim.frontdoor) --------
    def attach_front_door(self, front_door) -> None:
        """Attach an SLO-aware admission/autoscaling layer.  Once
        attached, ``admission`` gates every arrival and each tick's
        ``TickDecisions.scale_out`` carries the autoscale decision."""
        self.front_door = front_door

    def admission(self, view: ClusterView, now: float,
                  first_chunk_estimate: float, sid: int):
        """Per-arrival admission decision (``AdmissionDecision``), or
        None when no front door is attached (legacy: always admit)."""
        if self.front_door is None:
            return None
        return self.front_door.on_arrival(view, now,
                                          first_chunk_estimate, sid)

    # ---- admission (SS3.3 steps 1-2) --------------------------------------
    def choose_home(self, view: ClusterView) -> int:
        """Least-loaded worker, excluding SP donors: a worker serving
        someone else's SP2 half has no headroom its own queue shows
        (``Worker.load`` also counts the donation, but an admitted
        stream would still contend with the borrowed one, so donors are
        skipped outright while any non-donating worker exists).
        Retired workers (front-door scale-in) never take admissions.

        With heterogeneous co-serving the view carries ``stream_weight``
        (sid -> per-model placement weight) and the argmin runs over
        weighted load — a worker holding one heavy-model stream is more
        loaded than one holding one cheap stream.  ``stream_weight`` is
        None on single-model paths, where ``load(None)`` is the exact
        integer count."""
        free = [w for w in view.workers
                if w.donated_to is None and not w.retired]
        return min(free or view.workers,
                   key=lambda w: w.load(view.stream_weight)).wid

    def initial_slack(self, first_chunk_estimate: float) -> float:
        return self.config.ttfc_factor * first_chunk_estimate

    # ---- the control tick (Algorithm 2 lines 7-15) ------------------------
    def tick(self, view: ClusterView, now: float) -> TickDecisions:
        t0 = _time.perf_counter()
        cfg = self.config

        if cfg.vectorized and (not cfg.use_fidelity
                               or hasattr(self.fidelity_policy,
                                          "select_bulk")):
            self._update_streams_vectorized(view, now)
        else:
            self._update_streams_scalar(view, now)

        queues.order_all(view)

        # one tier-histogram pass shared by both planners (they plan
        # back-to-back with no mutation in between, so sharing is exact)
        counts = None
        if cfg.use_rehoming or cfg.use_elastic_sp:
            counts = queues.tier_counts(view)

        migrations: List[rehoming.Migration] = []
        if cfg.use_rehoming:
            migrations = rehoming.plan_rehoming(view, now, counts=counts)
            self.n_rehomings += len(migrations)

        sp_decisions: List[elastic_sp.SPDecision] = []
        if cfg.use_elastic_sp:
            just_migrated = {m.sid for m in migrations}
            # vectorized tick: hoist the donor-quality signal (min
            # resident credit per worker) to one pass instead of one
            # scan per (negative stream, candidate donor) pair
            donor_credits = (queues.min_credits(view) if cfg.vectorized
                             else None)
            sp_decisions = elastic_sp.plan_elastic_sp(
                view, now, exclude=just_migrated, counts=counts,
                donor_credits=donor_credits)
            self.n_sp_events += sum(1 for d in sp_decisions
                                    if d.kind == "expand")

        scale_out = 0
        scale_in = 0
        if self.front_door is not None:
            scale_out = self.front_door.autoscale(view, now)
            if scale_out == 0:
                # never shed and add capacity in the same tick
                scale_in = self.front_door.maybe_scale_in(view, now)

        dt = _time.perf_counter() - t0
        self.tick_times.append(dt)
        return TickDecisions(migrations, sp_decisions, dt, scale_out,
                             scale_in)

    def _update_streams_scalar(self, view: ClusterView, now: float) -> None:
        cfg = self.config
        for s in view.active_streams():
            # (3) fidelity selection under the current slack budget
            if cfg.use_fidelity and not s.finished:
                budget = max(s.playout_slack(now)
                             - (s.remaining if s.running_on else 0.0), 0.0)
                # co-serving: route through the stream's model bundle
                # when the policy is model-aware (``select_for``);
                # single-model streams (model None) take the exact
                # legacy call
                sel = getattr(self.fidelity_policy, "select_for", None)
                dec: BMPRDecision = (
                    sel(s.model, budget)
                    if sel is not None and s.model is not None
                    else self.fidelity_policy.select(budget))
                s.next_fidelity = dec.fidelity
                sp = 2 if s.sp_donor is not None else 1
                s.t_next = self.fidelity_policy.profile.latency(
                    dec.fidelity, sp_degree=sp) \
                    if hasattr(self.fidelity_policy, "profile") else dec.latency
            # (4) service credit + tier under the selected fidelity
            slack.update_stream_credit(s, now, cfg.alpha)

    def _update_streams_vectorized(self, view: ClusterView,
                                   now: float) -> None:
        """Numpy-batched equivalent of ``_update_streams_scalar``:
        fidelity via ``select_bulk`` (searchsorted over the eligible
        frontier), then Eq. 1 credit + tier thresholds as array ops.
        Operation order matches the scalar path term-for-term —
        ``(nd - now) - (rem + t_next)`` in float64 — so results are
        bit-identical (asserted by the scalar-vs-vectorized parity
        test)."""
        import math

        import numpy as np
        cfg = self.config
        streams = view.active_streams()
        if not streams:
            return
        n = len(streams)
        nd = np.fromiter((s.next_deadline for s in streams),
                         dtype=np.float64, count=n)
        rem = np.fromiter((s.remaining if s.running_on else 0.0
                           for s in streams), dtype=np.float64, count=n)
        if cfg.use_fidelity:
            fp = self.fidelity_policy
            budgets = np.maximum((nd - now) - rem, 0.0)
            idx = fp.select_bulk(budgets)
            pts = fp.eligible_points()
            prof = getattr(fp, "profile", None)
            # the ``t_next`` setter validates each assignment; the
            # eligible points' latencies are fixed floats, so validate
            # once per point here and write the backing field directly
            # (== profile.latency(fid, sp_degree=1): ChunkProfile
            # latencies come from the same chunk_latency surface)
            fids = tuple(p.fidelity for p in pts)
            lats = tuple(float(p.latency) for p in pts)
            for lat in lats:
                if not (math.isfinite(lat) and lat >= 0.0):
                    raise ValueError(
                        f"frontier latency {lat!r} is not a valid T_u")
            # T_u column built array-side from the selection (finished /
            # SP2 streams corrected below), replacing a second fromiter
            # pass plus a separate per-stream write loop
            tn = np.asarray(lats, dtype=np.float64)[idx]
            idx_l = idx.tolist()
            for i, s in enumerate(streams):
                if s.finished:
                    tn[i] = s._t_next
                elif s.sp_donor is not None and prof is not None:
                    tn[i] = prof.latency(fids[idx_l[i]], sp_degree=2)
        else:
            idx_l = None
            tn = np.fromiter((s.t_next for s in streams),
                             dtype=np.float64, count=n)
        credit = (nd - now) - (rem + tn)
        tier_idx = np.where(credit < cfg.alpha * tn, 0,
                            np.where(credit > 2.0 * cfg.alpha * tn,
                                     2, 1)).tolist()
        tiers = (Tier.URGENT, Tier.NORMAL, Tier.RELAXED)
        if idx_l is not None:
            tn_l = tn.tolist()
            for s, c, t, j, lat in zip(streams, credit.tolist(),
                                       tier_idx, idx_l, tn_l):
                if not s.finished:
                    s.next_fidelity = fids[j]
                    s._t_next = lat
                s.credit = c
                s.tier = tiers[t]
        else:
            for s, c, t in zip(streams, credit.tolist(), tier_idx):
                s.credit = c
                s.tier = tiers[t]
