"""Real JAX serving executor: the SAME SlackServe control plane that
drives the simulator schedules actual AR-DiT chunk generation.

Workers here are logical lanes over the local device (CPU in this
container; one lane per accelerator in a real deployment).  Each
``serve_chunk`` call runs the real model at the BMPR-selected fidelity;
playout bookkeeping, credit scheduling, and cache management are the
repro.core code paths.  This is the executor behind
``examples/serve_stream.py`` and the Fig. 10 quality study.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.core import slack as slack_mod
from repro.core.bmpr import BMPR
from repro.core.control_plane import ControlPlane, ControlConfig
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.models import ardit as A
from repro.profiler.profiles import get_profile

# blend of the prior vs the newest measured latency in the online
# re-profiling EMAs (shared with the batched executor)
EMA_DECAY = 0.7


@dataclasses.dataclass
class ServedStream:
    sid: int
    cond: jax.Array
    cache: Dict[str, Any]
    target_chunks: int
    chunks: List[jax.Array] = dataclasses.field(default_factory=list)
    fidelity_log: List[str] = dataclasses.field(default_factory=list)
    next_deadline: float = 0.0
    chunk_seconds: float = 0.75

    @property
    def done(self) -> bool:
        return len(self.chunks) >= self.target_chunks


class ChunkExecutor:
    """Generates chunks for one model; measures real wall latency and
    feeds it back as the timing prior (online re-profiling)."""

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 params: Optional[Any] = None, seed: int = 0):
        self.cfg = cfg or get_config("ardit-self-forcing").reduced()
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else A.init_params(
            self.cfg, key)
        self.latency_ema: Dict[str, float] = {}

    def open_stream(self, sid: int, target_chunks: int, *,
                    now: float, ttfc_slack: float,
                    seed: int = 0) -> ServedStream:
        key = jax.random.PRNGKey(1000 + seed)
        cond = jax.random.normal(
            key, (1, A.COND_TOKENS, self.cfg.d_model)) * 0.02
        cache = A.init_cache(self.cfg, self.params, cond)
        return ServedStream(sid=sid, cond=cond, cache=cache,
                            target_chunks=target_chunks,
                            next_deadline=now + ttfc_slack)

    def generate_chunk(self, s: ServedStream,
                       fidelity: FidelityConfig) -> Tuple[jax.Array, float]:
        key = jax.random.PRNGKey(len(s.chunks) * 7919 + s.sid)
        tc = A.chunk_tokens(self.cfg)
        noise = jax.random.normal(key, (1, tc, A.LATENT_CH))
        t0 = time.perf_counter()
        chunk, s.cache = A.serve_chunk(self.cfg, self.params, s.cache,
                                       noise, fidelity)
        chunk.block_until_ready()
        dt = time.perf_counter() - t0
        s.chunks.append(chunk)
        s.fidelity_log.append(fidelity.key)
        self.latency_ema[fidelity.key] = (
            EMA_DECAY * self.latency_ema.get(fidelity.key, dt)
            + (1.0 - EMA_DECAY) * dt)
        return chunk, dt


def serve_session(n_streams: int = 2, chunks_per_stream: int = 4,
                  realtime_budget: Optional[float] = None,
                  verbose: bool = True,
                  batched: bool = False,
                  max_batch: int = 4,
                  pool_streams: Optional[int] = None,
                  context_backend: str = "paged") -> List[ServedStream]:
    """Small end-to-end session: BMPR-driven fidelity on the real model.

    ``realtime_budget``: seconds of playout per chunk used for slack
    bookkeeping; defaults to 4x the measured top-fidelity latency so the
    session exercises both BMPR modes on any host speed.

    ``batched=True`` routes to the credit-ordered micro-batch executor
    (``repro.serve.batcher``): same control mechanisms, but up to
    ``max_batch`` streams advance together per denoise step.
    ``pool_streams`` (batched only) caps co-resident streams in the page
    pool — fewer than ``n_streams`` oversubscribes: overflow spills to
    host and rotates back in via credit-aware eviction.
    ``context_backend`` (batched only): ``"paged"`` (default) serves
    attention straight from the page pool through block tables;
    ``"gather"`` materializes the contiguous context per boundary.
    """
    if batched:
        from repro.serve.batcher import serve_session_batched
        return serve_session_batched(
            n_streams=n_streams, chunks_per_stream=chunks_per_stream,
            max_batch=max_batch, realtime_budget=realtime_budget,
            pool_streams=pool_streams, context_backend=context_backend,
            verbose=verbose)
    ex = ChunkExecutor()
    bmpr = BMPR(get_profile())
    # calibrate the wall-clock playout rate to this host
    warm = ex.open_stream(-1, 1, now=0.0, ttfc_slack=1e9)
    _, top_lat = ex.generate_chunk(warm, HIGHEST_QUALITY)
    chunk_seconds = realtime_budget or (4.0 * top_lat)

    streams = []
    now = 0.0
    for i in range(n_streams):
        st = ex.open_stream(i, chunks_per_stream, now=now,
                            ttfc_slack=2.0 * chunk_seconds, seed=i)
        st.chunk_seconds = chunk_seconds
        streams.append(st)

    t_start = time.perf_counter()
    while any(not s.done for s in streams):
        now = time.perf_counter() - t_start
        # lowest playout slack first (the paper's credit ordering)
        s = min((x for x in streams if not x.done),
                key=lambda x: x.next_deadline)
        budget = max(s.next_deadline - now, 0.0)
        # budget is wall-seconds; scale into the profile's latency units
        dec = bmpr.select(budget / max(chunk_seconds, 1e-9) * 0.72)
        _, dt = ex.generate_chunk(s, dec.fidelity)
        now = time.perf_counter() - t_start
        ddl = s.next_deadline
        s.next_deadline = max(ddl, now) + s.chunk_seconds
        if verbose:
            print(f"t={now:6.2f}s stream {s.sid} chunk "
                  f"{len(s.chunks)}/{s.target_chunks} "
                  f"fid={dec.fidelity.key:22s} lat={dt:.2f}s "
                  f"{'LATE' if now > ddl else 'on-time'}")
    return streams
