"""Real JAX serving executor: the SAME SlackServe control plane that
drives the simulator schedules actual AR-DiT chunk generation.

Workers here are logical lanes over the local device (CPU in this
container; one lane per accelerator in a real deployment).  Each
``serve_chunk`` call runs the real model at the BMPR-selected fidelity;
playout bookkeeping, credit scheduling, and cache management are the
repro.core code paths.  This is the executor behind
``examples/serve_stream.py`` and the Fig. 10 quality study.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax

from repro.configs.base import ModelConfig, get_config
from repro.core.fidelity import FidelityConfig
from repro.core.state_plane import AsyncTransferEngine
from repro.core.types import Stream
from repro.models import ardit as A

# blend of the prior vs the newest measured latency in the online
# re-profiling EMAs (shared with the batched executor)
EMA_DECAY = 0.7


@dataclasses.dataclass
class ServedStream:
    sid: int
    cond: jax.Array
    cache: Dict[str, Any]
    target_chunks: int
    chunks: List[jax.Array] = dataclasses.field(default_factory=list)
    fidelity_log: List[str] = dataclasses.field(default_factory=list)
    next_deadline: float = 0.0
    chunk_seconds: float = 0.75

    @property
    def done(self) -> bool:
        return len(self.chunks) >= self.target_chunks


class ChunkExecutor:
    """Generates chunks for one model; measures real wall latency and
    feeds it back as the timing prior (online re-profiling)."""

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 params: Optional[Any] = None, seed: int = 0):
        self.cfg = cfg or get_config("ardit-self-forcing").reduced()
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else A.init_params(
            self.cfg, key)
        self.latency_ema: Dict[str, float] = {}

    def open_stream(self, sid: int, target_chunks: int, *,
                    now: float, ttfc_slack: float,
                    seed: int = 0) -> ServedStream:
        key = jax.random.PRNGKey(1000 + seed)
        cond = jax.random.normal(
            key, (1, A.COND_TOKENS, self.cfg.d_model)) * 0.02
        cache = A.init_cache(self.cfg, self.params, cond)
        return ServedStream(sid=sid, cond=cond, cache=cache,
                            target_chunks=target_chunks,
                            next_deadline=now + ttfc_slack)

    def generate_chunk(self, s: ServedStream,
                       fidelity: FidelityConfig) -> Tuple[jax.Array, float]:
        key = jax.random.PRNGKey(len(s.chunks) * 7919 + s.sid)
        tc = A.chunk_tokens(self.cfg)
        noise = jax.random.normal(key, (1, tc, A.LATENT_CH))
        t0 = time.perf_counter()
        chunk, s.cache = A.serve_chunk(self.cfg, self.params, s.cache,
                                       noise, fidelity)
        chunk.block_until_ready()
        dt = time.perf_counter() - t0
        s.chunks.append(chunk)
        s.fidelity_log.append(fidelity.key)
        self.latency_ema[fidelity.key] = (
            EMA_DECAY * self.latency_ema.get(fidelity.key, dt)
            + (1.0 - EMA_DECAY) * dt)
        return chunk, dt


@dataclasses.dataclass
class _Flight:
    """One stream's pending chunk in the sequential adapter (the whole
    chunk is one atomic 'step')."""
    fidelity: FidelityConfig
    started: float = 0.0
    step: int = 0


class SequentialChunkExecutor(ChunkExecutor):
    """Whole-chunk-atomic adapter: exposes the batched executor's step
    interface (``admit`` / ``begin_chunk`` / ``run_step`` / ``retire``)
    over the eager one-stream-at-a-time path, so
    ``repro.serve.session.StreamingSession`` drives either executor
    through ONE control loop.  Batch size is 1 and one ``run_step``
    call generates one complete chunk."""

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 params: Optional[Any] = None, seed: int = 0):
        super().__init__(cfg=cfg, params=params, seed=seed)
        self.streams: Dict[int, ServedStream] = {}
        self.inflight: Dict[int, _Flight] = {}
        self.chunks: Dict[int, List[jax.Array]] = {}
        self.fidelity_log: Dict[int, List[str]] = {}
        # no KV pool, so no spill/restore traffic: the engine exists
        # only to satisfy the shared metrics surface (empty log)
        self.engine = AsyncTransferEngine(n_layers=self.cfg.n_layers)

    def admit(self, sid: int, seed: int = 0,
              streams: Optional[Dict[int, Stream]] = None,
              protect: Sequence[int] = ()) -> bool:
        st = self.open_stream(sid, target_chunks=1 << 30, now=0.0,
                              ttfc_slack=0.0, seed=seed)
        self.streams[sid] = st
        self.chunks[sid] = st.chunks           # same list object
        self.fidelity_log[sid] = st.fidelity_log
        return True

    def ensure_resident(self, sid: int,
                        streams: Optional[Dict[int, Stream]] = None,
                        protect: Sequence[int] = ()) -> bool:
        assert sid in self.streams, f"stream {sid} was never admitted"
        return True                            # whole cache lives on-device

    def begin_chunk(self, sid: int, fidelity: FidelityConfig,
                    now: float) -> None:
        self.inflight[sid] = _Flight(fidelity=fidelity, started=now)

    def run_step(self, sids: Sequence[int]) -> Tuple[List[int], float]:
        assert len(sids) == 1, \
            "the sequential executor serves one stream per step"
        sid = sids[0]
        f = self.inflight.pop(sid)
        _, dt = self.generate_chunk(self.streams[sid], f.fidelity)
        return [sid], dt

    def remaining_estimate(self, sid: int) -> float:
        f = self.inflight.get(sid)
        if f is None:
            return 0.0
        return self.latency_ema.get(f.fidelity.key, 0.0)

    def abort_chunk(self, sid: int) -> None:
        """Drop the pending chunk (prompt switch before generation)."""
        self.inflight.pop(sid, None)

    def reset_condition(self, sid: int, seed: int) -> bool:
        """Prompt switch: re-encode a fresh conditioning and rebuild the
        stream's cache around it (the eager path's sink rewrite) — the
        old prompt's context KV is discarded with it.  Unlike the
        batched executor, the noise sequence continues (the eager cache
        has no separate generation counter)."""
        self.inflight.pop(sid, None)
        st = self.streams[sid]
        key = jax.random.PRNGKey(1000 + seed)
        st.cond = jax.random.normal(
            key, (1, A.COND_TOKENS, self.cfg.d_model)) * 0.02
        st.cache = A.init_cache(self.cfg, self.params, st.cond)
        return True

    def retire(self, sid: int, drop_history: bool = False) -> None:
        """Retire a stream; ``drop_history=True`` also removes its
        record and generated chunks (warm-up calibration stream — no
        residue may survive into the serving session)."""
        self.inflight.pop(sid, None)
        if drop_history:
            self.streams.pop(sid, None)
            self.chunks.pop(sid, None)
            self.fidelity_log.pop(sid, None)


def serve_session(n_streams: int = 2, chunks_per_stream: int = 4,
                  realtime_budget: Optional[float] = None,
                  verbose: bool = True,
                  batched: bool = False,
                  max_batch: int = 4,
                  pool_streams: Optional[int] = None,
                  context_backend: str = "paged") -> List[ServedStream]:
    """Legacy entry point — now a thin wrapper over the unified
    ``repro.serve.session.StreamingSession`` (all streams arrive at
    t=0, exact per-stream chunk counts).

    ``realtime_budget``: seconds of playout per chunk used for slack
    bookkeeping; defaults to 4x the measured top-fidelity latency so the
    session exercises both BMPR modes on any host speed.

    ``batched=True`` routes to the credit-ordered micro-batch executor
    (``repro.serve.batcher``): same control mechanisms, but up to
    ``max_batch`` streams advance together per denoise step.
    ``pool_streams`` (batched only) caps co-resident streams in the page
    pool — fewer than ``n_streams`` oversubscribes: overflow spills to
    host and rotates back in via credit-aware eviction.
    ``context_backend`` (batched only): ``"paged"`` (default) serves
    attention straight from the page pool through block tables;
    ``"gather"`` materializes the contiguous context per boundary.
    """
    if batched:
        from repro.serve.batcher import serve_session_batched
        return serve_session_batched(
            n_streams=n_streams, chunks_per_stream=chunks_per_stream,
            max_batch=max_batch, realtime_budget=realtime_budget,
            pool_streams=pool_streams, context_backend=context_backend,
            verbose=verbose)
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     uniform_specs)
    session = StreamingSession(SessionConfig(
        executor="sequential", max_batch=1,
        realtime_budget=realtime_budget, verbose=verbose))
    for spec in uniform_specs(n_streams, chunks_per_stream):
        session.submit(spec)
    session.run()
    return session.served_streams()
