"""Unified StreamingSession API: ONE control plane, ONE workload spec,
ONE metrics surface for the simulator and the real JAX executor.

Before this module, the repo had three divergent serving drivers: the
discrete-event ``sched_sim.Simulator`` (which runs the paper's Algorithm
2 through ``core.control_plane.ControlPlane``), the sequential
``serve_session`` loop, and the batched ``serve_session_batched`` loop —
the latter two re-implementing hand-rolled subsets of the control tick
(inline slack updates, ad-hoc queue ordering, a magic hand-tuned
fidelity-budget scale) and emitting no ``sched_sim.metrics.Summary``.

``StreamingSession`` consolidates them:

    * requests are submitted as ``sched_sim.workloads.StreamSpec``s —
      online arrivals, per-stream chunk counts, pause and prompt-switch
      events — exactly the objects every workload generator produces;
    * stream lifecycle is exposed through handles
      (``submit() -> StreamHandle``, ``.chunks_ready``, ``.done``);
    * the scheduling loop is driven by ``ControlPlane.tick()`` — the
      SAME decision code the simulator runs (BMPR fidelity -> Eq. 1
      service credit -> three-tier queue ordering) — with a real
      executor (batched page-pool executor or the sequential
      whole-chunk executor) as the apply layer;
    * every stream's playout timeline lives in ONE per-stream record
      (``core.types.Stream``), so ``sched_sim.metrics.summarize()``
      produces the same CPR / TTFC / stall Summary over a real session
      that it produces over a simulation.

Multi-lane sessions (``SessionConfig.lanes > 1``): the session owns a
``serve.lanes.LanePool`` — one ``BatchedChunkExecutor`` (own paged KV
pool) per device lane, lanes grouped into nodes via
``workers_per_node`` — and the cluster view grows one Worker per lane,
which re-enables the cross-worker mechanisms the single-lane session
had to switch off: ``rehoming.Migration`` decisions become real
cross-lane KV moves (bit-exact spill through the state plane, restored
into the destination lane's pool at a chunk boundary) and
``elastic_sp.SPDecision`` becomes a real Ulysses head-split SP2 step
on the donor lane's pool (pre-jitted, released at the next safe
boundary).  On CPU the lanes are distinct executor instances over the
host device, so the full decision -> apply -> metrics loop runs in CI.

Budget units (the fix for the old hand-tuned budget fudge): the offline
profile's latencies are H100-calibrated while the session's clock is
this host's wall clock, so the session measures one top-fidelity warm-up
chunk and scales Eq. 1 budgets by

    time_scale = profile.latency(HIGHEST_QUALITY) / measured_top_latency

(``_HostCalibratedPolicy``).  Once a fidelity's measured-latency EMA
exists it replaces the scaled profile estimate entirely (online
re-profiling), so T_u in Eq. 1 tracks this host, not the offline model.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import elastic_sp, queues, rehoming, slack
from repro.core.bmpr import BMPR, BMPRDecision
from repro.core.control_plane import (ControlConfig, ControlPlane,
                                      TickDecisions)
from repro.core.elastic_sp import SPDecision
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.core.state_plane import AsyncTransferEngine
from repro.core.types import ClusterView, Stream, Worker
from repro.profiler.profiles import get_profile
from repro.sched_sim import cost_model as cm
from repro.sched_sim.frontdoor import FrontDoor, FrontDoorConfig
from repro.sched_sim.workloads import StreamSpec
from repro.serve.executor import ServedStream
from repro.serve.lanes import LanePool


@dataclasses.dataclass
class SessionConfig:
    """Knobs of a real-model serving session.

    ``executor`` picks the apply layer: ``"batched"`` (credit-ordered
    micro-batches over the paged KV pool) or ``"sequential"``
    (whole-chunk-atomic, one stream at a time).  ``lanes`` is the
    number of device lanes (one batched executor + KV pool each; > 1
    re-enables re-homing and elastic SP in the control plane);
    ``workers_per_node`` groups lanes into nodes for the intra-node
    preferences of Algorithm 1 and SS4.3 (0 = all lanes in one node).
    ``pool_streams`` caps co-resident streams PER LANE.
    ``tick_interval`` is the control-tick cadence in session seconds; 0
    runs Algorithm 2 at every scheduler iteration (the natural cadence
    when chunk latencies are far below the paper's 3 s tick).
    ``arrival_scale`` multiplies every StreamSpec time (arrival, switch
    offsets, pause windows) — < 1 compresses a workload trace so demos
    and tests don't wait out real Poisson gaps.  ``realtime_budget``
    fixes the playout seconds per chunk; None calibrates 4x the
    measured top-fidelity latency so any host speed exercises both BMPR
    modes.
    """
    executor: str = "batched"
    max_batch: int = 4
    lanes: int = 1
    workers_per_node: int = 0
    pool_streams: Optional[int] = None
    context_backend: str = "paged"
    # fused heterogeneous-fidelity dispatch: micro-batches group by KV
    # quantization dtype only (steps/window/sparsity ride as per-row
    # data), one jitted launch per dtype instead of per fidelity key.
    # False restores the legacy per-key split dispatch.
    fuse_fidelity: bool = True
    # partial-window residency: under pool pressure evict single ring
    # pages (effective window degrades smoothly) before whole-stream
    # spill.  Off by default: page eviction discards KV, so bit-exact
    # spill/restore parity no longer holds once it fires.
    page_evict: bool = False
    # content-adaptive step cache (fifth fidelity knob,
    # models/stepcache.py): True unlocks the cache levels in the BMPR
    # candidate space (270 points), so slack-poor streams take cached
    # steps before degrading window/resolution.  Off by default until
    # the nightly bench gate proves the win on this host class; cache
    # levels still work when a custom ``fidelity_policy`` selects them.
    step_cache: bool = False
    model_cfg: Optional[Any] = None    # None -> the reduced default model
    # heterogeneous co-serving (serve/modelplane.py): registry arch ids
    # (or explicit ModelConfigs) to co-serve on ONE lane pool — one
    # executor + paged KV pool per (model, lane), streams routed to
    # their spec's model, placement weighted by per-model step/page
    # cost, re-homing and elastic SP same-model-only.  None (default)
    # takes the exact legacy single-model path; ``models`` and
    # ``model_cfg`` are mutually exclusive.
    models: Optional[List[Any]] = None
    realtime_budget: Optional[float] = None
    budget_factor: float = 4.0     # chunk_seconds = factor x top latency
    tick_interval: float = 0.0
    arrival_scale: float = 1.0
    seed: int = 0
    verbose: bool = True
    # SLO-aware admission control (sched_sim.frontdoor).  None = legacy
    # unconditional admission.  Autoscaling is forced OFF in a real
    # session — this host cannot provision lanes mid-run — so the front
    # door only admits, queues, or sheds.
    front_door: Optional[FrontDoorConfig] = None


@dataclasses.dataclass
class SessionResult:
    """Same surface as ``sched_sim.simulator.SimResult`` — one metrics
    language for simulated and real runs (``metrics.summarize`` accepts
    either).  The ``*_applied`` counters record decisions the apply
    layer actually executed (``n_rehomings``/``n_sp_events`` count
    decisions the control plane *planned*, like the simulator's)."""
    streams: Dict[int, Stream]
    engine: AsyncTransferEngine
    n_rehomings: int
    n_sp_events: int
    worker_tier_samples: List[Tuple[int, int, int]]
    fidelity_counts: Dict[str, int]
    control_tick_times: List[float]
    n_migrations_applied: int = 0
    n_sp_expands_applied: int = 0
    n_sp_releases_applied: int = 0
    admission: Dict[str, int] = dataclasses.field(default_factory=dict)
    # per-stream effective-window history (chunks of context each
    # generated chunk actually attended to — fidelity window clipped by
    # fill, minus page-evicted chunks), merged across lanes; migrations
    # carry it, so each stream has one entry per completed chunk
    effective_window: Dict[int, List[int]] = dataclasses.field(
        default_factory=dict)
    # step-cache counters summed across lanes (hits / misses /
    # hit_rate / skipped_launches); empty when no cache-on chunk ran
    step_cache: Dict[str, float] = dataclasses.field(default_factory=dict)


class StreamHandle:
    """Client-side view of one submitted stream.

    Valid from ``submit()`` on; the underlying per-stream record
    (``core.types.Stream``) appears once the stream's arrival time is
    reached inside ``run()``.
    """

    def __init__(self, session: "StreamingSession", spec: StreamSpec):
        self._session = session
        self.spec = spec

    @property
    def sid(self) -> int:
        return self.spec.sid

    @property
    def record(self) -> Optional[Stream]:
        """The session's per-stream record (None before arrival)."""
        return self._session.view.streams.get(self.sid)

    @property
    def chunks_ready(self) -> int:
        return len(self._session.lanes.chunks_of(self.sid))

    @property
    def chunks(self) -> List[Any]:
        """Generated latent chunks, in playout order."""
        return list(self._session.lanes.chunks_of(self.sid))

    @property
    def done(self) -> bool:
        r = self.record
        return r is not None and r.finished

    @property
    def fidelity_log(self) -> List[str]:
        r = self.record
        return list(r.fidelity_log) if r is not None else []

    def served_stream(self) -> ServedStream:
        """Back-compat ``ServedStream`` view, built from the per-stream
        record (single source of truth for deadlines/fidelity)."""
        return self._session._served_stream(self.sid)


class _HostCalibratedPolicy:
    """Budget adapter between wall-second Eq. 1 budgets and a fidelity
    policy whose frontier is in offline-profile latency units.

    ``select(B)`` hands the wrapped policy ``B * time_scale`` (profile
    units) and converts the decision's latency estimate back to wall
    seconds — replaced by the measured EMA for that fidelity (averaged
    across lanes: same host, same device class) as soon as one exists
    (online re-profiling).  Deliberately does NOT expose ``.profile``:
    ``ControlPlane.tick`` then takes T_u from the decision we return
    (wall units) instead of re-reading the offline profile.

    ``model`` (heterogeneous co-serving) scopes the EMA read to that
    bundle's executors — fidelity keys collide across models.
    """

    def __init__(self, inner, lanes: LanePool, time_scale: float,
                 model: Optional[str] = None):
        self.inner = inner
        self.lanes = lanes
        self.time_scale = time_scale
        self.model = model

    def select(self, budget: float) -> BMPRDecision:
        dec = self.inner.select(budget * self.time_scale)
        lat = self.lanes.latency_ema_get(
            dec.fidelity.key, dec.latency / self.time_scale,
            model=self.model)
        return BMPRDecision(dec.fidelity, lat, dec.quality, dec.mode)


class _ModelRoutedPolicy:
    """Fidelity-policy multiplexer for co-served bundles: one
    ``_HostCalibratedPolicy`` per model (each over ITS bundle's offline
    profile, host time scale, and measured EMAs).  ``select`` serves
    the session primary (legacy callers); the control plane routes
    per-stream calls through ``select_for(model, budget)``.  Like the
    single-model wrapper it deliberately does NOT expose ``.profile``,
    so T_u comes from the returned decision (wall units)."""

    def __init__(self, by_model: Dict[str, _HostCalibratedPolicy],
                 primary: str):
        self.by_model = by_model
        self.primary = by_model[primary]

    def select(self, budget: float) -> BMPRDecision:
        return self.primary.select(budget)

    def select_for(self, model: Optional[str],
                   budget: float) -> BMPRDecision:
        return self.by_model.get(model, self.primary).select(budget)


def uniform_specs(n_streams: int, chunks_per_stream: int) -> List[StreamSpec]:
    """All-arrive-at-t=0 specs with exact chunk counts — the workload
    the legacy ``serve_session*`` entry points implied."""
    frames = chunks_per_stream * cm.PIXEL_FRAMES_PER_CHUNK
    return [StreamSpec(sid=i, arrival=0.0, frames=frames)
            for i in range(n_streams)]


def cap_specs(specs: List[StreamSpec],
              max_chunks: int) -> List[StreamSpec]:
    """Trim every spec to at most ``max_chunks`` chunks (the real tiny
    model finishes promptly); arrivals and event times are kept."""
    return [dataclasses.replace(
        s, frames=min(s.frames, max_chunks * cm.PIXEL_FRAMES_PER_CHUNK))
        for s in specs]


def scale_specs(specs: List[StreamSpec],
                max_chunks: int) -> List[StreamSpec]:
    """Proportionally shrink spec lengths so the LONGEST stream runs
    ``max_chunks`` chunks and the workload's relative length diversity
    survives (a uniform ``cap_specs`` cap erases the short-vs-long
    imbalance that makes lanes drain unevenly — exactly what the
    cross-worker mechanisms feed on); arrivals and event times are
    kept."""
    longest = max(s.chunks for s in specs)
    return [dataclasses.replace(
        s, frames=max(1, round(s.chunks * max_chunks / longest))
        * cm.PIXEL_FRAMES_PER_CHUNK) for s in specs]


class StreamingSession:
    """One serving session over a real executor pool, driven by the
    paper's control plane.

    Usage::

        session = StreamingSession(SessionConfig(lanes=2))
        handles = [session.submit(spec) for spec in workloads.burst(n=6)]
        result = session.run()                 # SessionResult
        summary = sched_sim.metrics.summarize(result)

    ``submit`` only registers the spec; admission happens inside
    ``run()`` when the session clock reaches ``spec.arrival`` (times
    scaled by ``config.arrival_scale``), homed on the least-loaded
    non-donating lane (``ControlPlane.choose_home``).  Prompt switches
    reset playout slack to the initial TTFC, abort the in-flight chunk
    AND re-encode a fresh conditioning (sink-page rewrite through
    ``KVPool.admit`` — the old cond must not serve the new prompt);
    pauses extend the playout deadline by their duration — the same
    event semantics as ``sched_sim.Simulator``.
    """

    def __init__(self, config: Optional[SessionConfig] = None, *,
                 executor: Optional[Any] = None,
                 fidelity_policy: Optional[Any] = None):
        self.cfg = config or SessionConfig()
        n_lanes = max(1, self.cfg.lanes)
        self.bundles = None
        if self.cfg.models:
            assert executor is None and self.cfg.model_cfg is None, \
                "SessionConfig.models is incompatible with executor= " \
                "and model_cfg"
            assert self.cfg.executor == "batched", \
                "co-serving rides the batched paged executor"
            from repro.serve.modelplane import resolve_bundles
            self.bundles = resolve_bundles(
                self.cfg.models, seed=self.cfg.seed,
                step_cache=self.cfg.step_cache)
        if executor is not None:
            assert n_lanes == 1, \
                "multi-lane sessions build their own executors " \
                "(SessionConfig.lanes is incompatible with executor=)"
            self.lanes = LanePool.wrap(executor)
        elif self.cfg.executor == "sequential":
            assert n_lanes == 1, "the sequential executor is single-lane"
            from repro.serve.executor import SequentialChunkExecutor
            self.lanes = LanePool.wrap(
                SequentialChunkExecutor(seed=self.cfg.seed))
        elif self.bundles is not None:
            self.lanes = LanePool(
                n_lanes, seed=self.cfg.seed,
                max_streams=self.cfg.pool_streams or 16,
                context_backend=self.cfg.context_backend,
                page_evict=self.cfg.page_evict,
                bundles=self.bundles)
        else:
            self.lanes = LanePool(
                n_lanes, cfg=self.cfg.model_cfg, seed=self.cfg.seed,
                max_streams=self.cfg.pool_streams or 16,
                context_backend=self.cfg.context_backend,
                page_evict=self.cfg.page_evict)
        self.executor = self.lanes.ex(0)      # back-compat accessor

        if self.bundles is not None and fidelity_policy is None:
            inner_policies = {b.name: BMPR(b.profile)
                              for b in self.bundles}
            policy = inner_policies[self.bundles[0].name]
        else:
            inner_policies = None
            policy = fidelity_policy or BMPR(
                get_profile(step_cache=self.cfg.step_cache))
        self._profile = getattr(policy, "profile", None) or get_profile()
        self._bundle_profiles = (
            {b.name: b.profile for b in self.bundles}
            if self.bundles is not None else {})

        # ---- host calibration (one top-fidelity warm-up chunk) ----------
        # measures this host's top-fidelity chunk latency, warms the jit
        # cache for batch-size-1 shapes (shared by ALL lanes: the step
        # functions are module-level), and fixes the wall<->profile
        # time scale that replaces the old hand-tuned budget factor
        ex = self.executor
        ex.admit(-1, seed=999)
        ex.begin_chunk(-1, HIGHEST_QUALITY, 0.0)
        while -1 in ex.inflight:
            ex.run_step([-1])
        self.top_latency = ex.latency_ema[HIGHEST_QUALITY.key]
        # drop the calibration stream WITH its history: sid -1 must not
        # leak ledger/page-table/device-table entries or generated
        # chunks into the serving session
        ex.retire(-1, drop_history=True)
        # seed EVERY lane with the one measured prior (identical to
        # lane 0's single-observation EMA), so lane 0 carries no
        # warm-up asymmetry and cold lanes report honest R_u from their
        # first chunk
        step = self.top_latency / (HIGHEST_QUALITY.steps + 1)
        for lex in self.lanes.executors:
            lex.latency_ema[HIGHEST_QUALITY.key] = self.top_latency
            if hasattr(lex, "step_ema"):
                lex.step_ema[HIGHEST_QUALITY.key] = step
        self.chunk_seconds = (self.cfg.realtime_budget
                              or self.cfg.budget_factor * self.top_latency)
        time_scale = (self._profile.latency(HIGHEST_QUALITY)
                      / max(self.top_latency, 1e-9))
        # per-bundle warm-up calibration: every co-served model measures
        # ITS OWN top-fidelity chunk on lane 0 (warming that bundle's
        # jit cache), seeds its lanes' EMAs, and carries its own
        # wall<->profile time scale — a heavy model must not inherit a
        # light model's budget conversion
        if self.bundles is not None:
            self.bundles[0].top_latency = self.top_latency
            self.bundles[0].time_scale = time_scale
            for b in self.bundles[1:]:
                bex = self.lanes.bundle_executors[b.name][0]
                bex.admit(-1, seed=999)
                bex.begin_chunk(-1, HIGHEST_QUALITY, 0.0)
                while -1 in bex.inflight:
                    bex.run_step([-1])
                b.top_latency = bex.latency_ema[HIGHEST_QUALITY.key]
                bex.retire(-1, drop_history=True)
                bstep = b.top_latency / (HIGHEST_QUALITY.steps + 1)
                for lex in self.lanes.bundle_executors[b.name]:
                    lex.latency_ema[HIGHEST_QUALITY.key] = b.top_latency
                    if hasattr(lex, "step_ema"):
                        lex.step_ema[HIGHEST_QUALITY.key] = bstep
                b.time_scale = (b.profile.latency(HIGHEST_QUALITY)
                                / max(b.top_latency, 1e-9))
            # one session playout cadence, sized so the SLOWEST model's
            # top-fidelity chunk fits the same budget-factor headroom
            self.chunk_seconds = (
                self.cfg.realtime_budget
                or self.cfg.budget_factor
                * max(b.top_latency for b in self.bundles))
        multi = self.lanes.n_lanes > 1
        if self.bundles is not None:
            fid_policy: Any = _ModelRoutedPolicy(
                {b.name: _HostCalibratedPolicy(
                    (inner_policies[b.name] if inner_policies is not None
                     else policy),
                    self.lanes, b.time_scale, model=b.name)
                 for b in self.bundles},
                primary=self.bundles[0].name)
        else:
            fid_policy = _HostCalibratedPolicy(policy, self.lanes,
                                               time_scale)
        self.control = ControlPlane(
            ControlConfig(tick_interval=self.cfg.tick_interval,
                          # cross-worker mechanisms need >1 lane
                          use_rehoming=multi,
                          use_elastic_sp=multi),
            fidelity_policy=fid_policy)
        if multi:
            # SP2 expansion must never compile on the critical path
            self.lanes.prejit_sp()

        # ---- front door (admission control; autoscale forced off) -------
        self.front_door: Optional[FrontDoor] = None
        self._n_rejected = 0
        if self.cfg.front_door is not None:
            self.front_door = FrontDoor(
                dataclasses.replace(self.cfg.front_door, autoscale=False),
                first_chunk_estimate=self.top_latency)
            self.control.attach_front_door(self.front_door)

        # ---- cluster view: one Worker per lane --------------------------
        wpn = self.cfg.workers_per_node or self.lanes.n_lanes
        self.workers = [Worker(i, node=i // wpn)
                        for i in range(self.lanes.n_lanes)]
        self.worker = self.workers[0]         # back-compat accessor
        self.view = ClusterView({}, self.workers, wpn)
        if self.bundles is not None:
            # placement sees per-model weight: a heavy-model stream
            # occupies more of a worker than a cheap one (choose_home
            # argmin over Worker.load(weight))
            from repro.serve.modelplane import profile_name_of
            weights = {b.name: b.placement_weight for b in self.bundles}
            self.view.stream_weight = (
                lambda sid: weights.get(self.lanes.model_of.get(sid), 1.0))
            # spec.model accepts the registry arch id or its profile
            # alias ("self-forcing" -> "ardit-self-forcing")
            self._model_alias = {}
            for b in self.bundles:
                self._model_alias[b.name] = b.name
                self._model_alias[profile_name_of(b.name)] = b.name
        self.handles: Dict[int, StreamHandle] = {}
        self._order: List[int] = []
        self._events: List[Tuple[float, int, str, Any]] = []
        self._eseq = itertools.count()
        self._pending_arrivals = 0
        self._t0: Optional[float] = None
        self._next_tick = 0.0
        self._switches: Dict[int, int] = {}
        self._pending_sp_release: Dict[int, int] = {}
        self.fidelity_counts: Dict[str, int] = {}
        self.worker_tier_samples: List[Tuple[int, int, int]] = []

    # ---- submission --------------------------------------------------------
    def submit(self, spec: StreamSpec) -> StreamHandle:
        """Register one stream request.  Times in the spec are relative
        to session start (``run()``), scaled by ``arrival_scale``."""
        assert spec.sid not in self.handles, f"duplicate sid {spec.sid}"
        assert spec.sid >= 0, "negative sids are reserved (warm-up)"
        sc = self.cfg.arrival_scale
        h = StreamHandle(self, spec)
        self.handles[spec.sid] = h
        self._order.append(spec.sid)
        self._push(spec.arrival * sc, "arrival", spec.sid)
        self._pending_arrivals += 1
        for st in spec.switches:
            self._push((spec.arrival + st) * sc, "prompt_switch", spec.sid)
        for (ps, dur) in spec.pauses:
            self._push((spec.arrival + ps) * sc, "pause",
                       (spec.sid, dur * sc))
        return h

    def _push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    # ---- clock -------------------------------------------------------------
    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    # ---- event handlers (mirroring sched_sim.Simulator) --------------------
    def _bundle_for(self, sid: int):
        """The stream's model bundle (None on single-model sessions).
        A spec without a model rides the session primary."""
        if self.bundles is None:
            return None
        spec_model = getattr(self.handles[sid].spec, "model", None)
        if spec_model is None:
            return self.bundles[0]
        name = self._model_alias.get(spec_model)
        if name is None:
            raise KeyError(
                f"stream {sid} wants model {spec_model!r}, not in the "
                f"co-serve set {[b.name for b in self.bundles]}")
        return next(b for b in self.bundles if b.name == name)

    def _first_estimate(self, sid: int) -> float:
        b = self._bundle_for(sid)
        if b is None:
            return self.lanes.latency_ema_get(HIGHEST_QUALITY.key,
                                              self.top_latency)
        return self.lanes.latency_ema_get(HIGHEST_QUALITY.key,
                                          b.top_latency, model=b.name)

    def _on_arrival(self, sid: int, t_arr: float) -> None:
        self._pending_arrivals -= 1
        first_est = self._first_estimate(sid)
        if self.front_door is not None:
            dec = self.front_door.on_arrival(self.view, t_arr,
                                             first_est, sid)
            if dec.action == "reject":
                self._n_rejected += 1
                return
            if dec.action == "queue":
                return         # promoted by _drain_front_door (or shed)
        self._admit_stream(sid, t_arr, first_est)

    def _admit_stream(self, sid: int, t_arr: float,
                      first_est: float) -> None:
        """Place an admitted stream (``t_arr`` is the ORIGINAL arrival:
        a front-door queue wait consumes the stream's TTFC slack)."""
        spec = self.handles[sid].spec
        # SS3.3 steps 1-2: initial playout slack from the first-chunk
        # estimate (measured top-fidelity latency on THIS host), home
        # from the control plane (least-loaded non-donating lane)
        ttfc_slack = self.control.initial_slack(first_est)
        home = self.control.choose_home(self.view)
        bundle = self._bundle_for(sid)
        s = Stream(sid=sid, arrival=t_arr, target_chunks=spec.chunks,
                   chunk_seconds=self.chunk_seconds, home=home,
                   ttfc_slack=ttfc_slack,
                   next_deadline=t_arr + ttfc_slack)
        s.t_next = first_est
        if bundle is not None:
            s.model = bundle.name
        self.view.streams[sid] = s
        self.workers[home].queue.append(sid)
        model = bundle.name if bundle is not None else None
        self.lanes.admit(
            sid, home, seed=sid, streams=self.view.streams,
            protect=list(self.lanes.ex_for(home, model).inflight),
            model=model)

    def _on_prompt_switch(self, sid: int, now: float) -> None:
        s = self.view.streams.get(sid)
        if s is None or s.done:
            return
        # chunks buffered under the old condition are useless: playout
        # slack resets to the initial TTFC and the in-flight chunk is
        # aborted at the next step boundary (its denoise work is lost,
        # exactly the simulator's step_done = 0 reset)
        s.next_deadline = now + s.ttfc_slack
        s.step_done = 0
        s.remaining = 0.0
        self.lanes.abort_chunk(sid)
        if s.sp_donor is not None:
            # the donor's half-head mirror holds the OLD prompt's KV:
            # release the borrow before resetting (SP re-triggers if
            # the stream is still behind under the new prompt)
            self._pending_sp_release.pop(sid, None)
            elastic_sp.apply_release(
                self.view, SPDecision(sid, s.sp_donor, "release"))
            self.lanes.sp_release(sid)
        # fresh conditioning: the old cond embedding must NOT serve the
        # new prompt — re-encode and rewrite the sink page through the
        # normal KVPool.admit path (generation restarts bit-identically
        # to a fresh stream under the same conditioning seed)
        self._switches[sid] = self._switches.get(sid, 0) + 1
        self.lanes.reset_condition(sid, seed=self.switch_seed(sid))

    def switch_seed(self, sid: int) -> int:
        """Conditioning seed of a stream's CURRENT prompt: the admission
        seed (= sid) before any switch, then a deterministic fresh seed
        per switch (regression tests re-derive it)."""
        n = self._switches.get(sid, 0)
        return sid if n == 0 else sid + 100003 * n

    def _on_pause(self, payload: Tuple[int, float]) -> None:
        sid, dur = payload
        s = self.view.streams.get(sid)
        if s is None or s.done:
            return
        s.next_deadline += dur                 # playout halts; slack grows

    def _drain_events(self, now: float) -> None:
        while self._events and self._events[0][0] <= now:
            t, _, kind, payload = heapq.heappop(self._events)
            if kind == "arrival":
                self._on_arrival(payload, t)
            elif kind == "prompt_switch":
                self._on_prompt_switch(payload, now)
            elif kind == "pause":
                self._on_pause(payload)

    def _drain_front_door(self, now: float) -> None:
        admits, rejects = self.front_door.drain(self.view, now)
        self._n_rejected += len(rejects)
        for sid, t_arr in admits:
            self._admit_stream(sid, t_arr, self._first_estimate(sid))

    # ---- the session loop --------------------------------------------------
    def _all_done(self) -> bool:
        return (self._pending_arrivals == 0
                and (self.front_door is None
                     or not self.front_door.waiting)
                and all(s.done for s in self.view.streams.values()))

    def _sample_tiers(self) -> None:
        counts = queues.tier_counts(self.view)
        cls = [queues.worker_class(counts[w.wid]) for w in self.view.workers]
        self.worker_tier_samples.append(
            (cls.count("urgent"), cls.count("mixed"), cls.count("relaxed")))

    def run(self) -> SessionResult:
        """Drive every submitted stream to completion (or starvation
        stand-still) and return the session's metrics record."""
        while not self._all_done():
            now = self._now()
            self._drain_events(now)
            if self.front_door is not None and self.front_door.waiting:
                self._drain_front_door(now)

            # Algorithm 2 control tick: BMPR fidelity -> Eq. 1 credit ->
            # three-tier queue ordering -> re-homing plan -> elastic-SP
            # plan.  R_u comes from the executors' measured step EMAs
            # first so the tick sees honest remaining times (the
            # simulator's policy.on_tick equivalent).
            for s in self.view.active_streams():
                s.remaining = self.lanes.remaining_estimate(s.sid)
                if self.lanes.is_inflight(s.sid):
                    lane = self.lanes.lane_of.get(s.sid, 0)
                    link = self.lanes.sp_link(s.sid)
                    s.running_on = ((lane, link.donor) if link is not None
                                    else (lane,))
                else:
                    s.running_on = None
            if now >= self._next_tick:
                decisions = self.control.tick(self.view, now)
                self._apply_decisions(decisions)
                self._sample_tiers()
                self._next_tick = now + self.cfg.tick_interval
            else:
                # between ticks the queues keep tracking credit at step
                # boundaries, exactly like the simulator policy's order()
                for s in self.view.active_streams():
                    slack.update_stream_credit(s, now,
                                               self.control.config.alpha)
                queues.order_all(self.view)

            any_ran, any_runnable = self._dispatch_round(now)
            if any_ran:
                continue
            if any_runnable:
                # runnable streams, but none could be made page-resident
                # this round (all victims mid-chunk): defer one beat
                if not self.lanes.any_inflight():
                    if self._events:
                        self._wait_for(self._events[0][0])
                        continue
                    break      # no residency, no work: stand-still
                time.sleep(0.0005)
                continue
            if self._events:
                self._wait_for(self._events[0][0])
                continue
            if self.front_door is not None and self.front_door.waiting:
                # admission queue holds streams but no event is pending:
                # let wall-clock advance so the next drain can promote
                # (worker freed between checks) or time the entry out
                time.sleep(0.005)
                continue
            break                                # nothing left to serve
        return self.result()

    def _dispatch_round(self, now: float) -> Tuple[bool, bool]:
        """One step round over every lane: each lane advances at most
        one micro-batch (or one solo SP2 stream, which also consumes
        its donor lane's slot) by one denoise step.  Returns
        (any step ran, any lane had runnable streams)."""
        from repro.serve.batcher import compose_batch
        streams = self.view.streams
        runnables = {w.wid: queues.next_dispatch_set(w, streams, now)
                     for w in self.view.workers}

        # batch-axis SP rerouting: a stream whose link is mode "batch"
        # is served ON ITS DONOR lane as an extra row of the donor's
        # own micro-batch (one fused jitted call co-serving donor
        # streams + the borrowed stream) — it leaves its home lane's
        # runnable list and never consumes a solo dispatch slot
        guests: Dict[int, List[int]] = {}
        for w in self.view.workers:
            kept: List[int] = []
            for sid in runnables[w.wid]:
                link = self.lanes.sp_link(sid)
                if (link is not None
                        and getattr(link, "mode", "solo") == "batch"):
                    guests.setdefault(link.donor, []).append(sid)
                else:
                    kept.append(sid)
            runnables[w.wid] = kept

        # elastic SP2 reservation happens BEFORE any lane serves, so a
        # donor's step slot is genuinely consumed regardless of lane
        # iteration order (a donor with a smaller wid would otherwise
        # have served its own queue already by the time its borrower
        # dispatched).  Only a linked stream at the HEAD of its lane's
        # credit order reserves; linked streams deeper in the queue —
        # or whose donor is already committed — fold into the normal
        # micro-batch on the SP1 step (the home pool holds full heads,
        # so SP is an acceleration, never a correctness dependency; the
        # donor mirror keeps appending either way).
        sp_homes: Dict[int, int] = {}      # home wid -> linked sid
        lent: set = set()                  # donor wids, slot lent out
        for w in self.view.workers:
            r = runnables[w.wid]
            if not r or w.wid in lent:
                continue
            link = self.lanes.sp_link(r[0])
            if (link is not None and link.donor != w.wid
                    and link.donor not in lent
                    and link.donor not in sp_homes
                    # reserve only a stream that can actually run NOW:
                    # a failed residency fill must not idle the donor
                    # for the round (the stream defers; the lane serves
                    # its normal batch below)
                    and self.lanes.ex_for(
                        w.wid, self.lanes.model_of.get(r[0]))
                    .ensure_resident(r[0], streams, protect=[r[0]])):
                sp_homes[w.wid] = r[0]
                lent.add(link.donor)

        any_ran = False
        any_runnable = False
        for w in self.view.workers:
            runnable = runnables[w.wid]
            glist = guests.get(w.wid, [])
            if not runnable and not glist:
                continue
            any_runnable = True
            if w.wid in lent:
                continue       # step slot lent to another lane's SP2
            ex = self.lanes.ex(w.wid)
            max_batch = self.cfg.max_batch if hasattr(ex, "pool") else 1

            # per-stream executor on THIS lane: the stream's own
            # bundle's pool (single-model sessions resolve to ``ex``
            # itself, keeping the legacy call sequence object-for-object)
            def ex_of(sid: int) -> Any:
                return self.lanes.ex_for(w.wid,
                                         self.lanes.model_of.get(sid))

            sp_sid = sp_homes.get(w.wid)
            if sp_sid is not None:       # reserved (and already resident)
                sp_ex = ex_of(sp_sid)
                self._begin_if_needed(sp_ex, sp_sid, now)
                flights = {sp_sid: sp_ex.inflight[sp_sid]}
                completed, _ = sp_ex.run_step([sp_sid], sp_serve=True)
                any_ran = True
                now = self._now()
                for sid in completed:
                    self._complete_chunk(sid, flights[sid].fidelity,
                                         flights[sid].started, now)
                continue

            # page-granular admission control: fill the micro-batch from
            # the credit-ordered runnable set with streams that are — or
            # can be made — page-resident (credit-aware eviction); a
            # stream that cannot displace anyone defers one iteration.
            # Batch-axis guests ride ON TOP of max_batch (their donor
            # pages are already resident and eviction-protected), so a
            # borrow adds capacity instead of displacing donor streams.
            sids: List[int] = list(glist)
            for sid in runnable:
                if len(sids) >= max_batch + len(glist):
                    break
                if ex_of(sid).ensure_resident(sid, streams,
                                              protect=sids + [sid]):
                    sids.append(sid)
            if not sids:
                continue
            for sid in sids:
                self._begin_if_needed(ex_of(sid), sid, now)
            groups = compose_batch(
                sids, lambda sid: ex_of(sid).inflight[sid].fidelity,
                max_batch + len(glist), fuse=self.cfg.fuse_fidelity,
                model_of=(self.lanes.model_of.get
                          if self.lanes.bundle_executors else None))
            for grp in groups:
                # one sub-batch = one model's jitted step on one pool
                grp_ex = ex_of(grp[0])
                flights = {sid: grp_ex.inflight[sid] for sid in grp}
                completed, _ = grp_ex.run_step(grp)
                any_ran = True
                now = self._now()
                for sid in completed:
                    self._complete_chunk(sid, flights[sid].fidelity,
                                         flights[sid].started, now)
        return any_ran, any_runnable

    def _begin_if_needed(self, ex: Any, sid: int, now: float) -> None:
        if sid in ex.inflight:
            return
        s = self.view.streams[sid]
        # Eq. 1 (paper SS3.2): C_u = P_u - (R_u + T_u).  The fidelity
        # budget at a chunk boundary is the credit with T_u left free,
        # B = max(P_u - R_u, 0); R_u = 0 here because the stream is
        # between chunks.  The wall->profile unit conversion lives in
        # _HostCalibratedPolicy — no hand-tuned scale.
        budget = max(s.playout_slack(now) - s.remaining, 0.0)
        pol = self.control.fidelity_policy
        sel = getattr(pol, "select_for", None)
        dec = (sel(s.model, budget)
               if sel is not None and s.model is not None
               else pol.select(budget))
        s.next_fidelity = dec.fidelity
        s.t_next = dec.latency
        s.chunk_started = now
        s.step_done = 0
        ex.begin_chunk(sid, dec.fidelity, now)

    # ---- decision apply (the simulator's policy.on_tick equivalent) --------
    def _apply_decisions(self, decisions: TickDecisions) -> None:
        """Execute the tick's cross-worker decisions against the lane
        pool.  An apply can fail (state moved since planning — e.g. a
        full donor pool with nothing evictable); the decision is then
        dropped and the planner re-evaluates next tick."""
        for mig in decisions.migrations:
            if self.lanes.migrate(mig.sid, mig.src, mig.dst,
                                  cross_node=mig.cross_node):
                rehoming.apply_migration(self.view, mig)
        # a donor whose release had to be DEFERRED (its stream is
        # mid-chunk) is still physically borrowed until that boundary —
        # the planner's same-tick rejoin must not re-grant it, or the
        # deferred apply_release would later clear the NEW borrower's
        # donated_to mark (releases precede expands in the plan, so one
        # pass suffices)
        deferred_donors: set = set()
        for dec in decisions.sp_decisions:
            if dec.kind == "expand":
                if dec.donor in deferred_donors:
                    continue
                if self.lanes.sp_expand(dec.sid, dec.donor,
                                        self.view.streams):
                    elastic_sp.apply_expand(self.view, dec)
            elif self.lanes.is_inflight(dec.sid):
                # released at the next safe boundary (chunk completion):
                # the in-flight chunk's head-split step still reads the
                # donor pool
                self._pending_sp_release[dec.sid] = dec.donor
                deferred_donors.add(dec.donor)
            else:
                elastic_sp.apply_release(self.view, dec)
                self.lanes.sp_release(dec.sid)

    # ---- playout bookkeeping (the single per-stream record) ----------------
    def _complete_chunk(self, sid: int, fid: FidelityConfig,
                        started: float, now: float) -> None:
        s = self.view.streams[sid]
        ddl = s.next_deadline
        s.ready_times.append(now)
        s.deadlines.append(ddl)
        if s.first_chunk_time is None:
            s.first_chunk_time = now
        if now > ddl:
            s.stall_time += now - ddl
            s.stall_events.append(now - ddl)
        s.next_deadline = max(ddl, now) + s.chunk_seconds
        s.chunks_done += 1
        s.step_done = 0
        s.chunk_started = None
        s.running_on = None
        s.remaining = 0.0
        prof = (self._bundle_profiles.get(s.model, self._profile)
                if s.model is not None else self._profile)
        s.qualities.append(prof.quality(fid))
        s.fidelity_log.append(fid.key)
        self.fidelity_counts[fid.key] = \
            self.fidelity_counts.get(fid.key, 0) + 1
        if self.front_door is not None:
            self.front_door.observe_chunk(now - started,
                                          fidelity=fid.key, model=s.model)
        donor = self._pending_sp_release.pop(sid, None)
        if donor is not None and not s.finished:
            # the promised safe boundary: drop the borrow now
            elastic_sp.apply_release(
                self.view, SPDecision(sid, donor, "release"))
            self.lanes.sp_release(sid)
        if s.finished:
            # free the pages NOW: a finished stream's KV would otherwise
            # pin residency (generated chunks survive retire)
            s.done = True
            if s.sp_donor is not None:
                elastic_sp.apply_release(
                    self.view, SPDecision(sid, s.sp_donor, "release"))
            self.lanes.retire(sid)               # releases any SP link
            wq = self.workers[s.home].queue
            if sid in wq:
                wq.remove(sid)
        if self.cfg.verbose:
            print(f"t={now:6.2f}s stream {sid} chunk "
                  f"{s.chunks_done}/{s.target_chunks} "
                  f"fid={fid.key:22s} lat={now - started:.2f}s "
                  f"{'LATE' if now > ddl else 'on-time'}")

    def _wait_for(self, t_event: float) -> None:
        """Idle until the next workload event (capped nap so arrivals
        stay responsive without busy-spinning the host)."""
        now = self._now()
        time.sleep(max(0.0005, min(t_event - now, 0.05)))

    # ---- results -----------------------------------------------------------
    def result(self) -> SessionResult:
        # effective-window histories merged across lanes: a stream's
        # log lives wholly on its current lane (migrations carry it)
        eff_w: Dict[int, List[int]] = {}
        hits = misses = skipped = 0
        for ex in self.lanes.all_executors:
            for sid, log in getattr(ex, "effective_window_log",
                                    {}).items():
                if sid >= 0 and log:
                    eff_w.setdefault(sid, []).extend(log)
            sc = getattr(ex, "stepcache", None)
            if sc is not None:
                hits += sc.hits
                misses += sc.misses
            skipped += getattr(ex, "cache_skipped_launches", 0)
        cache_stats: Dict[str, float] = {}
        if hits or misses:
            cache_stats = {"hits": hits, "misses": misses,
                           "hit_rate": hits / (hits + misses),
                           "skipped_launches": skipped}
        return SessionResult(
            streams=dict(self.view.streams), engine=self.lanes.engine,
            n_rehomings=self.control.n_rehomings,
            n_sp_events=self.control.n_sp_events,
            worker_tier_samples=list(self.worker_tier_samples),
            fidelity_counts=dict(self.fidelity_counts),
            control_tick_times=list(self.control.tick_times),
            n_migrations_applied=self.lanes.n_migrations,
            n_sp_expands_applied=self.lanes.n_sp_expands,
            n_sp_releases_applied=self.lanes.n_sp_releases,
            admission=self.front_door.stats() if self.front_door else {},
            effective_window=eff_w, step_cache=cache_stats)

    def _served_stream(self, sid: int) -> ServedStream:
        """Back-compat view assembled FROM the per-stream record — the
        record is written once (``_complete_chunk``); nothing here is a
        second bookkeeping path."""
        r = self.view.streams.get(sid)
        spec = self.handles[sid].spec
        ex = self.lanes.executor_of(sid)
        base = getattr(ex, "streams", {}).get(sid)
        return ServedStream(
            sid=sid,
            cond=getattr(base, "cond", None),
            cache=getattr(base, "cache", None),
            target_chunks=r.target_chunks if r else spec.chunks,
            chunks=list(self.lanes.chunks_of(sid)),
            fidelity_log=list(r.fidelity_log) if r else [],
            next_deadline=r.next_deadline if r else 0.0,
            chunk_seconds=r.chunk_seconds if r else self.chunk_seconds)

    def served_streams(self) -> List[ServedStream]:
        """All submitted streams as ``ServedStream``s, submission order
        (the legacy ``serve_session*`` return type)."""
        return [self._served_stream(sid) for sid in self._order]
