"""Unified StreamingSession API: ONE control plane, ONE workload spec,
ONE metrics surface for the simulator and the real JAX executor.

Before this module, the repo had three divergent serving drivers: the
discrete-event ``sched_sim.Simulator`` (which runs the paper's Algorithm
2 through ``core.control_plane.ControlPlane``), the sequential
``serve_session`` loop, and the batched ``serve_session_batched`` loop —
the latter two re-implementing hand-rolled subsets of the control tick
(inline slack updates, ad-hoc queue ordering, a magic hand-tuned
fidelity-budget scale) and emitting no ``sched_sim.metrics.Summary``.

``StreamingSession`` consolidates them:

    * requests are submitted as ``sched_sim.workloads.StreamSpec``s —
      online arrivals, per-stream chunk counts, pause and prompt-switch
      events — exactly the objects every workload generator produces;
    * stream lifecycle is exposed through handles
      (``submit() -> StreamHandle``, ``.chunks_ready``, ``.done``);
    * the scheduling loop is driven by ``ControlPlane.tick()`` — the
      SAME decision code the simulator runs (BMPR fidelity -> Eq. 1
      service credit -> three-tier queue ordering) — with a real
      executor (batched page-pool executor or the sequential
      whole-chunk executor) as the apply layer;
    * every stream's playout timeline lives in ONE per-stream record
      (``core.types.Stream``), so ``sched_sim.metrics.summarize()``
      produces the same CPR / TTFC / stall Summary over a real session
      that it produces over a simulation.

Budget units (the fix for the old hand-tuned budget fudge): the offline
profile's latencies are H100-calibrated while the session's clock is
this host's wall clock, so the session measures one top-fidelity warm-up
chunk and scales Eq. 1 budgets by

    time_scale = profile.latency(HIGHEST_QUALITY) / measured_top_latency

(``_HostCalibratedPolicy``).  Once a fidelity's measured-latency EMA
exists it replaces the scaled profile estimate entirely (online
re-profiling), so T_u in Eq. 1 tracks this host, not the offline model.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import queues, slack
from repro.core.bmpr import BMPR, BMPRDecision
from repro.core.control_plane import ControlConfig, ControlPlane
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.core.state_plane import AsyncTransferEngine
from repro.core.types import ClusterView, Stream, Worker
from repro.profiler.profiles import get_profile
from repro.sched_sim import cost_model as cm
from repro.sched_sim.workloads import StreamSpec
from repro.serve.executor import ServedStream


@dataclasses.dataclass
class SessionConfig:
    """Knobs of a real-model serving session.

    ``executor`` picks the apply layer: ``"batched"`` (credit-ordered
    micro-batches over the paged KV pool) or ``"sequential"``
    (whole-chunk-atomic, one stream at a time).  ``tick_interval`` is
    the control-tick cadence in session seconds; 0 runs Algorithm 2 at
    every scheduler iteration (the natural cadence when chunk latencies
    are far below the paper's 3 s tick).  ``arrival_scale`` multiplies
    every StreamSpec time (arrival, switch offsets, pause windows) —
    < 1 compresses a workload trace so demos and tests don't wait out
    real Poisson gaps.  ``realtime_budget`` fixes the playout seconds
    per chunk; None calibrates 4x the measured top-fidelity latency so
    any host speed exercises both BMPR modes.
    """
    executor: str = "batched"
    max_batch: int = 4
    pool_streams: Optional[int] = None
    context_backend: str = "paged"
    realtime_budget: Optional[float] = None
    tick_interval: float = 0.0
    arrival_scale: float = 1.0
    seed: int = 0
    verbose: bool = True


@dataclasses.dataclass
class SessionResult:
    """Same surface as ``sched_sim.simulator.SimResult`` — one metrics
    language for simulated and real runs (``metrics.summarize`` accepts
    either)."""
    streams: Dict[int, Stream]
    engine: AsyncTransferEngine
    n_rehomings: int
    n_sp_events: int
    worker_tier_samples: List[Tuple[int, int, int]]
    fidelity_counts: Dict[str, int]
    control_tick_times: List[float]


class StreamHandle:
    """Client-side view of one submitted stream.

    Valid from ``submit()`` on; the underlying per-stream record
    (``core.types.Stream``) appears once the stream's arrival time is
    reached inside ``run()``.
    """

    def __init__(self, session: "StreamingSession", spec: StreamSpec):
        self._session = session
        self.spec = spec

    @property
    def sid(self) -> int:
        return self.spec.sid

    @property
    def record(self) -> Optional[Stream]:
        """The session's per-stream record (None before arrival)."""
        return self._session.view.streams.get(self.sid)

    @property
    def chunks_ready(self) -> int:
        return len(self._session.executor.chunks.get(self.sid, ()))

    @property
    def chunks(self) -> List[Any]:
        """Generated latent chunks, in playout order."""
        return list(self._session.executor.chunks.get(self.sid, ()))

    @property
    def done(self) -> bool:
        r = self.record
        return r is not None and r.finished

    @property
    def fidelity_log(self) -> List[str]:
        r = self.record
        return list(r.fidelity_log) if r is not None else []

    def served_stream(self) -> ServedStream:
        """Back-compat ``ServedStream`` view, built from the per-stream
        record (single source of truth for deadlines/fidelity)."""
        return self._session._served_stream(self.sid)


class _HostCalibratedPolicy:
    """Budget adapter between wall-second Eq. 1 budgets and a fidelity
    policy whose frontier is in offline-profile latency units.

    ``select(B)`` hands the wrapped policy ``B * time_scale`` (profile
    units) and converts the decision's latency estimate back to wall
    seconds — replaced by the executor's measured EMA for that fidelity
    as soon as one exists (online re-profiling).  Deliberately does NOT
    expose ``.profile``: ``ControlPlane.tick`` then takes T_u from the
    decision we return (wall units) instead of re-reading the offline
    profile.
    """

    def __init__(self, inner, executor, time_scale: float):
        self.inner = inner
        self.executor = executor
        self.time_scale = time_scale

    def select(self, budget: float) -> BMPRDecision:
        dec = self.inner.select(budget * self.time_scale)
        lat = self.executor.latency_ema.get(
            dec.fidelity.key, dec.latency / self.time_scale)
        return BMPRDecision(dec.fidelity, lat, dec.quality, dec.mode)


def uniform_specs(n_streams: int, chunks_per_stream: int) -> List[StreamSpec]:
    """All-arrive-at-t=0 specs with exact chunk counts — the workload
    the legacy ``serve_session*`` entry points implied."""
    frames = chunks_per_stream * cm.PIXEL_FRAMES_PER_CHUNK
    return [StreamSpec(sid=i, arrival=0.0, frames=frames)
            for i in range(n_streams)]


def cap_specs(specs: List[StreamSpec],
              max_chunks: int) -> List[StreamSpec]:
    """Trim every spec to at most ``max_chunks`` chunks (the real tiny
    model finishes promptly); arrivals and event times are kept."""
    return [dataclasses.replace(
        s, frames=min(s.frames, max_chunks * cm.PIXEL_FRAMES_PER_CHUNK))
        for s in specs]


class StreamingSession:
    """One serving session over a real executor, driven by the paper's
    control plane.

    Usage::

        session = StreamingSession(SessionConfig(executor="batched"))
        handles = [session.submit(spec) for spec in workloads.burst(n=6)]
        result = session.run()                 # SessionResult
        summary = sched_sim.metrics.summarize(result)

    ``submit`` only registers the spec; admission happens inside
    ``run()`` when the session clock reaches ``spec.arrival`` (times
    scaled by ``config.arrival_scale``).  Prompt switches reset playout
    slack to the initial TTFC and abort the in-flight chunk; pauses
    extend the playout deadline by their duration — the same event
    semantics as ``sched_sim.Simulator``.
    """

    def __init__(self, config: Optional[SessionConfig] = None, *,
                 executor: Optional[Any] = None,
                 fidelity_policy: Optional[Any] = None):
        self.cfg = config or SessionConfig()
        if executor is not None:
            self.executor = executor
        elif self.cfg.executor == "sequential":
            from repro.serve.executor import SequentialChunkExecutor
            self.executor = SequentialChunkExecutor(seed=self.cfg.seed)
        else:
            from repro.serve.batcher import BatchedChunkExecutor
            self.executor = BatchedChunkExecutor(
                seed=self.cfg.seed,
                max_streams=self.cfg.pool_streams or 16,
                context_backend=self.cfg.context_backend)

        policy = fidelity_policy or BMPR(get_profile())
        self._profile = getattr(policy, "profile", None) or get_profile()

        # ---- host calibration (one top-fidelity warm-up chunk) ----------
        # measures this host's top-fidelity chunk latency, warms the jit
        # cache for batch-size-1 shapes, and fixes the wall<->profile
        # time scale that replaces the old hand-tuned budget factor
        ex = self.executor
        ex.admit(-1, seed=999)
        ex.begin_chunk(-1, HIGHEST_QUALITY, 0.0)
        while -1 in ex.inflight:
            ex.run_step([-1])
        ex.retire(-1)
        self.top_latency = ex.latency_ema[HIGHEST_QUALITY.key]
        self.chunk_seconds = (self.cfg.realtime_budget
                              or 4.0 * self.top_latency)
        time_scale = (self._profile.latency(HIGHEST_QUALITY)
                      / max(self.top_latency, 1e-9))
        self.control = ControlPlane(
            ControlConfig(tick_interval=self.cfg.tick_interval,
                          use_rehoming=False,     # single local worker
                          use_elastic_sp=False),
            fidelity_policy=_HostCalibratedPolicy(policy, ex, time_scale))

        # ---- cluster view: one worker (this host's device) --------------
        self.worker = Worker(0, node=0)
        self.view = ClusterView({}, [self.worker], workers_per_node=1)
        self.handles: Dict[int, StreamHandle] = {}
        self._order: List[int] = []
        self._events: List[Tuple[float, int, str, Any]] = []
        self._eseq = itertools.count()
        self._pending_arrivals = 0
        self._t0: Optional[float] = None
        self._next_tick = 0.0
        self.fidelity_counts: Dict[str, int] = {}
        self.worker_tier_samples: List[Tuple[int, int, int]] = []

    # ---- submission --------------------------------------------------------
    def submit(self, spec: StreamSpec) -> StreamHandle:
        """Register one stream request.  Times in the spec are relative
        to session start (``run()``), scaled by ``arrival_scale``."""
        assert spec.sid not in self.handles, f"duplicate sid {spec.sid}"
        assert spec.sid >= 0, "negative sids are reserved (warm-up)"
        sc = self.cfg.arrival_scale
        h = StreamHandle(self, spec)
        self.handles[spec.sid] = h
        self._order.append(spec.sid)
        self._push(spec.arrival * sc, "arrival", spec.sid)
        self._pending_arrivals += 1
        for st in spec.switches:
            self._push((spec.arrival + st) * sc, "prompt_switch", spec.sid)
        for (ps, dur) in spec.pauses:
            self._push((spec.arrival + ps) * sc, "pause",
                       (spec.sid, dur * sc))
        return h

    def _push(self, t: float, kind: str, payload: Any) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    # ---- clock -------------------------------------------------------------
    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = time.perf_counter()
        return time.perf_counter() - self._t0

    # ---- event handlers (mirroring sched_sim.Simulator) --------------------
    def _on_arrival(self, sid: int, t_arr: float) -> None:
        spec = self.handles[sid].spec
        self._pending_arrivals -= 1
        # SS3.3 steps 1-2: initial playout slack from the first-chunk
        # estimate (measured top-fidelity latency on THIS host)
        first_est = self.executor.latency_ema.get(HIGHEST_QUALITY.key,
                                                  self.top_latency)
        ttfc_slack = self.control.initial_slack(first_est)
        s = Stream(sid=sid, arrival=t_arr, target_chunks=spec.chunks,
                   chunk_seconds=self.chunk_seconds, home=0,
                   ttfc_slack=ttfc_slack,
                   next_deadline=t_arr + ttfc_slack)
        s.t_next = first_est
        self.view.streams[sid] = s
        self.worker.queue.append(sid)
        self.executor.admit(sid, seed=sid, streams=self.view.streams,
                            protect=list(self.executor.inflight))

    def _on_prompt_switch(self, sid: int, now: float) -> None:
        s = self.view.streams.get(sid)
        if s is None or s.done:
            return
        # chunks buffered under the old condition are useless: playout
        # slack resets to the initial TTFC and the in-flight chunk is
        # aborted at the next step boundary (its denoise work is lost,
        # exactly the simulator's step_done = 0 reset)
        s.next_deadline = now + s.ttfc_slack
        s.step_done = 0
        s.remaining = 0.0
        self.executor.abort_chunk(sid)

    def _on_pause(self, payload: Tuple[int, float]) -> None:
        sid, dur = payload
        s = self.view.streams.get(sid)
        if s is None or s.done:
            return
        s.next_deadline += dur                 # playout halts; slack grows

    def _drain_events(self, now: float) -> None:
        while self._events and self._events[0][0] <= now:
            t, _, kind, payload = heapq.heappop(self._events)
            if kind == "arrival":
                self._on_arrival(payload, t)
            elif kind == "prompt_switch":
                self._on_prompt_switch(payload, now)
            elif kind == "pause":
                self._on_pause(payload)

    # ---- the session loop --------------------------------------------------
    def _all_done(self) -> bool:
        return (self._pending_arrivals == 0
                and all(s.done for s in self.view.streams.values()))

    def _sample_tiers(self) -> None:
        counts = queues.tier_counts(self.view)
        cls = [queues.worker_class(counts[w.wid]) for w in self.view.workers]
        self.worker_tier_samples.append(
            (cls.count("urgent"), cls.count("mixed"), cls.count("relaxed")))

    def run(self) -> SessionResult:
        """Drive every submitted stream to completion (or starvation
        stand-still) and return the session's metrics record."""
        ex = self.executor
        # the whole-chunk-atomic sequential adapter has no KV pool and
        # serves one stream per call; the batched executor micro-batches
        max_batch = self.cfg.max_batch if hasattr(ex, "pool") else 1
        from repro.serve.batcher import compose_batch

        while not self._all_done():
            now = self._now()
            self._drain_events(now)

            # Algorithm 2 control tick: BMPR fidelity -> Eq. 1 credit ->
            # three-tier queue ordering.  R_u comes from the executor's
            # measured step EMAs first so the tick sees honest remaining
            # times (the simulator's policy.on_tick equivalent).
            for s in self.view.active_streams():
                s.remaining = ex.remaining_estimate(s.sid)
                s.running_on = (0,) if s.sid in ex.inflight else None
            if now >= self._next_tick:
                self.control.tick(self.view, now)
                self._sample_tiers()
                self._next_tick = now + self.cfg.tick_interval
            else:
                # between ticks the queue keeps tracking credit at step
                # boundaries, exactly like the simulator policy's order()
                for s in self.view.active_streams():
                    slack.update_stream_credit(s, now,
                                               self.control.config.alpha)
                queues.order_queue(self.worker, self.view.streams)
            runnable = queues.next_dispatch_set(self.worker,
                                                self.view.streams, now)
            if not runnable:
                if self._events:
                    self._wait_for(self._events[0][0])
                    continue
                break                            # nothing left to serve

            # page-granular admission control: fill the micro-batch from
            # the credit-ordered runnable set with streams that are — or
            # can be made — page-resident (credit-aware eviction); a
            # stream that cannot displace anyone defers one iteration.
            sids: List[int] = []
            for sid in runnable:
                if len(sids) >= max_batch:
                    break
                if ex.ensure_resident(sid, self.view.streams,
                                      protect=sids + [sid]):
                    sids.append(sid)
            if not sids:
                if not ex.inflight:
                    if self._events:
                        self._wait_for(self._events[0][0])
                        continue
                    break          # no residency, no work: stand-still
                time.sleep(0.0005)
                continue

            for sid in sids:
                if sid not in ex.inflight:
                    s = self.view.streams[sid]
                    # Eq. 1 (paper SS3.2): C_u = P_u - (R_u + T_u).  The
                    # fidelity budget at a chunk boundary is the credit
                    # with T_u left free, B = max(P_u - R_u, 0); R_u = 0
                    # here because the stream is between chunks.  The
                    # wall->profile unit conversion lives in
                    # _HostCalibratedPolicy — no hand-tuned scale.
                    budget = max(s.playout_slack(now) - s.remaining, 0.0)
                    dec = self.control.fidelity_policy.select(budget)
                    s.next_fidelity = dec.fidelity
                    s.t_next = dec.latency
                    s.chunk_started = now
                    s.step_done = 0
                    ex.begin_chunk(sid, dec.fidelity, now)

            groups = compose_batch(
                sids, lambda sid: ex.inflight[sid].fidelity, max_batch)
            for grp in groups:
                flights = {sid: ex.inflight[sid] for sid in grp}
                completed, _ = ex.run_step(grp)
                now = self._now()
                for sid in completed:
                    self._complete_chunk(sid, flights[sid].fidelity,
                                         flights[sid].started, now)
        return self.result()

    def _wait_for(self, t_event: float) -> None:
        """Idle until the next workload event (capped nap so arrivals
        stay responsive without busy-spinning the host)."""
        now = self._now()
        time.sleep(max(0.0005, min(t_event - now, 0.05)))

    # ---- playout bookkeeping (the single per-stream record) ----------------
    def _complete_chunk(self, sid: int, fid: FidelityConfig,
                        started: float, now: float) -> None:
        s = self.view.streams[sid]
        ddl = s.next_deadline
        s.ready_times.append(now)
        s.deadlines.append(ddl)
        if s.first_chunk_time is None:
            s.first_chunk_time = now
        if now > ddl:
            s.stall_time += now - ddl
            s.stall_events.append(now - ddl)
        s.next_deadline = max(ddl, now) + s.chunk_seconds
        s.chunks_done += 1
        s.step_done = 0
        s.chunk_started = None
        s.running_on = None
        s.remaining = 0.0
        s.qualities.append(self._profile.quality(fid))
        s.fidelity_log.append(fid.key)
        self.fidelity_counts[fid.key] = \
            self.fidelity_counts.get(fid.key, 0) + 1
        if s.finished:
            # free the pages NOW: a finished stream's KV would otherwise
            # pin residency (generated chunks survive retire)
            s.done = True
            self.executor.retire(sid)
            if sid in self.worker.queue:
                self.worker.queue.remove(sid)
        if self.cfg.verbose:
            print(f"t={now:6.2f}s stream {sid} chunk "
                  f"{s.chunks_done}/{s.target_chunks} "
                  f"fid={fid.key:22s} lat={now - started:.2f}s "
                  f"{'LATE' if now > ddl else 'on-time'}")

    # ---- results -----------------------------------------------------------
    def result(self) -> SessionResult:
        engine = (self.executor.pool.engine
                  if hasattr(self.executor, "pool")
                  else getattr(self.executor, "engine",
                               AsyncTransferEngine()))
        return SessionResult(
            streams=dict(self.view.streams), engine=engine,
            n_rehomings=self.control.n_rehomings,
            n_sp_events=self.control.n_sp_events,
            worker_tier_samples=list(self.worker_tier_samples),
            fidelity_counts=dict(self.fidelity_counts),
            control_tick_times=list(self.control.tick_times))

    def _served_stream(self, sid: int) -> ServedStream:
        """Back-compat view assembled FROM the per-stream record — the
        record is written once (``_complete_chunk``); nothing here is a
        second bookkeeping path."""
        r = self.view.streams.get(sid)
        spec = self.handles[sid].spec
        base = getattr(self.executor, "streams", {}).get(sid)
        return ServedStream(
            sid=sid,
            cond=getattr(base, "cond", None),
            cache=getattr(base, "cache", None),
            target_chunks=r.target_chunks if r else spec.chunks,
            chunks=list(self.executor.chunks.get(sid, ())),
            fidelity_log=list(r.fidelity_log) if r else [],
            next_deadline=r.next_deadline if r else 0.0,
            chunk_seconds=r.chunk_seconds if r else self.chunk_seconds)

    def served_streams(self) -> List[ServedStream]:
        """All submitted streams as ``ServedStream``s, submission order
        (the legacy ``serve_session*`` return type)."""
        return [self._served_stream(sid) for sid in self._order]
