"""Batched multi-stream serving executor (continuous cross-request
batching at denoise-step granularity).

The sequential ``ChunkExecutor`` generates chunks one stream at a time,
so the control plane's credit ordering cannot exploit any batch
parallelism.  This module adds the execution-side counterpart of the
paper's step-boundary preemption (SS3.1): every scheduler iteration
composes a *micro-batch* from the credit-ordered runnable set (lowest
credit first, up to ``max_batch``), splits it into same-fidelity
sub-batches, and advances each sub-batch by ONE denoise step with a
single jitted batched denoise-step call over a PAGE-GRANULAR device KV
pool (SS4.1's state plane): each stream owns a cond sink page plus a
ring of chunk pages through a per-stream page table.  By default the
step is PAGE-TABLE-NATIVE (``context_backend="paged"``): attention
consumes (pool, block tables, page-coordinate masks) directly via
``ardit.denoise_step_paged`` -> ``attention.paged_mha`` ->
``kernels/paged_attention``, never materializing a contiguous context;
``context_backend="gather"`` keeps the gather-per-boundary path as the
executable reference.
Streams join and leave the batch at step boundaries; on admission
pressure the executor evicts the highest-credit resident (host spill,
bit-exact restore) instead of failing, so more streams than the pool
holds can be served (oversubscription).  Measured whole-chunk wall time
feeds the latency EMAs so BMPR budgets and service-credit estimates
stay honest (re-profiling).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import queues
from repro.core.fidelity import FidelityConfig
from repro.core.state_plane import AsyncTransferEngine, PagedKVPool
from repro.core.types import Stream
from repro.models import ardit as A
from repro.models import kvcache
from repro.models.stepcache import StepCacheManager
from repro.serve.executor import EMA_DECAY, ChunkExecutor, ServedStream


def compose_batch(sids: Sequence[int],
                  fidelity_of: Callable[[int], FidelityConfig],
                  max_batch: int, fuse: bool = False,
                  model_of: Optional[Callable[[int], str]] = None,
                  ) -> List[List[int]]:
    """Credit-ordered micro-batch composition.

    ``sids`` is the runnable set already ordered by service credit
    ascending (``queues.next_dispatch_set``).  Takes the lowest-credit
    ``max_batch`` streams and splits them into same-fidelity sub-batches
    (``FidelityConfig.key``), preserving credit order within and across
    groups — the first group contains the most urgent stream.

    ``fuse=True`` groups by **quantization dtype only** (the fused
    heterogeneous-fidelity dispatch): steps, window, and sparsity are
    per-row data inside ``run_step`` — the padded-steps schedule — so
    one jitted launch serves every fidelity of a dtype, cutting
    dispatch count from O(#fidelity keys) to O(#dtypes).  The dtype
    split stays: KV quantization changes the pool buffer dtype the
    jitted step is compiled against, which cannot be row data.

    ``model_of`` (heterogeneous co-serving) prefixes every group key
    with the stream's model bundle: a sub-batch runs one jitted step of
    ONE model against ONE pool, so ``(model, kv_dtype)`` is the fused
    grouping floor.  None (single-model sessions) keeps the exact
    legacy keys.
    """
    groups: Dict[Any, List[int]] = {}
    for sid in list(sids)[:max_batch]:
        fid = fidelity_of(sid)
        key = fid.quant if fuse else fid.key
        if model_of is not None:
            key = (model_of(sid), key)
        groups.setdefault(key, []).append(sid)
    return list(groups.values())


class PageLedger:
    """Host-side page bookkeeping of the device pool (no KV values).

    LIFO free list (O(1) pop/push), per-stream page tables (entry 0 =
    cond sink page, entry 1+r = ring slot r), per-stream chunk counts,
    and the set of spilled streams.  Residency is mirrored into a
    ``core.state_plane.PagedKVPool`` so the real executor and the
    simulator share one accounting model (and one invariant checker).
    """

    def __init__(self, n_pages: int, pages_per_stream: int):
        self.n_pages = n_pages
        self.pages_per_stream = pages_per_stream
        self._free: List[int] = list(range(n_pages))
        self.tables: Dict[int, np.ndarray] = {}
        self.chunks: Dict[int, int] = {}
        self.spilled: set = set()
        self.accounting = PagedKVPool(n_pages)
        # partial-window residency: absolute chunk indices whose ring
        # page was individually evicted (table entry -1, KV DISCARDED —
        # a degradation, not a spill).  The set survives whole-stream
        # spill/restore (the restored page holds zeros, not the lost
        # KV) and is pruned as chunks age out of the ring.
        self.dropped: Dict[int, set] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def can_admit(self) -> bool:
        return len(self._free) >= self.pages_per_stream

    def resident(self, sid: int) -> bool:
        return sid in self.tables

    def resident_sids(self) -> List[int]:
        return list(self.tables)

    def take(self, sid: int, chunks: int = 0) -> np.ndarray:
        """Allocate a page table for ``sid`` (admission or restore)."""
        assert sid not in self.tables, f"stream {sid} already resident"
        assert self.can_admit(), "ledger full: caller must evict first"
        table = np.asarray([self._free.pop()
                            for _ in range(self.pages_per_stream)])
        self.tables[sid] = table
        self.chunks[sid] = chunks
        self.spilled.discard(sid)
        self.accounting.alloc(sid, self.pages_per_stream)
        return table

    def drop(self, sid: int, spill: bool) -> Optional[np.ndarray]:
        """Free ``sid``'s pages; ``spill=True`` keeps it re-admittable.
        Idempotent: dropping a non-resident stream is a no-op (returns
        None) — no double-free."""
        table = self.tables.pop(sid, None)
        if table is None:
            if not spill:
                self.spilled.discard(sid)
                self.chunks.pop(sid, None)
                self.dropped.pop(sid, None)
            return None
        # hole entries (-1: individually evicted ring pages) own nothing
        self._free.extend(int(p) for p in table if int(p) >= 0)
        self.accounting.release(sid)
        if spill:
            self.spilled.add(sid)
        else:
            self.chunks.pop(sid, None)
            self.dropped.pop(sid, None)
        return table

    # ---- partial-window residency (page-granular eviction) -----------------
    def _ring_contents(self, sid: int) -> Dict[int, Optional[int]]:
        """Table ring entry (1..W) -> absolute chunk it currently holds,
        or None for an entry no chunk has reached yet."""
        w = self.pages_per_stream - 1
        n = self.chunks.get(sid, 0)
        held: Dict[int, Optional[int]] = {
            e: None for e in range(1, self.pages_per_stream)}
        for c in range(max(0, n - w), n):
            held[kvcache.page_of_chunk(c, w)] = c
        return held

    def page_eviction_entry(self, sid: int) -> Optional[int]:
        """Ring entry the partial-window ladder would free next for
        ``sid``, or None when the stream is at its residency floor.
        Preference order: an entry no chunk has reached yet (zero
        quality cost), else the entry holding the OLDEST retained chunk
        — never the newest chunk (always visible, most valuable) and
        never the last allocated ring entry (so an append into a hole
        can always self-heal by stealing a sibling page)."""
        table = self.tables.get(sid)
        if table is None:
            return None
        alloc = [e for e in range(1, len(table)) if int(table[e]) >= 0]
        if len(alloc) <= 1:
            return None
        held = self._ring_contents(sid)
        unwritten = [e for e in alloc if held[e] is None]
        if unwritten:
            return unwritten[-1]
        newest = self.chunks.get(sid, 0) - 1
        olds = sorted((held[e], e) for e in alloc if held[e] != newest)
        return olds[0][1] if olds else None

    def evict_page(self, sid: int) -> Optional[int]:
        """Free ONE of ``sid``'s ring pages (partial-window residency:
        the stream stays resident with its effective window reduced by
        one chunk).  The page's KV is DISCARDED, not spilled — the
        chunk it held joins ``dropped`` and the masks stop attending to
        it.  Returns the dropped absolute chunk index (or -1 for an
        unwritten entry), None when the stream is at its floor."""
        entry = self.page_eviction_entry(sid)
        if entry is None:
            return None
        held = self._ring_contents(sid)
        table = self.tables[sid]
        self._free.append(int(table[entry]))
        table[entry] = -1
        self.accounting.release_pages(sid, 1)
        c = held[entry]
        if c is not None:
            self.dropped.setdefault(sid, set()).add(c)
        return c if c is not None else -1

    def prune_dropped(self, sid: int) -> None:
        """Forget dropped chunks that aged out of the ring — they are
        no longer addressable, degraded window or not."""
        d = self.dropped.get(sid)
        if d:
            floor = self.chunks.get(sid, 0) - (self.pages_per_stream - 1)
            d.difference_update({c for c in d if c < floor})
            if not d:
                self.dropped.pop(sid, None)

    def append_page(self, sid: int) -> int:
        """Destination page of ``sid``'s next chunk (ring entry).  When
        the entry is a hole (its page was individually evicted), the
        append HEALS it: a free page if one exists, else the stream
        steals its own least-valuable sibling ring page (whose chunk
        joins ``dropped`` — degradation stays page-granular and
        self-contained)."""
        table = self.tables[sid]
        entry = kvcache.page_of_chunk(self.chunks[sid],
                                      self.pages_per_stream - 1)
        if int(table[entry]) < 0:
            if self._free:
                table[entry] = self._free.pop()
                ok = self.accounting.alloc(sid, 1)
                assert ok
            else:
                donor = self._steal_entry(sid, entry)
                table[entry] = int(table[donor])
                table[donor] = -1
        return int(table[entry])

    def _steal_entry(self, sid: int, target: int) -> int:
        """Sibling ring entry whose page a hole-append steals under a
        dry free list: an unreached entry first, else the oldest
        retained chunk's entry (which joins ``dropped``)."""
        table = self.tables[sid]
        alloc = [e for e in range(1, len(table))
                 if e != target and int(table[e]) >= 0]
        assert alloc, f"stream {sid} has no ring page left to steal"
        held = self._ring_contents(sid)
        unwritten = [e for e in alloc if held[e] is None]
        if unwritten:
            return unwritten[-1]
        donor = min(alloc, key=lambda e: held[e])
        self.dropped.setdefault(sid, set()).add(held[donor])
        return donor

    def check(self) -> None:
        """Pool invariants: page conservation, unique ownership, and
        agreement with the mirrored state-plane accounting."""
        allocated = [int(p) for t in self.tables.values()
                     for p in t if int(p) >= 0]
        assert len(set(allocated)) == len(allocated), \
            "page owned by two streams"
        assert len(set(self._free)) == len(self._free), \
            "duplicate page in free list (double-free)"
        assert not set(allocated) & set(self._free), \
            "page both free and allocated"
        assert len(allocated) + len(self._free) == self.n_pages, \
            "page leak: used + free != n_pages"
        assert not self.spilled & set(self.tables), \
            "stream both spilled and resident"
        for sid, t in self.tables.items():
            assert int(t[0]) >= 0, f"stream {sid} lost its sink page"
            assert len(t) == 1 or any(int(p) >= 0 for p in t[1:]), \
                f"stream {sid} degraded below the one-ring-page floor"
        assert self.accounting.used == len(allocated)
        self.accounting.check()


class KVPool:
    """Page-granular device KV pool (the ROADMAP "paged-KV
    defragmentation" item).

    KV lives as one [L, n_pages, page_tokens, Hkv, Dh] pair; a resident
    stream owns ``1 + window_chunks`` pages recorded in its page table
    (cond sink page + ring of chunk pages; chunk c lands in table entry
    ``1 + c % window_chunks``).  Sub-batches assemble their contiguous
    sink+ring context by gathering pages through the tables
    (``kvcache.gather_pages``), bitwise-identical to the stacked
    whole-stream rings this replaces.  On admission pressure ``admit``
    does NOT raise: the stream is parked host-side (evict-or-defer
    signal) and the executor decides — evict a victim via
    ``queues.pick_eviction`` and ``restore``, or defer.  Evicted
    streams spill their pages to host memory and are restored
    bit-exactly on re-admission, so oversubscription (more streams than
    the pool holds) never loses context.
    """

    def __init__(self, cfg: ModelConfig, params: Any, max_streams: int,
                 engine: Optional[AsyncTransferEngine] = None,
                 device: Optional[Any] = None):
        self.cfg, self.params = cfg, params
        self._tc = A.chunk_tokens(cfg)
        self._w = cfg.ardit_window_chunks
        self.page_tokens = max(A.COND_TOKENS, self._tc)
        pps = kvcache.pages_per_stream(self._w)
        self.ledger = PageLedger(max_streams * pps, pps)
        shape = (cfg.n_layers, self.ledger.n_pages, self.page_tokens,
                 cfg.n_kv_heads, cfg.head_dim)
        dt = jnp.dtype(cfg.kv_dtype)
        # a device-backed pool COMMITS its buffers to its lane's device
        # (``jax.devices()[lane]`` under a multi-device runtime), so a
        # cross-lane page move is a real ``jax.device_put`` between
        # device buffers, not a host-array relabel
        self.device = device
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        if device is not None:
            self.k = jax.device_put(self.k, device)
            self.v = jax.device_put(self.v, device)
        self._spill: Dict[int, Dict[str, Any]] = {}   # sid -> host pages
        # device-side per-stream page tables, built once per residency
        # epoch (invalidated on admit/evict/restore/retire) instead of
        # np.stack + host->device upload on every boundary
        self._dev_tables: Dict[int, jax.Array] = {}
        # spill/restore traffic goes through the state plane's async
        # transfer engine so residency churn is charged the paper's
        # async-stream protocol latency (ROADMAP "transfer-engine
        # timing"); the log doubles as the benchmark's transfer report.
        # A multi-lane session injects ONE shared engine so migrations
        # and SP head-partition moves land on one metrics surface.
        self.engine = engine or AsyncTransferEngine(n_layers=cfg.n_layers)
        # directional byte counters: what this pool RECEIVED vs what it
        # SENT AWAY.  A cross-lane move charges the source's ``out`` and
        # the destination's ``in`` — never the same pool twice — so
        # per-lane benchmark rows attribute traffic to the lane that
        # actually carried it (spill = out, restore = in)
        self.transfer_bytes_in = 0
        self.transfer_bytes_out = 0

    @property
    def transfer_bytes(self) -> int:
        """Total KV bytes moved through this pool's boundary (in + out):
        the back-compat aggregate the benchmark's transfer report keys
        on."""
        return self.transfer_bytes_in + self.transfer_bytes_out

    # ---- ledger views ------------------------------------------------------
    @property
    def n_pages(self) -> int:
        return self.ledger.n_pages

    @property
    def pages_per_stream(self) -> int:
        return self.ledger.pages_per_stream

    @property
    def free_pages(self) -> int:
        return self.ledger.free_pages

    @property
    def chunks(self) -> Dict[int, int]:
        """Per-stream chunk counts (resident and spilled streams)."""
        return self.ledger.chunks

    def can_admit(self) -> bool:
        return self.ledger.can_admit()

    def resident(self, sid: int) -> bool:
        return self.ledger.resident(sid)

    def resident_sids(self) -> List[int]:
        return self.ledger.resident_sids()

    def spilled(self, sid: int) -> bool:
        return sid in self._spill

    # ---- device writes / gathers -------------------------------------------
    def _write(self, pages: np.ndarray, nk: jax.Array,
               nv: jax.Array) -> None:
        pg = jnp.asarray(np.asarray(pages), jnp.int32)
        if self.device is not None:
            # incoming rows may be committed to ANOTHER lane's device
            # (batch-axis SP shipback, migration import): land them here
            # first — a same-device put is a no-op, a cross-device put
            # is the real move
            nk = jax.device_put(nk, self.device)
            nv = jax.device_put(nv, self.device)
            pg = jax.device_put(pg, self.device)
        self.k = kvcache.pool_write_pages(self.k, nk, pg)
        self.v = kvcache.pool_write_pages(self.v, nv, pg)

    def _sink_kv(self, cond: jax.Array) -> Tuple[jax.Array, jax.Array]:
        sub = A.init_batched_cache(self.cfg, self.params, cond)
        return (sub["k"][:, :, :A.COND_TOKENS],
                sub["v"][:, :, :A.COND_TOKENS])

    def table_rows(self, sid: int) -> np.ndarray:
        """Physical page rows of ``sid``'s table with holes (-1:
        individually evicted ring pages) mapped to the stream's own
        sink page — a valid, fully-masked stand-in: the visibility
        masks never attend to a dropped chunk's tokens, so the gather /
        kernel may read anything there."""
        t = self.ledger.tables[sid]
        return np.where(t < 0, t[0], t)

    def device_table(self, sid: int) -> jax.Array:
        """This stream's page table as a device int32 [1 + W] array,
        cached for the residency epoch (the table only changes on
        admit/evict/restore/retire/page-evict, so re-uploading it per
        boundary — let alone per step — is pure waste)."""
        t = self._dev_tables.get(sid)
        if t is None:
            t = jnp.asarray(self.table_rows(sid), jnp.int32)
            if self.device is not None:
                t = jax.device_put(t, self.device)
            self._dev_tables[sid] = t
        return t

    def tables_for(self, sids: Sequence[int]) -> jax.Array:
        """Stacked [b, 1 + W] block table of a sub-batch (device)."""
        return jnp.stack([self.device_table(sid) for sid in sids])

    def gather(self, sids: Sequence[int],
               n_ring: int) -> Tuple[jax.Array, jax.Array]:
        """Contiguous [L, b, COND + n_ring*tc, Hkv, Dh] context for a
        sub-batch, assembled through the page tables (the ``gather``
        context backend — the paged backend never materializes this)."""
        tables = self.tables_for(sids)
        k = kvcache.gather_pages(self.k, tables, A.COND_TOKENS,
                                 self._tc, n_ring)
        v = kvcache.gather_pages(self.v, tables, A.COND_TOKENS,
                                 self._tc, n_ring)
        return k, v

    # ---- residency lifecycle -----------------------------------------------
    def admit(self, sid: int, cond: jax.Array) -> bool:
        """Admit one stream: write its cond (sink) KV into a fresh page
        set.  Returns False when the pool is full — the stream is parked
        host-side and the caller must evict-and-``restore`` or defer
        (no exception)."""
        sk, sv = self._sink_kv(cond)
        if self.can_admit():
            table = self.ledger.take(sid)
            self._dev_tables.pop(sid, None)
            self._write(table[:1], sk, sv)
            return True
        dt = self.k.dtype
        pages = np.zeros((self.cfg.n_layers, self.pages_per_stream,
                          self.page_tokens) + self.k.shape[3:], dt)
        pages_v = np.zeros_like(pages)
        pages[:, 0, :A.COND_TOKENS] = np.asarray(sk[:, 0].astype(dt))
        pages_v[:, 0, :A.COND_TOKENS] = np.asarray(sv[:, 0].astype(dt))
        self._spill[sid] = {"k": pages, "v": pages_v}
        self.ledger.spilled.add(sid)
        self.ledger.chunks[sid] = 0
        return False

    def _charge_transfer(self, n_bytes: int, direction: str) -> None:
        """Record one spill/restore on the async transfer engine (the
        paper's async-stream protocol: the dispatcher only waits for the
        first layer; later layers overlap with compute).  ``direction``
        attributes the bytes: ``"out"`` = left this pool (spill),
        ``"in"`` = arrived (restore / import)."""
        if direction == "out":
            self.transfer_bytes_out += n_bytes
        else:
            self.transfer_bytes_in += n_bytes
        self.engine.transfer(time.perf_counter(), n_bytes,
                             cross_node=False)

    def evict(self, sid: int) -> int:
        """Spill a resident stream's pages to host memory and free them.
        Returns the number of pages released (credit-aware victim
        selection is the caller's job — ``queues.pick_eviction``).  A
        partially-degraded stream spills with its hole slices zeroed
        (their KV is already gone; ``ledger.dropped`` keeps masking the
        lost chunks after restore)."""
        table = self.ledger.tables[sid]
        holes = np.flatnonzero(np.asarray(table) < 0)
        rows = jnp.asarray(self.table_rows(sid), jnp.int32)
        # materialize on host BEFORE the pages are reused
        spill_k = np.asarray(self.k[:, rows])
        spill_v = np.asarray(self.v[:, rows])
        if holes.size:
            # np.asarray of a device buffer is a read-only view
            spill_k = spill_k.copy()
            spill_v = spill_v.copy()
            spill_k[:, holes] = 0
            spill_v[:, holes] = 0
        self._spill[sid] = {"k": spill_k, "v": spill_v}
        self.ledger.drop(sid, spill=True)
        self._dev_tables.pop(sid, None)
        self._charge_transfer(spill_k.nbytes + spill_v.nbytes, "out")
        return self.pages_per_stream

    def evict_page(self, sid: int) -> bool:
        """Free ONE ring page of ``sid`` (partial-window residency: the
        degradation ladder's first rung).  The page's KV is discarded —
        no host spill and NO transfer charge: nothing moved anywhere,
        the stream simply trades its effective window down by a chunk.
        False when the stream is at its residency floor."""
        if self.ledger.evict_page(sid) is None:
            return False
        self._dev_tables.pop(sid, None)
        return True

    def has_evictable_page(self, sid: int) -> bool:
        return self.ledger.page_eviction_entry(sid) is not None

    def effective_window(self, sid: int, window: int) -> int:
        """Chunks of context actually visible to ``sid``'s next chunk:
        the fidelity window clipped by fill and ring size, minus
        visible chunks lost to page-granular eviction."""
        n = self.ledger.chunks.get(sid, 0)
        w_vis = min(int(window), n, self._w)
        dropped = self.ledger.dropped.get(sid, ())
        lost = sum(1 for c in dropped if n - w_vis <= c < n)
        return w_vis - lost

    def restore(self, sid: int, *, charge: bool = True) -> bool:
        """Bring a spilled stream back resident (bit-exact: its pages
        are written back verbatim).  False when the pool is full.
        ``charge=False`` skips the transfer-engine accounting — used
        when the caller already charged the movement (a cross-lane
        migration models ONE src->dst transfer, not a host round
        trip)."""
        if not self.can_admit():
            return False
        sp = self._spill.pop(sid)
        table = self.ledger.take(sid, chunks=self.ledger.chunks[sid])
        self._dev_tables.pop(sid, None)
        self._write(table, jnp.asarray(sp["k"]), jnp.asarray(sp["v"]))
        if charge:
            self._charge_transfer(sp["k"].nbytes + sp["v"].nbytes, "in")
        return True

    def export_spill(self, sid: int, *,
                     to_host: bool = True) -> Tuple[Dict[str, Any], int]:
        """Detach one stream's KV as pages + chunk count (the migration
        export half): a resident stream's pages are materialized to host
        and freed, a spilled stream hands over its existing spill buffer
        verbatim.  ``to_host=False`` keeps a RESIDENT stream's pages as
        device arrays (no host round trip) so the caller can
        ``jax.device_put`` them straight onto the destination lane's
        device — the real cross-device migration path.  No transfer is
        charged — the caller owns the movement (``import_spill`` /
        ``import_pages`` on the destination pool is where the cross-lane
        transfer is accounted)."""
        n_chunks = self.ledger.chunks.get(sid, 0)
        if self.ledger.resident(sid):
            holes = np.flatnonzero(
                np.asarray(self.ledger.tables[sid]) < 0)
            rows = jnp.asarray(self.table_rows(sid), jnp.int32)
            if to_host:
                pages = {"k": np.asarray(self.k[:, rows]),
                         "v": np.asarray(self.v[:, rows])}
                if holes.size:
                    # np.asarray of a device buffer is a read-only view
                    pages = {n: a.copy() for n, a in pages.items()}
                    pages["k"][:, holes] = 0
                    pages["v"][:, holes] = 0
            else:
                # hole rows read the sink page: garbage, but the
                # dropped-chunk masks travel with the stream and keep
                # those slices invisible on the destination lane
                pages = {"k": self.k[:, rows], "v": self.v[:, rows]}
            self.ledger.drop(sid, spill=False)
        else:
            pages = self._spill.pop(sid)
            self.ledger.spilled.discard(sid)
            self.ledger.chunks.pop(sid, None)
        self._dev_tables.pop(sid, None)
        return pages, n_chunks

    def import_spill(self, sid: int, pages: Dict[str, Any],
                     n_chunks: int) -> None:
        """Adopt an exported stream host-side (spilled, re-admittable):
        the inverse of ``export_spill`` on the destination pool.  The
        stream becomes resident through the normal ``restore`` path, so
        the round trip is bit-exact."""
        assert not self.ledger.resident(sid) and sid not in self._spill, \
            f"stream {sid} already present in destination pool"
        self._spill[sid] = pages
        self.ledger.spilled.add(sid)
        self.ledger.chunks[sid] = n_chunks

    def import_pages(self, sid: int, pages: Dict[str, Any],
                     n_chunks: int) -> None:
        """Adopt an exported DEVICE page set directly into a fresh page
        table (the real cross-device migration landing: the caller
        already moved the pages to this pool's device with
        ``jax.device_put``).  Unlike ``import_spill`` the stream becomes
        page-resident immediately — no host-side parking."""
        assert not self.ledger.resident(sid) and sid not in self._spill, \
            f"stream {sid} already present in destination pool"
        assert self.can_admit(), \
            "direct import requires space (caller checks can_admit)"
        table = self.ledger.take(sid, chunks=n_chunks)
        self._dev_tables.pop(sid, None)
        self._write(table, pages["k"], pages["v"])

    def release(self, sid: int) -> None:
        """Retire a stream entirely (resident or spilled).  Idempotent."""
        self.ledger.drop(sid, spill=False)
        self._spill.pop(sid, None)
        self._dev_tables.pop(sid, None)

    def append(self, sids: Sequence[int], new_kv: Dict[str, jax.Array],
               quant: str) -> None:
        """Ring-write one finished chunk of KV per stream into its page
        and advance its chunk count (``new_kv`` rows align with
        ``sids``)."""
        if quant == "fp8":
            new_kv = {k: v.astype(jnp.float8_e4m3fn)
                      for k, v in new_kv.items()}
        for sid in sids:
            # an append into a hole heals the table (free page or a
            # stolen sibling): the cached device table goes stale
            if np.any(np.asarray(self.ledger.tables[sid]) < 0):
                self._dev_tables.pop(sid, None)
        pages = np.asarray([self.ledger.append_page(sid) for sid in sids])
        self._write(pages, new_kv["k"], new_kv["v"])
        for sid in sids:
            self.ledger.chunks[sid] += 1
            self.ledger.prune_dropped(sid)


@dataclasses.dataclass
class SPLink:
    """One stream's active elastic-SP2 borrow (SS4.3): the donor lane id
    and the donor lane's KV pool.  Two serving modes:

    * ``"solo"`` — same-device lanes: the donor page set carries the
      stream's UPPER half KV heads (Ulysses head partition, App. C.4)
      and the home lane runs the fused head-split step
      ``ardit.denoise_step_paged_sp`` reading BOTH pools in one jitted
      call, dispatched solo with the donor's step slot reserved.
    * ``"batch"`` — device-backed lanes (one jitted call cannot read two
      pools committed to different devices): the donor page set carries
      FULL heads and the stream is served ON the donor lane as an
      ordinary extra row of the donor's own micro-batch (one fused
      jitted call co-serving donor streams + the borrowed stream — no
      solo dispatch slot consumed), bit-identical to the SP1 step.

    Either way the home pool stays the full-head system of record
    (batch mode ships each completed chunk's KV home), so releasing a
    link frees the donor pages and nothing moves back."""
    donor: int
    pool: KVPool
    mode: str = "solo"


@dataclasses.dataclass
class SPGuest:
    """Donor-side view of one batch-axis SP borrow: the borrowed stream
    runs HERE as a guest batch row over full-head donor pages, while
    ``pool`` (the HOME lane's pool) stays the system of record — each
    completed guest chunk's full-head KV is shipped back into it."""
    home: int
    pool: KVPool


@dataclasses.dataclass
class InflightChunk:
    """One stream's chunk mid-generation (step-granular state)."""
    x: jax.Array                      # [1, T_c, LATENT_CH] latents
    fidelity: FidelityConfig
    step: int = 0                     # denoise steps completed
    started: float = 0.0              # session clock at chunk start
    active_s: float = 0.0             # wall spent in steps (not held out)

    @property
    def phase(self) -> str:
        """'denoise' while steps remain, then one 'clean' KV pass."""
        return "denoise" if self.step < self.fidelity.steps else "clean"


class BatchedChunkExecutor(ChunkExecutor):
    """Multi-stream executor over a shared KV pool.

    ``run_step`` advances one same-fidelity sub-batch by a single
    denoise step (or the clean-context pass that finishes a chunk), so
    the scheduler can recompose the batch between any two steps.

    ``context_backend`` selects how a sub-batch sees its cached KV:

    * ``"paged"`` (default) — page-table-native: the jitted step
      receives the pool itself plus per-stream block tables and
      page-coordinate visibility masks (``ardit.denoise_step_paged`` ->
      ``attention.paged_mha`` -> ``kernels/paged_attention``).  No
      [L, b, COND + W*tc, ...] context is ever materialized.
    * ``"gather"`` — the executable reference: contiguous context
      gathered through the tables once per chunk boundary, exactly the
      PR 2 data path.  The two backends agree numerically on every
      parity scenario (``tests/test_paged_backend.py``).
    """

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 params: Optional[Any] = None, seed: int = 0,
                 max_streams: int = 16,
                 context_backend: str = "paged",
                 engine: Optional[AsyncTransferEngine] = None,
                 device: Optional[Any] = None,
                 page_evict: bool = False):
        super().__init__(cfg=cfg, params=params, seed=seed)
        assert context_backend in ("gather", "paged"), context_backend
        self.context_backend = context_backend
        # partial-window residency: under pool pressure, evict single
        # ring pages from high-credit residents (effective window trades
        # down smoothly) before whole-stream spill.  Opt-in: page
        # eviction DISCARDS the page's KV, so numerical parity with an
        # unconstrained run no longer holds once it fires.
        self.page_evict = page_evict
        # a device-backed lane commits its params replica and pool
        # buffers to its own device, so every jitted step runs there and
        # cross-lane state movement is a real device-to-device copy
        self.device = device
        if device is not None:
            self.params = jax.device_put(self.params, device)
        self.pool = KVPool(self.cfg, self.params, max_streams,
                           engine=engine, device=device)
        self.inflight: Dict[int, InflightChunk] = {}
        self.chunks: Dict[int, List[jax.Array]] = {}
        self.fidelity_log: Dict[int, List[str]] = {}
        # noise-sequence counter per stream: tracks generated chunks
        # but RESETS on a prompt switch (generation restarts under the
        # new condition, so the post-switch chunk equals a fresh
        # stream's first chunk bit-exactly), while ``chunks`` keeps the
        # full playout history
        self.chunk_seq: Dict[int, int] = {}
        # active elastic-SP2 borrows: sid -> (donor lane, donor pool).
        # Set/cleared by the LanePool apply layer; run_step takes the
        # head-split path for a solo stream with a link.
        self.sp_links: Dict[int, SPLink] = {}
        # sids whose pages in THIS pool are another lane's live SP
        # half-head mirror (the stream is inflight on its HOME lane, so
        # the inflight filter alone would not protect it here)
        self.sp_mirrors: set = set()
        # batch-axis SP borrows served ON this lane: sid -> SPGuest
        # (guest rows join this lane's micro-batches; completed chunks
        # ship full-head KV back to the guest's home pool)
        self.sp_guests: Dict[int, SPGuest] = {}
        self.step_ema: Dict[str, float] = {}      # per-step wall seconds
        self.evictions = 0
        self.restores = 0
        self.deferrals = 0      # residency requests that had to wait
        self.page_evictions = 0   # single ring pages freed (ladder rung 1)
        self.dispatch_count = 0   # jitted step launches issued
        # content-adaptive step cache (fifth fidelity knob): lazily
        # built on the first cache-on chunk so cache=off executors pay
        # ZERO memory or dispatch overhead (the off path is untouched)
        self.max_streams = max_streams
        self.stepcache: Optional[StepCacheManager] = None
        self.cache_skipped_launches = 0   # whole launches never issued
        # per-stream effective-window history: one entry per completed
        # chunk = chunks of context its generation actually attended to
        # (fidelity window clipped by fill, minus page-evicted chunks)
        self.effective_window_log: Dict[int, List[int]] = {}
        # peak bytes of per-sub-batch context state staged for the
        # jitted step: gathered [L,b,ctx,...] copies for "gather",
        # tables + masks for "paged" (the acceptance metric)
        self.peak_ctx_bytes = 0
        # modeled async-stream transfer wait not yet charged to a
        # stream's measured chunk latency (spill/restore protocol cost)
        self._pending_wait: Dict[int, float] = {}
        self.transfer_wait_s = 0.0
        # per-sub-batch context + masks are constant across the steps of
        # a chunk (they change only when a stream's chunk count or page
        # table does), so they are cached per (group, fill, fidelity)
        # chunk boundary
        self._boundary_cache: Dict[tuple, Dict[str, Any]] = {}
        self._staging_cache: Dict[tuple, tuple] = {}

    # ---- stream lifecycle --------------------------------------------------
    def admit(self, sid: int, seed: int = 0,
              streams: Optional[Dict[int, Stream]] = None,
              protect: Sequence[int] = ()) -> bool:
        """Admit a stream.  On a full pool, evict the highest-credit
        evictable resident first (``streams`` supplies the credit view);
        without a credit view or an evictable victim the stream is
        parked host-side (defer) and False is returned — it joins later
        via ``ensure_resident``.  Never raises on exhaustion."""
        key = jax.random.PRNGKey(1000 + seed)
        cond = jax.random.normal(
            key, (1, A.COND_TOKENS, self.cfg.d_model)) * 0.02
        self.chunks[sid] = []
        self.fidelity_log[sid] = []
        self.effective_window_log[sid] = []
        self.chunk_seq[sid] = 0
        # boundary keys are (sids, fills, fid) and would collide with a
        # previous stream of the same id at the same fill — drop them
        self._boundary_cache.clear()
        mark = len(self.pool.engine.log)
        while not self.pool.can_admit():
            if not self._evict_one(streams, protect=set(protect) | {sid}):
                break
        ok = self.pool.admit(sid, cond)      # parks host-side when full
        if not ok:
            self.deferrals += 1
        self._charge_transfer_wait(sid, mark)
        return ok

    def _charge_transfer_wait(self, sid: int, log_mark: int) -> None:
        """Charge the dispatcher wait of any spill/restore transfers
        issued since ``log_mark`` to ``sid``'s next completed chunk, so
        residency churn shows up in the measured latency EMAs (the
        async-stream protocol only blocks until the first layer is
        resident; the rest overlaps with compute)."""
        new = self.pool.engine.log[log_mark:]
        if new:
            w = sum(t.residual_wait for t in new)
            self._pending_wait[sid] = self._pending_wait.get(sid, 0.0) + w
            self.transfer_wait_s += w

    def _evict_one(self, streams: Optional[Dict[int, Stream]],
                   protect: set) -> bool:
        """Free one stream's pages: credit-aware victim selection over
        the evictable residents.  In-flight streams are protected (their
        chunk is mid-denoise and rejoins the batch at the next step);
        so are live SP half-head mirrors (``sp_mirrors``) — the owning
        stream is inflight on its HOME lane, invisible to this lane's
        inflight set, and evicting its mirror would break the linked
        SP2 step mid-borrow.  A stream with a live SP link (home side)
        or borrowed onto this lane as a batch-axis guest is protected
        for the same reason: its pages on BOTH lanes must survive the
        borrow."""
        if streams is None:
            return False
        victims = [s for s in self.pool.resident_sids()
                   if s not in self.inflight and s not in self.sp_mirrors
                   and s not in self.sp_links and s not in self.sp_guests]
        if self.page_evict:
            # degradation ladder rung 1: free ONE ring page from the
            # highest-credit resident that still has one to give —
            # its effective window shrinks by a chunk, nothing spills
            victim = queues.pick_page_eviction(
                victims, streams, protect=protect,
                has_evictable=self.pool.has_evictable_page)
            if victim is not None:
                self.pool.evict_page(victim)
                self.page_evictions += 1
                self._boundary_cache.clear()
                return True
        # rung 2: whole-stream spill (host round trip, bit-exact)
        victim = queues.pick_eviction(victims, streams, protect=protect)
        if victim is None:
            return False
        self.pool.evict(victim)
        if self.stepcache is not None:
            # cache state is per-chunk transient: a spilled stream
            # rejoins at a chunk boundary, where it is stale anyway
            self.stepcache.drop(victim)
        self.evictions += 1
        self._boundary_cache.clear()
        return True

    def ensure_resident(self, sid: int,
                        streams: Optional[Dict[int, Stream]] = None,
                        protect: Sequence[int] = ()) -> bool:
        """Re-admit a spilled stream through the join/leave machinery
        (spilled streams rejoin at chunk boundaries, bit-exactly).
        False means the stream must wait this tick (defer)."""
        if self.pool.resident(sid):
            return True
        assert self.pool.spilled(sid), f"stream {sid} was never admitted"
        mark = len(self.pool.engine.log)
        while not self.pool.can_admit():
            if not self._evict_one(streams, protect=set(protect) | {sid}):
                self.deferrals += 1
                return False
        ok = self.pool.restore(sid)
        assert ok
        self.restores += 1
        self._charge_transfer_wait(sid, mark)
        # the restored stream owns DIFFERENT physical pages now: any
        # cached boundary still naming its old block table is stale
        # (the gathered backend tolerated this — restored data is
        # bit-identical — but the paged backend reads through tables)
        self._boundary_cache.clear()
        return True

    def abort_chunk(self, sid: int) -> None:
        """Drop an in-flight chunk at a step boundary (prompt switch):
        the partial denoise work is discarded.  Pool state needs no
        rollback — KV is only appended at the clean pass — and any
        pending transfer wait stays charged to the stream's next
        completed chunk (the restore really happened)."""
        self.inflight.pop(sid, None)
        if self.stepcache is not None:
            self.stepcache.reset_chunk(sid)

    def retire(self, sid: int, drop_history: bool = False) -> None:
        """Retire a stream: free its pages and per-stream counters.
        ``drop_history=True`` also drops the generated-chunk and
        fidelity history — used for the warm-up calibration stream
        (sid -1), whose residue would otherwise leak into lane 0's
        per-stream dicts forever."""
        assert sid not in self.sp_links, \
            f"stream {sid} retired with a live SP link (release first)"
        self.pool.release(sid)
        self.inflight.pop(sid, None)
        if self.stepcache is not None:
            self.stepcache.drop(sid)
        self._pending_wait.pop(sid, None)
        self.chunk_seq.pop(sid, None)
        if drop_history:
            self.chunks.pop(sid, None)
            self.fidelity_log.pop(sid, None)
            self.effective_window_log.pop(sid, None)
        self._boundary_cache.clear()

    def reset_condition(self, sid: int, seed: int) -> bool:
        """Prompt switch (SS3.3): re-encode a FRESH conditioning and
        rewrite the stream's sink page through the normal
        ``KVPool.admit`` path (release + re-admit), discarding the old
        prompt's ring KV (its chunks conditioned on the old prompt) and
        resetting the noise sequence — the post-switch chunk is
        bit-identical to a fresh stream's first chunk under the same
        conditioning seed.  Generated chunks/logs keep the playout
        history.  Returns False when the pool is full and the stream
        parked host-side (it rejoins via ``ensure_resident``)."""
        self.inflight.pop(sid, None)
        if self.stepcache is not None:
            self.stepcache.reset_chunk(sid)
        key = jax.random.PRNGKey(1000 + seed)
        cond = jax.random.normal(
            key, (1, A.COND_TOKENS, self.cfg.d_model)) * 0.02
        mark = len(self.pool.engine.log)
        self.pool.release(sid)
        ok = self.pool.admit(sid, cond)
        if not ok:
            self.deferrals += 1
        self._charge_transfer_wait(sid, mark)
        self.chunk_seq[sid] = 0
        self._boundary_cache.clear()
        return ok

    def export_stream(self, sid: int, *,
                      to_host: bool = True) -> Dict[str, Any]:
        """Detach a stream for cross-lane migration (KV pages, counters,
        generated chunks).  Only legal at a chunk boundary with no live
        SP link — exactly the streams ``rehoming.plan_rehoming`` deems
        movable.  ``to_host=False`` hands over device arrays (the real
        cross-device path; see ``KVPool.export_spill``).  No transfer is
        charged here; ``import_stream`` on the destination accounts the
        src->dst move."""
        assert sid not in self.inflight, f"stream {sid} is mid-chunk"
        assert sid not in self.sp_links, f"stream {sid} has a live SP link"
        if self.stepcache is not None:
            # step-cache state deliberately does NOT travel: it is
            # per-chunk transient and a migration lands at a chunk
            # boundary; motion recomputes from the chunk history below
            self.stepcache.drop(sid)
        dropped = sorted(self.pool.ledger.dropped.get(sid, ()))
        pages, n_chunks = self.pool.export_spill(sid, to_host=to_host)
        self._boundary_cache.clear()
        return {"pages": pages, "chunk_count": n_chunks,
                "chunks": self.chunks.pop(sid),
                "fidelity_log": self.fidelity_log.pop(sid),
                "chunk_seq": self.chunk_seq.pop(sid, 0),
                "pending_wait": self._pending_wait.pop(sid, 0.0),
                "dropped": dropped,
                "effective_window_log":
                    self.effective_window_log.pop(sid, [])}

    def import_stream(self, sid: int, state: Dict[str, Any], *,
                      cross_node: bool = False,
                      direct: bool = False) -> None:
        """Adopt an exported stream (the re-homing apply half): ONE
        src->dst transfer is charged on the shared engine (cross-node
        bandwidth when the lanes' nodes differ), and the dispatcher
        wait rides on the stream's next completed chunk.
        ``direct=True`` means ``state["pages"]`` are device arrays the
        caller already moved onto this lane's device — they are written
        straight into a fresh page table (immediately resident);
        otherwise the KV arrives host-side and the stream becomes
        page-resident through the normal restore path, bit-exactly."""
        self.chunks[sid] = state["chunks"]
        self.fidelity_log[sid] = state["fidelity_log"]
        self.chunk_seq[sid] = state["chunk_seq"]
        # degradation history travels too: the per-stream mean
        # effective window in SessionResult must span lane moves
        self.effective_window_log[sid] = \
            list(state.get("effective_window_log", []))
        if state.get("dropped"):
            # degradation history travels with the stream: the lost
            # chunks' slices (zeros / garbage) stay masked here too
            self.pool.ledger.dropped[sid] = set(state["dropped"])
        if direct:
            self.pool.import_pages(sid, state["pages"],
                                   state["chunk_count"])
        else:
            self.pool.import_spill(sid, state["pages"],
                                   state["chunk_count"])
        n_bytes = state["pages"]["k"].nbytes + state["pages"]["v"].nbytes
        self.pool.transfer_bytes_in += n_bytes
        t = self.pool.engine.transfer(time.perf_counter(), n_bytes,
                                      cross_node=cross_node)
        w = state["pending_wait"] + t.residual_wait
        self._pending_wait[sid] = self._pending_wait.get(sid, 0.0) + w
        self.transfer_wait_s += t.residual_wait
        self._boundary_cache.clear()

    def begin_chunk(self, sid: int, fidelity: FidelityConfig,
                    now: float) -> None:
        """Start a chunk at a step boundary (noise seeding matches the
        sequential path so the two executors are comparable)."""
        key = jax.random.PRNGKey(self.chunk_seq[sid] * 7919 + sid)
        tc = A.chunk_tokens(self.cfg)
        noise = jax.random.normal(key, (1, tc, A.LATENT_CH))
        self.inflight[sid] = InflightChunk(x=noise, fidelity=fidelity,
                                           started=now)
        if fidelity.cache != "off":
            self._stepcache().begin_chunk(sid, self.chunks.get(sid))

    def _stepcache(self) -> StepCacheManager:
        """Lazy step-cache manager: one residual-pool slot per possible
        concurrent stream, on this lane's device."""
        if self.stepcache is None:
            self.stepcache = StepCacheManager(
                self.max_streams + 1, A.chunk_tokens(self.cfg),
                A.LATENT_CH, self.cfg.n_layers, device=self.device)
        return self.stepcache

    def steps_left(self, sid: int) -> int:
        """Remaining forwards for the in-flight chunk (incl. clean pass)."""
        f = self.inflight[sid]
        return f.fidelity.steps + 1 - f.step

    # ---- the batched step --------------------------------------------------
    def _boundary(self, sids: Sequence[int], chunk_idx: np.ndarray,
                  fids: Sequence[FidelityConfig],
                  sp: Optional[SPLink] = None) -> Dict[str, Any]:
        """Per-chunk-boundary state of a sub-batch (constant across the
        chunk's steps): positions, denoise/clean visibility, and the
        backend's context handle — a gathered [L, b, extent, ...] copy
        for ``gather``, or the block tables + page-coordinate masks the
        paged step reads the pool through (both sliced to the group's
        resident extent, so compute scales with fill either way).
        ``fids`` is per-row: a fused heterogeneous-fidelity group hands
        each row the window/sparsity mask its own fidelity dictates —
        bit-identical per row to a split same-fidelity dispatch.  An
        active SP2 link adds the donor pool's block table — the
        head-split step reads its upper half heads through it."""
        key = (tuple(sids), tuple(chunk_idx.tolist()),
               tuple(f.key for f in fids),
               sp.donor if sp is not None else None)
        bnd = self._boundary_cache.get(key)
        if bnd is not None:
            return bnd
        tc = A.chunk_tokens(self.cfg)
        w_max = self.cfg.ardit_window_chunks
        n_ring = int(min(chunk_idx.max(initial=0), w_max))
        extent = A.COND_TOKENS + n_ring * tc
        # sparsity applies to denoise steps only; the clean-context pass
        # sees the full fidelity window.
        windows = np.asarray([f.window for f in fids], np.int64)
        dn = A.batched_context_mask_multi(
            self.cfg, chunk_idx, windows,
            np.asarray([f.sparsity for f in fids]))[:, :extent]
        cl = A.batched_context_mask_multi(
            self.cfg, chunk_idx, windows,
            np.zeros(len(fids)))[:, :extent]
        self._mask_dropped(sids, chunk_idx, dn, cl)
        bnd = {
            "q_offset": jnp.asarray(A.COND_TOKENS + chunk_idx * tc,
                                    jnp.int32),
        }
        if self.context_backend == "paged":
            # no gather: hand the step the tables and the masks mapped
            # into page coordinates.  dn all-true (homogeneous fill,
            # full window, no sparsity) drops BOTH masks — each page's
            # static valid prefix is visible and the step skips
            # per-score masking, like the gathered path's slices (cl is
            # a superset of dn, so dn all-true implies cl all-true);
            # an unsparsified fidelity's clean mask IS the denoise mask
            # — cl=None then means "reuse dn"
            tables = self.pool.tables_for(sids)[:, :1 + n_ring]
            bnd["tables"] = tables
            if sp is not None:
                bnd["tables_d"] = sp.pool.tables_for(sids)[:, :1 + n_ring]
            if dn.all():
                bnd["dn"] = None
                bnd["cl"] = None
            else:
                bnd["dn"] = jnp.asarray(kvcache.mask_to_pages(
                    dn, n_ring, A.COND_TOKENS, tc,
                    self.pool.page_tokens))
                bnd["cl"] = None if np.array_equal(dn, cl) else \
                    jnp.asarray(kvcache.mask_to_pages(
                        cl, n_ring, A.COND_TOKENS, tc,
                        self.pool.page_tokens))
            staged = (tables.nbytes
                      + (0 if bnd["dn"] is None else bnd["dn"].nbytes)
                      + (0 if bnd["cl"] is None else bnd["cl"].nbytes))
        else:
            # all-true masks (homogeneous fill, no sparsity, full
            # window) are dropped so the jitted step skips per-score
            # masking, like the sequential path's slices
            ctx_k, ctx_v = self.pool.gather(sids, n_ring)
            bnd["ctx_k"] = ctx_k
            bnd["ctx_v"] = ctx_v
            bnd["dn"] = None if dn.all() else jnp.asarray(dn)
            bnd["cl"] = None if cl.all() else jnp.asarray(cl)
            staged = ctx_k.nbytes + ctx_v.nbytes
        self.peak_ctx_bytes = max(self.peak_ctx_bytes, staged)
        if len(self._boundary_cache) >= 8:
            self._boundary_cache.pop(next(iter(self._boundary_cache)))
        self._boundary_cache[key] = bnd
        return bnd

    def _mask_dropped(self, sids: Sequence[int], chunk_idx: np.ndarray,
                      dn: np.ndarray, cl: np.ndarray) -> None:
        """Zero the token slices of page-evicted chunks in BOTH
        visibility masks (partial-window residency: the KV is gone, so
        no phase may attend to it).  Runs before the all-true fast-path
        check, forcing a degraded row onto the explicit-mask path —
        which is what keeps the sink-page stand-in rows of
        ``table_rows`` unread."""
        tc = A.chunk_tokens(self.cfg)
        w_max = self.cfg.ardit_window_chunks
        for i, sid in enumerate(sids):
            dropped = self.pool.ledger.dropped.get(sid)
            if not dropped:
                continue
            n = int(chunk_idx[i])
            for c in dropped:
                if n - w_max <= c < n:
                    lo = A.COND_TOKENS + (c % w_max) * tc
                    dn[i, lo:lo + tc] = False
                    cl[i, lo:lo + tc] = False

    def _staging(self, fids: Sequence[FidelityConfig],
                 steps: Tuple[int, ...], denoising: Tuple[bool, ...]):
        """Cached per-step staging arrays (t, dt, is_denoise): these
        repeat identically for every chunk of a given fidelity mix, so
        the tiny host->device uploads happen once, not every step.
        Per-row fidelity: each row walks its OWN sigma grid — a fused
        group's rows advance exactly as they would in split dispatch
        (rows whose chunk already completed simply leave the batch at
        the step boundary, so no padding rows are ever launched)."""
        key = (tuple(f.key for f in fids), steps, denoising)
        st = self._staging_cache.get(key)
        if st is None:
            grids = [A.sigma_schedule(f.steps) for f in fids]
            t = jnp.asarray([float(g[s]) if d else 0.0
                             for g, s, d in zip(grids, steps, denoising)],
                            jnp.float32)
            dt = jnp.asarray([float(g[s] - g[s + 1]) if d else 0.0
                              for g, s, d in zip(grids, steps, denoising)],
                             jnp.float32)
            st = (t, dt, jnp.asarray(denoising))
            if len(self._staging_cache) >= 64:
                self._staging_cache.pop(next(iter(self._staging_cache)))
            self._staging_cache[key] = st
        return st

    def run_step(self, sids: Sequence[int],
                 sp_serve: bool = False) -> Tuple[List[int], float]:
        """Advance one sub-batch by one step — same-fidelity (split
        dispatch) or mixed-fidelity sharing one KV quantization dtype
        (fused dispatch): window, sparsity, sigma grid, and phase are
        all per-row data, so each row computes exactly what its own
        fidelity's split launch would.

        ``sp_serve=True`` marks a dispatch that RESERVED the linked
        stream's donor step slot (the scheduler's solo SP2 dispatch):
        only then does a solo linked stream take the head-split path.
        An unreserved dispatch — even a singleton fidelity group — runs
        the SP1 step, so donor compute is never consumed twice (or zero
        times) in one round.

        Streams in their denoise phase take an Euler step; streams in
        their clean phase produce context KV, append it to the pool, and
        complete their chunk.  Both phases share ONE jitted batched
        call (``ardit.denoise_step``; phase differences are data).

        The host does NOT sync on intermediate steps — dispatch is
        asynchronous, so staging pipelines with compute; the executor
        syncs once per completed chunk, which also yields the measured
        whole-chunk wall latency fed into ``latency_ema``/``step_ema``
        (online re-profiling).  Returns (completed sids, wall seconds
        of this call).
        """
        flights = [self.inflight[sid] for sid in sids]
        fids = [f.fidelity for f in flights]
        quant = fids[0].quant
        # fused heterogeneous-fidelity dispatch: steps/window/sparsity
        # are per-row data (masks, sigma grids), but the KV quantization
        # dtype is a property of the append path shared by the whole
        # launch — groups must not mix dtypes
        assert all(f.quant == quant for f in fids), \
            "sub-batch must share one KV quantization dtype"
        assert all(self.pool.resident(sid) for sid in sids), \
            "sub-batch contains a non-resident (spilled) stream"
        chunk_idx = np.asarray([self.pool.chunks[sid] for sid in sids],
                               np.int64)
        # a batch-mode link is served on the DONOR lane (the stream is
        # a guest row there); its home lane must never also step it, or
        # the two page sets would diverge
        assert not any(s in self.sp_links
                       and self.sp_links[s].mode == "batch"
                       for s in sids), \
            "batch-axis SP: linked stream must be served on its donor lane"
        # elastic SP2 takes the head-split step for a SOLO linked stream
        # whose dispatch reserved the donor slot; a linked stream folded
        # into a normal batch falls back to the SP1 step — the home pool
        # holds full heads, so SP is an acceleration path, never a
        # correctness dependency
        sp = (self.sp_links.get(sids[0])
              if sp_serve and len(sids) == 1
              and self.context_backend == "paged"
              else None)
        if sp is not None and sp.mode != "solo":
            sp = None

        denoising = tuple(f.phase == "denoise" for f in flights)
        # content-adaptive step cache (fifth fidelity knob): decide
        # per-row reuse BEFORE staging.  A group whose rows are all
        # cache=off takes the exact legacy path below — zero tracker
        # calls, bit-identical launches (the safety rail).
        sc = self.stepcache
        cache_hits: Dict[int, float] = {}    # row -> dt of the reuse
        if any(fid.cache != "off" for fid in fids):
            sc = self._stepcache()
            for i, (f, fid) in enumerate(zip(flights, fids)):
                if fid.cache != "off" and denoising[i] \
                        and sc.should_hit(sids[i], fid.cache):
                    # uniform sigma grid (linspace 1 -> 0): dt = 1/S,
                    # host-side — no device read on the decision path
                    cache_hits[i] = 1.0 / fid.steps

        t0 = time.perf_counter()
        if cache_hits and len(cache_hits) == len(sids):
            # every row reuses its cached velocity: skip the jitted
            # launch entirely — the attention+MLP stack is replaced by
            # per-row AXPYs (this is the step cache's throughput win;
            # ``dispatch_count`` does not advance)
            self.cache_skipped_launches += 1
            for i, (sid, f) in enumerate(zip(sids, flights)):
                f.x = sc.apply_hit(sid, f.x, cache_hits[i])
                f.step += 1
            dt = time.perf_counter() - t0
            for f in flights:
                f.active_s += dt
            return [], dt

        bnd = self._boundary(sids, chunk_idx, fids, sp=sp)
        x = (flights[0].x if len(flights) == 1
             else jnp.concatenate([f.x for f in flights], axis=0))
        t, dt_sig, is_dn = self._staging(
            fids, tuple(f.step for f in flights), denoising)
        self.dispatch_count += 1
        if sp is not None:
            x_new, new_kv = A.denoise_step_paged_sp(
                self.cfg, self.params, x, t, dt_sig, self.pool.k,
                self.pool.v, sp.pool.k, sp.pool.v, bnd["tables"],
                bnd["tables_d"], bnd["dn"], bnd["cl"],
                bnd["q_offset"], is_dn)
        elif self.context_backend == "paged":
            # context stays IN the pool: the step reads the current
            # device buffers through the cached block tables (appends
            # only ever touch pages outside every in-flight window, so
            # the live read equals the boundary snapshot)
            x_new, new_kv = A.denoise_step_paged(
                self.cfg, self.params, x, t, dt_sig, self.pool.k,
                self.pool.v, bnd["tables"], bnd["dn"], bnd["cl"],
                bnd["q_offset"], is_dn)
        else:
            x_new, new_kv = A.denoise_step(
                self.cfg, self.params, x, t, dt_sig, bnd["ctx_k"],
                bnd["ctx_v"], bnd["q_offset"], bnd["dn"], bnd["cl"],
                is_dn)

        completed: List[int] = []
        clean_rows: List[int] = []
        for i, (sid, f) in enumerate(zip(sids, flights)):
            if denoising[i]:
                if i in cache_hits:
                    # masked no-op row of a mixed launch: the row rode
                    # along for shape stability; its output is the
                    # cached AXPY — identical to the skipped-launch
                    # path, so a hit never depends on group composition
                    f.x = sc.apply_hit(sid, f.x, cache_hits[i])
                else:
                    if fids[i].cache != "off":
                        sc.record_step(sid, f.x, x_new[i:i + 1],
                                       1.0 / fids[i].steps,
                                       new_kv["k"][:, i])
                    f.x = x_new[i:i + 1]
                f.step += 1
            else:
                clean_rows.append(i)
                completed.append(sid)
        if clean_rows:
            # effective window BEFORE the append advances chunk counts:
            # the context this chunk's generation actually attended to
            eff_w = {sids[i]: self.pool.effective_window(
                sids[i], fids[i].window) for i in clean_rows}
            rows = np.asarray(clean_rows)
            self.pool.append([sids[i] for i in clean_rows],
                             {"k": new_kv["k"][:, rows],
                              "v": new_kv["v"][:, rows]}, quant)
            for i in clean_rows:
                row = {"k": new_kv["k"][:, i:i + 1],
                       "v": new_kv["v"][:, i:i + 1]}
                link = self.sp_links.get(sids[i])
                if link is not None:
                    # the donor's half-head mirror must track the home
                    # pool: ring-write this chunk's upper half into the
                    # donor page set so the next SP2 boundary sees
                    # consistent halves (solo mode only — the assertion
                    # above keeps batch-linked streams off this lane)
                    self._append_sp_half(link, sids[i], row, quant)
                guest = self.sp_guests.get(sids[i])
                if guest is not None:
                    # batch-axis SP shipback: the guest's home pool is
                    # the system of record — append the full-head chunk
                    # there too (a real cross-device put when the lanes
                    # are device-backed), so release never moves state
                    guest.pool.append([sids[i]], row, quant)
            now_wall = None
            for i in clean_rows:
                sid = sids[i]
                fid = fids[i]
                f = self.inflight.pop(sid)
                self.chunks[sid].append(f.x)
                self.fidelity_log[sid].append(fid.key)
                self.effective_window_log.setdefault(sid, []).append(
                    eff_w[sid])
                self.chunk_seq[sid] = self.chunk_seq.get(sid, 0) + 1
                if now_wall is None:        # one sync per completion step
                    f.x.block_until_ready()
                    now_wall = time.perf_counter()
                # measured chunk wall -> timing priors, attributed to
                # each completing row's OWN fidelity key: under fused
                # dispatch ``active_s`` accrued per launch the row was
                # live in, so a fused launch's latency lands on member
                # keys weighted by the steps each member actually rode
                # — BMPR budgets and routing see the same per-fidelity
                # costs as under split dispatch.  Only time spent IN
                # the batch counts (a stream held out mid-chunk accrues
                # no active time).  Spill/restore dispatcher waits
                # charged by the transfer engine ride on the chunk they
                # delayed.
                lat = (f.active_s + (now_wall - t0)
                       + self._pending_wait.pop(sid, 0.0))
                self.latency_ema[fid.key] = (
                    EMA_DECAY * self.latency_ema.get(fid.key, lat)
                    + (1.0 - EMA_DECAY) * lat)
                step = lat / (fid.steps + 1)
                self.step_ema[fid.key] = (
                    EMA_DECAY * self.step_ema.get(fid.key, step)
                    + (1.0 - EMA_DECAY) * step)
        dt = time.perf_counter() - t0
        for sid in sids:
            f = self.inflight.get(sid)
            if f is not None:               # still mid-chunk
                f.active_s += dt
        return completed, dt

    def _append_sp_half(self, link: SPLink, sid: int,
                        new_kv: Dict[str, jax.Array], quant: str) -> None:
        """Ring-write one chunk's UPPER half KV heads into the donor
        pool's page set for ``sid`` (kept in lockstep with the home
        pool's full-head append)."""
        h2 = self.cfg.n_kv_heads // 2
        nk, nv = new_kv["k"][..., h2:, :], new_kv["v"][..., h2:, :]
        if quant == "fp8":
            nk = nk.astype(jnp.float8_e4m3fn)
            nv = nv.astype(jnp.float8_e4m3fn)
        page = jnp.asarray([link.pool.ledger.append_page(sid)], jnp.int32)
        link.pool.k = kvcache.pool_write_pages_heads(
            link.pool.k, nk, page, h2)
        link.pool.v = kvcache.pool_write_pages_heads(
            link.pool.v, nv, page, h2)
        link.pool.ledger.chunks[sid] += 1

    def remaining_estimate(self, sid: int) -> float:
        """R_u from the measured step EMA (not the offline profile)."""
        f = self.inflight.get(sid)
        if f is None:
            return 0.0
        per_step = self.step_ema.get(
            f.fidelity.key,
            self.latency_ema.get(f.fidelity.key, 0.0)
            / (f.fidelity.steps + 1))
        return self.steps_left(sid) * per_step


def serve_session_batched(n_streams: int = 4, chunks_per_stream: int = 4,
                          max_batch: int = 4,
                          realtime_budget: Optional[float] = None,
                          fidelity_policy=None,
                          pool_streams: Optional[int] = None,
                          context_backend: str = "paged",
                          verbose: bool = True) -> List[ServedStream]:
    """Legacy batched entry point — now a thin wrapper over the unified
    ``repro.serve.session.StreamingSession`` (all streams arrive at
    t=0, exact per-stream chunk counts).

    The session is driven by ``core.control_plane.ControlPlane.tick()``
    — the SAME Algorithm 2 decision code the discrete-event simulator
    runs — with this module's ``BatchedChunkExecutor`` as the apply
    layer; playout/stall state lives in ONE per-stream record
    (``core.types.Stream``) and the returned ``ServedStream``s are
    views over it.  Fidelity budgets follow Eq. 1
    (``B = max(P_u - R_u, 0)``) through the session's host-calibrated
    unit conversion — the old hand-tuned magic budget scale is gone.

    ``pool_streams`` caps co-resident streams (oversubscription when
    < n_streams: extra streams spill to host and rejoin at chunk
    boundaries); defaults to n_streams + 1, i.e. everyone resident.
    ``context_backend``: ``"paged"`` (default) serves attention straight
    from the page pool through block tables; ``"gather"`` materializes
    the contiguous context per boundary (executable reference).
    """
    from repro.serve.session import (SessionConfig, StreamingSession,
                                     uniform_specs)
    session = StreamingSession(
        SessionConfig(executor="batched", max_batch=max_batch,
                      pool_streams=pool_streams or (n_streams + 1),
                      context_backend=context_backend,
                      realtime_budget=realtime_budget, verbose=verbose),
        fidelity_policy=fidelity_policy)
    for spec in uniform_specs(n_streams, chunks_per_stream):
        session.submit(spec)
    session.run()
    return session.served_streams()
