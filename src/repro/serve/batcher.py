"""Batched multi-stream serving executor (continuous cross-request
batching at denoise-step granularity).

The sequential ``ChunkExecutor`` generates chunks one stream at a time,
so the control plane's credit ordering cannot exploit any batch
parallelism.  This module adds the execution-side counterpart of the
paper's step-boundary preemption (SS3.1): every scheduler iteration
composes a *micro-batch* from the credit-ordered runnable set (lowest
credit first, up to ``max_batch``), splits it into same-fidelity
sub-batches, and advances each sub-batch by ONE denoise step with a
single jitted batched ``ardit.denoise_step`` call over the stacked
per-stream ring KV caches.  Streams join and leave the batch at step
boundaries; measured whole-chunk wall time feeds the latency EMAs so
BMPR budgets and service-credit estimates stay honest (re-profiling).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import queues, slack
from repro.core.bmpr import BMPR
from repro.core.fidelity import FidelityConfig, HIGHEST_QUALITY
from repro.core.types import Stream, Worker
from repro.models import ardit as A
from repro.models import kvcache
from repro.profiler.profiles import get_profile
from repro.serve.executor import EMA_DECAY, ChunkExecutor, ServedStream


def compose_batch(sids: Sequence[int],
                  fidelity_of: Callable[[int], FidelityConfig],
                  max_batch: int) -> List[List[int]]:
    """Credit-ordered micro-batch composition.

    ``sids`` is the runnable set already ordered by service credit
    ascending (``queues.next_dispatch_set``).  Takes the lowest-credit
    ``max_batch`` streams and splits them into same-fidelity sub-batches
    (``FidelityConfig.key``), preserving credit order within and across
    groups — the first group contains the most urgent stream.
    """
    groups: Dict[str, List[int]] = {}
    for sid in list(sids)[:max_batch]:
        groups.setdefault(fidelity_of(sid).key, []).append(sid)
    return list(groups.values())


class KVPool:
    """Stacked per-stream ring KV caches: one [L, Bmax, cap, Hkv, Dh]
    pair with a free-slot list.  Sub-batches gather their rows, run, and
    scatter back — the device-side analogue of the simulator's paged
    pools (residency is whole-stream here; paged defrag is an open
    ROADMAP item)."""

    def __init__(self, cfg: ModelConfig, params: Any, max_streams: int):
        self.cfg, self.params = cfg, params
        cap = A.cache_capacity(cfg)
        shape = (cfg.n_layers, max_streams, cap, cfg.n_kv_heads,
                 cfg.head_dim)
        dt = jnp.dtype(cfg.kv_dtype)
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        self.chunks = np.zeros(max_streams, np.int64)
        self._free = list(range(max_streams))
        self._tc = A.chunk_tokens(cfg)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def alloc(self, cond: jax.Array) -> int:
        """Admit one stream: write its cond (sink) KV into a free slot."""
        if not self._free:
            raise RuntimeError("KVPool exhausted: no free stream slots")
        slot = self._free.pop(0)
        sub = A.init_batched_cache(self.cfg, self.params, cond)
        self.k = self.k.at[:, slot:slot + 1].set(
            sub["k"].astype(self.k.dtype))
        self.v = self.v.at[:, slot:slot + 1].set(
            sub["v"].astype(self.v.dtype))
        self.chunks[slot] = 0
        return slot

    def release(self, slot: int) -> None:
        # stale ring contents are invisible (masks derive from chunks=0)
        self.chunks[slot] = 0
        self._free.append(slot)

    def append(self, slots: Sequence[int], new_kv: Dict[str, jax.Array],
               quant: str) -> None:
        """Ring-write one finished chunk of KV per stream straight into
        the pool and advance its chunk count (``new_kv`` rows align
        with ``slots``)."""
        if quant == "fp8":
            new_kv = {k: v.astype(jnp.float8_e4m3fn)
                      for k, v in new_kv.items()}
        idx = np.asarray(slots)
        dest = np.asarray(kvcache.chunk_slot(
            self.chunks[idx], self.cfg.ardit_window_chunks,
            A.COND_TOKENS, self._tc))
        rows = jnp.asarray(idx, jnp.int32)
        dest = jnp.asarray(dest, jnp.int32)
        self.k = kvcache.pool_write_chunk(self.k, new_kv["k"], rows, dest)
        self.v = kvcache.pool_write_chunk(self.v, new_kv["v"], rows, dest)
        self.chunks[idx] += 1


@dataclasses.dataclass
class InflightChunk:
    """One stream's chunk mid-generation (step-granular state)."""
    x: jax.Array                      # [1, T_c, LATENT_CH] latents
    fidelity: FidelityConfig
    step: int = 0                     # denoise steps completed
    started: float = 0.0              # session clock at chunk start
    active_s: float = 0.0             # wall spent in steps (not held out)

    @property
    def phase(self) -> str:
        """'denoise' while steps remain, then one 'clean' KV pass."""
        return "denoise" if self.step < self.fidelity.steps else "clean"


class BatchedChunkExecutor(ChunkExecutor):
    """Multi-stream executor over a shared KV pool.

    ``run_step`` advances one same-fidelity sub-batch by a single
    denoise step (or the clean-context pass that finishes a chunk), so
    the scheduler can recompose the batch between any two steps.
    """

    def __init__(self, cfg: Optional[ModelConfig] = None,
                 params: Optional[Any] = None, seed: int = 0,
                 max_streams: int = 16):
        super().__init__(cfg=cfg, params=params, seed=seed)
        self.pool = KVPool(self.cfg, self.params, max_streams)
        self.slot: Dict[int, int] = {}
        self.inflight: Dict[int, InflightChunk] = {}
        self.chunks: Dict[int, List[jax.Array]] = {}
        self.fidelity_log: Dict[int, List[str]] = {}
        self.step_ema: Dict[str, float] = {}      # per-step wall seconds
        # gathered context + masks are constant across the steps of a
        # chunk (they change only when a stream's chunk count does), so
        # they are cached per (group, fill, fidelity) chunk boundary
        self._boundary_cache: Dict[tuple, Dict[str, Any]] = {}
        self._staging_cache: Dict[tuple, tuple] = {}

    # ---- stream lifecycle --------------------------------------------------
    def admit(self, sid: int, seed: int = 0) -> None:
        key = jax.random.PRNGKey(1000 + seed)
        cond = jax.random.normal(
            key, (1, A.COND_TOKENS, self.cfg.d_model)) * 0.02
        self.slot[sid] = self.pool.alloc(cond)
        self.chunks[sid] = []
        self.fidelity_log[sid] = []
        # boundary keys are (sids, fills, fid) and would collide with a
        # previous stream of the same id at the same fill — drop them
        self._boundary_cache.clear()

    def retire(self, sid: int) -> None:
        self.pool.release(self.slot.pop(sid))
        self.inflight.pop(sid, None)
        self._boundary_cache.clear()

    def begin_chunk(self, sid: int, fidelity: FidelityConfig,
                    now: float) -> None:
        """Start a chunk at a step boundary (noise seeding matches the
        sequential path so the two executors are comparable)."""
        key = jax.random.PRNGKey(len(self.chunks[sid]) * 7919 + sid)
        tc = A.chunk_tokens(self.cfg)
        noise = jax.random.normal(key, (1, tc, A.LATENT_CH))
        self.inflight[sid] = InflightChunk(x=noise, fidelity=fidelity,
                                           started=now)

    def steps_left(self, sid: int) -> int:
        """Remaining forwards for the in-flight chunk (incl. clean pass)."""
        f = self.inflight[sid]
        return f.fidelity.steps + 1 - f.step

    # ---- the batched step --------------------------------------------------
    def _boundary(self, sids: Sequence[int], slots: Sequence[int],
                  chunk_idx: np.ndarray,
                  fid: FidelityConfig) -> Dict[str, Any]:
        """Per-chunk-boundary state of a sub-batch: gathered context
        (sliced to the group's resident extent, so compute scales with
        fill like the sequential path), positions, and the denoise/clean
        visibility masks.  Constant across the chunk's steps."""
        key = (tuple(sids), tuple(chunk_idx.tolist()), fid.key)
        bnd = self._boundary_cache.get(key)
        if bnd is not None:
            return bnd
        tc = A.chunk_tokens(self.cfg)
        w_max = self.cfg.ardit_window_chunks
        extent = A.COND_TOKENS + int(min(chunk_idx.max(initial=0),
                                         w_max)) * tc
        idx = np.asarray(slots)
        # sparsity applies to denoise steps only; the clean-context pass
        # sees the full fidelity window.  All-true masks (homogeneous
        # fill, no sparsity, full window) are dropped so the jitted step
        # skips per-score masking, like the sequential path's slices.
        dn = A.batched_context_mask(self.cfg, chunk_idx, fid.window,
                                    fid.sparsity)[:, :extent]
        cl = A.batched_context_mask(self.cfg, chunk_idx,
                                    fid.window)[:, :extent]
        rows = jnp.asarray(idx, jnp.int32)
        bnd = {
            "ctx_k": kvcache.gather_rows(self.pool.k, rows, extent),
            "ctx_v": kvcache.gather_rows(self.pool.v, rows, extent),
            "q_offset": jnp.asarray(A.COND_TOKENS + chunk_idx * tc,
                                    jnp.int32),
            "dn": None if dn.all() else jnp.asarray(dn),
            "cl": None if cl.all() else jnp.asarray(cl),
        }
        if len(self._boundary_cache) >= 8:
            self._boundary_cache.pop(next(iter(self._boundary_cache)))
        self._boundary_cache[key] = bnd
        return bnd

    def _staging(self, fid: FidelityConfig, steps: Tuple[int, ...],
                 denoising: Tuple[bool, ...]):
        """Cached per-step staging arrays (t, dt, is_denoise): these
        repeat identically for every chunk of a given fidelity, so the
        tiny host->device uploads happen once, not every step."""
        key = (fid.key, steps, denoising)
        st = self._staging_cache.get(key)
        if st is None:
            grid = A.sigma_schedule(fid.steps)
            t = jnp.asarray([float(grid[s]) if d else 0.0
                             for s, d in zip(steps, denoising)],
                            jnp.float32)
            dt = jnp.asarray([float(grid[s] - grid[s + 1]) if d else 0.0
                              for s, d in zip(steps, denoising)],
                             jnp.float32)
            st = (t, dt, jnp.asarray(denoising))
            if len(self._staging_cache) >= 64:
                self._staging_cache.pop(next(iter(self._staging_cache)))
            self._staging_cache[key] = st
        return st

    def run_step(self, sids: Sequence[int]) -> Tuple[List[int], float]:
        """Advance a same-fidelity sub-batch by one step.

        Streams in their denoise phase take an Euler step; streams in
        their clean phase produce context KV, append it to the pool, and
        complete their chunk.  Both phases share ONE jitted batched
        call (``ardit.denoise_step``; phase differences are data).

        The host does NOT sync on intermediate steps — dispatch is
        asynchronous, so staging pipelines with compute; the executor
        syncs once per completed chunk, which also yields the measured
        whole-chunk wall latency fed into ``latency_ema``/``step_ema``
        (online re-profiling).  Returns (completed sids, wall seconds
        of this call).
        """
        flights = [self.inflight[sid] for sid in sids]
        fid = flights[0].fidelity
        assert all(f.fidelity.key == fid.key for f in flights), \
            "sub-batch must share one fidelity configuration"
        slots = [self.slot[sid] for sid in sids]
        chunk_idx = self.pool.chunks[np.asarray(slots)]

        t0 = time.perf_counter()
        bnd = self._boundary(sids, slots, chunk_idx, fid)
        x = (flights[0].x if len(flights) == 1
             else jnp.concatenate([f.x for f in flights], axis=0))
        denoising = tuple(f.phase == "denoise" for f in flights)
        t, dt_sig, is_dn = self._staging(
            fid, tuple(f.step for f in flights), denoising)
        x_new, new_kv = A.denoise_step(
            self.cfg, self.params, x, t, dt_sig, bnd["ctx_k"],
            bnd["ctx_v"], bnd["q_offset"], bnd["dn"], bnd["cl"], is_dn)

        completed: List[int] = []
        clean_rows: List[int] = []
        for i, (sid, f) in enumerate(zip(sids, flights)):
            if denoising[i]:
                f.x = x_new[i:i + 1]
                f.step += 1
            else:
                clean_rows.append(i)
                completed.append(sid)
        if clean_rows:
            rows = np.asarray(clean_rows)
            self.pool.append([slots[i] for i in clean_rows],
                             {"k": new_kv["k"][:, rows],
                              "v": new_kv["v"][:, rows]}, fid.quant)
            now_wall = None
            for i in clean_rows:
                sid = sids[i]
                f = self.inflight.pop(sid)
                self.chunks[sid].append(f.x)
                self.fidelity_log[sid].append(fid.key)
                if now_wall is None:        # one sync per completion step
                    f.x.block_until_ready()
                    now_wall = time.perf_counter()
                # measured chunk wall -> timing priors; only time spent
                # IN the batch counts (a stream held out of the batch
                # mid-chunk accrues no active time, so preemption does
                # not inflate the per-fidelity EMAs)
                lat = f.active_s + (now_wall - t0)
                self.latency_ema[fid.key] = (
                    EMA_DECAY * self.latency_ema.get(fid.key, lat)
                    + (1.0 - EMA_DECAY) * lat)
                step = lat / (fid.steps + 1)
                self.step_ema[fid.key] = (
                    EMA_DECAY * self.step_ema.get(fid.key, step)
                    + (1.0 - EMA_DECAY) * step)
        dt = time.perf_counter() - t0
        for sid in sids:
            f = self.inflight.get(sid)
            if f is not None:               # still mid-chunk
                f.active_s += dt
        return completed, dt

    def remaining_estimate(self, sid: int) -> float:
        """R_u from the measured step EMA (not the offline profile)."""
        f = self.inflight.get(sid)
        if f is None:
            return 0.0
        per_step = self.step_ema.get(
            f.fidelity.key,
            self.latency_ema.get(f.fidelity.key, 0.0)
            / (f.fidelity.steps + 1))
        return self.steps_left(sid) * per_step


def serve_session_batched(n_streams: int = 4, chunks_per_stream: int = 4,
                          max_batch: int = 4,
                          realtime_budget: Optional[float] = None,
                          fidelity_policy=None,
                          verbose: bool = True) -> List[ServedStream]:
    """End-to-end batched session: the SAME control-plane code paths as
    the simulator (service credit, credit-sorted queue, dispatch-set)
    drive real batched chunk generation.

    Per iteration: update credits -> order queue -> take the runnable
    set (``queues.next_dispatch_set``) -> compose same-fidelity
    sub-batches -> one jitted step each.  Measured wall time feeds
    ``t_next``/``remaining`` so credits track this host, not the
    H100-calibrated offline profile.
    """
    ex = BatchedChunkExecutor(max_streams=n_streams + 1)
    policy = fidelity_policy or BMPR(get_profile())

    # calibrate the wall-clock playout rate to this host (and warm the
    # jit cache for batch-size-1 shapes)
    ex.admit(-1, seed=999)
    ex.begin_chunk(-1, HIGHEST_QUALITY, 0.0)
    while -1 in ex.inflight:
        _, _ = ex.run_step([-1])
    top_lat = (HIGHEST_QUALITY.steps + 1) * ex.step_ema[HIGHEST_QUALITY.key]
    ex.retire(-1)
    chunk_seconds = realtime_budget or (4.0 * top_lat)

    worker = Worker(0, node=0)
    streams: Dict[int, Stream] = {}
    for i in range(n_streams):
        ex.admit(i, seed=i)
        s = Stream(sid=i, arrival=0.0, target_chunks=chunks_per_stream,
                   chunk_seconds=chunk_seconds, home=0,
                   ttfc_slack=2.0 * chunk_seconds,
                   next_deadline=2.0 * chunk_seconds)
        s.t_next = top_lat
        streams[i] = s
        worker.queue.append(i)

    t_start = time.perf_counter()
    clock = lambda: time.perf_counter() - t_start     # noqa: E731
    while any(not s.finished for s in streams.values()):
        now = clock()
        for s in streams.values():
            if not s.finished:
                s.remaining = ex.remaining_estimate(s.sid)
                s.running_on = (0,) if s.sid in ex.inflight else None
                slack.update_stream_credit(s, now)
        queues.order_queue(worker, streams)
        sids = queues.next_dispatch_set(worker, streams, now,
                                        max_batch=max_batch)
        if not sids:
            break
        for sid in sids:
            if sid not in ex.inflight:
                s = streams[sid]
                budget = max(s.playout_slack(now), 0.0)
                dec = policy.select(
                    budget / max(chunk_seconds, 1e-9) * 0.72)
                ex.begin_chunk(sid, dec.fidelity, now)
                s.t_next = ex.latency_ema.get(dec.fidelity.key,
                                              dec.latency)
        groups = compose_batch(
            sids, lambda sid: ex.inflight[sid].fidelity, max_batch)
        for grp in groups:
            flight_started = {sid: ex.inflight[sid].started for sid in grp}
            fid_key = ex.inflight[grp[0]].fidelity.key
            completed, _ = ex.run_step(grp)     # updates the latency EMAs
            now = clock()
            for sid in completed:
                s = streams[sid]
                lat = now - flight_started[sid]
                ddl = s.next_deadline
                s.ready_times.append(now)
                s.deadlines.append(ddl)
                if s.first_chunk_time is None:
                    s.first_chunk_time = now
                if now > ddl:
                    s.stall_time += now - ddl
                s.next_deadline = max(ddl, now) + s.chunk_seconds
                s.chunks_done += 1
                s.fidelity_log.append(fid_key)
                if verbose:
                    print(f"t={now:6.2f}s stream {sid} chunk "
                          f"{s.chunks_done}/{s.target_chunks} "
                          f"fid={fid_key:22s} lat={lat:.2f}s "
                          f"{'LATE' if now > ddl else 'on-time'}")

    out: List[ServedStream] = []
    for i in range(n_streams):
        st = ServedStream(sid=i, cond=None, cache=None,
                          target_chunks=chunks_per_stream,
                          chunks=ex.chunks[i],
                          fidelity_log=ex.fidelity_log[i],
                          next_deadline=streams[i].next_deadline,
                          chunk_seconds=chunk_seconds)
        out.append(st)
        ex.retire(i)
    return out
