"""Model plane: registry-backed bundles for heterogeneous co-serving.

A ``ModelBundle`` is everything the serving stack needs to run streams of
ONE model on a lane pool: the registry config, initialized params, the
uniform :mod:`repro.models.registry` API, the paged-pool geometry derived
from the config, the offline latency/quality profile, and the relative
placement costs (per-chunk step cost, per-page KV footprint) that let the
control plane weigh a cheap stream against a heavy one when choosing a
home (GENSERVE-style co-serving; see serve/README.md).

The serving stack is a *map over bundles*: ``LanePool`` commits one paged
``KVPool`` + params per bundle per lane, ``compose_batch`` keys sub-batches
by ``(model, kv_dtype)``, and re-homing / elastic SP stay same-model-only
because every source/target executor is resolved through the stream's
bundle.  A single-bundle session degenerates to exactly the pre-refactor
objects in the same construction order, so single-model runs are
bit-identical to the old path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig, get_config
from repro.models import ardit as A
from repro.models import kvcache
from repro.models.registry import ModelAPI, get_api
from repro.profiler.profiles import MODEL_COST, ModelProfile, get_profile

# Registry arch id -> profile surface name.  The analytic profile is keyed
# by the paper's model columns; registry ids not listed here use their own
# name (falling through to the default quality ceiling in ``Q_MAX`` and
# the per-model cost prior in ``MODEL_COST``).
PROFILE_NAME: Dict[str, str] = {
    "ardit-self-forcing": "self-forcing",
    "ardit-causal-forcing": "causal-forcing",
}


def profile_name_of(arch: str) -> str:
    return PROFILE_NAME.get(arch, arch)


@dataclasses.dataclass
class ModelBundle:
    """Config + params + profile + pool geometry for one served model."""
    name: str                 # registry arch id (e.g. "ardit-self-forcing")
    cfg: ModelConfig
    api: ModelAPI
    params: Any
    profile: ModelProfile
    # paged-pool geometry (mirrors KVPool's derivation; bundles own it so
    # placement can weigh footprints without instantiating a pool)
    page_tokens: int
    pages_per_stream: int
    kv_dtype: str
    # placement weights, relative to the session's primary bundle
    step_cost: float = 1.0    # per-chunk compute multiplier
    page_cost: float = 1.0    # per-page KV bytes multiplier
    # per-model warm-up calibration, filled in by StreamingSession
    top_latency: float = 0.0
    time_scale: float = 1.0

    @property
    def placement_weight(self) -> float:
        """Scalar load weight of one stream of this model.

        Service time dominates worker occupancy, residency pressure is
        secondary: ``step_cost * sqrt(page_cost)``.  The primary bundle
        weighs 1.0, so single-model placement reduces to the old
        integer queue-depth argmin."""
        return self.step_cost * float(np.sqrt(self.page_cost))

    @property
    def page_bytes(self) -> int:
        """KV bytes of one page of this bundle's pool."""
        itemsize = np.dtype(self.kv_dtype).itemsize
        return (self.cfg.n_layers * self.page_tokens
                * self.cfg.n_kv_heads * self.cfg.head_dim * itemsize)

    @property
    def stream_bytes(self) -> int:
        """KV bytes of one fully-resident stream (sink + ring pages)."""
        return self.pages_per_stream * self.page_bytes


def _pool_geometry(cfg: ModelConfig):
    page_tokens = max(A.COND_TOKENS, A.chunk_tokens(cfg))
    pps = kvcache.pages_per_stream(cfg.ardit_window_chunks)
    return page_tokens, pps


def resolve_bundle(model: Union[str, ModelConfig], *, seed: int = 0,
                   reduced: bool = True, step_cache: bool = False,
                   params: Any = None) -> ModelBundle:
    """Resolve one registry arch (or explicit config) into a bundle.

    Live serving drives the AR-DiT denoise path, so the config must be
    ``family == "ardit"``; other registry families are co-served
    analytically in the simulator (per-model cost priors) only."""
    if isinstance(model, str):
        cfg = get_config(model)
        if reduced:
            cfg = cfg.reduced()
        arch = model
    else:
        cfg = model
        arch = cfg.name[:-len("-reduced")] \
            if cfg.name.endswith("-reduced") else cfg.name
    if cfg.family != "ardit":
        raise ValueError(
            f"live co-serving requires an ardit-family config, got "
            f"{arch!r} (family {cfg.family!r}); non-ardit models are "
            f"simulated via per-model cost priors instead")
    api = get_api(cfg)
    if params is None:
        import jax
        params = api.init(cfg, jax.random.PRNGKey(seed))
    page_tokens, pps = _pool_geometry(cfg)
    pname = profile_name_of(arch)
    return ModelBundle(
        name=arch, cfg=cfg, api=api, params=params,
        profile=get_profile(pname, step_cache=step_cache),
        page_tokens=page_tokens, pages_per_stream=pps,
        kv_dtype=cfg.kv_dtype,
        step_cost=MODEL_COST.get(pname, 1.0))


def resolve_bundles(models: Sequence[Union[str, ModelConfig]], *,
                    seed: int = 0, reduced: bool = True,
                    step_cache: bool = False) -> List[ModelBundle]:
    """Resolve a co-served model set; weights are normalized so the FIRST
    bundle (the session primary) has step_cost == page_cost == 1.0."""
    if not models:
        raise ValueError("need at least one model")
    bundles = [resolve_bundle(m, seed=seed, reduced=reduced,
                              step_cache=step_cache) for m in models]
    names = [b.name for b in bundles]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate models in co-serve set: {names}")
    ref = bundles[0]
    ref_step = ref.step_cost or 1.0
    ref_page = float(ref.page_bytes) or 1.0
    for b in bundles:
        b.step_cost = b.step_cost / ref_step
        b.page_cost = float(b.page_bytes) / ref_page
    return bundles
