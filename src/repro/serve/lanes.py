"""Multi-lane serving: N DEVICE-BACKED lanes under one control plane.

A *lane* is one execution slot over a device — one
``BatchedChunkExecutor`` with its own paged ``KVPool`` — standing in
for one Worker of the paper's cluster (SS3.1).  When the runtime
exposes more than one device (real accelerators, or forced host
devices in CI via ``XLA_FLAGS=--xla_force_host_platform_device_
count=N``), each lane COMMITS its pool, params view, and per-stream
buffers to its own ``jax.devices()`` entry; cross-lane KV movement is
then a real ``jax.device_put`` between device buffers, timed on the
spot (``MeasuredTransfer``) next to the engine's modeled timeline, and
the measurements EMA-calibrate the model's ``bw_intra``.  A
single-device runtime keeps the legacy placement (uncommitted buffers)
bit-for-bit.

``LanePool`` is the **apply layer** for the cross-worker decisions
``core.control_plane.ControlPlane.tick`` already emits (and which the
discrete-event simulator already applies on its virtual clock):

* ``rehoming.Migration`` -> :meth:`migrate`: a real cross-lane KV move.
  Same-device lanes detach the pages host-side (``KVPool.export_spill``,
  bit-exact) and land through the normal restore path; device-backed
  lanes ship the page block device-to-device (measured) and land it
  immediately resident (``KVPool.import_pages``).  Either way ONE
  src->dst transfer is charged on the shared
  ``state_plane.AsyncTransferEngine`` (cross-node bandwidth when the
  lanes' nodes differ) and the bytes are attributed directionally:
  source ``transfer_bytes_out``, destination ``transfer_bytes_in``.
* ``elastic_sp.SPDecision`` -> :meth:`sp_expand` / :meth:`sp_release`,
  in one of two modes (``SPLink.mode``):

  - **solo** (same-device lanes): expand copies the stream's UPPER
    half KV heads into a page set of the donor lane's pool (the
    App. C.4 head-partition transfer: half the stream's bytes) and the
    executor serves it with the Ulysses head-split
    ``ardit.denoise_step_paged_sp`` — home computes heads [0, H/2),
    donor heads [H/2, H) — dispatched solo, so the donor's step slot
    is genuinely occupied.  The home pool stays the full-head system
    of record; release just frees the donor pages.
  - **batch** (cross-device lanes, where one jit cannot read two
    devices' pools): expand mirrors FULL-head pages into the donor
    pool and the borrowed stream joins the *batch axis* of the donor's
    own sub-batch — co-served with the donor's streams in the donor's
    standard fused ``denoise_step_paged`` call, consuming no solo
    dispatch slot.  Each completed chunk's KV is shipped back
    (appended) to the home pool, which therefore stays the system of
    record: release frees the donor pages and moves nothing back.

  Both modes are bit-identical to the SP1 step.

All lanes of one model share ONE replica (per-device views of the same
params), one transfer engine (one metrics surface), and — because the
jitted step functions are module-level — one compile cache per device.
:meth:`prejit_sp` warms the solo-SP executables up front so triggering
elastic SP never compiles on the critical path (batch-axis SP reuses
the donor's ordinary step shapes, which warm naturally).

**Heterogeneous co-serving** (``bundles=``): the pool holds one
executor + paged ``KVPool`` per *(bundle, lane)* — a lane's device
hosts one pool per co-served model, each with that model's params,
geometry, and compile cache.  Every stream is pinned to its bundle
(``model_of``) and all routing (``executor_of``/``serving_ex``,
migrate, SP expand/release) resolves through ``ex_for(lane, model)``,
so re-homing and elastic SP are *same-model-only by construction*: a
move or mirror always lands in the target lane's pool of the SAME
bundle.  ``bundles=None`` (or a single bundle) builds exactly the
legacy objects in the legacy order — single-model sessions are
bit-identical to the pre-refactor path.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state_plane import AsyncTransferEngine
from repro.core.types import Stream
from repro.models import ardit as A
from repro.models import kvcache
from repro.serve.batcher import (BatchedChunkExecutor, KVPool, SPGuest,
                                 SPLink)


class LanePool:
    """One ``BatchedChunkExecutor`` per lane + the decision apply layer.

    ``lane_of`` maps every admitted stream to its current home lane;
    migrations move it.  Counters (``n_migrations``, ``n_sp_expands``,
    ``n_sp_releases``) record decisions actually *applied* — the
    control plane separately counts decisions *planned*.
    """

    def __init__(self, n_lanes: int, cfg: Any = None, params: Any = None,
                 seed: int = 0, max_streams: int = 16,
                 context_backend: str = "paged",
                 engine: Optional[AsyncTransferEngine] = None,
                 sp_mode: str = "auto", page_evict: bool = False,
                 bundles: Optional[Sequence[Any]] = None):
        assert n_lanes >= 1
        assert sp_mode in ("auto", "solo", "batch"), sp_mode
        # lanes round-robin over the runtime's real devices (forced host
        # devices in CI via XLA_FLAGS=--xla_force_host_platform_device_
        # count=N); a single-device runtime keeps the legacy placement
        # (device=None, uncommitted buffers) bit-for-bit
        devs = jax.devices()
        self.lane_devices: List[Optional[Any]] = (
            [devs[i % len(devs)] for i in range(n_lanes)]
            if len(devs) > 1 else [None] * n_lanes)
        self.sp_mode = sp_mode
        # heterogeneous co-serving: the PRIMARY bundle's executors are
        # ``self.executors`` (constructed exactly like the legacy
        # single-model path, in the same order), extra bundles add one
        # executor + pool per lane on the same devices/engine
        self.bundles = list(bundles) if bundles else None
        if self.bundles:
            cfg, params = self.bundles[0].cfg, self.bundles[0].params
        first = BatchedChunkExecutor(cfg=cfg, params=params, seed=seed,
                                     max_streams=max_streams,
                                     context_backend=context_backend,
                                     engine=engine,
                                     device=self.lane_devices[0],
                                     page_evict=page_evict)
        self.engine = first.pool.engine
        self.executors: List[Any] = [first]
        for lane in range(1, n_lanes):
            self.executors.append(BatchedChunkExecutor(
                cfg=first.cfg, params=first.params,
                max_streams=max_streams, context_backend=context_backend,
                engine=self.engine, device=self.lane_devices[lane],
                page_evict=page_evict))
        self.bundle_executors: Dict[str, List[Any]] = {}
        self.model_of: Dict[int, str] = {}
        if self.bundles:
            self.bundle_executors[self.bundles[0].name] = self.executors
            for b in self.bundles[1:]:
                self.bundle_executors[b.name] = [
                    BatchedChunkExecutor(
                        cfg=b.cfg, params=b.params,
                        max_streams=max_streams,
                        context_backend=context_backend,
                        engine=self.engine,
                        device=self.lane_devices[lane],
                        page_evict=page_evict)
                    for lane in range(n_lanes)]
        self.lane_of: Dict[int, int] = {}
        self.n_migrations = 0
        self.n_sp_expands = 0
        self.n_sp_releases = 0

    @classmethod
    def wrap(cls, executor: Any) -> "LanePool":
        """Single-lane pool around an existing executor (the session's
        back-compat ``executor=`` injection; also adapts the sequential
        whole-chunk executor, which has no page pool)."""
        self = cls.__new__(cls)
        self.executors = [executor]
        self.lane_devices = [getattr(executor, "device", None)]
        self.sp_mode = "auto"
        pool = getattr(executor, "pool", None)
        self.engine = (pool.engine if pool is not None
                       else getattr(executor, "engine",
                                    AsyncTransferEngine()))
        self.bundles = None
        self.bundle_executors = {}
        self.model_of = {}
        self.lane_of = {}
        self.n_migrations = 0
        self.n_sp_expands = 0
        self.n_sp_releases = 0
        return self

    # ---- views -------------------------------------------------------------
    @property
    def n_lanes(self) -> int:
        return len(self.executors)

    def ex(self, lane: int) -> Any:
        return self.executors[lane]

    def ex_for(self, lane: int, model: Optional[str] = None) -> Any:
        """The executor of ``lane`` serving ``model``'s bundle — the
        primary list when ``model`` is None or unknown (single-model
        paths resolve here to exactly the legacy object)."""
        if model is not None and model in self.bundle_executors:
            return self.bundle_executors[model][lane]
        return self.executors[lane]

    @property
    def all_executors(self) -> List[Any]:
        """Every executor across bundles, primary bundle's lanes first."""
        if not self.bundle_executors:
            return self.executors
        out = list(self.executors)
        for name, exs in self.bundle_executors.items():
            if exs is not self.executors:
                out.extend(exs)
        return out

    def executor_of(self, sid: int) -> Any:
        return self.ex_for(self.lane_of.get(sid, 0), self.model_of.get(sid))

    def chunks_of(self, sid: int) -> List[Any]:
        return self.executor_of(sid).chunks.get(sid, [])

    def serving_ex(self, sid: int) -> Any:
        """The executor currently SERVING ``sid``: its donor lane during
        a batch-axis SP borrow (the stream runs there as a guest batch
        row), its home lane otherwise."""
        link = self.sp_link(sid)
        if link is not None and getattr(link, "mode", "solo") == "batch":
            return self.ex_for(link.donor, self.model_of.get(sid))
        return self.executor_of(sid)

    def is_inflight(self, sid: int) -> bool:
        return sid in self.serving_ex(sid).inflight

    def any_inflight(self) -> bool:
        return any(ex.inflight for ex in self.all_executors)

    def sp_link(self, sid: int) -> Optional[SPLink]:
        return getattr(self.executor_of(sid), "sp_links", {}).get(sid)

    def remaining_estimate(self, sid: int) -> float:
        return self.serving_ex(sid).remaining_estimate(sid)

    def latency_ema_get(self, key: str, default: float,
                        model: Optional[str] = None) -> float:
        """Measured chunk-latency EMA for a fidelity, averaged over the
        lanes that have observed it (all lanes share one host/device
        class, so their EMAs estimate the same quantity).  ``model``
        scopes the read to that bundle's executors — fidelity keys
        collide across co-served models, so a cross-bundle average
        would mix surfaces."""
        exs = (self.bundle_executors.get(model, self.executors)
               if model is not None else self.executors)
        vals = [ex.latency_ema[key] for ex in exs
                if key in ex.latency_ema]
        return sum(vals) / len(vals) if vals else default

    # ---- stream lifecycle (routed to the home lane) ------------------------
    def admit(self, sid: int, lane: int, seed: int = 0,
              streams: Optional[Dict[int, Stream]] = None,
              protect: Sequence[int] = (),
              model: Optional[str] = None) -> bool:
        self.lane_of[sid] = lane
        if model is not None:
            self.model_of[sid] = model
        else:
            self.model_of.pop(sid, None)      # sid reuse across models
        return self.ex_for(lane, model).admit(sid, seed=seed,
                                              streams=streams,
                                              protect=protect)

    def ensure_resident(self, sid: int,
                        streams: Optional[Dict[int, Stream]] = None,
                        protect: Sequence[int] = ()) -> bool:
        return self.executor_of(sid).ensure_resident(sid, streams,
                                                     protect=protect)

    def abort_chunk(self, sid: int) -> None:
        self.serving_ex(sid).abort_chunk(sid)

    def reset_condition(self, sid: int, seed: int) -> bool:
        """Prompt switch: fresh cond encode + sink rewrite on the home
        lane.  Any live SP link must be released by the caller FIRST
        (the donor's half mirrors the old prompt's KV)."""
        ex = self.executor_of(sid)
        assert sid not in getattr(ex, "sp_links", {}), \
            f"stream {sid}: release the SP link before a prompt switch"
        return ex.reset_condition(sid, seed)

    def retire(self, sid: int) -> None:
        if self.sp_link(sid) is not None:
            self.sp_release(sid)
        self.executor_of(sid).retire(sid)
        # model_of is deliberately RETAINED: generated chunks survive
        # retire inside the bundle's executor, so chunks_of / handle
        # reads must keep routing to it (admit() clears stale entries
        # if a sid is ever reused)

    # ---- real device moves -------------------------------------------------
    def _measured_put(self, tree: Any, device: Any, *,
                      cross_node: bool = False,
                      kind: str = "move") -> Any:
        """Move a pytree of arrays onto ``device`` with
        ``jax.device_put``, timing the copy wall-to-wall (source blocked
        first so pending compute doesn't pollute the measurement) and
        recording the measured move on the shared engine — which
        calibrates its bandwidth model from the observed bytes/sec."""
        jax.block_until_ready(tree)
        n = sum(int(x.nbytes) for x in jax.tree_util.tree_leaves(tree))
        t0 = time.perf_counter()
        moved = jax.device_put(tree, device)
        jax.block_until_ready(moved)
        self.engine.record_measured(n, time.perf_counter() - t0,
                                    cross_node=cross_node, kind=kind)
        return moved

    # ---- decision apply: re-homing -----------------------------------------
    def migrate(self, sid: int, src: int, dst: int, *,
                cross_node: bool = False) -> bool:
        """Apply one ``rehoming.Migration`` as a real KV move.  Returns
        False (decision dropped) when the stream is mid-chunk or
        SP-linked — states the planner excludes, re-checked here
        because the executor, not the planner, owns ground truth.

        Device-backed lanes take the DIRECT path: the source's resident
        pages are handed over as device arrays and ``jax.device_put``
        onto the destination lane's device (measured, recorded on the
        engine), landing straight in the destination page table — no
        host round trip.  Lanes sharing one device (or a parked source
        stream) keep the host-spill path; either way the stream's KV is
        bit-identical after the move."""
        if self.lane_of.get(sid) != src or src == dst:
            return False
        # same-model-only by construction: both endpoints resolve to the
        # stream's OWN bundle's executor on each lane
        model = self.model_of.get(sid)
        src_ex, dst_ex = self.ex_for(src, model), self.ex_for(dst, model)
        if sid in src_ex.inflight or sid in src_ex.sp_links:
            return False
        dst_dev = getattr(dst_ex, "device", None)
        direct = (dst_dev is not None
                  and dst_dev != getattr(src_ex, "device", None)
                  and src_ex.pool.resident(sid)
                  and dst_ex.pool.can_admit())
        state = src_ex.export_stream(sid, to_host=not direct)
        n_bytes = int(state["pages"]["k"].nbytes
                      + state["pages"]["v"].nbytes)
        src_ex.pool.transfer_bytes_out += n_bytes
        if direct:
            state["pages"] = self._measured_put(
                state["pages"], dst_dev, cross_node=cross_node,
                kind="migration")
        dst_ex.import_stream(sid, state, cross_node=cross_node,
                             direct=direct)
        self.lane_of[sid] = dst
        # land it in the destination pool right away when there is room
        # — the import already charged the src->dst move, so this
        # restore is free; under pressure the stream stays parked and
        # rejoins via ensure_resident (a genuine second movement,
        # charged then).  The direct path is already page-resident.
        if not direct and dst_ex.pool.can_admit():
            dst_ex.pool.restore(sid, charge=False)
            dst_ex._boundary_cache.clear()
        self.n_migrations += 1
        return True

    # ---- decision apply: elastic SP ----------------------------------------
    def _sp_mode_for(self, home_ex: Any, donor_ex: Any) -> str:
        """Serving mode of a new SP link.  Lanes on DIFFERENT devices
        always use batch-axis SP: the fused head-split step reads both
        pools in ONE jitted call, which JAX rejects across committed
        devices.  Same-device lanes follow ``sp_mode`` ("auto" keeps
        the legacy solo head-split; "batch" forces the batch axis —
        how the parity tests compare the two on one device)."""
        if getattr(home_ex, "device", None) != \
                getattr(donor_ex, "device", None):
            return "batch"
        return "batch" if self.sp_mode == "batch" else "solo"

    def sp_expand(self, sid: int, donor: int,
                  streams: Optional[Dict[int, Stream]] = None) -> bool:
        """Apply one SP expand: allocate a donor-pool page set, copy the
        stream's KV into it, and link the stream.  Solo mode copies the
        UPPER half heads (App. C.4 head-partition transfer, half the
        stream's bytes) and ``run_step`` takes the head-split path;
        batch mode copies FULL heads onto the donor's device (a
        measured ``jax.device_put`` when the lanes are device-backed)
        and registers the stream as a donor-lane guest — it joins the
        donor's own micro-batches instead of consuming a solo dispatch
        slot.  False when the apply is impossible right now (non-paged
        backend, stream not resident, donor pool unevictable) — the
        decision is dropped and the planner may re-issue it next tick."""
        home = self.lane_of.get(sid)
        if home is None or donor == home:
            return False
        # same-model-only: the mirror lands in the donor LANE's pool of
        # the stream's own bundle (that model's params drive the split)
        model = self.model_of.get(sid)
        ex = self.ex_for(home, model)
        if getattr(ex, "context_backend", None) != "paged":
            return False          # head split rides the paged step only
        if sid in ex.sp_links:
            return True
        if not ex.pool.resident(sid) and \
                not ex.ensure_resident(sid, streams, protect=[sid]):
            return False
        donor_ex = self.ex_for(donor, model)
        dpool: KVPool = donor_ex.pool
        while not dpool.can_admit():
            # the executor's own credit-aware eviction (protects the
            # donor's in-flight streams AND any live SP mirrors)
            if not donor_ex._evict_one(streams, protect={sid}):
                return False
        mode = self._sp_mode_for(ex, donor_ex)
        dpool.ledger.take(sid, chunks=ex.pool.ledger.chunks[sid])
        dpool._dev_tables.pop(sid, None)
        if mode == "batch":
            n_bytes = self._copy_sp_full(ex.pool, dpool, sid)
            # the donor serves the guest with the HOME stream's noise
            # cursor and playout history: the chunk/fidelity lists are
            # SHARED objects (one system of record), the noise counter
            # is synced here and synced back on release
            donor_ex.sp_guests[sid] = SPGuest(home=home, pool=ex.pool)
            donor_ex.chunk_seq[sid] = ex.chunk_seq.get(sid, 0)
            donor_ex.chunks[sid] = ex.chunks[sid]
            donor_ex.fidelity_log[sid] = ex.fidelity_log[sid]
            # guest rows build their masks on the DONOR executor: any
            # page-evicted chunks must stay masked there too
            dropped = ex.pool.ledger.dropped.get(sid)
            if dropped:
                dpool.ledger.dropped[sid] = set(dropped)
        else:
            n_bytes = self._copy_sp_half(ex.pool, dpool, sid)
        t = self.engine.transfer(time.perf_counter(), n_bytes,
                                 cross_node=False)
        # the modeled dispatcher wait rides on the stream's next
        # completed chunk — which batch mode completes on the DONOR
        serving = donor_ex if mode == "batch" else ex
        serving._pending_wait[sid] = \
            serving._pending_wait.get(sid, 0.0) + t.residual_wait
        serving.transfer_wait_s += t.residual_wait
        # per-lane attribution: the mirror bytes LEAVE the home pool and
        # LAND in the donor pool (charging the home pool's aggregate for
        # pages the donor received made per-lane rows lie)
        ex.pool.transfer_bytes_out += n_bytes
        dpool.transfer_bytes_in += n_bytes
        ex.sp_links[sid] = SPLink(donor=donor, pool=dpool, mode=mode)
        donor_ex.sp_mirrors.add(sid)   # shield the mirror from eviction
        ex._boundary_cache.clear()
        donor_ex._boundary_cache.clear()
        self.n_sp_expands += 1
        return True

    def _copy_sp_half(self, home: KVPool, dpool: KVPool,
                      sid: int) -> int:
        """Mirror the stream's upper half KV heads (all of its pages)
        into the donor pool's page set.  Verbatim copy — the SP2 step's
        donor shard then reads bit-identical values, which is what
        makes SP2 == SP1 numerically."""
        h2 = home.cfg.n_kv_heads // 2
        # holes (page-evicted ring entries) map to the sink page: the
        # mirrored rows are garbage there, but the dropped-chunk masks
        # keep them unread on both pools
        rows = jnp.asarray(home.table_rows(sid), jnp.int32)
        drows = jnp.asarray(dpool.ledger.tables[sid], jnp.int32)
        kh = home.k[:, rows][..., h2:, :]       # [L, pps, P, H/2, Dh]
        vh = home.v[:, rows][..., h2:, :]
        dpool.k = kvcache.pool_write_pages_heads(dpool.k, kh, drows, h2)
        dpool.v = kvcache.pool_write_pages_heads(dpool.v, vh, drows, h2)
        return kh.nbytes + vh.nbytes

    def _copy_sp_full(self, home: KVPool, dpool: KVPool,
                      sid: int) -> int:
        """Copy the stream's FULL-head pages into the donor pool's page
        set (batch-axis SP): a measured ``jax.device_put`` when the
        pools live on different devices.  Verbatim copy — the donor
        then serves the stream with the ordinary SP1 step over
        bit-identical values."""
        rows = jnp.asarray(home.table_rows(sid), jnp.int32)
        pages = {"k": home.k[:, rows], "v": home.v[:, rows]}
        if dpool.device is not None and dpool.device != home.device:
            pages = self._measured_put(pages, dpool.device,
                                       kind="sp-expand")
        dpool._write(dpool.ledger.tables[sid], pages["k"], pages["v"])
        return int(pages["k"].nbytes + pages["v"].nbytes)

    def sp_release(self, sid: int) -> None:
        """Apply one SP release at a safe boundary: drop the link and
        free the donor pages.  The home pool kept full heads (batch
        mode shipped each completed chunk's KV home), so nothing moves
        back; a batch-mode release also clears the guest registration
        and carries the noise cursor home.  Idempotent."""
        ex = self.executor_of(sid)
        link = getattr(ex, "sp_links", {}).pop(sid, None)
        if link is None:
            return
        donor_ex = self.ex_for(link.donor, self.model_of.get(sid))
        if link.mode == "batch":
            assert sid not in donor_ex.inflight, \
                "batch-axis SP release only at a chunk boundary"
            donor_ex.sp_guests.pop(sid, None)
            ex.chunk_seq[sid] = donor_ex.chunk_seq.pop(
                sid, ex.chunk_seq.get(sid, 0))
            donor_ex.chunks.pop(sid, None)        # shared list: home keeps it
            donor_ex.fidelity_log.pop(sid, None)
            w = donor_ex._pending_wait.pop(sid, 0.0)
            if w:
                ex._pending_wait[sid] = ex._pending_wait.get(sid, 0.0) + w
            donor_ex._boundary_cache.clear()
        link.pool.ledger.drop(sid, spill=False)
        link.pool._dev_tables.pop(sid, None)
        donor_ex.sp_mirrors.discard(sid)
        ex._boundary_cache.clear()
        self.n_sp_releases += 1

    # ---- compile-cache warm-up ---------------------------------------------
    def prejit_sp(self, extents: Sequence[int] = (0, 1, 2)) -> None:
        """Warm the SP2 head-split executables for the given ring
        extents — unmasked, dn-masked, and dn+cl-masked variants (a
        C<0 stream is exactly the one BMPR pushes toward sparsified
        fidelities, whose clean mask differs from the denoise mask) —
        so an expansion mid-burst never compiles on the critical path.
        All SP groups share these executables — the jitted steps are
        module-level, so one warm-up covers every (home, donor) lane
        pair.  Extents beyond the list (deep rings under long streams)
        compile on first use.  With co-served bundles every bundle's
        executable set is warmed — each bundle's head-split step is
        compiled against ITS config/params/pool shapes."""
        if self.n_lanes < 2 or self.sp_mode == "batch":
            return
        lanes_by_bundle = (self.bundle_executors.values()
                           if self.bundle_executors else [self.executors])
        for exs in lanes_by_bundle:
            self._prejit_sp_bundle(exs, extents)

    def _prejit_sp_bundle(self, executors: List[Any],
                          extents: Sequence[int]) -> None:
        ex0 = executors[0]
        if getattr(ex0, "context_backend", None) != "paged":
            return
        # the fused two-pool head-split step only ever runs between
        # lanes that SHARE a device (cross-device pairs use batch-axis
        # SP, which rides the already-warm SP1 step) — warm it for the
        # first same-device pair, or skip when every pair is split
        ex1 = next((e for e in executors[1:]
                    if getattr(e, "device", None)
                    == getattr(ex0, "device", None)), None)
        if ex1 is None:
            return
        cfg = ex0.cfg
        tc = A.chunk_tokens(cfg)
        pt = ex0.pool.page_tokens
        x = jnp.zeros((1, tc, A.LATENT_CH))
        t = jnp.zeros((1,), jnp.float32)
        qo = jnp.asarray([A.COND_TOKENS], jnp.int32)
        is_dn = jnp.asarray([True])
        for n_ring in extents:
            if n_ring > cfg.ardit_window_chunks:
                continue
            tables = jnp.zeros((1, 1 + n_ring), jnp.int32)
            full = np.zeros((1, (1 + n_ring) * pt), bool)
            full[:, :A.COND_TOKENS] = True
            for r in range(n_ring):
                lo = (1 + r) * pt
                full[:, lo:lo + tc] = True
            m = jnp.asarray(full)
            for dn, cl in ((None, None), (m, None), (m, m)):
                A.denoise_step_paged_sp(
                    cfg, ex0.params, x, t, t, ex0.pool.k, ex0.pool.v,
                    ex1.pool.k, ex1.pool.v, tables, tables, dn, cl,
                    qo, is_dn)
