"""Multi-lane serving: N device lanes under one control plane.

A *lane* is one execution slot over a device — one
``BatchedChunkExecutor`` with its own paged ``KVPool`` — standing in
for one Worker of the paper's cluster (SS3.1).  On CPU the lanes are
distinct executor instances over the host device (``jax.device_put``
sharding applies when real devices exist), which makes the whole
decision -> apply -> metrics loop testable in CI.

``LanePool`` is the **apply layer** for the cross-worker decisions
``core.control_plane.ControlPlane.tick`` already emits (and which the
discrete-event simulator already applies on its virtual clock):

* ``rehoming.Migration`` -> :meth:`migrate`: a real cross-lane KV move.
  The source lane's pages are detached host-side
  (``KVPool.export_spill``, bit-exact), ONE src->dst transfer is
  charged on the shared ``state_plane.AsyncTransferEngine``
  (cross-node bandwidth when the lanes' nodes differ), and the stream
  lands in the destination pool through the normal restore path — at a
  chunk boundary, exactly the streams ``plan_rehoming`` deems movable.
* ``elastic_sp.SPDecision`` -> :meth:`sp_expand` / :meth:`sp_release`:
  a real SP2 step.  Expand copies the stream's UPPER half KV heads
  into a page set of the donor lane's pool (the App. C.4
  head-partition transfer: half the stream's bytes through the state
  plane) and links the stream; the executor then serves it with the
  Ulysses head-split ``ardit.denoise_step_paged_sp`` — home lane
  computes heads [0, H/2) from its pool, donor lane heads [H/2, H)
  from its copy — dispatched solo so the donor's step slot is
  genuinely occupied.  The home pool stays the full-head system of
  record, so release just frees the donor pages at the next safe
  boundary.

All lanes share ONE model replica (same params), one transfer engine
(one metrics surface), and — because the jitted step functions are
module-level — one compile cache: warming a shape on any lane warms it
for every lane.  :meth:`prejit_sp` warms the SP2 executables up front
so triggering elastic SP never compiles on the critical path.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state_plane import AsyncTransferEngine
from repro.core.types import Stream
from repro.models import ardit as A
from repro.models import kvcache
from repro.serve.batcher import BatchedChunkExecutor, KVPool, SPLink


class LanePool:
    """One ``BatchedChunkExecutor`` per lane + the decision apply layer.

    ``lane_of`` maps every admitted stream to its current home lane;
    migrations move it.  Counters (``n_migrations``, ``n_sp_expands``,
    ``n_sp_releases``) record decisions actually *applied* — the
    control plane separately counts decisions *planned*.
    """

    def __init__(self, n_lanes: int, cfg: Any = None, params: Any = None,
                 seed: int = 0, max_streams: int = 16,
                 context_backend: str = "paged",
                 engine: Optional[AsyncTransferEngine] = None):
        assert n_lanes >= 1
        first = BatchedChunkExecutor(cfg=cfg, params=params, seed=seed,
                                     max_streams=max_streams,
                                     context_backend=context_backend,
                                     engine=engine)
        self.engine = first.pool.engine
        self.executors: List[Any] = [first]
        for _ in range(n_lanes - 1):
            self.executors.append(BatchedChunkExecutor(
                cfg=first.cfg, params=first.params,
                max_streams=max_streams, context_backend=context_backend,
                engine=self.engine))
        self.lane_of: Dict[int, int] = {}
        self.n_migrations = 0
        self.n_sp_expands = 0
        self.n_sp_releases = 0

    @classmethod
    def wrap(cls, executor: Any) -> "LanePool":
        """Single-lane pool around an existing executor (the session's
        back-compat ``executor=`` injection; also adapts the sequential
        whole-chunk executor, which has no page pool)."""
        self = cls.__new__(cls)
        self.executors = [executor]
        pool = getattr(executor, "pool", None)
        self.engine = (pool.engine if pool is not None
                       else getattr(executor, "engine",
                                    AsyncTransferEngine()))
        self.lane_of = {}
        self.n_migrations = 0
        self.n_sp_expands = 0
        self.n_sp_releases = 0
        return self

    # ---- views -------------------------------------------------------------
    @property
    def n_lanes(self) -> int:
        return len(self.executors)

    def ex(self, lane: int) -> Any:
        return self.executors[lane]

    def executor_of(self, sid: int) -> Any:
        return self.executors[self.lane_of.get(sid, 0)]

    def chunks_of(self, sid: int) -> List[Any]:
        return self.executor_of(sid).chunks.get(sid, [])

    def is_inflight(self, sid: int) -> bool:
        return sid in self.executor_of(sid).inflight

    def any_inflight(self) -> bool:
        return any(ex.inflight for ex in self.executors)

    def sp_link(self, sid: int) -> Optional[SPLink]:
        return getattr(self.executor_of(sid), "sp_links", {}).get(sid)

    def remaining_estimate(self, sid: int) -> float:
        return self.executor_of(sid).remaining_estimate(sid)

    def latency_ema_get(self, key: str, default: float) -> float:
        """Measured chunk-latency EMA for a fidelity, averaged over the
        lanes that have observed it (all lanes share one host/device
        class, so their EMAs estimate the same quantity)."""
        vals = [ex.latency_ema[key] for ex in self.executors
                if key in ex.latency_ema]
        return sum(vals) / len(vals) if vals else default

    # ---- stream lifecycle (routed to the home lane) ------------------------
    def admit(self, sid: int, lane: int, seed: int = 0,
              streams: Optional[Dict[int, Stream]] = None,
              protect: Sequence[int] = ()) -> bool:
        self.lane_of[sid] = lane
        return self.executors[lane].admit(sid, seed=seed, streams=streams,
                                          protect=protect)

    def ensure_resident(self, sid: int,
                        streams: Optional[Dict[int, Stream]] = None,
                        protect: Sequence[int] = ()) -> bool:
        return self.executor_of(sid).ensure_resident(sid, streams,
                                                     protect=protect)

    def abort_chunk(self, sid: int) -> None:
        self.executor_of(sid).abort_chunk(sid)

    def reset_condition(self, sid: int, seed: int) -> bool:
        """Prompt switch: fresh cond encode + sink rewrite on the home
        lane.  Any live SP link must be released by the caller FIRST
        (the donor's half mirrors the old prompt's KV)."""
        ex = self.executor_of(sid)
        assert sid not in getattr(ex, "sp_links", {}), \
            f"stream {sid}: release the SP link before a prompt switch"
        return ex.reset_condition(sid, seed)

    def retire(self, sid: int) -> None:
        if self.sp_link(sid) is not None:
            self.sp_release(sid)
        self.executor_of(sid).retire(sid)

    # ---- decision apply: re-homing -----------------------------------------
    def migrate(self, sid: int, src: int, dst: int, *,
                cross_node: bool = False) -> bool:
        """Apply one ``rehoming.Migration`` as a real KV move.  Returns
        False (decision dropped) when the stream is mid-chunk or
        SP-linked — states the planner excludes, re-checked here
        because the executor, not the planner, owns ground truth."""
        if self.lane_of.get(sid) != src or src == dst:
            return False
        src_ex, dst_ex = self.executors[src], self.executors[dst]
        if sid in src_ex.inflight or sid in src_ex.sp_links:
            return False
        state = src_ex.export_stream(sid)
        dst_ex.import_stream(sid, state, cross_node=cross_node)
        self.lane_of[sid] = dst
        # land it in the destination pool right away when there is room
        # — the import already charged the src->dst move, so this
        # restore is free; under pressure the stream stays parked and
        # rejoins via ensure_resident (a genuine second movement,
        # charged then)
        if dst_ex.pool.can_admit():
            dst_ex.pool.restore(sid, charge=False)
            dst_ex._boundary_cache.clear()
        self.n_migrations += 1
        return True

    # ---- decision apply: elastic SP ----------------------------------------
    def sp_expand(self, sid: int, donor: int,
                  streams: Optional[Dict[int, Stream]] = None) -> bool:
        """Apply one SP expand: allocate a donor-pool page set, copy the
        stream's upper half KV heads into it (App. C.4 head-partition
        transfer, half the stream's bytes), and link the stream so
        ``run_step`` takes the head-split path.  False when the apply
        is impossible right now (non-paged backend, stream not
        resident, donor pool unevictable) — the decision is dropped
        and the planner may re-issue it next tick."""
        home = self.lane_of.get(sid)
        if home is None or donor == home:
            return False
        ex = self.executors[home]
        if getattr(ex, "context_backend", None) != "paged":
            return False          # head split rides the paged step only
        if sid in ex.sp_links:
            return True
        if not ex.pool.resident(sid) and \
                not ex.ensure_resident(sid, streams, protect=[sid]):
            return False
        donor_ex = self.executors[donor]
        dpool: KVPool = donor_ex.pool
        while not dpool.can_admit():
            # the executor's own credit-aware eviction (protects the
            # donor's in-flight streams AND any live SP mirrors)
            if not donor_ex._evict_one(streams, protect={sid}):
                return False
        dpool.ledger.take(sid, chunks=ex.pool.ledger.chunks[sid])
        dpool._dev_tables.pop(sid, None)
        n_bytes = self._copy_sp_half(ex.pool, dpool, sid)
        t = self.engine.transfer(time.perf_counter(), n_bytes,
                                 cross_node=False)
        ex._pending_wait[sid] = ex._pending_wait.get(sid, 0.0) \
            + t.residual_wait
        ex.transfer_wait_s += t.residual_wait
        ex.pool.transfer_bytes += n_bytes
        ex.sp_links[sid] = SPLink(donor=donor, pool=dpool)
        donor_ex.sp_mirrors.add(sid)   # shield the mirror from eviction
        ex._boundary_cache.clear()
        self.n_sp_expands += 1
        return True

    def _copy_sp_half(self, home: KVPool, dpool: KVPool,
                      sid: int) -> int:
        """Mirror the stream's upper half KV heads (all of its pages)
        into the donor pool's page set.  Verbatim copy — the SP2 step's
        donor shard then reads bit-identical values, which is what
        makes SP2 == SP1 numerically."""
        h2 = home.cfg.n_kv_heads // 2
        rows = jnp.asarray(home.ledger.tables[sid], jnp.int32)
        drows = jnp.asarray(dpool.ledger.tables[sid], jnp.int32)
        kh = home.k[:, rows][..., h2:, :]       # [L, pps, P, H/2, Dh]
        vh = home.v[:, rows][..., h2:, :]
        dpool.k = kvcache.pool_write_pages_heads(dpool.k, kh, drows, h2)
        dpool.v = kvcache.pool_write_pages_heads(dpool.v, vh, drows, h2)
        return kh.nbytes + vh.nbytes

    def sp_release(self, sid: int) -> None:
        """Apply one SP release at a safe boundary: drop the link and
        free the donor pages.  The home pool kept full heads, so
        nothing moves back.  Idempotent."""
        ex = self.executor_of(sid)
        link = getattr(ex, "sp_links", {}).pop(sid, None)
        if link is None:
            return
        link.pool.ledger.drop(sid, spill=False)
        link.pool._dev_tables.pop(sid, None)
        self.executors[link.donor].sp_mirrors.discard(sid)
        ex._boundary_cache.clear()
        self.n_sp_releases += 1

    # ---- compile-cache warm-up ---------------------------------------------
    def prejit_sp(self, extents: Sequence[int] = (0, 1, 2)) -> None:
        """Warm the SP2 head-split executables for the given ring
        extents — unmasked, dn-masked, and dn+cl-masked variants (a
        C<0 stream is exactly the one BMPR pushes toward sparsified
        fidelities, whose clean mask differs from the denoise mask) —
        so an expansion mid-burst never compiles on the critical path.
        All SP groups share these executables — the jitted steps are
        module-level, so one warm-up covers every (home, donor) lane
        pair.  Extents beyond the list (deep rings under long streams)
        compile on first use."""
        if self.n_lanes < 2:
            return
        ex0, ex1 = self.executors[0], self.executors[1]
        if getattr(ex0, "context_backend", None) != "paged":
            return
        cfg = ex0.cfg
        tc = A.chunk_tokens(cfg)
        pt = ex0.pool.page_tokens
        x = jnp.zeros((1, tc, A.LATENT_CH))
        t = jnp.zeros((1,), jnp.float32)
        qo = jnp.asarray([A.COND_TOKENS], jnp.int32)
        is_dn = jnp.asarray([True])
        for n_ring in extents:
            if n_ring > cfg.ardit_window_chunks:
                continue
            tables = jnp.zeros((1, 1 + n_ring), jnp.int32)
            full = np.zeros((1, (1 + n_ring) * pt), bool)
            full[:, :A.COND_TOKENS] = True
            for r in range(n_ring):
                lo = (1 + r) * pt
                full[:, lo:lo + tc] = True
            m = jnp.asarray(full)
            for dn, cl in ((None, None), (m, None), (m, m)):
                A.denoise_step_paged_sp(
                    cfg, ex0.params, x, t, t, ex0.pool.k, ex0.pool.v,
                    ex1.pool.k, ex1.pool.v, tables, tables, dn, cl,
                    qo, is_dn)
