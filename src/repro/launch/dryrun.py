import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh) cell: build the production
mesh, ``jax.jit(step, in_shardings=...).lower(**ShapeDtypeStructs)``,
``.compile()``, and record memory_analysis / cost_analysis / collective
bytes.  Success proves the distribution config is coherent; results feed
EXPERIMENTS.md SSDry-run and SSRoofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b \
        --shape train_4k [--multi-pod] [--windowed-adaptation]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts: benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch import analysis
from repro.launch.lowering import lower_cell, cell_config
from repro.launch.mesh import make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             windowed_adaptation: bool = False, verbose: bool = True,
             save: bool = True, analyze: bool = True,
             save_hlo: bool = False) -> dict:
    """One dry-run cell.  ``analyze=False`` skips the (expensive) HLO
    roofline pass — compile success + memory_analysis only, used for the
    multi-pod coherence check (the roofline table is single-pod).
    ``save_hlo`` gzips the optimized HLO next to the artifact so the
    analyzer can be re-run without recompiling."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + \
        ("__winadapt" if windowed_adaptation else "")
    if not windowed_adaptation and not cfg.supports_shape(shape):
        rec = {"cell": tag, "status": "skipped",
               "reason": "long_500k needs sub-quadratic attention "
                         "(DESIGN.md SS4); windowed adaptation lowered "
                         "separately"}
        if save:
            os.makedirs(ART_DIR, exist_ok=True)
            with open(os.path.join(ART_DIR, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
        if verbose:
            print(json.dumps(rec))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, mesh, shape,
                             windowed_adaptation=windowed_adaptation)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        rec = {
            "cell": tag, "status": "ok", "n_chips": n_chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        }
        mf = analysis.model_flops(cell_config(
            cfg, shape, windowed_adaptation=windowed_adaptation), shape)
        rec["model_flops"] = mf
        if analyze:
            roof = analysis.analyze(lowered, compiled, n_chips)
            rec.update(roof.row())
            rec["useful_ratio"] = (mf / roof.flops) if roof.flops else None
            if save_hlo:
                import gzip
                os.makedirs(ART_DIR, exist_ok=True)
                with gzip.open(os.path.join(ART_DIR, tag + ".hlo.gz"),
                               "wt") as f:
                    f.write(compiled.as_text())
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "peak_bytes": getattr(ma, "peak_memory_in_bytes", None),
            }
        except Exception:
            pass
    except Exception as e:       # a failure here is a bug in the system
        rec = {"cell": tag, "status": "FAILED",
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        with open(os.path.join(ART_DIR, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        show = {k: v for k, v in rec.items() if k != "traceback"}
        print(json.dumps(show, default=str))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--windowed-adaptation", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    lm_archs = [a for a in list_archs() if not a.startswith("ardit")]
    cells = []
    if args.all:
        for a in lm_archs:
            for s in SHAPES:
                cells.append((a, s, args.multi_pod, False))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, args.multi_pod,
                      args.windowed_adaptation))

    failures = 0
    for (a, s, mp, wa) in cells:
        rec = run_cell(a, s, multi_pod=mp, windowed_adaptation=wa)
        if rec["status"] == "FAILED":
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
