"""Shared lowering helpers: build the sharded train / prefill / decode
step for any (arch x shape x mesh) cell and ``.lower()`` it with
ShapeDtypeStruct stand-ins (no allocation) — the substrate of the
multi-pod dry-run and the roofline analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.distributed import sharding as shd
from repro.distributed.logical import logical_axis_rules
from repro.models import registry

# the paper's knob Q applied at the serving layer: MHA-width KV caches
# (kv_heads == n_heads) at decode_32k x batch 128 exceed HBM in bf16;
# fp8 KV (SS2.1, SageAttention2-style online quant) halves them.
FP8_KV_ARCHS = {"qwen1.5-32b"}

# sink+local windowed-KV adaptation (SS2.1) lowered as an EXTRA cell for
# pure full-attention archs at long_500k (the base cell stays skipped)
ADAPT_WINDOW = 61440
ADAPT_SINK = 4096


def cell_config(cfg: ModelConfig, shape: ShapeConfig, *,
                windowed_adaptation: bool = False) -> ModelConfig:
    if windowed_adaptation:
        cfg = cfg.with_window(ADAPT_WINDOW, ADAPT_SINK)
    if shape.kind == "decode" and cfg.name in FP8_KV_ARCHS:
        cfg = dataclasses.replace(cfg, kv_dtype="float8_e4m3fn")
    return cfg


def _path_names(path) -> Tuple[str, ...]:
    return tuple(str(k.key) if hasattr(k, "key") else str(k) for k in path)


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_spec: Any, *,
                    shard_seq: bool = False) -> Any:
    """Path-aware cache sharding: KV leaves [*,B,S,H,D] shard batch over
    data + heads over model (or sequence over data for long-context);
    SSM states shard heads/channels over model."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b_ax: Any = batch_axes if not shard_seq else None
    s_ax: Any = batch_axes if shard_seq else None
    tp_size = mesh.shape[shd.TP]
    # KV heads shard over "model" only when divisible (GQA kv=8/16);
    # MHA-width or tiny-kv archs (40, 36, 4 heads) shard the cache
    # SEQUENCE over "model" instead — GSPMD emits the flash-decoding
    # partial-softmax combine for the sharded softmax reduction.
    heads_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp_size == 0

    def spec(path, leaf):
        name = _path_names(path)[-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):                     # [L,B,S,H,D]
            if heads_ok:
                p = P(None, b_ax, s_ax, shd.TP, None)
            else:
                p = P(None, b_ax, shd.TP if not shard_seq else s_ax,
                      None, None)
        elif name in ("ck", "cv"):                 # [L,B,T_enc,H,D]
            p = P(None, b_ax, None, shd.TP if heads_ok else None, None)
        elif name == "ssm":                        # [L,B,H,P,N]
            p = P(None, b_ax, shd.TP, None, None)
        elif name == "conv":                       # [L,B,K-1,C]
            p = P(None, b_ax, None, shd.TP)
        else:
            p = P(*([None] * nd))
        assert len(p) <= nd, (name, nd)
        return NamedSharding(mesh, p)

    return jax.tree_util.tree_map_with_path(spec, cache_spec)


def lower_prefill(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    api = registry.get_api(cfg)
    p_specs = registry.param_specs(cfg)
    p_shard = shd.param_shardings(p_specs, mesh, serve=True, ep=cfg.moe_ep)
    batch = registry.input_specs(cfg, shape)
    rules = shd.serve_rules(mesh, ep=cfg.moe_ep)
    bp = shd.batch_pspec(mesh)

    b_shard = jax.tree_util.tree_map(
        lambda leaf: NamedSharding(
            mesh, P(*(tuple(bp) + (None,) * (len(leaf.shape) - 1)))), batch)

    extras = {k: v for k, v in batch.items() if k != "tokens"}

    def fn(params, batch_in):
        with logical_axis_rules(mesh, rules):
            kw = {k: batch_in[k] for k in extras}
            logits, cache, clen = api.prefill(cfg, params,
                                              batch_in["tokens"], **kw)
            return logits, cache, clen

    jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
    return jitted.lower(p_specs, batch)


def lower_decode(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    api = registry.get_api(cfg)
    p_specs = registry.param_specs(cfg)
    p_shard = shd.param_shardings(p_specs, mesh, serve=True, ep=cfg.moe_ep)
    cache_spec = registry.cache_specs(cfg, shape)
    shard_seq = shape.global_batch == 1
    c_shard = cache_shardings(cfg, mesh, cache_spec, shard_seq=shard_seq)
    io = registry.input_specs(cfg, shape)        # token [B,1], pos [B]
    bp = shd.batch_pspec(mesh)
    b_axis = bp[0] if len(bp) else None          # flat axis (or axis tuple)
    tok_shard = NamedSharding(mesh, P(None if shard_seq else b_axis, None))
    pos_shard = NamedSharding(mesh, P(None if shard_seq else b_axis))
    rules = shd.serve_rules(mesh, shard_seq=shard_seq, ep=cfg.moe_ep)

    def fn(params, cache, token, pos):
        with logical_axis_rules(mesh, rules):
            return api.decode_step(cfg, params, cache, token, pos)

    jitted = jax.jit(fn, in_shardings=(p_shard, c_shard, tok_shard,
                                       pos_shard))
    return jitted.lower(p_specs, cache_spec, io["token"], io["pos"])


def lower_train(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                microbatches: int = 1):
    from repro.train.loop import lower_train_step
    return lower_train_step(cfg, mesh, shape, microbatches=microbatches)


def lower_cell(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
               windowed_adaptation: bool = False, microbatches: int = 1):
    cfg = cell_config(cfg, shape, windowed_adaptation=windowed_adaptation)
    if shape.kind == "train":
        return lower_train(cfg, mesh, shape, microbatches=microbatches)
    if shape.kind == "prefill":
        return lower_prefill(cfg, mesh, shape)
    return lower_decode(cfg, mesh, shape)
