"""Serving launcher.

Two modes, ONE workload spec and ONE metrics surface:

    --sim      cluster-scale discrete-event evaluation (the paper's SS7
               experiments): real control plane, modeled 16-worker
               cluster, any workload/policy.
    --real     real JAX AR-DiT execution on this host through the
               unified ``serve.session.StreamingSession``: the SAME
               ``ControlPlane.tick()`` decisions as --sim drive actual
               chunk generation (tiny model), over the same
               --workload/--rate/--seed StreamSpec generators, and the
               run prints the same one-line ``Summary.row()`` — so a
               workload can be compared sim-vs-real apples-to-apples.

    PYTHONPATH=src python -m repro.launch.serve --sim \
        --workload steady --policy slackserve --streams 300
    PYTHONPATH=src python -m repro.launch.serve --real --streams 2
    PYTHONPATH=src python -m repro.launch.serve --real --batched \
        --workload burst --streams 6 --seed 0
    PYTHONPATH=src python -m repro.launch.serve --real --batched \
        --streams 4 --pool-streams 2        # oversubscribed page pool
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--sim", action="store_true")
    mode.add_argument("--real", action="store_true")
    ap.add_argument("--workload", default="steady")
    ap.add_argument("--policy", default="slackserve")
    ap.add_argument("--streams", type=int, default=300)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--model", default="causal-forcing")
    ap.add_argument("--chunks", type=int, default=4,
                    help="per-stream chunk cap for --real (the tiny "
                         "model; --sim uses the spec lengths as-is)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batched", action="store_true",
                    help="credit-ordered micro-batch executor (--real)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--arrival-scale", type=float, default=1.0,
                    help="multiply workload event times for --real "
                         "(< 1 compresses Poisson gaps / trace idles)")
    ap.add_argument("--pool-streams", type=int, default=0,
                    help="co-resident stream cap of the paged KV pool "
                         "(< --streams oversubscribes; 0 -> all fit)")
    ap.add_argument("--context-backend", choices=("gather", "paged"),
                    default="paged",
                    help="how sub-batches see cached KV: 'paged' serves "
                         "attention straight from the page pool through "
                         "block tables; 'gather' materializes the "
                         "contiguous context (reference path)")
    args = ap.parse_args()

    if args.pool_streams and not (args.real and args.batched):
        ap.error("--pool-streams only applies to --real --batched")
    if any(a.startswith("--context-backend") for a in sys.argv[1:]) \
            and not (args.real and args.batched):
        ap.error("--context-backend only applies to --real --batched")

    from repro.sched_sim.metrics import summarize, transfer_stats
    from repro.sched_sim.workloads import WORKLOADS

    if args.real:
        from repro.serve.session import (SessionConfig, StreamingSession,
                                         cap_specs)

        specs = cap_specs(
            WORKLOADS[args.workload](n=args.streams, rate=args.rate,
                                     seed=args.seed), args.chunks)
        session = StreamingSession(SessionConfig(
            executor="batched" if args.batched else "sequential",
            max_batch=args.max_batch,
            # 0 -> everyone fits, like the legacy wrapper default
            pool_streams=args.pool_streams or args.streams + 1,
            context_backend=args.context_backend,
            arrival_scale=args.arrival_scale,
            verbose=True))   # --seed varies the workload, not the model
        for spec in specs:
            session.submit(spec)
        res = session.run()
        s = summarize(res)
        label = "real-batched" if args.batched else "real-sequential"
        print(f"{label} on {args.workload}: {s.row()}")
        print(f"  rehomings={s.n_rehomings} elastic_sp={s.n_sp_events} "
              f"transfers={transfer_stats(res)}")
        return

    from repro.sched_sim.policies import SDV2Policy, make_policy
    from repro.sched_sim.simulator import SimConfig, Simulator

    specs = WORKLOADS[args.workload](n=args.streams, rate=args.rate,
                                     seed=args.seed)
    policy = make_policy(args.policy, model=args.model)
    sim_cfg = (SDV2Policy.sim_config() if args.policy == "sdv2"
               else SimConfig(model=args.model))
    res = Simulator(sim_cfg, specs, policy).run()
    s = summarize(res)
    print(f"{args.policy} on {args.workload}: {s.row()}")
    print(f"  rehomings={s.n_rehomings} elastic_sp={s.n_sp_events} "
          f"transfers={transfer_stats(res)}")


if __name__ == "__main__":
    main()
